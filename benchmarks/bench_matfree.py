"""Matrix-free apply/solve vs the assembled-CSR path.

The trade the subsystem sells: the matrix-free operator applies the weak
form element-locally (gather → per-element fused action → scatter-Reduce)
and stores essentially nothing beyond the plan, while the CSR path
materializes 3 nnz-sized arrays (values + column indices + row ids) before
the Krylov loop runs.  Tracked claims (perf-smoke CI gates these rows
against ``BENCH_baseline.json``):

* apply time within ~2× of the CSR matvec at small N (same asymptotic
  work: the fused diffusion action touches O(E·Q·k·d) intermediates, the
  SpMV touches O(nnz));
* operator state at the largest benched mesh: ``matfree_state_bytes`` ≪
  ``csr_bytes`` (JSON extras carry both numbers);
* a full matrix-free CG Poisson solve matching the assembled solve.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit_json, is_quick, time_fn
except ImportError:  # flat execution
    from common import emit_json, is_quick, time_fn

from repro.core import (
    FunctionSpace,
    assemble,
    build_plan,
    matfree_operator,
    unit_cube_tet,
    unit_square_tri,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh


def _csr_bytes(k) -> int:
    return int(
        k.vals.nbytes + k.indices.nbytes + k.row_of_nnz.nbytes + k.indptr.nbytes
    )


def _apply_case(mesh, tag: str):
    # tag must encode the problem size: quick and full runs emit different
    # row names, so a baseline recorded at one size never silently gates
    # the other
    space = FunctionSpace(mesh, element_for_mesh(mesh))
    plan = build_plan(space)
    rng = np.random.default_rng(0)
    rho = jnp.asarray(rng.uniform(0.5, 2.0, mesh.num_cells))
    form = wf.diffusion(rho)

    k = assemble(plan, form)
    csr_mv = jax.jit(k.matvec)
    x = jnp.asarray(rng.standard_normal(space.num_dofs))

    op_ctx = matfree_operator(plan, form, store="context")
    op_coords = matfree_operator(plan, form, store="coords")
    np.testing.assert_allclose(
        np.asarray(op_ctx.matvec(x)), np.asarray(k.matvec(x)), atol=1e-12
    )

    # sub-millisecond rows gate CI at 1.5×: medians need real sample counts
    # or scheduler noise alone trips the threshold
    t_csr = time_fn(csr_mv, x, warmup=3, iters=25)
    t_ctx = time_fn(op_ctx.matvec, x, warmup=3, iters=25)
    t_coords = time_fn(op_coords.matvec, x, warmup=3, iters=25)
    csr_b = _csr_bytes(k)
    # reference=True: compare.py normalizes the CI gate's machine scale on
    # these rows (SpMV code the matfree PRs don't touch)
    emit_json(
        f"csr_matvec_{tag}", t_csr, f"nnz={k.nnz};bytes={csr_b}",
        dofs=space.num_dofs, nnz=k.nnz, csr_bytes=csr_b, reference=True,
    )
    emit_json(
        f"matfree_apply_{tag}", t_ctx,
        f"vs_csr={t_ctx / t_csr:.2f}x;state_bytes={op_ctx.state_bytes()}",
        dofs=space.num_dofs, ratio_vs_csr=round(t_ctx / t_csr, 2),
        matfree_state_bytes=op_ctx.state_bytes(), csr_bytes=csr_b,
    )
    emit_json(
        f"matfree_apply_coords_{tag}", t_coords,
        f"vs_csr={t_coords / t_csr:.2f}x;state_bytes={op_coords.state_bytes()}",
        dofs=space.num_dofs, ratio_vs_csr=round(t_coords / t_csr, 2),
        matfree_state_bytes=op_coords.state_bytes(), csr_bytes=csr_b,
    )

    # streaming SpMV (HBM-resident x): VMEM footprint independent of N —
    # the row carries the footprint formula's value next to the CSR bytes
    from repro.core import csr_to_ell
    from repro.kernels import ell_matvec_stream
    from repro.kernels.spmv_ell import BLOCK_N, N_BUFFERS, stream_vmem_bytes

    ell = csr_to_ell(k)
    stream_mv = lambda v: ell_matvec_stream(ell, v)  # noqa: E731
    np.testing.assert_allclose(
        np.asarray(stream_mv(x)), np.asarray(k.matvec(x)), atol=1e-12
    )
    t_stream = time_fn(stream_mv, x, warmup=3, iters=25)
    vmem_b = stream_vmem_bytes(*ell.vals.shape, block_n=BLOCK_N,
                               nbuf=N_BUFFERS)
    emit_json(
        f"ell_stream_matvec_{tag}", t_stream,
        f"vs_csr={t_stream / t_csr:.2f}x;vmem_bytes={vmem_b}",
        dofs=space.num_dofs, ratio_vs_csr=round(t_stream / t_csr, 2),
        stream_vmem_bytes=vmem_b, csr_bytes=csr_b,
    )

    # sharded matrix-free apply (1 device locally; CI runs the 8-device leg)
    import jax as _jax

    sop = op_ctx.sharded()
    np.testing.assert_allclose(
        np.asarray(sop.matvec(x)), np.asarray(k.matvec(x)), atol=1e-12
    )
    t_sh = time_fn(sop.matvec, x, warmup=3, iters=25)
    emit_json(
        f"matfree_sharded_apply_{tag}", t_sh,
        f"vs_csr={t_sh / t_csr:.2f}x;devices={len(_jax.devices())}",
        dofs=space.num_dofs, ratio_vs_csr=round(t_sh / t_csr, 2),
        devices=len(_jax.devices()), csr_bytes=csr_b,
    )


def _solve_case(n: int):
    from repro.fem.tensormesh import PoissonProblem

    prob = PoissonProblem(unit_cube_tet(n))
    res_csr = prob.solve()
    res_mf, info_mf = prob.solve(backend="matfree", return_info=True)
    err = float(jnp.max(jnp.abs(res_csr.u - res_mf.u)))
    assert err < 1e-8, f"matrix-free solve deviates from assembled: {err}"
    assert res_mf.converged, "matrix-free solve did not converge"

    t_csr = time_fn(lambda: prob.solve().u)
    t_mf = time_fn(lambda: prob.solve(backend="matfree").u)
    emit_json(
        f"matfree_poisson_solve_tet{n}", t_mf,
        f"csr_us={t_csr:.1f};iters={res_mf.iters};err={err:.1e}",
        dofs=prob.space.num_dofs, csr_us=round(t_csr, 1),
        iters=res_mf.iters, iterations=int(info_mf.iters),
        final_residual=float(info_mf.residual),
        converged=bool(info_mf.converged), max_err_vs_csr=err,
    )


def main():
    quick = is_quick()
    n_tri = 12 if quick else 16
    n_tet = 6 if quick else 10
    # small N: apply overhead comparison
    _apply_case(unit_square_tri(n_tri), f"tri{n_tri}_small")
    # largest benched mesh: the memory story
    _apply_case(unit_cube_tet(n_tet), f"tet{n_tet}_large")
    _solve_case(4 if quick else 6)


if __name__ == "__main__":
    main()
