"""Paper SM B.1.4 (Fig. B.4): batched data generation — solve the same
Poisson operator for B right-hand sides; derived: per-sample time (should
flatten as batch amortizes fixed overheads, slope < 1)."""

import jax.numpy as jnp
import numpy as np

from repro.core import unit_cube_tet
from repro.fem import PoissonProblem

from .common import emit, time_fn


def main():
    prob = PoissonProblem(unit_cube_tet(8))
    rng = np.random.default_rng(0)
    for batch in (1, 4, 16, 64):
        fb = jnp.asarray(rng.normal(size=(batch, prob.space.num_dofs)))
        t = time_fn(lambda: prob.solve_batch(fb)[0], warmup=1, iters=3)
        emit(
            f"batch_generation_B{batch}", t,
            f"us_per_sample={t / batch:.1f};dofs={prob.space.num_dofs}",
        )


if __name__ == "__main__":
    main()
