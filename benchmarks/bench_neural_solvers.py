"""Paper Table 1 (reduced budget): PINN vs VPINN vs Deep Ritz vs TensorPILS
on the K=2 checkerboard Poisson problem — same SIREN backbone, same mesh,
reduced iteration counts for CPU.  Derived: relative L2 error vs the FEM
reference and it/s.  The paper's claim to validate: TensorPILS is the most
accurate AND the fastest per iteration."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    cg,
    jacobi_preconditioner,
    unit_square_tri,
)
from repro.core.mesh import element_for_mesh
from repro.pils import (
    GalerkinResidualLoss,
    deep_ritz_loss,
    pinn_poisson_loss,
    siren_apply,
    siren_init,
    train_adam,
    vpinn_loss,
)

from .common import emit

K_FREQ = 2
STEPS = 300


def main():
    m = unit_square_tri(16)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    f = lambda x: jnp.sign(
        jnp.sin(K_FREQ * np.pi * x[..., 0] + 1e-9)
        * jnp.sin(K_FREQ * np.pi * x[..., 1] + 1e-9)
    )

    gl = GalerkinResidualLoss(asm, bc, f=f)
    u_fem, _ = cg(gl.k.matvec, gl.f, m=jacobi_preconditioner(gl.k), tol=1e-12)
    u_fem = np.asarray(u_fem)
    norm = np.linalg.norm(u_fem)

    pts = jnp.asarray(space.dof_points)
    free = np.asarray(bc.free_mask, bool)
    interior, boundary = pts[free], pts[~free]
    f_int = f(interior[None])[0]
    ctx = asm.context()
    fq = f(ctx.xq)
    f_load = asm.assemble_load(f)

    def eval_err(params):
        u = np.asarray(siren_apply(params, pts)[:, 0]) * free
        return np.linalg.norm(u - u_fem) / norm

    key = jax.random.PRNGKey(0)
    init = lambda: siren_init(key, 2, 64, 1, depth=4)

    losses = {
        "tensorpils": lambda p: gl.loss_from_net(siren_apply, p),
        "pinn": lambda p: pinn_poisson_loss(siren_apply, p, interior, f_int, boundary),
        "deep_ritz": lambda p: deep_ritz_loss(siren_apply, p, ctx.xq, ctx.wdet, fq, boundary),
        "vpinn": lambda p: vpinn_loss(siren_apply, p, asm, f_load, bc.free_mask, boundary),
    }
    for name, loss in losses.items():
        params, _, its = train_adam(loss, init(), STEPS, lr=1e-3)
        err = eval_err(params)
        emit(f"neural_solver_{name}", 1e6 / its, f"rel_l2={err:.4f};it_per_s={its:.1f}")


if __name__ == "__main__":
    main()
