"""Transient rollout benchmarks (repro.transient).

Steps/sec and end-to-end wall-clock for heat (θ-method) and wave
(Newmark-β) rollouts on the assembled operators, plus the inner
residual/matvec CSR vs ELL (jnp) vs ELL (Pallas kernel, interpret on CPU)
comparison — the matrix-free fast-path trade the subsystem exposes.

Emits JSON-lines alongside the CSV rows when ``BENCH_JSON`` is set
(see :mod:`benchmarks.common`).
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    csr_to_ell,
    unit_square_tri,
)
from repro.core.mesh import element_for_mesh

try:  # package-relative when run via benchmarks.run, flat when run directly
    from .common import emit_json, time_fn
except ImportError:  # pragma: no cover
    from common import emit_json, time_fn

N_STEPS = 50


def main() -> None:
    from repro.kernels import ell_residual
    from repro.transient import (
        CRANK_NICOLSON,
        NewmarkIntegrator,
        ThetaIntegrator,
        batched_rollout,
    )

    m = unit_square_tri(24)
    sp = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(sp)
    bc = DirichletCondenser(asm, sp.boundary_dofs())
    mass, stiff = asm.assemble_mass(), asm.assemble_stiffness()
    pts = sp.dof_points
    u0 = (
        jnp.sin(np.pi * jnp.asarray(pts[:, 0]))
        * jnp.sin(np.pi * jnp.asarray(pts[:, 1]))
    ) * bc.free_mask
    n = sp.num_dofs

    # -- rollouts (end-to-end wall-clock → steps/sec) --------------------------
    configs = [
        ("transient/heat_be_csr",
         ThetaIntegrator(mass, stiff, dt=1e-3, theta=1.0, bc=bc, tol=1e-10)),
        ("transient/heat_cn_csr",
         ThetaIntegrator(mass, stiff, dt=1e-3, theta=CRANK_NICOLSON, bc=bc,
                         tol=1e-10)),
        ("transient/heat_be_ell",
         ThetaIntegrator(mass, stiff, dt=1e-3, theta=1.0, bc=bc, tol=1e-10,
                         backend="ell")),
        ("transient/wave_newmark_csr",
         NewmarkIntegrator(mass, stiff, dt=1e-3, bc=bc, tol=1e-10)),
    ]
    for name, integ in configs:
        fn = jax.jit(lambda u, _integ=integ: _integ.rollout(u, N_STEPS))
        us = time_fn(fn, u0, iters=3)
        steps_per_sec = N_STEPS / (us * 1e-6)
        emit_json(name, us, f"steps_per_sec={steps_per_sec:.0f}",
                  n_dofs=n, n_steps=N_STEPS, steps_per_sec=round(steps_per_sec))

    # -- batched rollout (the pils trajectory-generation shape) ----------------
    integ = configs[0][1]
    u0s = jnp.stack([u0 * s for s in np.linspace(0.5, 1.5, 8)])
    fn_b = jax.jit(lambda b: batched_rollout(integ, b, N_STEPS))
    us = time_fn(fn_b, u0s, iters=3)
    total = 8 * N_STEPS
    emit_json("transient/heat_be_csr_batch8", us,
              f"traj_steps_per_sec={total / (us * 1e-6):.0f}",
              n_dofs=n, n_steps=N_STEPS, batch=8)

    # -- inner residual: CSR vs ELL(jnp) vs ELL(Pallas) ------------------------
    lhs = integ.lhs
    ell = csr_to_ell(lhs)
    f = mass.matvec(u0)
    r_csr = jax.jit(lambda u: lhs.matvec(u) - f)
    r_ell = jax.jit(lambda u: ell.matvec(u) - f)
    emit_json("transient/residual_csr", time_fn(r_csr, u0), n_dofs=n)
    emit_json("transient/residual_ell_jnp", time_fn(r_ell, u0), n_dofs=n)
    emit_json("transient/residual_ell_pallas",
              time_fn(lambda u: ell_residual(ell, u, f), u0, iters=3),
              "interpret_mode" if jax.default_backend() != "tpu" else "",
              n_dofs=n)


if __name__ == "__main__":
    main()
