"""Shared benchmark utilities: timing + CSV/JSON emission.

Every row goes to stdout as ``name,us_per_call,derived`` CSV (the harness
contract).  Set ``BENCH_JSON=<path>`` to additionally append one JSON
object per row (``{"name", "us_per_call", "derived", ...extras}``) — the
machine-readable results file consumed by dashboards/CI trend jobs.
"""

from __future__ import annotations

import json
import os
import time

import jax


def is_quick() -> bool:
    """True when the harness runs in reduced-size mode (``--quick`` /
    ``BENCH_QUICK=1``) — the perf-smoke CI subset."""
    return os.environ.get("BENCH_QUICK", "") not in ("", "0")


def time_fn(fn, *args, warmup: int = 1, iters: int = 5, **kw) -> float:
    """Median wall-time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def emit_json(name: str, us_per_call: float, derived: str = "", **extra):
    """CSV row (same contract as :func:`emit`) + optional JSON-lines record.

    ``extra`` keys land only in the JSON record, which is appended to the
    file named by the ``BENCH_JSON`` environment variable when set.
    """
    emit(name, us_per_call, derived)
    path = os.environ.get("BENCH_JSON")
    if path:
        record = {"name": name, "us_per_call": round(us_per_call, 1),
                  "derived": derived, **extra}
        with open(path, "a") as f:
            f.write(json.dumps(record) + "\n")
