"""CI telemetry smoke: one instrumented end-to-end solve, checked hard.

Exercises the full observability path on a small 3D Poisson problem:

1. ``telemetry.enable(jsonl=...)`` + ``telemetry.capture(trace_dir)`` around
   an assembled-CSR solve and a matrix-free solve (named-phase annotations
   land in the profiler trace),
2. ``SolveInfo`` comes back through ``return_info=True`` with
   ``converged=True``,
3. ``export_jsonl`` flushes the metrics registry next to the streamed
   events, and the JSONL is then *parsed back* and asserted to contain
   solve rows with ``converged == true`` and assembly rows,
4. the report CLI renders the log without error,
5. an instrumented :class:`~repro.serve.SolveService` window under a
   defined SLO: every answered request must carry a span tree whose
   top-level segments cover its e2e wall, the span rows must land in the
   JSONL stream, a forced non-converged wave must auto-dump the flight
   recorder, and ``report --slo`` must render the attainment table.

Exit code 0 only if every check passes — this is the CI leg that keeps the
telemetry layer honest (a refactor that silently stops recording fails
here, not in production dashboards).

Usage::

    PYTHONPATH=src python -m benchmarks.telemetry_smoke \
        [--jsonl telemetry.jsonl] [--trace-dir telemetry_trace]
"""

import argparse
import json
import os

from repro import telemetry
from repro.core import unit_cube_tet
from repro.fem import PoissonProblem


def _load_rows(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jsonl", default="telemetry.jsonl")
    ap.add_argument("--trace-dir", default="telemetry_trace")
    args = ap.parse_args(argv)

    if os.path.exists(args.jsonl):
        os.remove(args.jsonl)

    telemetry.enable(jsonl=args.jsonl, on_nonconverged="raise")
    prob = PoissonProblem(unit_cube_tet(6))
    with telemetry.capture(args.trace_dir):
        res_csr, info_csr = prob.solve(return_info=True)
        res_mf, info_mf = prob.solve(backend="matfree", return_info=True)

    assert bool(info_csr.converged), "assembled solve did not converge"
    assert bool(info_mf.converged), "matrix-free solve did not converge"
    err = float(abs(res_csr.u - res_mf.u).max())
    assert err < 1e-8, f"matfree deviates from assembled solve: {err:.3e}"

    telemetry.export_jsonl(args.jsonl)

    rows = _load_rows(args.jsonl)
    solves = [r for r in rows if r.get("kind") == "solve"]
    assemblies = [r for r in rows if r.get("kind") == "assembly"]
    metrics = [r for r in rows if r.get("kind") == "metric"]
    assert solves, f"no solve rows in {args.jsonl}"
    assert assemblies, f"no assembly rows in {args.jsonl}"
    assert metrics, f"no metric rows in {args.jsonl}"
    bad = [r["name"] for r in solves if not r.get("converged")]
    assert not bad, f"solve rows without converged=true: {bad}"
    backends = {r.get("backend") for r in solves}
    assert "matfree" in backends, f"no matfree solve row (saw {backends})"
    traces = [r for r in metrics if "jit_traces" in r["name"]]
    assert traces, "no jit-trace counters in the metrics export"

    trace_files = [
        os.path.join(dp, fn)
        for dp, _, fns in os.walk(args.trace_dir) for fn in fns
    ]
    assert trace_files, f"profiler capture wrote nothing under {args.trace_dir}"

    # the report CLI must render the log it just produced
    from repro.telemetry import report

    rc = report.main([args.jsonl, "--snapshot"])
    assert rc == 0, f"report CLI failed with exit code {rc}"

    # --- instrumented serve window: spans + flight recorder + SLO gate ---
    import dataclasses

    from repro import serve
    from repro.serve import SolveService

    telemetry.define_slo("serve_p99", p99_us=60e6, histogram="serve_e2e_us")
    flight_path = args.jsonl + ".flight.jsonl"
    if os.path.exists(flight_path):
        os.remove(flight_path)

    reqs = serve.poisson_requests(n_requests=6, resolution=8)
    with SolveService(window=0.002) as svc:
        svc.warmup(reqs[0], batch_sizes=(1, 2, 4))
        load = serve.open_loop_load(svc, reqs, rate=500.0)
    assert load.ok == len(reqs), f"serve window lost requests: {load}"
    assert load.span_coverage > 0.95, (
        f"span segments cover only {load.span_coverage:.2%} of e2e")

    # a forced non-converged wave must auto-dump the flight recorder
    bad = [dataclasses.replace(r, maxiter=3)
           for r in serve.poisson_requests(n_requests=2, resolution=8)]
    svc2 = SolveService(window=0.0)
    pend = [svc2.submit(r) for r in bad]
    svc2.drain()
    assert all(p.response().status == "nonconverged" for p in pend)
    assert os.path.exists(flight_path), "flight recorder did not auto-dump"
    flight_rows = _load_rows(flight_path)
    reasons = {r["reason"] for r in flight_rows if r["kind"] == "flight_dump"}
    assert "nonconverged" in reasons, f"no nonconverged dump (saw {reasons})"
    nonconv = [r for r in flight_rows
               if r["kind"] == "flight" and r.get("outcome") == "nonconverged"]
    assert nonconv and all(r["trace"]["name"] == "serve.request"
                           for r in nonconv), nonconv

    telemetry.export_jsonl(args.jsonl)
    rows = _load_rows(args.jsonl)
    span_rows = [r for r in rows if r.get("kind") == "span"]
    req_spans = [r for r in span_rows if r["name"] == "span/serve.request"]
    assert req_spans, f"no serve.request span rows in {args.jsonl}"
    segs = {r["name"] for r in span_rows if r.get("parent_id") is not None}
    assert {"span/queue_wait", "span/solve"} <= segs, segs
    slo_rows = [r for r in rows if r.get("kind") == "slo"]
    assert slo_rows and slo_rows[-1]["met"], f"SLO rows wrong: {slo_rows}"

    rc = report.main([args.jsonl, "--slo"])
    assert rc == 0, f"report --slo failed with exit code {rc}"

    print(
        f"telemetry smoke OK: {len(solves)} solve rows (converged), "
        f"{len(assemblies)} assembly rows, {len(metrics)} metric rows, "
        f"{len(trace_files)} trace files, matfree-vs-csr err {err:.2e}, "
        f"{len(req_spans)} request span trees "
        f"(coverage {load.span_coverage:.1%}), "
        f"{len(nonconv)} flight records dumped"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
