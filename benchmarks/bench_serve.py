"""repro.serve admission-batching economics + latency SLO rows.

Three measurements on the canonical heterogeneous-coefficient Poisson
workload (one shared plan, per-request per-element ρ):

* ``serve_sequential_solve_n*`` — B warm sequential ``PoissonProblem
  .solve(rho=ρ_i)`` calls (the pre-serve dispatch cost, compile excluded),
* ``serve_batched_solve_n*`` — the same B requests admitted through a
  warmed-up :class:`~repro.serve.service.SolveService` and answered by ONE
  vmapped executable; the derived field carries the speedup (the ≥3x
  acceptance gate — asserted here, so CI fails loudly on regression),
* ``serve_e2e_p99_us_n*`` / ``serve_e2e_p50_us_n*`` — open-loop latency
  percentiles out of the telemetry histograms under Poisson arrivals
  (the p99 row is baseline-gated by ``benchmarks/compare.py``), plus
  ``serve_cache_hit_rate`` — must be 1.0 across the post-warmup waves
  (asserted, together with zero ``jit_traces{kind=serve}`` retraces).
"""

import time

import numpy as np

from repro import serve, telemetry
from repro.fem import PoissonProblem

from .common import emit_json, is_quick, time_fn


def main():
    quick = is_quick()
    b = 16
    resolution = 10 if quick else 16
    waves = 3
    rate = 4000.0

    reqs = serve.poisson_requests(n_requests=b, resolution=resolution)
    plan = reqs[0].plan
    n = plan.static.num_dofs
    n_elems = plan.static.scalar_cell_dofs.shape[0]
    rng = np.random.default_rng(7)
    rhos = rng.uniform(0.5, 2.0, size=(b, n_elems))

    # -- sequential reference: B warm .solve() dispatches -------------------
    prob = PoissonProblem(_mesh(resolution))
    prob.solve(rho=rhos[0])  # compile once (cold-cache excluded)

    def sequential():
        return [prob.solve(rho=rhos[i]).u for i in range(b)]

    t_seq = time_fn(sequential, warmup=1, iters=3)
    emit_json(
        f"serve_sequential_solve_n{n}", t_seq,
        f"B={b};dofs={n};per_req={t_seq / b:.0f}us",
        dofs=n, batch=b, us_per_request=round(t_seq / b, 1),
    )

    # -- batched service path: same B requests, one executable --------------
    telemetry.enable()
    svc = serve.SolveService(window=0.0)
    svc.warmup(reqs[0], batch_sizes=(b,))

    def serve_wave(seed=0):
        wave = serve.poisson_requests(n_requests=b, resolution=resolution,
                                      seed=seed)
        pend = [svc.submit(r) for r in wave]
        svc.drain()
        return [p.result() for p in pend]

    serve_wave()  # warm the dispatch path itself
    base_traces = telemetry.jit_trace_total("serve")
    hits0, miss0 = svc.cache.hits, svc.cache.misses
    t_batch = time_fn(serve_wave, warmup=0, iters=3)
    retraces = telemetry.jit_trace_total("serve") - base_traces
    assert retraces == 0, f"serve waves retraced {retraces}x after warmup"
    assert svc.cache.misses == miss0, "executable cache missed after warmup"
    hit_rate = (svc.cache.hits - hits0) / max(1, (svc.cache.hits - hits0)
                                              + (svc.cache.misses - miss0))
    speedup = t_seq / t_batch
    emit_json(
        f"serve_batched_solve_n{n}", t_batch,
        f"B={b};speedup={speedup:.1f}x;per_req={t_batch / b:.0f}us",
        dofs=n, batch=b, speedup_vs_sequential=round(speedup, 2),
        us_per_request=round(t_batch / b, 1),
    )
    emit_json(
        "serve_cache_hit_rate", 1e6 * hit_rate,  # rate as a pseudo-time row
        f"hit_rate={hit_rate:.2f};retraces={retraces}",
        hit_rate=hit_rate, retraces=retraces,
    )
    assert speedup >= 3.0, (
        f"admission batching speedup {speedup:.2f}x < 3x acceptance floor")
    assert hit_rate == 1.0, f"cache hit rate {hit_rate:.2f} != 1.0 after warmup"

    # -- open-loop latency SLO rows ----------------------------------------
    telemetry.reset()
    with serve.SolveService(window=0.002) as live:
        live.warmup(reqs[0], batch_sizes=(1, 2, 4, 8, 16))
        t0 = time.monotonic()
        reports = [
            serve.open_loop_load(
                live,
                serve.poisson_requests(n_requests=b, resolution=resolution,
                                       seed=100 + w),
                rate=rate, seed=w)
            for w in range(waves)
        ]
        wall = time.monotonic() - t0
    rep = reports[-1]  # cumulative histograms: last report sees all waves
    ok = sum(r.ok for r in reports)
    assert ok == waves * b, f"only {ok}/{waves * b} open-loop requests ok"
    emit_json(
        f"serve_e2e_p50_us_n{n}", rep.e2e_p50_us,
        f"waves={waves};B={b};rate={rate:.0f}/s",
        dofs=n, batch=b, waves=waves, offered_rate=rate,
        queue_wait_p50_us=rep.queue_wait_p50_us,
    )
    emit_json(
        f"serve_e2e_p99_us_n{n}", rep.e2e_p99_us,
        f"waves={waves};B={b};rate={rate:.0f}/s;"
        f"throughput={ok / wall:.0f}/s",
        dofs=n, batch=b, waves=waves, offered_rate=rate,
        throughput=round(ok / wall, 1),
    )


def _mesh(resolution: int):
    from repro.core import unit_square_tri

    return unit_square_tri(resolution)


if __name__ == "__main__":
    main()
