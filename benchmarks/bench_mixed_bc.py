"""Paper SM B.1.5 (Table B.3): mixed Dirichlet+Neumann+Robin Poisson on a
disk and a non-convex (annulus-sector 'boomerang') domain with an analytic
solution; derived: relative error (paper band: < 1e-4 on comparable meshes)
and end-to-end assembly+solve time."""

import jax.numpy as jnp
import numpy as np

from repro.core import annulus_sector_tri, disk_tri
from repro.fem import MixedBCPoisson

try:
    from .common import emit, time_fn
except ImportError:  # flat execution: python benchmarks/bench_mixed_bc.py
    from common import emit, time_fn


def _run(mesh, name, r_outer=1.0):
    # Neumann/Robin only on the outer circular arc (bottom half) so the
    # normal is (x, y)/r and the analytic data stays simple; everything
    # else is Dirichlet.
    def on_arc(c):
        r = np.sqrt(c[:, 0] ** 2 + c[:, 1] ** 2)
        return (r > 0.95 * r_outer) & (c[:, 1] <= 0)

    prob = MixedBCPoisson(
        mesh,
        dirichlet_pred=lambda c: ~on_arc(c),
        neumann_pred=lambda c: on_arc(c) & (c[:, 0] > 0),
        robin_pred=lambda c: on_arc(c) & (c[:, 0] <= 0),
    )
    # u = x is harmonic; BC data chosen to match on each part.  Coefficient
    # callables must be jax-traceable (jnp, not np): MixedBCPoisson.solve
    # evaluates them to quadrature arrays before the fused assembly.
    pts = prob.space.dof_points
    r_at = lambda x: jnp.sqrt(x[..., 0] ** 2 + x[..., 1] ** 2)
    g_n = lambda x: x[..., 0] / r_at(x)
    g_r = lambda x: x[..., 0] / r_at(x) + x[..., 0]
    g_d = lambda p: p[:, 0]

    def solve():
        return prob.solve(
            f=0.0, g_neumann=g_n, robin_alpha=1.0, g_robin=g_r,
            dirichlet_values=g_d,
        )

    res = solve()
    err = np.linalg.norm(np.asarray(res.u) - pts[:, 0]) / np.linalg.norm(pts[:, 0])
    t = time_fn(lambda: solve().u, warmup=0, iters=3)
    emit(
        f"mixed_bc_{name}", t,
        f"dofs={prob.space.num_dofs};rel_err={err:.2e};relres={res.residual:.1e}",
    )


def main():
    _run(disk_tri(14, center=(0.0, 0.0), radius=1.0), "disk")
    _run(annulus_sector_tri(10, 48), "boomerang")


if __name__ == "__main__":
    main()
