"""Paper Fig. 1 + §2 (O(1)-graph property): TensorGalerkin Map-Reduce vs the
scatter-add baseline vs a per-element Python loop, across mesh sizes.

Derived column: speedup over scatter-add, and jaxpr-equation count (which
must not grow with E — the O(1) claim).  The Map-Reduce rows emit JSON and
are gated by the perf-smoke CI pipeline against ``BENCH_baseline.json``
(quick mode runs the two smallest meshes; row names encode E, so quick and
full baselines never mix)."""

import jax
import jax.numpy as jnp

from repro.core import FunctionSpace, GalerkinAssembler, unit_square_tri
from repro.core.mesh import element_for_mesh

from .common import emit, emit_json, is_quick, time_fn


def main():
    quick = is_quick()
    for n in (16, 32) if quick else (16, 32, 64, 128):
        m = unit_square_tri(n)
        space = FunctionSpace(m, element_for_mesh(m))
        asm = GalerkinAssembler(space)
        rho = jnp.ones(m.num_cells)

        t_mr = time_fn(lambda: asm.assemble_stiffness(rho).vals)
        t_sc = time_fn(lambda: asm.assemble_stiffness_scatter(rho)) if n <= 64 else float("nan")

        # O(1)-graph evidence: jaxpr size
        from repro.core import forms
        from repro.core.assembly import reduce_matrix

        def assemble(coords, r):
            return reduce_matrix(forms.diffusion(asm.context(coords), r), asm.mat_routing)

        n_eqns = len(jax.make_jaxpr(assemble)(asm.coords, rho).jaxpr.eqns)
        emit_json(
            f"assembly_mapreduce_E{m.num_cells}", t_mr,
            f"jaxpr_eqns={n_eqns};scatter_us={t_sc:.1f}",
            num_cells=m.num_cells, dofs=space.num_dofs,
            jaxpr_eqns=n_eqns, scatter_us=round(t_sc, 1),
        )

    if quick:
        return

    # per-element loop baseline (tiny mesh only; the paper's 'white box')
    m = unit_square_tri(8)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    t_loop = time_fn(lambda: asm.assemble_stiffness_loop(), warmup=0, iters=2)
    t_mr = time_fn(lambda: asm.assemble_stiffness().vals)
    emit(f"assembly_loop_E{m.num_cells}", t_loop, f"mapreduce_speedup={t_loop / t_mr:.0f}x")


if __name__ == "__main__":
    main()
