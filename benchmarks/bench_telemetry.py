"""Telemetry overhead benchmark: the PR-5/PR-10 zero-cost-when-off claim.

One tracked claim: enabling telemetry — span trees, solve events, and
histogram folds on the hot solve path — costs < 3 % of the wall time of a
representative matrix-free solve.  The budget is a **hard assertion**, not
just a tracked row: the module raises (and the benchmark harness exits
non-zero) when the measured overhead exceeds it.

Methodology: the off/on timings are taken in alternating rounds
(off, on, off, on, ...) so slow machine-wide drift lands on both sides
equally, and the gated figure is the **min over all samples** of each
side — contention noise on a shared runner is strictly additive, so with
enough alternating samples both minima approach the true quiet-machine
wall and their difference isolates the instrumentation cost.  The
workload is sized so that 3 % of one solve is far above the absolute
per-call cost of a span tree (sub-100 µs), i.e. the gate fails on real
regressions, not timer noise.

Rows (perf-smoke CI gates these against ``BENCH_baseline.json``):
  telemetry_solve_off_{tag}   — hot matfree CG solve, telemetry disabled
  telemetry_solve_spans_{tag} — same executable, telemetry + spans enabled
"""

import time

import jax

try:
    from .common import emit_json, is_quick
except ImportError:  # flat execution
    from common import emit_json, is_quick

from repro import telemetry
from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    SolverSpec,
    matfree_operator,
    matfree_solve,
    unit_square_tri,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh

OVERHEAD_BUDGET = 0.03  # hard gate: enabled-with-spans vs disabled


def _setup(n):
    mesh = unit_square_tri(n)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 1))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    op = matfree_operator(asm.plan, wf.diffusion(1.0)).condensed(bc)
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    return op, f


def _timed_calls(fn, iters):
    """Raw per-call walls (µs) — callers aggregate, no median here."""
    out = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        out.append((time.perf_counter() - t0) * 1e6)
    return out


def _overhead_case(n, tag, rounds, iters):
    op, f = _setup(n)
    spec = SolverSpec(method="cg", tol=1e-10, atol=1e-10, maxiter=20000)

    def solve():
        return matfree_solve(op, f, spec=spec)

    jax.block_until_ready(solve())  # compile once, outside both timings

    was_enabled = telemetry.is_enabled()
    t_off, t_on = [], []
    try:
        for _ in range(rounds):
            telemetry.disable()
            t_off.extend(_timed_calls(solve, iters))
            telemetry.enable()
            telemetry.reset()
            t_on.extend(_timed_calls(solve, iters))
        # the enabled rounds must have exercised the real instrumentation:
        # a span per solve folded into span_us
        snap = telemetry.snapshot()
        spans_seen = [k for k in snap["histograms"]
                      if k.startswith("span_us{span=matfree_solve")]
        assert spans_seen, "enabled rounds recorded no matfree_solve spans"
    finally:
        telemetry.reset()
        telemetry.disable()
        if was_enabled:  # pragma: no cover - harness runs disabled
            telemetry.enable()

    off, on = min(t_off), min(t_on)
    overhead = (on - off) / off
    emit_json(f"telemetry_solve_off_{tag}", off,
              f"n={n};rounds={rounds}x{iters}")
    emit_json(f"telemetry_solve_spans_{tag}", on,
              f"n={n};overhead={100 * overhead:.2f}%;"
              f"budget={100 * OVERHEAD_BUDGET:.0f}%",
              overhead_pct=round(100 * overhead, 2),
              off_us=round(off, 1))
    assert overhead < OVERHEAD_BUDGET, (
        f"telemetry overhead {100 * overhead:.2f}% exceeds the "
        f"{100 * OVERHEAD_BUDGET:.0f}% budget ({off:.0f}us off -> "
        f"{on:.0f}us on, n={n})")


def main():
    if is_quick():
        _overhead_case(32, "n1089", rounds=5, iters=3)
    else:
        _overhead_case(32, "n1089", rounds=6, iters=4)
        _overhead_case(64, "n4225", rounds=4, iters=3)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
