"""Paper Table 2 (heavily reduced): physics-informed operator learning on
the wave equation (disk domain) — AGN backbone trained with (a) data-driven
supervised loss and (b) the TensorPILS Galerkin-residual loss; evaluated on
ID (first half of rollout) and OOD (second half) segments of held-out
trajectories.  Derived: rel-L2 errors.  Claim: Galerkin training generalizes
better OOD (paper's key operator-learning result)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import disk_tri
from repro.pils.gnn import agn_apply, agn_init, agn_rollout, element_graph_edges
from repro.pils.operator import TimeDependentProblem, random_initial_condition
from repro.pils.training import adam_init, adam_update

from .common import emit

W = 4            # bundle window
N_BUNDLES = 4    # rollout = 16 steps; ID = first 8, OOD = last 8
EPOCHS = 400
N_TRAIN, N_TEST = 4, 4


def main():
    tp = TimeDependentProblem(disk_tri(5), dt=5e-4, c=4.0)
    mesh = tp.mesh
    edges = element_graph_edges(mesh.cells)
    deg = np.zeros(mesh.num_vertices)
    np.add.at(deg, edges[:, 1], 1)
    deg = jnp.asarray(np.maximum(deg, 1.0))
    coords = jnp.asarray(mesh.points)
    interior = tp.interior

    total = W * N_BUNDLES

    def make_traj(key):
        u0 = random_initial_condition(key, tp.space.dof_points)
        ref = tp.wave_reference(u0, W + total)
        u0m = (u0 * tp.bc.free_mask)[None]
        return jnp.concatenate([u0m, ref], axis=0)  # (W+total+1, N)

    keys = jax.random.split(jax.random.PRNGKey(0), N_TRAIN + N_TEST)
    train_trajs = [make_traj(k) for k in keys[:N_TRAIN]]
    test_trajs = [make_traj(k) for k in keys[N_TRAIN:]]

    def rollout(params, traj):
        # window seeded with the first w true steps (both methods get the
        # same teacher-forced seed; the paper seeds from the known IC window)
        u_win = traj[:W].T
        return agn_rollout(params, u_win, coords, edges, deg, N_BUNDLES, interior)

    def data_loss(params, traj):
        pred = rollout(params, traj)                        # (N, total)
        tgt = traj[W : W + total].T
        return jnp.mean((pred - tgt) ** 2)

    def galerkin_loss(params, traj):
        pred = rollout(params, traj)                        # (N, total)
        full = jnp.concatenate([traj[W - 2 : W], pred.T], axis=0)
        return tp.wave_trajectory_loss(full, normalized=True)

    def train(loss_fn):
        params = agn_init(jax.random.PRNGKey(1), W, W, hidden=32, n_layers=2)
        state = adam_init(params)
        total_loss = lambda p: sum(loss_fn(p, t) for t in train_trajs) / N_TRAIN
        vg = jax.jit(jax.value_and_grad(total_loss))
        for i in range(EPOCHS):
            _, g = vg(params)
            lr = 3e-3 if i < EPOCHS // 2 else 1e-3
            params, state = adam_update(params, g, state, lr)
        return params

    def errors(params):
        id_err, ood_err = [], []
        half = total // 2
        for traj in test_trajs:
            pred = np.asarray(rollout(params, traj)).T      # (total, N)
            tgt = np.asarray(traj[W : W + total])
            nrm = np.linalg.norm(tgt, axis=1) + 1e-12
            rel = np.linalg.norm(pred - tgt, axis=1) / nrm
            id_err.append(rel[:half].mean())
            ood_err.append(rel[half:].mean())
        return float(np.mean(id_err)), float(np.mean(ood_err))

    import time

    for name, loss_fn in (("data_driven", data_loss), ("tensorpils", galerkin_loss)):
        t0 = time.perf_counter()
        params = train(loss_fn)
        dt = (time.perf_counter() - t0) / EPOCHS * 1e6
        id_e, ood_e = errors(params)
        emit(f"operator_wave_{name}", dt, f"id_rel={id_e:.3f};ood_rel={ood_e:.3f}")


if __name__ == "__main__":
    main()
