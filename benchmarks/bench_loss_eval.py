"""Paper Fig. 4 + SM B.2.4 (Fig. B.12): wall-clock of one loss evaluation
(forward AND backward) vs DoFs for supervised / TensorPILS / PINN objectives
on the same SIREN backbone.  The claim to validate: PINN grows much faster
with DoFs (AD-through-space overhead) while TensorPILS tracks the
supervised baseline."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DirichletCondenser, FunctionSpace, GalerkinAssembler, unit_square_tri
from repro.core.mesh import element_for_mesh
from repro.pils import GalerkinResidualLoss, pinn_poisson_loss, siren_apply, siren_init

from .common import emit, time_fn


def main():
    key = jax.random.PRNGKey(0)
    params = siren_init(key, 2, 64, 1, depth=4)

    for n in (16, 32, 64):
        m = unit_square_tri(n)
        space = FunctionSpace(m, element_for_mesh(m))
        asm = GalerkinAssembler(space)
        bc = DirichletCondenser(asm, space.boundary_dofs())
        gl = GalerkinResidualLoss(asm, bc, f=1.0)
        pts = jnp.asarray(space.dof_points)
        free = np.asarray(bc.free_mask, bool)
        interior, boundary = pts[free], pts[~free]
        f_int = jnp.ones(interior.shape[0])
        target = jnp.zeros(pts.shape[0])
        dofs = space.num_dofs

        sup = jax.jit(lambda p: jnp.mean((siren_apply(p, pts)[:, 0] - target) ** 2))
        pils = jax.jit(lambda p: gl.loss_from_net(siren_apply, p))
        pinn = jax.jit(
            lambda p: pinn_poisson_loss(siren_apply, p, interior, f_int, boundary)
        )
        g_sup = jax.jit(jax.grad(lambda p: jnp.mean((siren_apply(p, pts)[:, 0] - target) ** 2)))
        g_pils = jax.jit(jax.grad(lambda p: gl.loss_from_net(siren_apply, p)))
        g_pinn = jax.jit(
            jax.grad(lambda p: pinn_poisson_loss(siren_apply, p, interior, f_int, boundary))
        )

        for name, fn in (("supervised", sup), ("tensorpils", pils), ("pinn", pinn)):
            emit(f"loss_fwd_{name}_dof{dofs}", time_fn(fn, params), f"dofs={dofs}")
        for name, fn in (("supervised", g_sup), ("tensorpils", g_pils), ("pinn", g_pinn)):
            emit(
                f"loss_bwd_{name}_dof{dofs}",
                time_fn(lambda: jax.tree.leaves(fn(params))[0]),
                f"dofs={dofs}",
            )


if __name__ == "__main__":
    main()
