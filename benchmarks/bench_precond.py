"""Preconditioner and static-condensation benchmark (repro.core.elemalg).

Two tracked claims of the element tensor-algebra layer:

* the matrix-free EbE (element-by-element additive Schwarz) and Chebyshev
  polynomial preconditioners cut CG iteration counts below Jacobi on the
  anisotropic Poisson problem while materializing no global matrix — each
  row carries ``iters`` next to the wall time per solve;
* static condensation of a P2 Poisson system runs the Krylov loop on a
  strictly smaller interface system with strictly fewer outer iterations
  than the full-system CG, at solution parity.

Rows (perf-smoke CI gates these against ``BENCH_baseline.json``):
  precond_{jacobi,ebe,chebyshev}_{tag} — one preconditioned CG solve
  condensed_solve_{tag} / full_solve_{tag} — P2 condensation vs full system
"""

import jax.numpy as jnp
import numpy as np

try:
    from .common import emit_json, is_quick, time_fn
except ImportError:  # flat execution
    from common import emit_json, is_quick, time_fn

from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    SolverSpec,
    condensed_solve,
    matfree_operator,
    matfree_solve,
    unit_square_tri,
    vertex_split,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh


def _setup(n, degree, form):
    mesh = unit_square_tri(n)
    space = FunctionSpace(mesh, element_for_mesh(mesh, degree))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    op = matfree_operator(asm.plan, form).condensed(bc)
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    return space, op, f


def _precond_case(n, tag):
    """Anisotropic Poisson: A = diag(100, 1) — the conditioning stressor
    the EbE/Chebyshev preconditioners were tuned on."""
    a = jnp.asarray(np.diag([100.0, 1.0]))
    space, op, f = _setup(n, 1, wf.anisotropic_diffusion(a))
    iters = {}
    for name in ("jacobi", "ebe", "chebyshev"):
        spec = SolverSpec(method="cg", tol=1e-10, atol=1e-10, maxiter=20000,
                          precond=name)

        def solve():
            return matfree_solve(op, f, spec, return_info=True)

        u, info = solve()
        u.block_until_ready()
        iters[name] = int(info.iters)
        t = time_fn(lambda: solve()[0], warmup=2, iters=5)
        emit_json(
            f"precond_{name}_{tag}", t,
            f"iters={iters[name]};dofs={space.num_dofs}",
            dofs=space.num_dofs, iters=iters[name], precond=name,
        )
    # the layer's contract: both element-algebra preconditioners beat Jacobi
    assert iters["ebe"] < iters["jacobi"], iters
    assert iters["chebyshev"] < iters["jacobi"], iters


def _condensation_case(n, tag):
    space, op, f = _setup(n, 2, wf.diffusion(1.0))
    split = vertex_split(space)
    spec = SolverSpec(method="cg", tol=1e-10, atol=1e-10, maxiter=20000)

    def full():
        return matfree_solve(op, f, spec, return_info=True)

    def cond():
        return condensed_solve(op, f, spec, split=split, return_info=True)

    u_full, info_full = full()
    u_cond, info_cond = cond()
    parity = float(jnp.max(jnp.abs(u_cond - u_full)))
    assert parity < 1e-8, parity
    nb = int(np.asarray(split.interface_mask).sum())
    t_full = time_fn(lambda: full()[0], warmup=2, iters=5)
    t_cond = time_fn(lambda: cond()[0], warmup=2, iters=5)
    emit_json(
        f"full_solve_{tag}", t_full,
        f"iters={int(info_full.iters)};dofs={space.num_dofs}",
        dofs=space.num_dofs, iters=int(info_full.iters),
    )
    emit_json(
        f"condensed_solve_{tag}", t_cond,
        f"iters={int(info_cond.iters)};interface_dofs={nb}",
        dofs=space.num_dofs, interface_dofs=nb, iters=int(info_cond.iters),
        parity=parity,
    )
    assert int(info_cond.iters) < int(info_full.iters)
    assert nb < space.num_dofs


def main():
    if is_quick():
        _precond_case(24, "aniso_24")
        _condensation_case(12, "p2_12")
    else:
        _precond_case(64, "aniso_64")
        _condensation_case(32, "p2_32")


if __name__ == "__main__":
    main()
