"""Pallas kernel micro-benchmarks (interpret mode on CPU — numbers are for
relative comparison with the pure-jnp reference path, not TPU projections;
BlockSpec VMEM footprints are reported as the derived column)."""

import numpy as np
import jax.numpy as jnp

import repro.core  # noqa: F401  x64
from repro.kernels.local_assembly import BLOCK_E, local_stiffness_p1
from repro.kernels.ref import local_stiffness_p1_ref

from .common import emit, time_fn


def main():
    rng = np.random.default_rng(0)
    for e in (4096, 16384):
        ident = np.concatenate([np.zeros((1, 3)), np.eye(3)], axis=0)
        coords = jnp.asarray(
            rng.normal(size=(e, 1, 3)) + ident[None] + 0.1 * rng.normal(size=(e, 4, 3))
        )
        rho = jnp.ones(e)
        t_ref = time_fn(lambda: local_stiffness_p1_ref(coords, rho), iters=3)
        t_k = time_fn(
            lambda: local_stiffness_p1(coords, rho, interpret=True), iters=3
        )
        vmem_kb = (12 + 1 + 16) * BLOCK_E * 4 / 1024
        emit(
            f"kernel_local_assembly_E{e}", t_k,
            f"ref_us={t_ref:.1f};vmem_per_block_KB={vmem_kb:.0f};mode=interpret",
        )


if __name__ == "__main__":
    main()
