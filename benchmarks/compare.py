"""Perf-regression gate: compare a BENCH_JSON run against a committed baseline.

Usage (the perf-smoke CI job):

    BENCH_JSON=bench_results.json python -m benchmarks.run --only matfree --quick
    python -m benchmarks.compare bench_results.json

Every row in the baseline is *tracked*: it must appear in the results, and
its slowdown must not exceed ``tolerance ×`` (default 1.5, overridable
with ``--tolerance`` or ``BENCH_TOLERANCE``).  Because the committed
baseline is usually recorded on a different machine than the CI runner,
per-row ratios are **normalized by a machine scale** before gating: the
median ratio of the baseline's *reference rows* (records carrying
``"reference": true`` — the CSR SpMV rows, whose code the PRs under test
rarely touch).  A runner that is uniformly 2× slower shifts references
and gated rows equally and still passes, while gated rows regressing
relative to the references are caught — normalizing over *all* rows
instead would let a regression across the whole gated subsystem shift the
median itself and slip through.  Without any reference rows the scale
falls back to the median over everything (same-machine semantics);
``--no-normalize`` gates raw ratios.  Rows in the results that are not in
the baseline are reported but never fail the gate.

Refreshing the baseline after an intentional perf change:

    BENCH_JSON=bench_results.json python -m benchmarks.run --only matfree --quick
    python -m benchmarks.compare bench_results.json --update-baseline

then commit ``benchmarks/BENCH_baseline.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_baseline.json")


def load_rows(path: str) -> dict[str, dict]:
    """JSON-lines → {name: record}; a repeated name keeps the last record."""
    rows: dict[str, dict] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rec = json.loads(line)
                rows[rec["name"]] = rec
    return rows


def _median(xs: list[float]) -> float:
    xs = sorted(xs)
    mid = len(xs) // 2
    return xs[mid] if len(xs) % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def compare(results: dict[str, dict], baseline: dict[str, dict],
            tolerance: float, normalize: bool = True,
            subset: bool = False) -> list[str]:
    failures = []
    if subset:
        skipped = sorted(set(baseline) - set(results))
        baseline = {k: v for k, v in baseline.items() if k in results}
        if skipped:
            print(f"subset mode: {len(skipped)} tracked rows not in this "
                  f"run (skipped): {', '.join(skipped)}")
    ratios = {
        name: results[name]["us_per_call"] / base["us_per_call"]
        for name, base in baseline.items()
        if name in results
    }
    ref = [r for name, r in ratios.items() if baseline[name].get("reference")]
    scale = 1.0
    if normalize and ratios:
        scale = _median(ref if ref else list(ratios.values()))
        kind = f"{len(ref)} reference rows" if ref else f"all {len(ratios)} rows"
        print(f"machine scale (median ratio over {kind}): {scale:.2f}x")
    width = max((len(n) for n in baseline), default=4) + 2
    print(f"{'row'.ljust(width)}{'baseline_us':>12}{'now_us':>12}"
          f"{'ratio':>8}{'rel':>8}  status")
    for name, base in sorted(baseline.items()):
        rec = results.get(name)
        if rec is None:
            failures.append(f"{name}: tracked row missing from results")
            print(f"{name.ljust(width)}{base['us_per_call']:>12}"
                  f"{'—':>12}{'—':>8}{'—':>8}  MISSING")
            continue
        ratio = ratios[name]
        rel = ratio / scale
        ok = rel <= tolerance
        status = "ok" if ok else f"SLOWDOWN > {tolerance:g}x"
        print(
            f"{name.ljust(width)}{base['us_per_call']:>12}"
            f"{rec['us_per_call']:>12}{ratio:>8.2f}{rel:>8.2f}  {status}"
        )
        if not ok:
            failures.append(
                f"{name}: {rec['us_per_call']:.1f}us vs baseline "
                f"{base['us_per_call']:.1f}us ({rel:.2f}x relative to the "
                f"machine scale {scale:.2f}x > {tolerance:g}x)"
            )
    untracked = sorted(set(results) - set(baseline))
    if untracked:
        print(f"untracked (not gated): {', '.join(untracked)}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("Usage")[0])
    ap.add_argument("results", help="BENCH_JSON output of benchmarks.run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--tolerance", type=float,
        default=float(os.environ.get("BENCH_TOLERANCE", "1.5")),
        help="max allowed us_per_call ratio vs baseline (default 1.5)",
    )
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline from the results instead of comparing",
    )
    ap.add_argument(
        "--no-normalize", action="store_true",
        help="gate raw ratios (same-machine baseline) instead of "
             "median-normalized ones",
    )
    ap.add_argument(
        "--subset", action="store_true",
        help="gate only the baseline rows present in the results (for CI "
             "jobs that run a subset of the benchmark modules); missing "
             "tracked rows are skipped instead of failing",
    )
    args = ap.parse_args(argv)

    results = load_rows(args.results)
    if args.update_baseline:
        with open(args.baseline, "w") as f:
            for name in sorted(results):
                f.write(json.dumps(results[name]) + "\n")
        print(f"baseline updated: {args.baseline} ({len(results)} rows)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; run with --update-baseline first",
              file=sys.stderr)
        return 2
    baseline = load_rows(args.baseline)
    failures = compare(results, baseline, args.tolerance,
                       normalize=not args.no_normalize, subset=args.subset)
    if failures:
        print("\nPERF REGRESSION:", file=sys.stderr)
        for fail in failures:
            print(f"  {fail}", file=sys.stderr)
        return 1
    gated = len(set(baseline) & set(results)) if args.subset else len(baseline)
    print(f"\nall {gated} tracked rows within "
          f"{args.tolerance:g}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
