"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Module → paper artifact map:
  bench_assembly_scaling   — Fig. 1 / §2 O(1)-graph property
  bench_solver_scaling     — Fig. 2 (3D Poisson + elasticity scaling)
  bench_mixed_bc           — SM B.1.5 Table B.3 (mixed-BC Poisson)
  bench_batch_generation   — SM B.1.4 Fig. B.4 (batched RHS solves)
  bench_neural_solvers     — Table 1 (PINN/VPINN/DeepRitz/TensorPILS)
  bench_loss_eval          — Fig. 4 / Fig. B.12 (loss-eval cost vs DoF)
  bench_operator_learning  — Table 2 (wave operator learning, ID/OOD)
  bench_topo_opt           — Table 3 (cantilever SIMP)
  bench_kernels            — Pallas kernel microbench (interpret mode)
  bench_transient          — repro.transient rollouts (heat/wave, CSR vs ELL)
  bench_weakform           — fused multi-term WeakForm assemble vs separate+add
  bench_batched_assembly   — vmap-batched multi-instance assembly vs B singles
  bench_matfree            — matrix-free apply/solve vs assembled CSR
  bench_precond            — elemalg preconditioners + static condensation
  bench_serve              — repro.serve admission batching vs sequential
  bench_telemetry          — spans/telemetry overhead on the hot solve path
  bench_dryrun_roofline    — harness roofline table (from dry-run JSON)

Usage:
  python -m benchmarks.run [--only PREFIX[,PREFIX...]] [--quick]

``--only matfree`` runs just the modules whose name contains the prefix
(``bench_`` is implied); a comma-separated list (``--only matfree,serve``)
runs every module matching any prefix.  ``--quick`` switches modules to
their reduced problem sizes (the perf-smoke CI subset).
``BENCH_JSON=<path>`` appends machine-readable JSON-lines rows (compared
against the committed ``benchmarks/BENCH_baseline.json`` by
``benchmarks/compare.py``).
"""

import argparse
import os
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("Usage:")[0])
    ap.add_argument(
        "--only", default=None, metavar="PREFIX[,PREFIX...]",
        help="run only modules whose name contains any PREFIX "
             "(bench_ implied; comma-separated)",
    )
    ap.add_argument(
        "--quick", action="store_true",
        help="reduced problem sizes (sets BENCH_QUICK=1 for all modules)",
    )
    args = ap.parse_args(argv)
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"

    from . import (
        bench_assembly_scaling,
        bench_batch_generation,
        bench_batched_assembly,
        bench_dryrun_roofline,
        bench_kernels,
        bench_loss_eval,
        bench_matfree,
        bench_mixed_bc,
        bench_neural_solvers,
        bench_operator_learning,
        bench_precond,
        bench_serve,
        bench_solver_scaling,
        bench_telemetry,
        bench_topo_opt,
        bench_transient,
        bench_weakform,
    )

    modules = [
        bench_assembly_scaling,
        bench_solver_scaling,
        bench_mixed_bc,
        bench_batch_generation,
        bench_neural_solvers,
        bench_loss_eval,
        bench_operator_learning,
        bench_topo_opt,
        bench_kernels,
        bench_transient,
        bench_weakform,
        bench_batched_assembly,
        bench_matfree,
        bench_precond,
        bench_serve,
        bench_telemetry,
        bench_dryrun_roofline,
    ]
    if args.only:
        needles = [p.removeprefix("bench_") for p in args.only.split(",") if p]
        modules = [m for m in modules
                   if any(nd in m.__name__ for nd in needles)]
        if not modules:
            print(f"no benchmark module matches --only {args.only!r}", file=sys.stderr)
            sys.exit(2)
    print("name,us_per_call,derived")
    failed = []
    for mod in modules:
        try:
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED_MODULES={failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
