"""Paper Fig. 2: end-to-end (assembly + Krylov solve) runtime vs DoFs for
3D Poisson and 3D elasticity; scipy spsolve as the 'legacy CPU' baseline.
Derived: DoFs, solver iterations, relative residual (must be < 1e-10 to
match the paper's tolerance).

Streaming/sharded rows (this file's perf-gate additions): ``ell_stream``
runs the whole CG on the HBM-resident streaming SpMV — full mode solves an
N ≥ 1e6-DOF 2D Poisson end-to-end (the million-DOF claim), quick mode the
same path at CI scale; ``matfree_sharded`` spans a single matrix-free CG
over every local device."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hollow_cube_tet, unit_cube_tet, unit_square_tri
from repro.fem import ElasticityProblem, PoissonProblem

from .common import emit, emit_json, is_quick, time_fn


def _stream_case(quick: bool):
    # quick: the reduced-N CI proof of the streaming solve path; full: the
    # million-DOF row — unit_square_tri(1000) has 1_002_001 DoFs, and the
    # streaming kernel's VMEM footprint is independent of N
    n = 32 if quick else 1000
    prob = PoissonProblem(unit_square_tri(n))
    res, info = prob.solve(backend="ell_stream", tol=1e-10, return_info=True)
    assert res.converged, "streaming-SpMV CG did not converge"
    dofs = prob.space.num_dofs
    if not quick:
        assert dofs >= 1_000_000, f"full-mode streaming row must be ≥1e6 DoFs, got {dofs}"
    t = time_fn(lambda: prob.solve(backend="ell_stream", tol=1e-10).u,
                warmup=0, iters=2 if quick else 1)
    emit_json(
        f"poisson2d_stream_solve_n{dofs}", t,
        f"dofs={dofs};iters={res.iters};relres={res.residual:.1e}",
        dofs=dofs, iterations=int(info.iters),
        final_residual=float(info.residual),
        converged=bool(info.converged), relres=res.residual,
    )


def _sharded_case(quick: bool):
    prob = PoissonProblem(unit_cube_tet(4 if quick else 8))
    res, info = prob.solve(backend="matfree_sharded", tol=1e-10,
                           return_info=True)
    assert res.converged, "sharded matrix-free CG did not converge"
    dofs = prob.space.num_dofs
    t = time_fn(lambda: prob.solve(backend="matfree_sharded", tol=1e-10).u,
                warmup=0, iters=2)
    emit_json(
        f"poisson3d_sharded_solve_n{dofs}", t,
        f"dofs={dofs};devices={len(jax.devices())};iters={res.iters}",
        dofs=dofs, devices=len(jax.devices()), iterations=int(info.iters),
        final_residual=float(info.residual),
        converged=bool(info.converged), relres=res.residual,
    )


def main():
    quick = is_quick()
    for n in (4, 6) if quick else (6, 10, 14):
        prob = PoissonProblem(unit_cube_tet(n))
        res, info = prob.solve(return_info=True)  # warm compile
        t = time_fn(lambda: prob.solve(tol=1e-10).u, warmup=0, iters=3)
        emit_json(
            f"poisson3d_solve_n{prob.space.num_dofs}", t,
            f"dofs={prob.space.num_dofs};iters={res.iters};relres={res.residual:.1e}",
            dofs=prob.space.num_dofs, iterations=int(info.iters),
            final_residual=float(info.residual),
            converged=bool(info.converged), relres=res.residual,
        )
        # scipy direct-solve baseline on the same system
        k, f = prob.assemble()
        ks = k.to_scipy().tocsc()
        import scipy.sparse.linalg as spla

        t_sp = time_fn(lambda: spla.spsolve(ks, np.asarray(f)), warmup=0, iters=2)
        emit(f"poisson3d_scipy_n{prob.space.num_dofs}", t_sp, "baseline=scipy_spsolve")

    _stream_case(quick)
    _sharded_case(quick)

    for n in (3,) if quick else (4, 8):
        prob = ElasticityProblem(hollow_cube_tet(n))
        res, info = prob.solve(return_info=True)
        t = time_fn(lambda: prob.solve(tol=1e-10).u, warmup=0, iters=2)
        emit_json(
            f"elasticity3d_solve_n{prob.space.num_dofs}", t,
            f"dofs={prob.space.num_dofs};iters={res.iters};relres={res.residual:.1e}",
            dofs=prob.space.num_dofs, iterations=int(info.iters),
            final_residual=float(info.residual),
            converged=bool(info.converged), relres=res.residual,
        )


if __name__ == "__main__":
    main()
