"""Paper Fig. 2: end-to-end (assembly + Krylov solve) runtime vs DoFs for
3D Poisson and 3D elasticity; scipy spsolve as the 'legacy CPU' baseline.
Derived: DoFs, solver iterations, relative residual (must be < 1e-10 to
match the paper's tolerance)."""

import jax.numpy as jnp
import numpy as np

from repro.core import hollow_cube_tet, unit_cube_tet
from repro.fem import ElasticityProblem, PoissonProblem

from .common import emit, emit_json, is_quick, time_fn


def main():
    quick = is_quick()
    for n in (4, 6) if quick else (6, 10, 14):
        prob = PoissonProblem(unit_cube_tet(n))
        res, info = prob.solve(return_info=True)  # warm compile
        t = time_fn(lambda: prob.solve(tol=1e-10).u, warmup=0, iters=3)
        emit_json(
            f"poisson3d_solve_n{prob.space.num_dofs}", t,
            f"dofs={prob.space.num_dofs};iters={res.iters};relres={res.residual:.1e}",
            dofs=prob.space.num_dofs, iterations=int(info.iters),
            final_residual=float(info.residual),
            converged=bool(info.converged), relres=res.residual,
        )
        # scipy direct-solve baseline on the same system
        k, f = prob.assemble()
        ks = k.to_scipy().tocsc()
        import scipy.sparse.linalg as spla

        t_sp = time_fn(lambda: spla.spsolve(ks, np.asarray(f)), warmup=0, iters=2)
        emit(f"poisson3d_scipy_n{prob.space.num_dofs}", t_sp, "baseline=scipy_spsolve")

    for n in (3,) if quick else (4, 8):
        prob = ElasticityProblem(hollow_cube_tet(n))
        res, info = prob.solve(return_info=True)
        t = time_fn(lambda: prob.solve(tol=1e-10).u, warmup=0, iters=2)
        emit_json(
            f"elasticity3d_solve_n{prob.space.num_dofs}", t,
            f"dofs={prob.space.num_dofs};iters={res.iters};relres={res.residual:.1e}",
            dofs=prob.space.num_dofs, iterations=int(info.iters),
            final_residual=float(info.residual),
            converged=bool(info.converged), relres=res.residual,
        )


if __name__ == "__main__":
    main()
