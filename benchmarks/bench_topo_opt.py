"""Paper Table 3: 2D cantilever SIMP compliance minimization.  Reduced mesh
for CPU but the same structure: setup time vs optimization-loop time, OC and
MMA optimizers, AD-vs-analytic sensitivity parity.  Derived: compliance
reduction and final volume fraction."""

import time

import jax.numpy as jnp
import numpy as np

from repro.opt import CantileverProblem, MMAState, mma_update, oc_update

from .common import emit

ITERS = 15


def main():
    t0 = time.perf_counter()
    prob = CantileverProblem(nx=30, ny=15, lx=30.0, ly=15.0)
    rho = jnp.full((prob.n_elem,), 0.5)
    c0, _ = prob.compliance_and_sensitivity(rho)  # includes compile
    setup_s = time.perf_counter() - t0
    emit("topo_opt_setup", setup_s * 1e6, f"elements={prob.n_elem}")

    # sensitivity parity (paper's Eq. B.28 consistency check)
    g_ad = prob.compliance_and_sensitivity(rho)[1]
    g_an = prob.analytic_sensitivity(rho)
    rel = float(jnp.max(jnp.abs(g_ad - g_an) / (jnp.abs(g_an) + 1e-12)))
    emit("topo_opt_sens_parity", 0.0, f"ad_vs_analytic_relerr={rel:.2e}")

    # OC loop
    t0 = time.perf_counter()
    r = rho
    for _ in range(ITERS):
        c, g = prob.compliance_and_sensitivity(r)
        gf = prob.filter(g * r) / jnp.maximum(r, 1e-3)
        r = oc_update(r, gf, prob.volfrac)
    c_oc, _ = prob.compliance_and_sensitivity(r)
    loop_s = time.perf_counter() - t0
    emit(
        "topo_opt_oc_loop", loop_s * 1e6 / ITERS,
        f"iters={ITERS};compliance={float(c0):.1f}->{float(c_oc):.1f};vol={float(r.mean()):.3f}",
    )

    # MMA loop (the paper's optimizer)
    t0 = time.perf_counter()
    r = rho
    state = MMAState(low=r - 0.5, upp=r + 0.5)
    n = prob.n_elem
    for _ in range(ITERS):
        c, g = prob.compliance_and_sensitivity(r)
        gf = prob.filter(g * r) / jnp.maximum(r, 1e-3)
        r, state = mma_update(
            r, gf, jnp.asarray(float(r.mean()) - prob.volfrac),
            jnp.full((n,), 1.0 / n), state,
        )
    c_mma, _ = prob.compliance_and_sensitivity(r)
    loop_s = time.perf_counter() - t0
    emit(
        "topo_opt_mma_loop", loop_s * 1e6 / ITERS,
        f"iters={ITERS};compliance={float(c0):.1f}->{float(c_mma):.1f};vol={float(r.mean()):.3f}",
    )


if __name__ == "__main__":
    main()
