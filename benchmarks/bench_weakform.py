"""Fused multi-term weak-form assembly vs separate assemble + CSR add.

The composable-form claim: ``assemble(mass(c) + dt·diffusion(rho))`` traces
one Map + one Reduce, so it must be no slower (expected faster) than the
shim path ``M = assemble_mass(c); K = assemble_stiffness(rho); M + dt·K``.
Also measured: a three-term operator (diffusion + advection + mass) and the
mixed volume+Robin single-CSR assembly.  Derived column: speedup of the
fused path; JSON rows carry dofs/nnz for trend dashboards.
"""

import jax.numpy as jnp
import numpy as np

try:
    from .common import emit_json, time_fn
except ImportError:  # flat execution: python benchmarks/bench_weakform.py
    from common import emit_json, time_fn

from repro.core import (
    FacetAssembler,
    FunctionSpace,
    GalerkinAssembler,
    disk_tri,
    unit_square_tri,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh
from repro.transient.stepping import axpy_csr


def _theta_case(n, dt=1e-3):
    m = unit_square_tri(n)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    rho = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))

    form = wf.mass(c) + dt * wf.diffusion(rho)

    def fused():
        return asm.assemble(form).vals

    def separate():
        return axpy_csr(1.0, asm.assemble_mass(c), dt, asm.assemble_stiffness(rho)).vals

    np.testing.assert_allclose(
        np.asarray(fused()), np.asarray(separate()), atol=1e-12
    )
    t_fused = time_fn(fused)
    t_sep = time_fn(separate)
    emit_json(
        f"weakform_fused_theta_E{m.num_cells}", t_fused,
        f"separate_us={t_sep:.1f};speedup={t_sep / t_fused:.2f}x",
        dofs=space.num_dofs, nnz=asm.mat_routing.nnz,
        separate_us=round(t_sep, 1), n_terms=2,
    )


def _three_term_case(n):
    m = unit_square_tri(n)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rng = np.random.default_rng(1)
    rho = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    c = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    beta = jnp.array([1.0, 0.5])

    form = wf.diffusion(rho) + wf.advection(beta) + wf.mass(c)

    def fused():
        return asm.assemble(form).vals

    def separate():
        return (
            asm.assemble(wf.diffusion(rho)).vals
            + asm.assemble(wf.advection(beta)).vals
            + asm.assemble(wf.mass(c)).vals
        )

    t_fused = time_fn(fused)
    t_sep = time_fn(separate)
    emit_json(
        f"weakform_fused_advdiff_E{m.num_cells}", t_fused,
        f"separate_us={t_sep:.1f};speedup={t_sep / t_fused:.2f}x",
        dofs=space.num_dofs, nnz=asm.mat_routing.nnz,
        separate_us=round(t_sep, 1), n_terms=3,
    )


def _robin_case(n):
    m = disk_tri(n, center=(0.0, 0.0), radius=1.0)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    fa = FacetAssembler(space, m.boundary_facets(), volume_routing=asm.mat_routing)

    form = wf.diffusion() + wf.robin(1.0, on=fa)

    def fused():
        return asm.assemble(form).vals

    def separate():
        return fa.add_robin(asm.assemble_stiffness(), 1.0).vals

    t_fused = time_fn(fused)
    t_sep = time_fn(separate)
    emit_json(
        f"weakform_fused_robin_E{m.num_cells}", t_fused,
        f"separate_us={t_sep:.1f};speedup={t_sep / t_fused:.2f}x",
        dofs=space.num_dofs, nnz=asm.mat_routing.nnz,
        separate_us=round(t_sep, 1), n_terms=2,
    )


def main():
    for n in (32, 64, 128):
        _theta_case(n)
    _three_term_case(64)
    _robin_case(24)


if __name__ == "__main__":
    main()
