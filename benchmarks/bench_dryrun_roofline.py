"""Harness deliverable (g): the roofline table, read from the dry-run JSON
(run ``python -m repro.launch.dryrun --all`` first; this consumes its
output).  Emits one CSV row per (arch × shape × mesh) with the three terms
and the bottleneck; skips gracefully if no dry-run results exist."""

import json
import os

from .common import emit

RESULTS = os.environ.get(
    "DRYRUN_RESULTS",
    "dryrun_results_singlepod.json"
    if os.path.exists("dryrun_results_singlepod.json")
    else "dryrun_results.json",
)


def main():
    if not os.path.exists(RESULTS):
        emit("dryrun_roofline_missing", 0.0, f"run repro.launch.dryrun first ({RESULTS})")
        return
    rows = json.load(open(RESULTS))
    for r in rows:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] != "ok":
            emit(name, 0.0, f"status={r['status']}")
            continue
        emit(
            name,
            max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
            (
                f"bottleneck={r['bottleneck']};"
                f"t_comp={r['t_compute_s']:.2e};t_mem={r['t_memory_s']:.2e};"
                f"t_coll={r['t_collective_s']:.2e};"
                f"useful_flops={r['useful_flops_ratio']:.3f};"
                f"roofline_frac={r['roofline_fraction']:.3f}"
            ),
        )


if __name__ == "__main__":
    main()
