"""Batched multi-instance assembly vs a Python loop of single assembles.

The functional-core claim: ``assemble_batched`` maps B coefficient-sets (or
geometries) through ONE fused ``(B, E, ...)`` Map and one vmapped Reduce —
a single XLA executable with zero retraces across the batch — so it must
beat B sequential dispatches of the (already jit-cached) single-instance
path.  Acceptance: ≥3× at B=32.  Also measured: batched SIMP elasticity
(the multi-start scale slot) and the end-to-end batched condense+solve
pipeline.  JSON rows carry B/dofs/nnz and the measured speedup.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit_json, time_fn
except ImportError:  # flat execution: python benchmarks/bench_batched_assembly.py
    from common import emit_json, time_fn

from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    assemble,
    assemble_batched,
    sparse_solve_batched,
    unit_square_tri,
    weakform as wf,
)
from repro.core import assembly as asm_mod
from repro.core.mesh import element_for_mesh


def _coeff_batch_case(n, b=32):
    m = unit_square_tri(n)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rng = np.random.default_rng(0)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (b, m.num_cells)))
    form = wf.diffusion(rho_b[0]) + wf.mass(0.5)

    def batched():
        return assemble_batched(
            asm.plan, form, leaves_batch=(rho_b, None, None, None)
        ).vals

    def loop():
        return jnp.stack(
            [assemble(asm.plan, wf.diffusion(rho_b[i]) + wf.mass(0.5)).vals
             for i in range(b)]
        )

    np.testing.assert_allclose(
        np.asarray(batched()), np.asarray(loop()), atol=1e-12
    )
    # zero retraces across batch values (the executable is value-agnostic)
    n0 = asm_mod.n_core_traces()
    jax.block_until_ready(
        assemble_batched(asm.plan, form, leaves_batch=(2.0 * rho_b, None, None, None)).vals
    )
    retraces = asm_mod.n_core_traces() - n0
    assert retraces == 0, f"batched assembly retraced: {retraces}"

    t_batched = time_fn(batched)
    t_loop = time_fn(loop)
    emit_json(
        f"batched_assembly_B{b}_E{m.num_cells}", t_batched,
        f"loop_us={t_loop:.1f};speedup={t_loop / t_batched:.2f}x;retraces=0",
        batch=b, dofs=space.num_dofs, nnz=asm.mat_routing.nnz,
        loop_us=round(t_loop, 1), speedup=round(t_loop / t_batched, 2),
    )


def _simp_batch_case(n=16, b=8):
    from repro.opt import CantileverProblem

    prob = CantileverProblem(nx=n, ny=n // 2, lx=float(n), ly=float(n // 2))
    rng = np.random.default_rng(1)
    rho_b = jnp.asarray(rng.uniform(0.3, 0.9, (b, prob.n_elem)))

    def batched():
        return prob.compliance_batch(rho_b)

    def loop():
        return jnp.stack([prob.compliance(rho_b[i]) for i in range(b)])

    np.testing.assert_allclose(np.asarray(batched()), np.asarray(loop()), rtol=1e-9)
    t_batched = time_fn(batched)
    t_loop = time_fn(loop)
    emit_json(
        f"batched_simp_compliance_B{b}_E{prob.n_elem}", t_batched,
        f"loop_us={t_loop:.1f};speedup={t_loop / t_batched:.2f}x",
        batch=b, dofs=prob.space.num_dofs,
        loop_us=round(t_loop, 1), speedup=round(t_loop / t_batched, 2),
    )


def _family_solve_case(n=16, b=16):
    m = unit_square_tri(n)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    rng = np.random.default_rng(2)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (b, m.num_cells)))
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))

    def pipeline():
        kb = assemble_batched(asm.plan, wf.diffusion(rho_b[0]),
                              leaves_batch=(rho_b, None))
        return sparse_solve_batched(bc.apply_matrix_only(kb), f,
                                    "cg", 1e-10, 1e-10, 2000)

    t = time_fn(pipeline)
    emit_json(
        f"batched_assemble_solve_B{b}_E{m.num_cells}", t,
        f"per_instance_us={t / b:.1f}",
        batch=b, dofs=space.num_dofs, per_instance_us=round(t / b, 1),
    )


def main():
    _coeff_batch_case(12, b=32)
    _coeff_batch_case(24, b=32)
    _simp_batch_case()
    _family_solve_case()


if __name__ == "__main__":
    main()
