"""Element tensor-algebra layer: batched factorizations, static
condensation, EbE/Chebyshev preconditioners, and the redesigned
SolverSpec/preconditioner API that fronts them.

Verifies the PR's acceptance criteria directly: condensed solves match the
full system to 1e-10 on a strictly smaller interface system with strictly
fewer Krylov iterations; EbE and Chebyshev both beat Jacobi on the
anisotropic Poisson iteration counts without materializing any global
matrix; gradients through condensed and preconditioned matrix-free solves
match the assembled adjoint path to 1e-12; every solve entry point accepts
``spec=SolverSpec(...)`` while legacy kwargs still work under a
``DeprecationWarning``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    SolverSpec,
    block_partition,
    condensed_solve,
    dof_split,
    factorize,
    make_preconditioner,
    matfree_operator,
    matfree_solve,
    register_preconditioner,
    sparse_solve,
    unit_cube_tet,
    unit_square_tri,
    vertex_split,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh
from repro.core.solvers import _PRECONDITIONERS

RNG = np.random.default_rng(7)


def _poisson_op(n=12, degree=2, form=None):
    mesh = unit_square_tri(n)
    space = FunctionSpace(mesh, element_for_mesh(mesh, degree))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    form = wf.diffusion(1.0) if form is None else form
    op = matfree_operator(asm.plan, form).condensed(bc)
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    return space, asm, bc, op, f


def _aniso_setup(n=32):
    """P1 anisotropic Poisson — the preconditioner benchmark problem."""
    mesh = unit_square_tri(n)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 1))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    a = jnp.asarray(np.diag([100.0, 1.0]))
    op = matfree_operator(asm.plan, wf.anisotropic_diffusion(a)).condensed(bc)
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    return op, f


# ---------------------------------------------------------------------------
# batched dense kernels
# ---------------------------------------------------------------------------

def test_factorize_spd_and_lu_solve_element_batches():
    e, k = 17, 6
    q = RNG.standard_normal((e, k, k))
    spd = q @ np.swapaxes(q, 1, 2) + 3.0 * np.eye(k)
    gen = RNG.standard_normal((e, k, k)) + 4.0 * np.eye(k)
    rhs = jnp.asarray(RNG.standard_normal((e, k)))
    for mat, is_spd in ((spd, True), (gen, False)):
        fac = factorize(jnp.asarray(mat), spd=is_spd)
        x = fac.solve(rhs)
        ref = np.stack([np.linalg.solve(mat[i], np.asarray(rhs[i]))
                        for i in range(e)])
        np.testing.assert_allclose(np.asarray(x), ref, atol=1e-12)
        # multi-RHS route: (E, k, m)
        rhs2 = jnp.asarray(RNG.standard_normal((e, k, 3)))
        x2 = fac.solve(rhs2)
        ref2 = np.stack([np.linalg.solve(mat[i], np.asarray(rhs2[i]))
                         for i in range(e)])
        np.testing.assert_allclose(np.asarray(x2), ref2, atol=1e-12)


def test_block_partition_extracts_static_subblocks():
    k_e = jnp.asarray(RNG.standard_normal((5, 6, 6)))
    sub = block_partition(k_e, [0, 2], [1, 3, 5])
    assert sub.shape == (5, 2, 3)
    np.testing.assert_allclose(
        np.asarray(sub), np.asarray(k_e)[:, [0, 2]][:, :, [1, 3, 5]])
    sym = block_partition(k_e, [3, 4])
    np.testing.assert_allclose(np.asarray(sym),
                               np.asarray(k_e)[:, [3, 4]][:, :, [3, 4]])


def test_element_matrices_match_assembled_operator():
    _, asm, bc, op, _ = _poisson_op(6)
    k_e = op.element_matrices()
    # reduce the per-element tensors by hand and compare one matvec
    k = bc.apply_matrix_only(asm.assemble(wf.diffusion(1.0)))
    x = jnp.asarray(RNG.standard_normal(k.shape[0]))
    np.testing.assert_allclose(np.asarray(op.matvec(x)),
                               np.asarray(k.matvec(x)), atol=1e-12)
    assert k_e.shape[1] == k_e.shape[2] == op.static.cell_dofs.shape[1]


# ---------------------------------------------------------------------------
# static condensation
# ---------------------------------------------------------------------------

def test_condensation_parity_smaller_system_fewer_iters():
    space, asm, bc, op, f = _poisson_op(12, degree=2)
    u_full, info_full = matfree_solve(
        op, f, SolverSpec(method="cg", tol=1e-12, atol=1e-12, maxiter=10000),
        return_info=True)
    split = vertex_split(space)
    u_cond, info_cond = condensed_solve(
        op, f, SolverSpec(method="cg", tol=1e-12, atol=1e-12, maxiter=10000),
        split=split, return_info=True)
    # acceptance: strictly smaller global system …
    nb = int(np.asarray(split.interface_mask).sum())
    assert nb < space.num_dofs
    # … strictly fewer Krylov iterations …
    assert int(info_cond.iters) < int(info_full.iters)
    # … solution parity within 1e-10 (interface AND interior DOFs: the
    # interior recovery is exact up to the inner solve tolerance)
    assert float(jnp.max(jnp.abs(u_cond - u_full))) < 1e-10
    # the recovered full vector solves the original system
    r = float(jnp.linalg.norm(op.matvec(u_cond) - f))
    assert r < 1e-9


def test_condensation_exact_interior_recovery():
    """Interior unknowns come back through the element-wise K_ii solves:
    the interior residual rows of the recovered solution vanish to the
    inner solver tolerance, independently of the outer tolerance."""
    space, asm, bc, op, f = _poisson_op(8, degree=2)
    split = vertex_split(space)
    # loose outer solve: interface error is large, interior recovery must
    # still satisfy the interior equations for THAT interface solution
    u = condensed_solve(op, f, SolverSpec(method="cg", tol=1e-3, atol=1e-3),
                        split=split)
    res = op.matvec(u) - f
    interior = jnp.asarray(~split.interface_mask) & (op.free_mask > 0)
    assert float(jnp.max(jnp.abs(res * interior))) < 1e-9


def test_condensed_solve_p3_and_space_kwarg():
    mesh = unit_square_tri(6)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 3))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    op = matfree_operator(asm.plan, wf.diffusion(1.0)).condensed(bc)
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    u_cond = condensed_solve(op, f, space=space)
    u_full = matfree_solve(op, f, SolverSpec(method="cg", tol=1e-12,
                                             atol=1e-12))
    assert float(jnp.max(jnp.abs(u_cond - u_full))) < 1e-10


def test_dof_split_rejects_non_uniform_and_p1():
    mesh = unit_square_tri(4)
    p1 = FunctionSpace(mesh, element_for_mesh(mesh, 1))
    with pytest.raises(ValueError, match="degree"):
        vertex_split(p1)
    p2 = FunctionSpace(mesh, element_for_mesh(mesh, 2))
    bad = np.zeros(p2.num_dofs, dtype=bool)
    bad[0] = True  # one vertex DOF interface, the rest interior: not uniform
    with pytest.raises(ValueError, match="slot-uniform"):
        dof_split(p2.cell_dofs, bad)


# ---------------------------------------------------------------------------
# preconditioners: iteration-count regression + registry
# ---------------------------------------------------------------------------

def test_ebe_and_chebyshev_beat_jacobi_on_anisotropic_poisson():
    op, f = _aniso_setup(32)
    iters = {}
    for name in ("jacobi", "ebe", "chebyshev"):
        _, info = matfree_solve(
            op, f, SolverSpec(method="cg", tol=1e-10, atol=1e-10,
                              maxiter=10000, precond=name),
            return_info=True)
        assert bool(info.converged), name
        iters[name] = int(info.iters)
    assert iters["ebe"] < iters["jacobi"]
    assert iters["chebyshev"] < iters["jacobi"]


def test_preconditioned_solutions_agree():
    op, f = _aniso_setup(16)
    sols = {
        name: matfree_solve(op, f, SolverSpec(method="cg", tol=1e-12,
                                              atol=1e-12, precond=name))
        for name in ("jacobi", "ebe", "chebyshev", "identity")
    }
    ref = sols.pop("jacobi")
    for name, u in sols.items():
        assert float(jnp.max(jnp.abs(u - ref))) < 1e-9, name


def test_matrix_free_preconditioners_materialize_no_global_matrix():
    """EbE/Chebyshev carry only per-element factors / diagonal scalings —
    the operator_state_bytes gauge is untouched by building and applying
    them (no global (n,n) or CSR state appears)."""
    op, f = _aniso_setup(16)
    telemetry.enable()
    try:
        before = telemetry.snapshot()["gauges"]
        for name in ("ebe", "chebyshev"):
            m = make_preconditioner(op, name)
            m(f).block_until_ready()
        after = telemetry.snapshot()["gauges"]
        sb = [k for k in after if "operator_state_bytes" in k]
        for k in sb:
            assert before.get(k) == after[k]
    finally:
        telemetry.disable()


def test_preconditioner_registry_unknown_name_and_registration():
    op, _ = _aniso_setup(8)
    with pytest.raises(KeyError, match="jacobi"):
        make_preconditioner(op, "does-not-exist")
    calls = []

    def scaled_jacobi(a):
        calls.append(a)
        d = a.diagonal()
        return lambda x: x / jnp.maximum(d, 1e-30)

    register_preconditioner("scaled-jacobi-test", scaled_jacobi)
    try:
        m = make_preconditioner(op, "scaled-jacobi-test")
        assert calls and m(jnp.ones(op.static.num_dofs)).shape == (
            op.static.num_dofs,)
        with pytest.raises(ValueError, match="registered"):
            register_preconditioner("scaled-jacobi-test", scaled_jacobi)
        register_preconditioner("scaled-jacobi-test", scaled_jacobi,
                                overwrite=True)
    finally:
        _PRECONDITIONERS.pop("scaled-jacobi-test", None)
    # callables pass through as factories; None is the identity
    m2 = make_preconditioner(op, scaled_jacobi)
    assert callable(m2)
    ident = make_preconditioner(op, None)
    x = jnp.arange(4.0)
    np.testing.assert_array_equal(np.asarray(ident(x)), np.asarray(x))


def test_cached_diagonal_computed_once_per_operator_identity():
    from repro.core.sparse import _DIAGONALS, cached_diagonal

    op, _ = _aniso_setup(8)
    d1 = cached_diagonal(op)
    key_count = len(_DIAGONALS)
    d2 = cached_diagonal(op)
    assert d2 is d1  # memoized, not recomputed
    assert len(_DIAGONALS) == key_count
    np.testing.assert_allclose(np.asarray(d1), np.asarray(op.diagonal()),
                               atol=0)


# ---------------------------------------------------------------------------
# gradients: condensed + preconditioned adjoints match the assembled path
# ---------------------------------------------------------------------------

def test_grads_through_condensed_and_preconditioned_solves():
    mesh = unit_square_tri(8)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 2))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    split = vertex_split(space)
    rho0 = jnp.asarray(1.0 + 0.3 * RNG.random(space.num_dofs))
    tight = SolverSpec(method="cg", tol=1e-13, atol=1e-13, maxiter=20000)

    def loss_assembled(rho):
        k = bc.apply_matrix_only(asm.assemble(wf.diffusion(rho)))
        return jnp.sum(sparse_solve(k, f, tight) ** 2)

    def loss_condensed(rho):
        op = matfree_operator(asm.plan, wf.diffusion(rho)).condensed(bc)
        return jnp.sum(condensed_solve(op, f, tight, split=split) ** 2)

    def loss_precond(rho, name):
        op = matfree_operator(asm.plan, wf.diffusion(rho)).condensed(bc)
        return jnp.sum(matfree_solve(op, f, tight.replace(precond=name)) ** 2)

    g_ref = jax.grad(loss_assembled)(rho0)
    scale = float(jnp.max(jnp.abs(g_ref)))
    g_cond = jax.grad(loss_condensed)(rho0)
    assert float(jnp.max(jnp.abs(g_cond - g_ref))) < 1e-12 * max(1.0, scale)
    for name in ("ebe", "chebyshev"):
        g_p = jax.grad(loss_precond)(rho0, name)
        assert float(jnp.max(jnp.abs(g_p - g_ref))) < 1e-12 * max(1.0, scale)


def test_ebe_lu_route_on_nonsymmetric_form():
    """Advection makes the form non-SPD: the EbE factors must take the LU
    route and the preconditioned BiCGStab still converges to the reference."""
    mesh = unit_square_tri(12)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 1))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    form = wf.diffusion(0.05) + wf.advection(jnp.asarray([1.0, 0.3]))
    op = matfree_operator(asm.plan, form).condensed(bc)
    assert not op.is_spd()
    f = bc.project_residual(asm.assemble_rhs(wf.source(1.0)))
    u, info = matfree_solve(
        op, f, SolverSpec(method="bicgstab", tol=1e-11, atol=1e-11,
                          precond="ebe"), return_info=True)
    assert bool(info.converged)
    k = bc.apply_matrix_only(asm.assemble(form))
    u_ref = sparse_solve(k, f, SolverSpec(method="bicgstab", tol=1e-12,
                                          atol=1e-12))
    assert float(jnp.max(jnp.abs(u - u_ref))) < 1e-8


def test_preconditioners_on_3d_and_vector_spaces():
    mesh = unit_cube_tet(5)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 1), value_size=3)
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    op = matfree_operator(asm.plan, wf.elasticity(1.0, 0.4)).condensed(bc)
    f = bc.project_residual(
        asm.assemble_rhs(wf.source(jnp.asarray([0.0, 0.0, -1.0]))))
    for name in ("ebe", "chebyshev"):
        u, info = matfree_solve(
            op, f, SolverSpec(method="cg", tol=1e-10, atol=1e-10,
                              precond=name), return_info=True)
        assert bool(info.converged), name
        assert float(jnp.linalg.norm(op.matvec(u) - f)) < 1e-8


# ---------------------------------------------------------------------------
# SolverSpec API: acceptance + legacy deprecation shims
# ---------------------------------------------------------------------------

def test_solver_spec_frozen_hashable_replace():
    s = SolverSpec(method="cg", tol=1e-8, precond="ebe")
    assert s == SolverSpec(method="cg", tol=1e-8, precond="ebe")
    assert hash(s) == hash(SolverSpec(method="cg", tol=1e-8, precond="ebe"))
    assert s.replace(precond="jacobi").precond == "jacobi"
    assert s.replace(precond="jacobi") != s
    with pytest.raises((AttributeError, TypeError)):
        s.tol = 1.0
    d = {s: 1, s.replace(maxiter=5): 2}
    assert len(d) == 2


def test_legacy_kwargs_warn_and_match_spec():
    op, f = _aniso_setup(8)
    spec = SolverSpec(method="cg", tol=1e-11, atol=1e-11, maxiter=5000)
    u_spec = matfree_solve(op, f, spec)
    with pytest.warns(DeprecationWarning, match="SolverSpec"):
        u_legacy = matfree_solve(op, f, "cg", 1e-11, 1e-11, 5000)
    np.testing.assert_array_equal(np.asarray(u_spec), np.asarray(u_legacy))
    with pytest.warns(DeprecationWarning):
        u_kw = matfree_solve(op, f, method="cg", tol=1e-11, atol=1e-11,
                             maxiter=5000)
    np.testing.assert_array_equal(np.asarray(u_spec), np.asarray(u_kw))
    with pytest.raises(TypeError, match="SolverSpec"):
        matfree_solve(op, f, 1e-10)  # junk in the spec slot
    with pytest.raises(TypeError):
        matfree_solve(op, f, "cg", method="bicgstab")  # double method


def test_problem_solve_and_integrators_accept_spec():
    from repro.fem.tensormesh import PoissonProblem
    from repro.transient import ThetaIntegrator

    p = PoissonProblem(unit_square_tri(8))
    u1 = p.solve(spec=SolverSpec(method="cg", tol=1e-11, atol=1e-11))
    with pytest.warns(DeprecationWarning):
        u2 = p.solve(tol=1e-11)
    np.testing.assert_allclose(np.asarray(u1.u), np.asarray(u2.u), atol=1e-12)

    mesh = unit_square_tri(6)
    space = FunctionSpace(mesh, element_for_mesh(mesh, 1))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    mass = asm.assemble(wf.mass(1.0))
    stiff = asm.assemble(wf.diffusion(1.0))
    u0 = jnp.asarray(RNG.standard_normal(space.num_dofs)) * bc.free_mask
    integ = ThetaIntegrator(mass, stiff, dt=0.01, bc=bc,
                            spec=SolverSpec(method="cg", tol=1e-12,
                                            atol=1e-12))
    traj = integ.rollout(u0, 3)
    with pytest.warns(DeprecationWarning):
        integ_legacy = ThetaIntegrator(mass, stiff, dt=0.01, bc=bc,
                                       solver="cg", tol=1e-12)
    traj_legacy = integ_legacy.rollout(u0, 3)
    np.testing.assert_allclose(np.asarray(traj), np.asarray(traj_legacy),
                               atol=1e-12)
    # resolved mirrors stay readable for downstream consumers
    assert integ_legacy.solver == "cg" and integ_legacy.tol == 1e-12


def test_serve_admission_key_carries_spec():
    from repro.serve.batching import SolveRequest, admission_key
    from repro.serve.client import _poisson_workload

    plan, bc, rhs = _poisson_workload(6)
    rho = np.full(plan.static.scalar_cell_dofs.shape[0], 1.0)
    mk = lambda **kw: SolveRequest(  # noqa: E731
        plan=plan, form=wf.diffusion(rho), rhs=rhs, bc=bc, **kw)
    base = mk(spec=SolverSpec(method="cg", tol=1e-10, atol=1e-10))
    same = mk(spec=SolverSpec(method="cg", tol=1e-10, atol=1e-10))
    other = mk(spec=SolverSpec(method="cg", tol=1e-10, atol=1e-10,
                               precond="ebe"))
    assert admission_key(base) == admission_key(same)
    assert admission_key(base) != admission_key(other)
    assert isinstance(admission_key(base)[-1], SolverSpec)
    with pytest.warns(DeprecationWarning):
        legacy = mk(method="cg", tol=1e-10)
    assert legacy.spec.method == "cg" and legacy.tol == 1e-10


def test_solve_records_precond_in_telemetry():
    op, f = _aniso_setup(8)
    telemetry.enable()
    try:
        telemetry.events.clear_events()
        matfree_solve(op, f, SolverSpec(method="cg", precond="chebyshev"),
                      return_info=True)
        evs = [e for e in telemetry.events.event_log()
               if e.get("kind") == "solve"]
        assert any(e.get("precond") == "chebyshev" for e in evs)
    finally:
        telemetry.disable()
