"""Functional assembly core: AssemblyPlan, batched multi-instance assembly,
BatchedCSR / batched sparse_solve, dtype + deprecation regressions."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    AssemblyPlan,
    BatchedCSR,
    DirichletCondenser,
    FacetAssembler,
    FunctionSpace,
    GalerkinAssembler,
    assemble,
    assemble_batched,
    assemble_rhs,
    assemble_rhs_batched,
    disk_tri,
    sparse_solve,
    sparse_solve_batched,
    unit_square_tri,
    weakform as wf,
)
from repro.core import assembly as asm_mod
from repro.core.mesh import element_for_mesh


def _setup(n=6, mesh_fn=unit_square_tri, **kw):
    m = mesh_fn(n)
    space = FunctionSpace(m, element_for_mesh(m), **kw)
    return m, space, GalerkinAssembler(space)


# ---------------------------------------------------------------------------
# the plan: pytree structure + pure functions == facade
# ---------------------------------------------------------------------------

def test_plan_is_pytree_with_single_coords_leaf():
    m, space, asm = _setup(4)
    plan = asm.plan
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    assert len(leaves) == 1 and leaves[0] is plan.coords
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(plan2, AssemblyPlan)
    assert plan2.static is plan.static  # aux shared by identity
    # a plan crosses jit as an argument (coords traced, static hashed)
    vals = jax.jit(lambda p: assemble(p, wf.diffusion()).vals)(plan)
    np.testing.assert_allclose(
        np.asarray(vals), np.asarray(asm.assemble(wf.diffusion()).vals), atol=1e-15
    )


def test_pure_assemble_matches_facade():
    m, space, asm = _setup(6)
    rng = np.random.default_rng(0)
    rho = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    form = wf.diffusion(rho) + wf.mass(0.3)
    np.testing.assert_array_equal(
        np.asarray(assemble(asm.plan, form).vals),
        np.asarray(asm.assemble(form).vals),
    )
    rhs = wf.source(lambda x: x[..., 0])
    np.testing.assert_array_equal(
        np.asarray(assemble_rhs(asm.plan, rhs)),
        np.asarray(asm.assemble_rhs(rhs)),
    )


def test_plan_coords_differentiable():
    m, space, asm = _setup(4)

    def vol(coords):  # ∫ 1 dx via the mass matrix row sums
        k = assemble(asm.plan.with_coords(coords), wf.mass())
        return jnp.sum(k.vals)

    g = jax.grad(vol)(asm.plan.coords)
    assert np.all(np.isfinite(np.asarray(g)))
    eps = 1e-6
    c = asm.plan.coords
    fd = (vol(c.at[3, 0, 0].add(eps)) - vol(c.at[3, 0, 0].add(-eps))) / (2 * eps)
    np.testing.assert_allclose(float(g[3, 0, 0]), float(fd), rtol=1e-6, atol=1e-9)


# ---------------------------------------------------------------------------
# batched assembly: exact parity with stacked single assembles
# ---------------------------------------------------------------------------

def test_batched_coefficients_match_stacked_singles():
    m, space, asm = _setup(8)
    rng = np.random.default_rng(1)
    b = 5
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (b, m.num_cells)))
    kb = assemble_batched(asm.plan, wf.diffusion(rho_b[0]),
                          leaves_batch=(rho_b, None))
    assert isinstance(kb, BatchedCSR) and kb.vals.shape == (b, kb.nnz)
    stacked = jnp.stack(
        [assemble(asm.plan, wf.diffusion(rho_b[i])).vals for i in range(b)]
    )
    np.testing.assert_allclose(np.asarray(kb.vals), np.asarray(stacked), atol=1e-12)


def test_batched_geometries_match_stacked_singles():
    m, space, asm = _setup(6)
    b = 4
    coords_b = jnp.stack([asm.plan.coords * (1.0 + 0.05 * i) for i in range(b)])
    kb = assemble_batched(asm.plan, wf.diffusion() + wf.mass(0.5),
                          coords_batch=coords_b)
    stacked = jnp.stack(
        [assemble(asm.plan, wf.diffusion() + wf.mass(0.5), coords=coords_b[i]).vals
         for i in range(b)]
    )
    np.testing.assert_allclose(np.asarray(kb.vals), np.asarray(stacked), atol=1e-12)


def test_batched_rhs_and_mixed_batching():
    m, space, asm = _setup(6)
    rng = np.random.default_rng(2)
    b = 3
    f_b = jnp.asarray(rng.uniform(-1.0, 1.0, (b, m.num_cells)))
    fb = assemble_rhs_batched(asm.plan, wf.source(f_b[0]), leaves_batch=(f_b, None))
    assert fb.shape == (b, space.num_dofs)
    stacked = jnp.stack([assemble_rhs(asm.plan, wf.source(f_b[i])) for i in range(b)])
    np.testing.assert_allclose(np.asarray(fb), np.asarray(stacked), atol=1e-13)
    # bare-array convenience batches the first traced slot
    fb2 = assemble_rhs_batched(asm.plan, wf.source(f_b[0]), leaves_batch=f_b)
    np.testing.assert_array_equal(np.asarray(fb), np.asarray(fb2))


def test_batched_assembly_validates_inputs():
    m, space, asm = _setup(4)
    with pytest.raises(ValueError, match="nothing is batched"):
        assemble_batched(asm.plan, wf.diffusion(1.0))
    with pytest.raises(ValueError, match="slots"):
        assemble_batched(asm.plan, wf.diffusion(1.0) + wf.mass(1.0),
                         leaves_batch=(jnp.ones((2, m.num_cells)),))
    with pytest.raises(ValueError, match="batch sizes"):
        assemble_batched(asm.plan, wf.diffusion(jnp.ones(m.num_cells)),
                         coords_batch=jnp.stack([asm.plan.coords] * 2),
                         leaves_batch=(jnp.ones((3, m.num_cells)), None))
    fa = FacetAssembler(space, m.boundary_facets(), volume_routing=asm.mat_routing)
    with pytest.raises(NotImplementedError, match="volume terms only"):
        assemble_batched(asm.plan, wf.diffusion() + wf.robin(1.0, on=fa),
                         coords_batch=jnp.stack([asm.plan.coords] * 2))


def test_batched_assembly_zero_retraces_across_values():
    """One trace serves the whole batch loop: new coefficient *values* (and
    new batched coords values) must not retrace the functional core."""
    m, space, asm = _setup(7)
    b = 3
    rho_b = jnp.ones((b, m.num_cells))
    form = wf.mass(1.0) + 0.1 * wf.diffusion(rho_b[0])
    lb = (None, None, rho_b, None)
    assemble_batched(asm.plan, form, leaves_batch=lb)      # trace once
    n0 = asm_mod.n_core_traces()
    for i in range(4):
        assemble_batched(asm.plan, form, leaves_batch=(None, None, rho_b * (i + 2), None))
    assert asm_mod.n_core_traces() == n0, "batched assembly retraced on new values"


# ---------------------------------------------------------------------------
# BatchedCSR ops + vmapped differentiable solve
# ---------------------------------------------------------------------------

def _family(n=6, b=4, seed=3):
    m, space, asm = _setup(n)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    rng = np.random.default_rng(seed)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (b, m.num_cells)))
    kb = assemble_batched(asm.plan, wf.diffusion(rho_b[0]),
                          leaves_batch=(rho_b, None))
    f = bc.project_residual(assemble_rhs(asm.plan, wf.source(1.0)))
    return asm, bc, rho_b, bc.apply_matrix_only(kb), f


def test_batched_csr_ops_match_per_instance():
    asm, bc, rho_b, kc, f = _family()
    assert isinstance(kc, BatchedCSR)  # condensation preserves the container
    x = jnp.asarray(np.random.default_rng(4).uniform(-1, 1, (kc.batch, kc.shape[0])))
    y = kc.matvec(x)
    for i in range(kc.batch):
        np.testing.assert_allclose(
            np.asarray(y[i]), np.asarray(kc[i].matvec(x[i])), atol=1e-14
        )
    np.testing.assert_allclose(
        np.asarray(kc.diagonal()[1]), np.asarray(kc[1].diagonal()), atol=1e-14
    )
    np.testing.assert_allclose(
        np.asarray(kc.to_dense()[2]), np.asarray(kc[2].to_dense()), atol=1e-14
    )
    restacked = BatchedCSR.stack([kc[i] for i in range(kc.batch)])
    np.testing.assert_array_equal(np.asarray(restacked.vals), np.asarray(kc.vals))
    # slicing returns a sub-family, not a malformed CSR
    sub = kc[1:3]
    assert isinstance(sub, BatchedCSR) and sub.batch == 2
    np.testing.assert_array_equal(np.asarray(sub.matvec(x[1:3])), np.asarray(y[1:3]))
    with pytest.raises(TypeError, match="int or slice"):
        kc[[0, 1]]


def test_batched_csr_stack_rejects_mismatched_patterns():
    _, _, _, k_a, _ = _family(n=5)
    _, _, _, k_b, _ = _family(n=6)
    with pytest.raises(ValueError, match="patterns differ"):
        BatchedCSR.stack([k_a[0], k_b[0]])


def test_plan_identity_eq_and_hash():
    m, space, asm = _setup(4)
    p = asm.plan
    assert p == p and hash(p) == hash(p)
    assert p != p.with_coords(p.coords * 2.0)  # identity semantics, no raise


def test_form_executable_cache_is_fifo_bounded():
    """Per-call lambda coefficients mint fresh signatures; the executable
    cache must evict instead of growing without limit."""
    m, space, asm = _setup(4)
    limit = asm_mod._FORM_FNS_LIMIT
    asm_mod._FORM_FNS_LIMIT = 4
    try:
        for i in range(10):
            assemble_rhs(asm.plan, wf.source(lambda x, i=i: x[..., 0] + i))
        assert len(asm_mod._FORM_FNS) <= 4
    finally:
        asm_mod._FORM_FNS_LIMIT = limit


def test_facade_and_pure_api_share_one_executable():
    """Mixing asm.assemble(form) and assemble(plan, form) on one signature
    must not compile twice (the facade delegates to the module jit cache)."""
    m, space, asm = _setup(7)
    rho = jnp.asarray(np.random.default_rng(10).uniform(0.5, 2.0, m.num_cells))
    form = wf.diffusion(rho) + wf.advection(jnp.array([0.3, 0.9]))
    assemble(asm.plan, form)                      # traces the core once
    n0, t0 = asm_mod.n_core_traces(), asm.n_traces
    k = asm.assemble(form)                        # facade: cache hit, no trace
    assert asm_mod.n_core_traces() == n0
    assert asm.n_traces == t0
    np.testing.assert_array_equal(
        np.asarray(k.vals), np.asarray(assemble(asm.plan, form).vals)
    )


def test_sparse_solve_batched_matches_per_instance():
    asm, bc, rho_b, kc, f = _family()
    u_b = sparse_solve_batched(kc, f, "cg", 1e-12, 1e-12, 2000)
    for i in range(kc.batch):
        u_i = sparse_solve(kc[i], f, "cg", 1e-12, 1e-12, 2000)
        np.testing.assert_allclose(np.asarray(u_b[i]), np.asarray(u_i), atol=1e-10)


def test_vmap_grad_through_sparse_solve_on_batched_csr():
    """vmap(grad(...)) through the adjoint solve over a BatchedCSR family:
    per-instance coefficient gradients in one executable, checked vs FD."""
    asm, bc, rho_b, _, f = _family(n=5, b=3)

    def loss_one(rho):
        k = bc.apply_matrix_only(assemble(asm.plan, wf.diffusion(rho)))
        u = sparse_solve(k, f, "cg", 1e-12, 1e-12, 2000)
        return jnp.sum(u**2)

    def loss_batched(rho_b):
        kb = bc.apply_matrix_only(
            assemble_batched(asm.plan, wf.diffusion(rho_b[0]),
                             leaves_batch=(rho_b, None))
        )
        u = sparse_solve_batched(kb, f, "cg", 1e-12, 1e-12, 2000)
        return jnp.sum(u**2, axis=-1)

    g_b = jax.vmap(jax.grad(loss_one))(rho_b)
    assert np.all(np.isfinite(np.asarray(g_b)))
    # per-instance gradient of the batched pipeline (vjp rows) agrees
    _, vjp = jax.vjp(loss_batched, rho_b)
    (g_rows,) = vjp(jnp.ones(rho_b.shape[0]))
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_rows), rtol=1e-8,
                               atol=1e-10)
    i = int(np.argmax(np.abs(np.asarray(g_b[0]))))
    eps = 1e-6
    fd = (loss_one(rho_b[0].at[i].add(eps)) - loss_one(rho_b[0].at[i].add(-eps))) / (2 * eps)
    np.testing.assert_allclose(float(g_b[0, i]), float(fd), rtol=1e-4)


# ---------------------------------------------------------------------------
# downstream batched consumers
# ---------------------------------------------------------------------------

def test_batched_theta_rollout_matches_per_instance():
    from repro.transient import CRANK_NICOLSON, ThetaIntegrator, batched_theta_rollout

    m, space, asm = _setup(5)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    rng = np.random.default_rng(5)
    b, dt, theta, steps = 3, 1e-2, CRANK_NICOLSON, 4
    kappa_b = jnp.asarray(rng.uniform(0.5, 2.0, (b, m.num_cells)))
    lb = (None, None, kappa_b, None)
    lhs = assemble_batched(asm.plan, wf.mass(1.0) + (theta * dt) * wf.diffusion(kappa_b[0]),
                           leaves_batch=lb)
    rhs = assemble_batched(
        asm.plan, wf.mass(1.0) + (-(1.0 - theta) * dt) * wf.diffusion(kappa_b[0]),
        leaves_batch=lb,
    )
    u0_b = jnp.asarray(rng.uniform(-1, 1, (b, space.num_dofs))) * jnp.asarray(bc.free_mask)
    trajs = batched_theta_rollout(lhs, rhs, u0_b, steps, dt=dt, theta=theta, bc=bc)
    assert trajs.shape == (b, steps, space.num_dofs)
    for i in range(b):
        integ = ThetaIntegrator.from_form(asm, wf.diffusion(kappa_b[i]), dt=dt,
                                          theta=theta, mass_coeff=1.0, bc=bc)
        ref = integ.rollout(u0_b[i], steps)
        np.testing.assert_allclose(np.asarray(trajs[i]), np.asarray(ref), atol=1e-12)


def test_poisson_solve_coeff_batch_matches_single_solves():
    from repro.fem import PoissonProblem

    prob = PoissonProblem(unit_square_tri(8))
    rng = np.random.default_rng(6)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (3, prob.mesh.num_cells)))
    u_b = prob.solve_coeff_batch(rho_b)
    for i in range(3):
        res = prob.solve(rho=rho_b[i])
        np.testing.assert_allclose(np.asarray(u_b[i]), np.asarray(res.u), atol=1e-8)


def test_batched_galerkin_residual_loss_matches_single():
    from repro.pils import BatchedGalerkinResidualLoss, GalerkinResidualLoss

    m, space, asm = _setup(6)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    rng = np.random.default_rng(7)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (3, m.num_cells)))
    loss_b = BatchedGalerkinResidualLoss(asm, bc, rho_b)
    u_b = jnp.asarray(rng.uniform(-1, 1, (3, space.num_dofs)))
    singles = [GalerkinResidualLoss(asm, bc, rho=rho_b[i]) for i in range(3)]
    want = np.mean([float(s(u_b[i])) for i, s in enumerate(singles)])
    np.testing.assert_allclose(float(loss_b(u_b)), want, rtol=1e-12)
    # direct family solve zeroes the family residual
    u_star = loss_b.solve()
    assert float(loss_b(u_star)) < 1e-16


def test_fit_family_trains_toward_direct_solves():
    from repro.pils import fit_family

    m, space, asm = _setup(5)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    rng = np.random.default_rng(11)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (3, m.num_cells)))
    u_fit, hist, its, loss = fit_family(asm, bc, rho_b, steps=800, lr=5e-2)
    assert u_fit.shape == (3, space.num_dofs)
    assert float(loss(u_fit)) < 1e-4  # family residual driven toward zero
    u_star = loss.solve()
    rel = float(jnp.linalg.norm(u_fit - u_star) / jnp.linalg.norm(u_star))
    assert rel < 0.05, rel
    # hard-constrained net loss: zero net + zero Dirichlet data has residual
    # equal to the plain ||F||² family loss
    zero_net = lambda p, x: jnp.zeros((x.shape[0], 1))
    val = loss.loss_from_net(zero_net, jnp.zeros((3, 1)))
    want = loss(jnp.zeros((3, space.num_dofs)))
    np.testing.assert_allclose(float(val), float(want), rtol=1e-12)


def test_simp_compliance_batch_matches_single():
    from repro.opt import CantileverProblem

    prob = CantileverProblem(nx=10, ny=5, lx=10.0, ly=5.0)
    rng = np.random.default_rng(8)
    rho_b = jnp.asarray(rng.uniform(0.3, 0.9, (2, prob.n_elem)))
    c_b = prob.compliance_batch(rho_b)
    c_sens, g_b = prob.compliance_and_sensitivity_batch(rho_b)
    np.testing.assert_allclose(np.asarray(c_b), np.asarray(c_sens), rtol=1e-12)
    for i in range(2):
        c_i, g_i = prob.compliance_and_sensitivity(rho_b[i])
        np.testing.assert_allclose(float(c_b[i]), float(c_i), rtol=1e-9)
        np.testing.assert_allclose(np.asarray(g_b[i]), np.asarray(g_i), rtol=1e-6,
                                   atol=1e-8)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_facet_only_form_preserves_input_dtype():
    """The all-facet zero fallback must derive its dtype from the traced
    inputs — a float32 plan/facet geometry must not upcast to float64."""
    m = disk_tri(6, center=(0.0, 0.0), radius=1.0)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    fa = FacetAssembler(space, m.boundary_facets(), volume_routing=asm.mat_routing)
    fa32 = FacetAssembler(space, m.boundary_facets(), volume_routing=asm.mat_routing)
    for name in ("coords", "w", "phi", "gradhat"):
        setattr(fa32, name, getattr(fa32, name).astype(jnp.float32))
    plan32 = asm.plan.with_coords(asm.plan.coords.astype(jnp.float32))

    k32 = assemble(plan32, wf.robin(jnp.float32(1.0), on=fa32))
    assert k32.vals.dtype == jnp.float32, k32.vals.dtype
    f32 = assemble_rhs(plan32, wf.neumann(jnp.float32(1.0), on=fa32))
    assert f32.dtype == jnp.float32, f32.dtype

    # float64 facet values stay float64 and exact
    k64 = assemble(asm.plan, wf.robin(1.0, on=fa))
    assert k64.vals.dtype == jnp.float64
    np.testing.assert_allclose(
        np.asarray(k64.vals), np.asarray(k32.vals), atol=1e-6
    )


def test_deprecated_shims_warn_and_match_form_api():
    m, space, asm = _setup(5)
    rho = jnp.asarray(np.random.default_rng(9).uniform(0.5, 2.0, m.num_cells))
    with pytest.warns(DeprecationWarning, match="assemble_stiffness"):
        k_shim = asm.assemble_stiffness(rho)
    np.testing.assert_array_equal(
        np.asarray(k_shim.vals), np.asarray(asm.assemble(wf.diffusion(rho)).vals)
    )
    with pytest.warns(DeprecationWarning, match="assemble_mass"):
        asm.assemble_mass()
    with pytest.warns(DeprecationWarning, match="assemble_load"):
        f_shim = asm.assemble_load(2.0)
    np.testing.assert_array_equal(
        np.asarray(f_shim), np.asarray(asm.assemble_rhs(wf.source(2.0)))
    )
    with pytest.warns(DeprecationWarning, match="assemble_reaction_load"):
        asm.assemble_reaction_load(jnp.ones(space.num_dofs), jnp.tanh)
    m2, space2, asm2 = _setup(4, value_size=2)
    with pytest.warns(DeprecationWarning, match="assemble_elasticity"):
        k_el = asm2.assemble_elasticity(1.0, 1.0)
    np.testing.assert_array_equal(
        np.asarray(k_el.vals),
        np.asarray(asm2.assemble(wf.elasticity(1.0, 1.0)).vals),
    )


def test_routing_device_mirrors_are_prestaged():
    m, space, asm = _setup(4)
    r = asm.mat_routing
    for name in ("perm_dev", "seg_ids_dev", "seg_ids_unsorted_dev"):
        arr = getattr(r, name)
        assert isinstance(arr, jnp.ndarray)
    np.testing.assert_array_equal(np.asarray(r.perm_dev), r.perm)
    v = asm.vec_routing
    assert isinstance(v.touched_dev, jnp.ndarray)
    # frozen dataclass round-trip (replace) recomputes the mirrors
    r2 = dataclasses.replace(r)
    assert isinstance(r2.perm_dev, jnp.ndarray)
