"""End-to-end driver tests: training runs, checkpoints, and auto-resumes
after a simulated failure (the fault-tolerance requirement)."""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.launch.train import main as train_main  # noqa: E402


def test_train_loss_decreases(tmp_path):
    loss = train_main([
        "--arch", "qwen3-4b", "--smoke",
        "--steps", "30", "--seq-len", "64", "--batch", "8",
        "--log-every", "29",
    ])
    assert np.isfinite(loss)
    assert loss < 5.7  # ln(256) ≈ 5.55 at init + margin; motifs learn fast


def test_train_resume_after_kill(tmp_path):
    """Run 20 steps with checkpoints, 'crash', relaunch → must resume from
    the checkpoint (not step 0) and finish at the same final step count."""
    ckpt = str(tmp_path / "ck")
    args = [
        "--arch", "qwen3-4b", "--smoke",
        "--seq-len", "64", "--batch", "8",
        "--ckpt-dir", ckpt, "--ckpt-every", "10", "--log-every", "100",
    ]
    train_main(args + ["--steps", "20"])
    from repro.checkpoint import CheckpointManager

    assert CheckpointManager(ckpt).latest_step() == 20
    # relaunch with more steps: resumes at 20, continues to 35
    loss = train_main(args + ["--steps", "35"])
    assert CheckpointManager(ckpt).latest_step() == 35
    assert np.isfinite(loss)


def test_sharded_vs_single_device_loss_close(tmp_path):
    """The same seed/config must give (near-)identical first-step loss on a
    1-device and a 2x4 sharded mesh (GSPMD correctness check)."""
    l1 = train_main([
        "--arch", "qwen3-4b", "--smoke", "--steps", "3",
        "--seq-len", "64", "--batch", "8", "--log-every", "100",
    ])
    l2 = train_main([
        "--arch", "qwen3-4b", "--smoke", "--steps", "3",
        "--seq-len", "64", "--batch", "8", "--log-every", "100",
        "--data-axis", "4", "--model-axis", "2",
    ])
    assert abs(l1 - l2) < 0.15, (l1, l2)  # bf16 reduction-order tolerance
