"""Pallas Sparse-Reduce kernel vs the reduce_matrix oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401
from repro.core import FunctionSpace, GalerkinAssembler, unit_square_tri, unit_cube_tet
from repro.core.assembly import reduce_matrix
from repro.core.mesh import element_for_mesh
from repro.kernels.seg_reduce import build_padded_reduce, seg_reduce


@pytest.mark.parametrize("mesh_fn,n", [(unit_square_tri, 8), (unit_cube_tet, 4)])
def test_seg_reduce_matches_reduce_matrix(mesh_fn, n):
    m = mesh_fn(n)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rng = np.random.default_rng(0)
    k_local = jnp.asarray(
        rng.normal(size=(m.num_cells, space.local_dofs, space.local_dofs))
    )
    want = reduce_matrix(k_local, asm.mat_routing)
    idx = build_padded_reduce(asm.mat_routing)
    got = seg_reduce(k_local, idx, interpret=True, block_n=512)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


def test_seg_reduce_full_assembly_equivalence():
    """Kernel Map (local_assembly) + kernel Reduce (seg_reduce) == assembler."""
    from repro.kernels import batch_map_stiffness

    m = unit_cube_tet(3)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rho = jnp.asarray(np.random.default_rng(1).uniform(0.5, 2.0, m.num_cells))
    want = asm.assemble_stiffness(rho).vals
    k_local = batch_map_stiffness(asm.coords, rho, interpret=True)
    idx = build_padded_reduce(asm.mat_routing)
    got = seg_reduce(k_local, idx, interpret=True, block_n=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)
