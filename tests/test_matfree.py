"""Matrix-free operator subsystem + unified matvec-backend registry.

Covers the PR-4 acceptance criteria: apply parity vs the assembled CSR
matvec (≤1e-12) across ALL element types, grad-vs-FD and grad-vs-adjoint
through matrix-free solves, the zero-retrace property on coefficient value
updates, the condensed (Dirichlet) apply, the registry dispatch incl. the
fused Pallas residual, and the deprecation shims of the old
``transient.stepping`` dispatch names.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CSR,
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    MATVEC_BACKENDS,
    assemble,
    assemble_rhs,
    build_plan,
    make_matvec,
    make_residual,
    matfree_operator,
    SolverSpec,
    matfree_solve,
    n_matfree_traces,
    sparse_solve,
    unit_cube_hex,
    unit_cube_tet,
    unit_square_tri,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh, rectangle_quad
from repro.core.operator import _apply_jit  # noqa: F401 (retrace counter target)

RNG = np.random.default_rng(0)
_SPEC = SolverSpec(method="cg", tol=1e-12, atol=1e-12, maxiter=10000)


def _space(mesh, degree=1, value_size=1):
    return FunctionSpace(mesh, element_for_mesh(mesh, degree), value_size)


CASES = {
    "P1_tri": lambda: _space(unit_square_tri(6)),
    "P2_tri": lambda: _space(unit_square_tri(4), degree=2),
    "P1_tet": lambda: _space(unit_cube_tet(3)),
    "Q1_quad": lambda: _space(rectangle_quad(5, 4, 1.0, 1.0)),
    "Q1_hex": lambda: _space(unit_cube_hex(3)),
}


# ---------------------------------------------------------------------------
# apply parity across element types and storage strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("element", sorted(CASES))
@pytest.mark.parametrize("store", ["coords", "context", "local"])
def test_apply_parity_all_elements(element, store):
    space = CASES[element]()
    assert space.element.name == element
    plan = build_plan(space)
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, space.mesh.num_cells))
    form = wf.diffusion(rho) + 0.3 * wf.mass()
    k = assemble(plan, form)
    op = matfree_operator(plan, form, store=store)
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    np.testing.assert_allclose(
        np.asarray(op.matvec(x)), np.asarray(k.matvec(x)), atol=1e-12
    )
    np.testing.assert_allclose(
        np.asarray(op.diagonal()), np.asarray(k.diagonal()), atol=1e-12
    )


def test_rmatvec_parity_nonsymmetric():
    space = CASES["P1_tri"]()
    plan = build_plan(space)
    form = wf.diffusion() + wf.advection(jnp.array([1.0, 0.5]))
    k = assemble(plan, form)
    op = matfree_operator(plan, form)
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    np.testing.assert_allclose(
        np.asarray(op.rmatvec(x)), np.asarray(k.rmatvec(x)), atol=1e-12
    )


def test_anisotropic_action_parity():
    space = CASES["P1_tri"]()
    plan = build_plan(space)
    a = jnp.array([[2.0, 0.5], [0.3, 1.0]])  # nonsymmetric tensor coeff
    form = wf.anisotropic_diffusion(a)
    k = assemble(plan, form)
    op = matfree_operator(plan, form)
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    np.testing.assert_allclose(
        np.asarray(op.matvec(x)), np.asarray(k.matvec(x)), atol=1e-12
    )
    np.testing.assert_allclose(
        np.asarray(op.rmatvec(x)), np.asarray(k.rmatvec(x)), atol=1e-12
    )
    np.testing.assert_allclose(
        np.asarray(op.diagonal()), np.asarray(k.diagonal()), atol=1e-12
    )


def test_elasticity_vector_space_fallback():
    # no fused action registered for elasticity → the generic K_e fallback,
    # on an interleaved vector space
    mesh = unit_square_tri(4)
    space = _space(mesh, value_size=2)
    plan = build_plan(space)
    form = wf.elasticity(1.2, 0.7)
    k = assemble(plan, form)
    op = matfree_operator(plan, form)
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    np.testing.assert_allclose(
        np.asarray(op.matvec(x)), np.asarray(k.matvec(x)), atol=1e-12
    )
    np.testing.assert_allclose(
        np.asarray(op.diagonal()), np.asarray(k.diagonal()), atol=1e-12
    )


def test_condensed_matches_condensed_csr():
    space = CASES["P1_tri"]()
    plan = build_plan(space)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    form = wf.diffusion(2.0)
    kc = bc.apply_matrix_only(assemble(plan, form))
    opc = matfree_operator(plan, form).condensed(bc)
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    np.testing.assert_allclose(
        np.asarray(opc.matvec(x)), np.asarray(kc.matvec(x)), atol=1e-12
    )
    np.testing.assert_allclose(
        np.asarray(opc.diagonal()), np.asarray(kc.diagonal()), atol=1e-12
    )


# ---------------------------------------------------------------------------
# zero-retrace on coefficient value updates
# ---------------------------------------------------------------------------

def test_zero_retrace_on_coefficient_update():
    space = CASES["P1_tri"]()
    plan = build_plan(space)
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, space.mesh.num_cells))
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    op = matfree_operator(plan, wf.diffusion(rho))
    jax.block_until_ready(op.matvec(x))  # compile once
    before = n_matfree_traces()
    for scale in (2.0, 3.0, 4.0):
        op2 = matfree_operator(plan, wf.diffusion(scale * rho))
        jax.block_until_ready(op2.matvec(2.0 * x))
    assert n_matfree_traces() == before, "coefficient value update retraced"


# ---------------------------------------------------------------------------
# differentiable matrix-free solve (the PR acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cube_problem():
    mesh = unit_cube_tet(3)
    space = _space(mesh)
    plan = build_plan(space)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    f = bc.project_residual(assemble_rhs(plan, wf.source(1.0)))
    rho0 = jnp.asarray(RNG.uniform(0.5, 2.0, mesh.num_cells))
    return plan, bc, f, rho0


def _solve_mf(plan, bc, f, rho):
    op = matfree_operator(plan, wf.diffusion(rho)).condensed(bc)
    return matfree_solve(op, f, _SPEC)


def _solve_csr(plan, bc, f, rho):
    k = bc.apply_matrix_only(assemble(plan, wf.diffusion(rho)))
    return sparse_solve(k, f, _SPEC)


def test_matfree_solve_matches_assembled_3d(cube_problem):
    plan, bc, f, rho0 = cube_problem
    u_mf = _solve_mf(plan, bc, f, rho0)
    u_csr = _solve_csr(plan, bc, f, rho0)
    assert float(jnp.max(jnp.abs(u_mf - u_csr))) < 1e-8


def test_grad_matches_adjoint_sparse_solve(cube_problem):
    plan, bc, f, rho0 = cube_problem
    g_mf = jax.grad(lambda r: jnp.sum(_solve_mf(plan, bc, f, r) ** 2))(rho0)
    g_csr = jax.grad(lambda r: jnp.sum(_solve_csr(plan, bc, f, r) ** 2))(rho0)
    np.testing.assert_allclose(np.asarray(g_mf), np.asarray(g_csr), atol=1e-6)


def test_grad_vs_finite_differences(cube_problem):
    plan, bc, f, rho0 = cube_problem
    loss = lambda r: jnp.sum(_solve_mf(plan, bc, f, r) ** 2)  # noqa: E731
    g = jax.grad(loss)(rho0)
    eps = 1e-5
    for i in (0, 11, 47):
        e = jnp.zeros_like(rho0).at[i].set(1.0)
        fd = (loss(rho0 + eps * e) - loss(rho0 - eps * e)) / (2 * eps)
        assert abs(float(g[i]) - float(fd)) < 1e-6


def test_grad_wrt_rhs_is_adjoint_solution(cube_problem):
    plan, bc, f, rho0 = cube_problem
    gb = jax.grad(
        lambda b: jnp.sum(_solve_mf(plan, bc, b, rho0) ** 2)
    )(f)
    gb_csr = jax.grad(
        lambda b: jnp.sum(_solve_csr(plan, bc, b, rho0) ** 2)
    )(f)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_csr), atol=1e-8)


def test_poisson_problem_matfree_backend():
    from repro.fem.tensormesh import PoissonProblem

    prob = PoissonProblem(unit_cube_tet(3))
    res_csr = prob.solve()
    res_mf = prob.solve(backend="matfree")
    assert float(jnp.max(jnp.abs(res_csr.u - res_mf.u))) < 1e-8
    assert res_mf.residual < 1e-9


# ---------------------------------------------------------------------------
# the unified backend registry
# ---------------------------------------------------------------------------

def _small_system():
    space = CASES["P1_tri"]()
    plan = build_plan(space)
    k = assemble(plan, wf.diffusion(1.5))
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))
    return plan, k, x


def test_registry_backends_agree():
    plan, k, x = _small_system()
    assert set(MATVEC_BACKENDS) >= {"csr", "ell", "ell_pallas", "matfree"}
    y_ref = np.asarray(k.matvec(x))
    for backend in ("csr", "ell", "ell_pallas"):
        mv = make_matvec(k, backend)
        np.testing.assert_allclose(np.asarray(mv(x)), y_ref, atol=1e-12)
    op = matfree_operator(plan, wf.diffusion(1.5))
    np.testing.assert_allclose(
        np.asarray(make_matvec(op, "matfree")(x)), y_ref, atol=1e-12
    )


def test_registry_residuals_agree():
    plan, k, x = _small_system()
    f = jnp.asarray(RNG.standard_normal(x.shape[0]))
    r_ref = np.asarray(k.matvec(x) - f)
    for backend in ("csr", "ell", "ell_pallas"):
        r = make_residual(k, backend)(x, f)
        np.testing.assert_allclose(np.asarray(r), r_ref, atol=1e-12)
    op = matfree_operator(plan, wf.diffusion(1.5))
    np.testing.assert_allclose(
        np.asarray(make_residual(op, "matfree")(x, f)), r_ref, atol=1e-12
    )


def test_registry_errors():
    plan, k, x = _small_system()
    op = matfree_operator(plan, wf.diffusion(1.5))
    with pytest.raises(ValueError, match="unknown matvec backend"):
        make_matvec(k, "nope")
    with pytest.raises(TypeError, match="matrix-free operator"):
        make_matvec(k, "matfree")
    with pytest.raises(TypeError, match="assembled CSR"):
        make_matvec(op, "ell")


def test_register_custom_backend():
    from repro.core.matvec import _BACKENDS, matvec_backends, register_matvec_backend

    _, k, x = _small_system()
    register_matvec_backend(
        "dense_test", lambda op: op.to_dense().__matmul__, overwrite=True
    )
    try:
        np.testing.assert_allclose(
            np.asarray(make_matvec(k, "dense_test")(x)),
            np.asarray(k.matvec(x)), atol=1e-12,
        )
        # the live set sees the registration; the built-in constant does not
        assert "dense_test" in matvec_backends()
        assert "dense_test" not in MATVEC_BACKENDS
        with pytest.raises(ValueError, match="already registered"):
            register_matvec_backend("dense_test", lambda op: op.matvec)
    finally:
        _BACKENDS.pop("dense_test", None)


def test_ell_layout_cached_per_pattern():
    from repro.core.sparse import _ELL_LAYOUTS, csr_to_ell

    _, k, x = _small_system()
    ell1 = csr_to_ell(k)
    assert id(k.indices) in _ELL_LAYOUTS
    ell2 = csr_to_ell(k)
    assert ell1.cols is ell2.cols  # layout derived once, not per call site


# ---------------------------------------------------------------------------
# consumers: losses, transient, deprecation shims
# ---------------------------------------------------------------------------

def test_galerkin_residual_loss_backends():
    from repro.pils.losses import GalerkinResidualLoss

    space = CASES["P1_tri"]()
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    u = jnp.asarray(RNG.standard_normal(space.num_dofs))
    ref = float(GalerkinResidualLoss(asm, bc)(u))
    for backend in ("ell", "ell_pallas", "matfree"):
        val = float(GalerkinResidualLoss(asm, bc, backend=backend)(u))
        assert abs(val - ref) < 1e-9 * max(1.0, abs(ref))


def test_theta_matfree_rollout_matches_csr():
    from repro.transient import ThetaIntegrator

    space = CASES["P1_tri"]()
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    u0 = jnp.asarray(RNG.standard_normal(space.num_dofs)) * jnp.asarray(bc.free_mask)
    mk = lambda be: ThetaIntegrator.from_form(  # noqa: E731
        asm, wf.diffusion(1.0), 0.01, theta=0.5, bc=bc, backend=be
    )
    traj_csr = mk("csr").rollout(u0, 4)
    traj_mf = mk("matfree").rollout(u0, 4)
    np.testing.assert_allclose(
        np.asarray(traj_mf), np.asarray(traj_csr), atol=1e-10
    )
    # grad through the matrix-free rollout matches the adjoint CSR path
    def loss(kappa, backend):
        integ = ThetaIntegrator.from_form(
            asm, wf.diffusion(kappa), 0.01, theta=0.5, bc=bc, backend=backend
        )
        return jnp.sum(integ.rollout(u0, 3) ** 2)

    g_csr = jax.grad(lambda c: loss(c, "csr"))(1.3)
    g_mf = jax.grad(lambda c: loss(c, "matfree"))(1.3)
    assert abs(float(g_csr) - float(g_mf)) < 1e-8 * max(1.0, abs(float(g_csr)))


def test_newmark_backend_dispatch():
    from repro.transient import NewmarkIntegrator

    space = CASES["P1_tri"]()
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    mass = asm.assemble(wf.mass())
    stiff = asm.assemble(wf.diffusion())
    u0 = jnp.asarray(RNG.standard_normal(space.num_dofs)) * jnp.asarray(bc.free_mask)
    t_csr = NewmarkIntegrator(mass, stiff, 0.01, bc=bc).rollout(u0, 3)
    t_ell = NewmarkIntegrator(mass, stiff, 0.01, bc=bc, backend="ell").rollout(u0, 3)
    np.testing.assert_allclose(np.asarray(t_ell), np.asarray(t_csr), atol=1e-10)


def test_stepping_names_deprecated_but_working():
    from repro.transient import stepping

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        backends = stepping.MATVEC_BACKENDS
        mv_factory = stepping.make_matvec
    assert {w.category for w in caught} == {DeprecationWarning}
    assert "matfree" in backends
    _, k, x = _small_system()
    np.testing.assert_allclose(
        np.asarray(mv_factory(k, "ell")(x)), np.asarray(k.matvec(x)), atol=1e-12
    )
    with pytest.raises(AttributeError):
        stepping.not_a_name  # noqa: B018


def test_matfree_rejects_facet_terms_and_vector_arity():
    from repro.core.boundary import FacetAssembler

    space = CASES["P1_tri"]()
    plan = build_plan(space)
    fa = FacetAssembler(space, space.mesh.boundary_facets(),
                        volume_routing=plan.static.mat_routing)
    with pytest.raises(NotImplementedError, match="volume terms only"):
        matfree_operator(plan, wf.diffusion() + wf.robin(1.0, on=fa))
    with pytest.raises(TypeError):
        matfree_operator(plan, wf.source(1.0))


def test_matfree_solve_on_csr_matches_sparse_solve():
    # the generic adjoint solve also accepts an assembled CSR pytree
    space = CASES["P1_tri"]()
    plan = build_plan(space)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    f = bc.project_residual(assemble_rhs(plan, wf.source(1.0)))
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, space.mesh.num_cells))

    def solve_generic(r):
        k = bc.apply_matrix_only(assemble(plan, wf.diffusion(r)))
        return matfree_solve(k, f, _SPEC)

    def solve_sparse(r):
        k = bc.apply_matrix_only(assemble(plan, wf.diffusion(r)))
        return sparse_solve(k, f, _SPEC)

    np.testing.assert_allclose(
        np.asarray(solve_generic(rho)), np.asarray(solve_sparse(rho)), atol=1e-10
    )
    g1 = jax.grad(lambda r: jnp.sum(solve_generic(r) ** 2))(rho)
    g2 = jax.grad(lambda r: jnp.sum(solve_sparse(r) ** 2))(rho)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


# ---------------------------------------------------------------------------
# the new hex mesh (satellite: Q1_hex end-to-end)
# ---------------------------------------------------------------------------

def test_hex_mesh_poisson_sanity():
    mesh = unit_cube_hex(4)
    assert mesh.cell_type == "hex"
    # structured box: volumes sum to 1, boundary facet count = 6 n²
    np.testing.assert_allclose(mesh.cell_volumes().sum(), 1.0, atol=1e-12)
    assert mesh.boundary_facets().shape == (6 * 16, 4)
    space = _space(mesh)
    plan = build_plan(space)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    k = bc.apply_matrix_only(assemble(plan, wf.diffusion()))
    f = bc.project_residual(assemble_rhs(plan, wf.source(1.0)))
    u = sparse_solve(k, f, _SPEC)
    # interior solution of -Δu = 1 on the unit cube is positive, max ≈ 0.056
    assert float(jnp.min(u)) >= 0.0
    assert 0.03 < float(jnp.max(u)) < 0.09


def test_matfree_state_is_small():
    # the memory story: a coords-store operator carries only the coefficient
    # leaves beyond the plan — far below the 3 nnz-sized CSR arrays
    space = _space(unit_cube_tet(4))
    plan = build_plan(space)
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, space.mesh.num_cells))
    k = assemble(plan, wf.diffusion(rho))
    op = matfree_operator(plan, wf.diffusion(rho), store="coords")
    csr_bytes = k.vals.nbytes + k.indices.nbytes + k.row_of_nnz.nbytes
    assert op.state_bytes() < csr_bytes / 2
    assert isinstance(k, CSR)


# ---------------------------------------------------------------------------
# batched matrix-free families (PR 7): (B, ...) coefficient leaves on one
# shared plan — vmap-able diagonal()/condensed(), family solves + gradients
# ---------------------------------------------------------------------------

from repro.core import (  # noqa: E402
    MatFreeFamily,
    assemble_batched,
    matfree_family,
    matfree_solve_batched,
)


def _family_fixture(batch=4, n=6, seed=3):
    space = _space(unit_square_tri(n))
    plan = build_plan(space)
    rng = np.random.default_rng(seed)
    rho_b = jnp.asarray(
        rng.uniform(0.5, 2.0, (batch, space.mesh.num_cells)))
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    return plan, rho_b, bc


@pytest.mark.parametrize("store", ["context", "coords", "local"])
def test_family_matvec_diagonal_parity(store):
    plan, rho_b, _ = _family_fixture()
    fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                         leaves_batch=(rho_b, None), store=store)
    assert isinstance(fam, MatFreeFamily) and fam.batch == rho_b.shape[0]
    x = jnp.asarray(RNG.normal(size=fam.shape[0]))
    y = fam.matvec(x)
    d = fam.diagonal()
    for b in range(fam.batch):
        op_b = matfree_operator(plan, wf.diffusion(rho_b[b]))
        np.testing.assert_allclose(np.asarray(y[b]),
                                   np.asarray(op_b.matvec(x)), atol=1e-12)
        np.testing.assert_allclose(np.asarray(d[b]),
                                   np.asarray(op_b.diagonal()), atol=1e-12)


def test_family_condensed_diagonal_under_vmap():
    # satellite regression: diagonal() and condensed(bc) must work when
    # vmapped over coefficient leaves (family Jacobi preconditioning)
    plan, rho_b, bc = _family_fixture()
    fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                         leaves_batch=(rho_b, None)).condensed(bc)
    d = fam.diagonal()
    x = jnp.asarray(RNG.normal(size=fam.shape[0]))
    y = fam.matvec(x)
    for b in range(fam.batch):
        opc = matfree_operator(plan, wf.diffusion(rho_b[b])).condensed(bc)
        np.testing.assert_allclose(np.asarray(d[b]),
                                   np.asarray(opc.diagonal()), atol=1e-12)
        np.testing.assert_allclose(np.asarray(y[b]),
                                   np.asarray(opc.matvec(x)), atol=1e-12)


def test_family_getitem_and_validation():
    plan, rho_b, _ = _family_fixture()
    fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                         leaves_batch=(rho_b, None))
    x = jnp.asarray(RNG.normal(size=fam.shape[0]))
    np.testing.assert_allclose(np.asarray(fam[2].matvec(x)),
                               np.asarray(fam.matvec(x)[2]), atol=1e-12)
    with pytest.raises(TypeError):
        fam[0:2]
    with pytest.raises(ValueError, match="nothing is batched"):
        matfree_family(plan, wf.diffusion(rho_b[0]))
    with pytest.raises(ValueError, match="leaves_batch has"):
        matfree_family(plan, wf.diffusion(rho_b[0]), leaves_batch=(rho_b,))
    with pytest.raises(ValueError, match="inconsistent"):
        matfree_family(plan, wf.mass(1.0) + wf.diffusion(rho_b[0]),
                       leaves_batch=(jnp.ones((3, 1)), None, rho_b, None))


def test_family_solve_matches_sequential_and_batched_csr():
    plan, rho_b, bc = _family_fixture()
    f = jnp.asarray(RNG.normal(size=(rho_b.shape[0], plan.static.num_dofs)))
    f = f * bc.free_mask
    fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                         leaves_batch=(rho_b, None)).condensed(bc)
    x = matfree_solve_batched(fam, f, _SPEC)
    kb = bc.apply_matrix_only(assemble_batched(
        plan, wf.diffusion(rho_b[0]), leaves_batch=(rho_b, None)))
    from repro.core import sparse_solve_batched
    x_csr = sparse_solve_batched(kb, f, _SPEC)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_csr), atol=1e-9)
    for b in range(fam.batch):
        opc = matfree_operator(plan, wf.diffusion(rho_b[b])).condensed(bc)
        xb = matfree_solve(opc, f[b], _SPEC)
        np.testing.assert_allclose(np.asarray(x[b]), np.asarray(xb),
                                   atol=1e-9)


def test_family_solve_info_and_record():
    plan, rho_b, bc = _family_fixture()
    f = bc.project_residual(
        jnp.asarray(RNG.normal(size=plan.static.num_dofs)))
    fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                         leaves_batch=(rho_b, None)).condensed(bc)
    x, info = matfree_solve_batched(fam, f, return_info=True)
    assert x.shape == (fam.batch, plan.static.num_dofs)
    assert info.iters.shape == (fam.batch,)
    assert bool(jnp.all(info.converged))


def test_family_grad_matches_per_instance_adjoints():
    # acceptance: gradients through the vmapped family solve match B
    # per-instance adjoint matfree_solve gradients to <= 1e-12 (relative)
    plan, rho_b, bc = _family_fixture(batch=3)
    f = bc.project_residual(
        jnp.asarray(RNG.normal(size=plan.static.num_dofs)))

    def loss_family(rb):
        fam = matfree_family(plan, wf.diffusion(rb[0]),
                             leaves_batch=(rb, None)).condensed(bc)
        return jnp.sum(matfree_solve_batched(fam, f, _SPEC) ** 2)

    def loss_sequential(rb):
        tot = 0.0
        for b in range(rb.shape[0]):
            opc = matfree_operator(plan, wf.diffusion(rb[b])).condensed(bc)
            tot = tot + jnp.sum(
                matfree_solve(opc, f, _SPEC) ** 2)
        return tot

    g1 = jax.grad(loss_family)(rho_b)
    g2 = jax.grad(loss_sequential)(rho_b)
    scale = float(jnp.max(jnp.abs(g2)))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-12 * scale)


def test_family_batched_coords():
    # batched geometry: perturb each instance's mesh, store forced to coords
    plan, rho_b, _ = _family_fixture()
    batch = rho_b.shape[0]
    rng = np.random.default_rng(11)
    coords_b = jnp.asarray(
        np.asarray(plan.coords)[None]
        + 1e-3 * rng.normal(size=(batch,) + plan.coords.shape))
    fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                         leaves_batch=(rho_b, None), coords_batch=coords_b)
    assert fam.op.store == "coords" and fam.coords_ax == 0
    x = jnp.asarray(RNG.normal(size=fam.shape[0]))
    y = fam.matvec(x)
    for b in range(batch):
        op_b = matfree_operator(plan, wf.diffusion(rho_b[b]), store="coords",
                                coords=coords_b[b])
        np.testing.assert_allclose(np.asarray(y[b]),
                                   np.asarray(op_b.matvec(x)), atol=1e-12)


def test_family_theta_rollout_matches_batched_csr():
    from repro.transient import batched_theta_rollout

    plan, kap_b, bc = _family_fixture()
    batch, dt, theta, n_steps = kap_b.shape[0], 0.01, 1.0, 4
    u0 = jnp.asarray(RNG.normal(size=(batch, plan.static.num_dofs)))
    u0 = u0 * bc.free_mask
    lhs_form = wf.mass(1.0) + (theta * dt) * wf.diffusion(kap_b[0])
    rhs_form = wf.mass(1.0) + (-(1 - theta) * dt) * wf.diffusion(kap_b[0])
    lb = (None, None, kap_b, None)
    traj_csr = batched_theta_rollout(
        assemble_batched(plan, lhs_form, leaves_batch=lb),
        assemble_batched(plan, rhs_form, leaves_batch=lb),
        u0, n_steps, dt=dt, theta=theta, bc=bc)
    traj_mf = batched_theta_rollout(
        matfree_family(plan, lhs_form, leaves_batch=lb),
        matfree_family(plan, rhs_form, leaves_batch=lb),
        u0, n_steps, dt=dt, theta=theta, bc=bc)
    np.testing.assert_allclose(np.asarray(traj_mf), np.asarray(traj_csr),
                               atol=1e-10)


def test_family_pils_loss_backend_parity():
    from repro.pils.losses import BatchedGalerkinResidualLoss

    space = _space(unit_square_tri(6))
    asm = GalerkinAssembler(space)
    plan = build_plan(space)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    rng = np.random.default_rng(5)
    rho_b = jnp.asarray(rng.uniform(0.5, 2.0, (3, space.mesh.num_cells)))
    l_csr = BatchedGalerkinResidualLoss(asm, bc, rho_b)
    l_mf = BatchedGalerkinResidualLoss(asm, bc, rho_b, backend="matfree")
    u = jnp.asarray(rng.normal(size=(3, space.num_dofs)))
    np.testing.assert_allclose(float(l_mf(u)), float(l_csr(u)), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(l_mf.solve()),
                               np.asarray(l_csr.solve()), atol=1e-9)
    with pytest.raises(ValueError, match="unknown backend"):
        BatchedGalerkinResidualLoss(asm, bc, rho_b, backend="ell")
