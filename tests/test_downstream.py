"""Integration tests for the three downstream products:
TensorMesh (solver), TensorPILS (learning), TensorOpt (optimization)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    disk_tri,
    unit_square_tri,
)
from repro.core.mesh import element_for_mesh
from repro.fem import ElasticityProblem, MixedBCPoisson, PoissonProblem
from repro.core import hollow_cube_tet, unit_cube_tet
from repro.pils import (
    GalerkinResidualLoss,
    deep_ritz_loss,
    pinn_poisson_loss,
    siren_apply,
    siren_init,
    train_adam,
    lbfgs_minimize,
    vpinn_loss,
)
from repro.pils.operator import TimeDependentProblem, random_initial_condition
from repro.opt import CantileverProblem, MMAState, mma_update, oc_update


# ---------------------------------------------------------------------------
# TensorMesh
# ---------------------------------------------------------------------------

def test_poisson3d_residual_below_paper_tol():
    res = PoissonProblem(unit_cube_tet(5)).solve()
    assert res.residual < 1e-10


def test_elasticity3d_hollow_cube():
    res = ElasticityProblem(hollow_cube_tet(6)).solve()
    assert res.residual < 1e-8
    assert float(jnp.abs(res.u).max()) > 0


def test_batched_rhs_matches_individual():
    p = PoissonProblem(unit_square_tri(8))
    rng = np.random.default_rng(0)
    fb = jnp.asarray(rng.normal(size=(3, p.space.num_dofs)))
    us, _ = p.solve_batch(fb)
    for b in range(3):
        res = p.solve(f=fb[b])
        np.testing.assert_allclose(np.asarray(us[b]), np.asarray(res.u), atol=1e-8)


def test_mixed_bc_disk_analytic():
    """Paper SM B.1.5 analogue: u = x with Dirichlet+Neumann+Robin parts."""
    m = disk_tri(10, center=(0.0, 0.0), radius=1.0)
    prob = MixedBCPoisson(
        m,
        dirichlet_pred=lambda c: c[:, 1] > 0,
        neumann_pred=lambda c: (c[:, 1] <= 0) & (c[:, 0] > 0),
        robin_pred=lambda c: (c[:, 1] <= 0) & (c[:, 0] <= 0),
    )
    res = prob.solve(
        f=0.0,
        g_neumann=lambda x: x[..., 0],
        robin_alpha=1.0,
        g_robin=lambda x: 2 * x[..., 0],
        dirichlet_values=lambda p: p[:, 0],
    )
    exact = prob.space.dof_points[:, 0]
    err = np.linalg.norm(np.asarray(res.u) - exact) / np.linalg.norm(exact)
    assert err < 1e-3, err  # paper reports <1e-4 vs FEniCS at finer meshes


def test_mixed_bc_nonconvex_boomerang():
    from repro.core import annulus_sector_tri

    m = annulus_sector_tri(6, 24)
    prob = MixedBCPoisson(m, dirichlet_pred=lambda c: np.ones(len(c), bool))
    res = prob.solve(f=1.0)
    assert res.residual < 1e-9


# ---------------------------------------------------------------------------
# TensorPILS — neural solvers (reduced-budget versions of Table 1)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def checkerboard_setup():
    m = unit_square_tri(10)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    f = lambda x: jnp.sign(
        jnp.sin(2 * np.pi * x[..., 0] + 1e-9) * jnp.sin(2 * np.pi * x[..., 1] + 1e-9)
    )
    return m, space, asm, bc, f


def test_galerkin_loss_trains_to_fem_solution(checkerboard_setup):
    m, space, asm, bc, f = checkerboard_setup
    gl = GalerkinResidualLoss(asm, bc, f=f)
    params = siren_init(jax.random.PRNGKey(0), 2, 32, 1, depth=3)
    loss_fn = lambda p: gl.loss_from_net(siren_apply, p)
    params, hist, _ = train_adam(loss_fn, params, 400, lr=2e-3, log_every=100)
    # the discrete residual must drop by orders of magnitude
    assert hist[-1] < 1e-4 * hist[0]
    # and the recovered field must approach the FEM solution
    from repro.core import cg, jacobi_preconditioner

    u_fem, _ = cg(gl.k.matvec, gl.f, m=jacobi_preconditioner(gl.k), tol=1e-12)
    u_net = siren_apply(params, gl.dof_points)[:, 0]
    u_net = u_net * bc.free_mask
    rel = np.linalg.norm(np.asarray(u_net - u_fem)) / np.linalg.norm(np.asarray(u_fem))
    assert rel < 0.05, rel


def test_pinn_and_ritz_losses_decrease(checkerboard_setup):
    m, space, asm, bc, f = checkerboard_setup
    pts = jnp.asarray(space.dof_points)
    interior = pts[np.asarray(bc.free_mask, bool)]
    boundary = pts[~np.asarray(bc.free_mask, bool)]
    f_int = f(interior[None])[0]
    params = siren_init(jax.random.PRNGKey(1), 2, 16, 1, depth=2)

    pinn = lambda p: pinn_poisson_loss(siren_apply, p, interior, f_int, boundary)
    p1, h1, _ = train_adam(pinn, params, 60, lr=1e-3, log_every=59)
    assert h1[-1] < h1[0]

    ctx = asm.context()
    fq = f(ctx.xq)
    ritz = lambda p: deep_ritz_loss(siren_apply, p, ctx.xq, ctx.wdet, fq, boundary)
    p2, h2, _ = train_adam(ritz, params, 60, lr=1e-3, log_every=59)
    assert h2[-1] < h2[0]


def test_vpinn_loss_runs(checkerboard_setup):
    m, space, asm, bc, f = checkerboard_setup
    f_load = asm.assemble_load(f)
    boundary = jnp.asarray(space.dof_points[~np.asarray(bc.free_mask, bool)])
    params = siren_init(jax.random.PRNGKey(2), 2, 16, 1, depth=2)
    loss = lambda p: vpinn_loss(
        siren_apply, p, asm, f_load, bc.free_mask, boundary
    )
    val = loss(params)
    assert np.isfinite(float(val))
    g = jax.grad(loss)(params)
    assert all(np.all(np.isfinite(x)) for x in jax.tree.leaves(g))


def test_lbfgs_refines_after_adam(checkerboard_setup):
    m, space, asm, bc, f = checkerboard_setup
    gl = GalerkinResidualLoss(asm, bc, f=f)
    params = siren_init(jax.random.PRNGKey(3), 2, 16, 1, depth=2)
    loss_fn = lambda p: gl.loss_from_net(siren_apply, p)
    params, hist, _ = train_adam(loss_fn, params, 100, lr=2e-3, log_every=99)
    params, losses, _ = lbfgs_minimize(loss_fn, params, steps=20)
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# operator learning substrate
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def wave_problem():
    return TimeDependentProblem(disk_tri(6), dt=5e-4)


def test_wave_reference_stable_and_consistent(wave_problem):
    tp = wave_problem
    u0 = random_initial_condition(jax.random.PRNGKey(0), tp.space.dof_points)
    traj = tp.wave_reference(u0, 40)
    assert not bool(jnp.any(jnp.isnan(traj)))
    # energy boundedness (Newmark β=¼ is unconditionally stable)
    assert float(jnp.abs(traj).max()) < 10 * float(jnp.abs(u0).max())
    # the reference trajectory nearly zeroes the discrete residual
    full = jnp.concatenate([(u0 * tp.bc.free_mask)[None], traj], axis=0)
    r = tp.wave_trajectory_loss(full)
    u_scale = float(jnp.sum(full[0] ** 2))
    assert float(r) < 1e-2 * max(u_scale, 1e-12) * (tp.c / tp.dt) ** 0


def test_ac_reference_decays(wave_problem):
    tp = TimeDependentProblem(disk_tri(5), dt=1e-4, a2=1e-2, eps2=1.0)
    u0 = random_initial_condition(jax.random.PRNGKey(1), tp.space.dof_points)
    traj = tp.ac_reference(u0, 30)
    assert not bool(jnp.any(jnp.isnan(traj)))
    full = jnp.concatenate([(u0 * tp.bc.free_mask)[None], traj], axis=0)
    assert float(tp.ac_trajectory_loss(full)) < 1e-6


def test_agn_shapes_and_rollout():
    from repro.pils.gnn import agn_init, agn_apply, agn_rollout, element_graph_edges

    m = disk_tri(4)
    edges = element_graph_edges(m.cells)
    deg = np.zeros(m.num_vertices)
    np.add.at(deg, edges[:, 1], 1)
    deg = jnp.asarray(np.maximum(deg, 1.0))
    coords = jnp.asarray(m.points)
    w = 4
    params = agn_init(jax.random.PRNGKey(0), w, w, hidden=16, n_layers=2)
    u_win = jnp.asarray(np.random.default_rng(0).normal(size=(m.num_vertices, w)))
    out = agn_apply(params, u_win, coords, edges, deg)
    assert out.shape == (m.num_vertices, w)
    interior = jnp.asarray(np.ones(m.num_vertices, bool))
    traj = agn_rollout(params, u_win, coords, edges, deg, 3, interior)
    assert traj.shape == (m.num_vertices, 3 * w)
    assert np.all(np.isfinite(np.asarray(traj)))


# ---------------------------------------------------------------------------
# TensorOpt
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cantilever():
    return CantileverProblem(nx=16, ny=8, lx=16.0, ly=8.0)


def test_ad_sensitivity_matches_analytic_eq_b28(cantilever):
    """The paper's consistency claim: autodiff through assembly+solve equals
    the closed-form SIMP sensitivity (Eq. B.28)."""
    rho = jnp.full((cantilever.n_elem,), 0.5)
    _, g_ad = cantilever.compliance_and_sensitivity(rho)
    g_an = cantilever.analytic_sensitivity(rho)
    np.testing.assert_allclose(np.asarray(g_ad), np.asarray(g_an), rtol=1e-5)


def test_oc_optimization_reduces_compliance(cantilever):
    rho = jnp.full((cantilever.n_elem,), 0.5)
    c0, _ = cantilever.compliance_and_sensitivity(rho)
    for _ in range(8):
        c, g = cantilever.compliance_and_sensitivity(rho)
        gf = cantilever.filter(g * rho) / jnp.maximum(rho, 1e-3)
        rho = oc_update(rho, gf, cantilever.volfrac)
    c_end, _ = cantilever.compliance_and_sensitivity(rho)
    assert float(c_end) < 0.7 * float(c0)
    assert abs(float(rho.mean()) - cantilever.volfrac) < 1e-3


def test_mma_optimization_reduces_compliance(cantilever):
    rho = jnp.full((cantilever.n_elem,), 0.5)
    c0, _ = cantilever.compliance_and_sensitivity(rho)
    state = MMAState(low=rho - 0.5, upp=rho + 0.5)
    n = cantilever.n_elem
    for _ in range(8):
        c, g = cantilever.compliance_and_sensitivity(rho)
        gf = cantilever.filter(g * rho) / jnp.maximum(rho, 1e-3)
        vol_g = float(rho.mean()) - cantilever.volfrac
        rho, state = mma_update(
            rho, gf, jnp.asarray(vol_g), jnp.full((n,), 1.0 / n), state
        )
    c_end, _ = cantilever.compliance_and_sensitivity(rho)
    assert float(c_end) < 0.8 * float(c0)
    assert float(rho.mean()) <= cantilever.volfrac + 1e-2
