"""Infrastructure tests: sharded train step on a host mesh, checkpoint
save/restore (incl. elastic re-shard + crash recovery), data pipeline
determinism, optimizer correctness, roofline analyzer units."""

import json
import os

import numpy as np
import pytest

# 8 host devices for sharding tests — must be set before first jax import
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs import ARCHS, smoke_variant  # noqa: E402
from repro.configs.base import ShapeSpec  # noqa: E402
from repro.data import SyntheticLMData  # noqa: E402
from repro.models.layers import abstract_params, init_params  # noqa: E402
from repro.sharding.partitioning import (  # noqa: E402
    RULES_SINGLE_POD,
    make_shardings,
    use_rules,
)
from repro.train.train_step import make_train_state_specs, make_train_step  # noqa: E402


def _mesh(data=4, model=2):
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(data, model)


@pytest.fixture(scope="module")
def small_setup():
    cfg = smoke_variant(ARCHS["qwen3-4b"])
    shape = ShapeSpec("t", "train", 64, 8)
    mesh = _mesh()
    state_specs = make_train_state_specs(cfg)
    state_sh = make_shardings(state_specs, mesh, RULES_SINGLE_POD)
    from repro.models.model_zoo import build_model

    model = build_model(cfg, tp_degree=2)
    batch_sh = make_shardings(model.batch_axes(shape), mesh, RULES_SINGLE_POD)
    step = make_train_step(cfg, shape, lr=1e-3)

    def wrapped(state, batch):
        with use_rules(RULES_SINGLE_POD):
            return step(state, batch)

    return cfg, shape, mesh, state_specs, state_sh, batch_sh, wrapped


def test_sharded_train_step_runs_and_improves(small_setup):
    cfg, shape, mesh, specs, state_sh, batch_sh, wrapped = small_setup
    with mesh:
        jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        state = jax.device_put(init_params(specs, jax.random.PRNGKey(0)), state_sh)
        data = SyntheticLMData(cfg.vocab_size, shape.seq_len, shape.global_batch)
        losses = []
        it = iter(data)
        for i in range(20):
            batch = jax.device_put(next(it), batch_sh)
            state, metrics = jitted(state, batch)
            losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0], losses  # learning on synthetic motifs
        assert int(state["step"]) == 20


def test_grad_accum_equivalence():
    """n microbatches must give (numerically close) grads to one batch."""
    import dataclasses

    cfg = dataclasses.replace(
        smoke_variant(ARCHS["qwen3-4b"]), compute_dtype="float32",
        microbatches={"t1": 1, "t4": 4},
    )
    from repro.models.model_zoo import build_model

    model = build_model(cfg, tp_degree=1)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    data = SyntheticLMData(cfg.vocab_size, 32, 8)
    batch = {k: jnp.asarray(v) for k, v in next(iter(data)).items()}

    from repro.train.train_step import _split_microbatches

    loss1, g1 = jax.value_and_grad(model.loss)(params, batch)
    mbs = _split_microbatches(batch, 4)
    g4 = jax.tree.map(jnp.zeros_like, params)
    l4 = 0.0
    for i in range(4):
        mb = {k: v[i] for k, v in mbs.items()}
        li, gi = jax.value_and_grad(model.loss)(params, mb)
        g4 = jax.tree.map(lambda a, b: a + b / 4, g4, gi)
        l4 += li / 4
    np.testing.assert_allclose(float(l4), float(loss1), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def test_checkpoint_roundtrip_and_elastic_reshard(tmp_path, small_setup):
    cfg, shape, mesh, specs, state_sh, batch_sh, wrapped = small_setup
    with mesh:
        state = jax.device_put(init_params(specs, jax.random.PRNGKey(1)), state_sh)
        mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
        mgr.save(7, state, extra={"data": {"step": 3, "seed": 0}}, blocking=True)
        assert mgr.latest_step() == 7

        # restore onto a DIFFERENT mesh layout (elastic re-shard)
        mesh2 = _mesh(2, 4)
        with mesh2:
            sh2 = make_shardings(specs, mesh2, RULES_SINGLE_POD)
            target = abstract_params(specs)
            restored = mgr.restore(7, target, sh2)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        manifest = mgr.restore_manifest(7)
        assert manifest["extra"]["data"]["step"] == 3


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4,))}
    mgr.save(1, state, blocking=True)
    # simulate a crash mid-write: directory without the commit marker
    os.makedirs(tmp_path / "step_00000002")
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.full((2,), float(s))}, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_data_pipeline_deterministic_resume():
    d1 = SyntheticLMData(1000, 32, 4, seed=5)
    batches = [next(iter(d1)) for _ in range(5)]
    d2 = SyntheticLMData(1000, 32, 4, seed=5)
    d2.restore({"step": 3, "seed": 5})
    b3 = next(iter(d2))
    np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])


def test_adamw_matches_reference():
    from repro.optim import make_optimizer
    from repro.models.layers import P

    opt = make_optimizer("adamw")
    specs = {"w": P((4, 4), ("embed", "mlp"))}
    params = {"w": jnp.ones((4, 4))}
    state = init_params(opt.init_specs(specs), jax.random.PRNGKey(0))
    g = {"w": jnp.full((4, 4), 0.5)}
    new_p, new_s = opt.update(params, g, state, lr=0.1, step=1.0, wd=0.0)
    # first adam step: update = m̂/(√v̂+eps) = g/(|g|+eps) ≈ sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 0.1, rtol=1e-4)


def test_adafactor_factored_shapes():
    from repro.optim import adafactor_init_specs
    from repro.models.layers import P

    specs = {"w": P((8, 16), ("embed", "mlp")), "b": P((16,), ("mlp",))}
    st = adafactor_init_specs(specs)
    assert st["w"]["vr"].shape == (8,)
    assert st["w"]["vc"].shape == (16,)
    assert st["b"]["v"].shape == (16,)


def test_adafactor_reduces_loss():
    from repro.optim import make_optimizer
    from repro.models.layers import P

    opt = make_optimizer("adafactor")
    specs = {"w": P((8, 8), ("embed", "mlp"))}
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    state = init_params(opt.init_specs(specs), jax.random.PRNGKey(0))
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for step in range(1, 30):
        g = jax.grad(loss)(params)
        params, state = opt.update(params, g, state, lr=0.05, step=float(step), wd=0.0)
    assert float(loss(params)) < 0.3 * l0


# ---------------------------------------------------------------------------
# roofline analyzer
# ---------------------------------------------------------------------------

def test_hlo_cost_scales_with_scan_length():
    from repro.analysis.roofline import validate_loop_accounting

    f1, f8 = validate_loop_accounting()
    assert abs(f8 / f1 - 8.0) < 0.2, (f1, f8)


def test_hlo_cost_dot_flops_exact():
    from repro.analysis.hlo_cost import analyze_hlo_text

    f = jax.jit(lambda a, b: a @ b)
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    cost = analyze_hlo_text(f.lower(a, b).compile().as_text())
    assert cost.flops == 2 * 64 * 32 * 128


def test_collective_parsing_on_psum():
    from repro.analysis.hlo_cost import analyze_hlo_text
    from jax.sharding import PartitionSpec as P_

    mesh = _mesh(4, 2)
    with mesh:
        def f(x):
            y = jax.lax.with_sharding_constraint(x, P_("data", None))
            s = jnp.sum(y, axis=0, keepdims=True)  # cross-shard reduce
            return jax.lax.with_sharding_constraint(
                jnp.broadcast_to(s, x.shape), P_(None, None)
            )

        x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        compiled = jax.jit(f).lower(x).compile()
        cost = analyze_hlo_text(compiled.as_text())
    # some cross-device collective must appear
    assert cost.collective_bytes > 0, compiled.as_text()[-2000:]


def test_roofline_report_fields():
    from repro.analysis.roofline import RooflineReport

    r = RooflineReport(
        arch="a", shape="s", mesh="m", chips=256,
        flops=197e12, hbm_bytes=819e9, collective_bytes=50e9,
        collective_detail={}, model_flops=197e12 * 256,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert r.useful_flops_ratio == 1.0
    assert r.roofline_fraction == 1.0
