"""Multi-device sharded matrix-free Krylov + streaming-SpMV solve paths.

``ShardedMatFreeOperator`` partitions the gather → per-element action →
scatter apply over the named FEM mesh axis (per-device partial touched-DoF
scatter + one psum); every test asserts ≤1e-12 parity against the
single-device operator — applies, solves, and custom-vjp gradients.

Runs on however many devices the host exposes (1 locally); CI exercises the
real multi-device path with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    MATVEC_BACKENDS,
    ShardedMatFreeOperator,
    assemble,
    build_plan,
    make_matvec,
    make_residual,
    matfree_operator,
    matfree_solve,
    sparse_solve,
    unit_cube_tet,
    unit_square_tri,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh
from repro.fem.tensormesh import PoissonProblem
from repro.sharding.partitioning import FEM_MESH_AXIS, fem_mesh
from repro.transient.theta import CRANK_NICOLSON, ThetaIntegrator

RNG = np.random.default_rng(0)


def _setup(n=8, cube=False, **kw):
    m = unit_cube_tet(n) if cube else unit_square_tri(n)
    space = FunctionSpace(m, element_for_mesh(m), **kw)
    return m, space, build_plan(space)


# ---------------------------------------------------------------------------
# apply parity: matvec / rmatvec / diagonal across storage strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("store", ["coords", "context", "local"])
def test_sharded_apply_parity(store):
    m, space, plan = _setup(7)
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, m.num_cells))
    form = wf.diffusion(rho) + 0.3 * wf.mass()
    op = matfree_operator(plan, form, store=store)
    sop = op.sharded()
    assert isinstance(sop, ShardedMatFreeOperator)
    assert sop.shape == op.shape
    x = jnp.asarray(RNG.standard_normal(op.shape[0]))
    np.testing.assert_allclose(
        np.asarray(sop.matvec(x)), np.asarray(op.matvec(x)), atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(sop.rmatvec(x)), np.asarray(op.rmatvec(x)), atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(sop.diagonal()), np.asarray(op.diagonal()), atol=1e-12)


def test_sharded_transpose_on_nonsymmetric_form():
    """advection makes A ≠ Aᵀ — the sharded rmatvec must take the true
    per-element transpose path, not the symmetric shortcut."""
    m, space, plan = _setup(8)
    form = wf.diffusion(1.0) + wf.advection(jnp.asarray([1.0, 0.5]))
    k = assemble(plan, form)
    sop = matfree_operator(plan, form).sharded()
    x = jnp.asarray(RNG.standard_normal(k.shape[0]))
    np.testing.assert_allclose(
        np.asarray(sop.rmatvec(x)), np.asarray(k.rmatvec(x)), atol=1e-12)
    with np.testing.assert_raises(AssertionError):  # sanity: truly nonsym
        np.testing.assert_allclose(
            np.asarray(sop.matvec(x)), np.asarray(sop.rmatvec(x)), atol=1e-8)


def test_sharded_handles_nondivisible_element_count():
    # E = 2·9² = 162: not divisible by 2/4/8 devices → element padding path
    m, space, plan = _setup(9)
    assert m.num_cells % 4 != 0
    op = matfree_operator(plan, wf.diffusion())
    sop = op.sharded(mesh=fem_mesh(), axis_name=FEM_MESH_AXIS)
    x = jnp.asarray(RNG.standard_normal(op.shape[0]))
    np.testing.assert_allclose(
        np.asarray(sop.matvec(x)), np.asarray(op.matvec(x)), atol=1e-12)


def test_sharded_vector_valued_space():
    m, space, plan = _setup(6, value_size=2)
    form = wf.elasticity(1.2, 0.6)
    op = matfree_operator(plan, form)
    sop = op.sharded()
    x = jnp.asarray(RNG.standard_normal(op.shape[0]))
    np.testing.assert_allclose(
        np.asarray(sop.matvec(x)), np.asarray(op.matvec(x)), atol=1e-12)
    np.testing.assert_allclose(
        np.asarray(sop.diagonal()), np.asarray(op.diagonal()), atol=1e-12)


# ---------------------------------------------------------------------------
# sharded Krylov solve: one CG spans all devices, ≤1e-12 vs single-device
# ---------------------------------------------------------------------------

def _poisson_setup(n=4):
    m, space, plan = _setup(n, cube=True)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, m.num_cells))
    b = bc.project_residual(jnp.asarray(RNG.standard_normal(plan.static.num_dofs)))
    return plan, bc, rho, b


def test_sharded_solve_matches_single_device():
    plan, bc, rho, b = _poisson_setup()
    form = wf.diffusion(rho) + 0.3 * wf.mass()
    u0 = matfree_solve(matfree_operator(plan, form).condensed(bc), b, tol=1e-12)
    u1 = matfree_solve(
        matfree_operator(plan, form).sharded().condensed(bc), b, tol=1e-12)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u0), atol=1e-12)


def test_sharded_grads_match_assembled_adjoint():
    """d(loss)/d(rho) through the sharded matfree_solve (custom-vjp adjoint
    solve + operator-cotangent pullback, all sharded) vs the assembled
    sparse_solve adjoint — ≤1e-12."""
    plan, bc, rho, b = _poisson_setup(3)

    def loss_sharded(r):
        op = matfree_operator(plan, wf.diffusion(r)).sharded().condensed(bc)
        return jnp.sum(matfree_solve(op, b, tol=1e-13) ** 2)

    def loss_assembled(r):
        k = bc.apply_matrix_only(assemble(plan, wf.diffusion(r)))
        return jnp.sum(sparse_solve(k, b, "cg", 1e-13, 1e-13, 10000) ** 2)

    g0 = jax.grad(loss_assembled)(rho)
    g1 = jax.grad(loss_sharded)(rho)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0), atol=1e-12)


def test_sharded_reapply_hits_compiled_executable():
    """New coefficient values on the same signature must NOT retrace."""
    from repro.core import n_matfree_traces

    plan, bc, rho, b = _poisson_setup(3)
    sop = matfree_operator(plan, wf.diffusion(rho)).sharded()
    x = jnp.asarray(RNG.standard_normal(sop.shape[0]))
    sop.matvec(x)
    before = n_matfree_traces()
    sop2 = matfree_operator(plan, wf.diffusion(rho * 2.0)).sharded()
    y2 = sop2.matvec(x)
    assert n_matfree_traces() == before
    np.testing.assert_allclose(
        np.asarray(y2), 2.0 * np.asarray(sop.matvec(x)), atol=1e-12)


# ---------------------------------------------------------------------------
# registry / consumer dispatch
# ---------------------------------------------------------------------------

def test_registry_has_streaming_and_sharded_backends():
    assert set(MATVEC_BACKENDS) >= {"csr", "ell", "ell_pallas", "ell_stream",
                                    "matfree", "matfree_sharded"}


def test_registry_dispatch_parity():
    m, space, plan = _setup(8)
    form = wf.diffusion(1.0) + 0.2 * wf.mass()
    k = assemble(plan, form)
    op = matfree_operator(plan, form)
    x = jnp.asarray(RNG.standard_normal(k.shape[0]))
    f = jnp.asarray(RNG.standard_normal(k.shape[0]))
    ref = np.asarray(k.matvec(x))
    for backend, target in [("ell_stream", k), ("matfree_sharded", op)]:
        mv = make_matvec(target, backend)
        rs = make_residual(target, backend)
        np.testing.assert_allclose(np.asarray(mv(x)), ref, atol=1e-12)
        np.testing.assert_allclose(
            np.asarray(rs(x, f)), ref - np.asarray(f), atol=1e-12)
    # already-sharded operators pass through unchanged
    mv = make_matvec(op.sharded(), "matfree_sharded")
    np.testing.assert_allclose(np.asarray(mv(x)), ref, atol=1e-12)


def test_registry_sharded_rejects_csr():
    m, space, plan = _setup(4)
    k = assemble(plan, wf.diffusion())
    with pytest.raises(TypeError, match="matrix-free"):
        make_matvec(k, "matfree_sharded")
    with pytest.raises(TypeError, match="CSR"):
        make_matvec(matfree_operator(plan, wf.diffusion()), "ell_stream")


def test_poisson_problem_sharded_backend():
    p = PoissonProblem(unit_cube_tet(4))
    r0 = p.solve(backend="matfree", tol=1e-12)
    r1 = p.solve(backend="matfree_sharded", tol=1e-12)
    assert r1.converged
    np.testing.assert_allclose(np.asarray(r1.u), np.asarray(r0.u), atol=1e-12)


def test_theta_integrator_sharded_backend():
    m, space, plan = _setup(8)
    asm = GalerkinAssembler(space)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    u0 = bc.project_residual(jnp.asarray(RNG.standard_normal(space.num_dofs)))
    kw = dict(dt=0.01, theta=CRANK_NICOLSON, bc=bc, tol=1e-12)
    t0 = ThetaIntegrator.from_form(asm, wf.diffusion(1.0),
                                   backend="matfree", **kw)
    t1 = ThetaIntegrator.from_form(asm, wf.diffusion(1.0),
                                   backend="matfree_sharded", **kw)
    assert isinstance(t1.lhs_full, ShardedMatFreeOperator)
    np.testing.assert_allclose(
        np.asarray(t1.rollout(u0, 5)), np.asarray(t0.rollout(u0, 5)),
        atol=1e-12)


# ---------------------------------------------------------------------------
# streaming SpMV end-to-end: the CI-scale proof of the million-DOF path
# (same kernel + schedule, reduced N; full N runs in bench_solver_scaling)
# ---------------------------------------------------------------------------

def test_streaming_backend_poisson_solve_end_to_end():
    p = PoissonProblem(unit_square_tri(16))
    r0 = p.solve(backend="csr", tol=1e-12)
    r1 = p.solve(backend="ell_stream", tol=1e-12)
    assert r1.converged
    np.testing.assert_allclose(np.asarray(r1.u), np.asarray(r0.u), atol=1e-10)
