"""repro.transient tests: MMS convergence orders (BE vs CN), exactness with
time-varying Dirichlet data, Newmark energy conservation, Newton–Krylov on
Allen–Cahn, adjoint grad-check through a scanned rollout, batched vmap+jit
rollouts, and backend/checkpoint equivalences."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401  (x64 on)
from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    unit_square_tri,
)
from repro.core.mesh import element_for_mesh
from repro.transient import (
    CRANK_NICOLSON,
    NewmarkIntegrator,
    NewtonKrylovIntegrator,
    ThetaIntegrator,
    batched_rollout,
    segmented_scan,
)


@pytest.fixture(scope="module")
def heat_setup():
    m = unit_square_tri(8)
    sp = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(sp)
    bc = DirichletCondenser(asm, sp.boundary_dofs())
    return m, sp, asm, bc, asm.assemble_mass(), asm.assemble_stiffness()


def _interior_dense(mat, free):
    return np.asarray(mat.to_dense())[np.ix_(free, free)]


# ---------------------------------------------------------------------------
# θ-method
# ---------------------------------------------------------------------------

def test_theta_mms_convergence_orders(heat_setup):
    """Heat MMS: observed temporal order ≈1 for backward Euler, ≈2 for
    Crank–Nicolson, against the exact decay of a discrete eigenmode."""
    import scipy.linalg as sla

    m, sp, asm, bc, mass, stiff = heat_setup
    free = np.asarray(bc.free_mask, dtype=bool)
    md = _interior_dense(mass, free)
    kd = _interior_dense(stiff, free)
    lam, vecs = sla.eigh(kd, md)
    u0f = vecs[:, 0] / np.linalg.norm(vecs[:, 0])
    u0 = np.zeros(sp.num_dofs)
    u0[free] = u0f
    u0 = jnp.asarray(u0)
    t_final = 0.05
    u_exact = np.exp(-lam[0] * t_final) * u0f

    orders = {}
    for theta in (1.0, CRANK_NICOLSON):
        errs = []
        for nsteps in (4, 8, 16):
            integ = ThetaIntegrator(
                mass, stiff, dt=t_final / nsteps, theta=theta, bc=bc, tol=1e-13
            )
            traj = integ.rollout(u0, nsteps)
            errs.append(float(np.linalg.norm(np.asarray(traj[-1])[free] - u_exact)))
        orders[theta] = [np.log2(errs[i] / errs[i + 1]) for i in range(2)]

    for p in orders[1.0]:
        assert 0.8 < p < 1.25, f"backward Euler order {p} not ≈1"
    for p in orders[CRANK_NICOLSON]:
        assert 1.8 < p < 2.3, f"Crank–Nicolson order {p} not ≈2"


def test_theta_exact_on_linear_in_time_with_moving_dirichlet(heat_setup):
    """u(x,t) = t(1+x+y): u_t = 1+x+y, Δu = 0 — backward Euler reproduces
    the semidiscrete solution to solver tolerance, exercising per-step
    time-varying Dirichlet data inside the lax.scan (no condenser rebuild)."""
    m, sp, asm, bc, mass, stiff = heat_setup
    w = jnp.asarray(1.0 + sp.dof_points[:, 0] + sp.dof_points[:, 1])
    load = mass.matvec(w)                                    # ∫(1+x+y)φ = M w
    n_steps, dt = 10, 0.01
    integ = ThetaIntegrator(mass, stiff, dt=dt, theta=1.0, bc=bc, tol=1e-13)
    bcd = jnp.asarray(bc.bc_dofs)
    g = jnp.stack([(n + 1) * dt * w[bcd] for n in range(n_steps)])  # (T, n_bc)
    traj = integ.rollout(jnp.zeros(sp.num_dofs), n_steps, loads=load, bc_values=g)
    exact = n_steps * dt * w
    np.testing.assert_allclose(np.asarray(traj[-1]), np.asarray(exact), atol=1e-10)


def test_theta_ell_backend_matches_csr(heat_setup):
    m, sp, asm, bc, mass, stiff = heat_setup
    pts = sp.dof_points
    u0 = (
        jnp.sin(np.pi * jnp.asarray(pts[:, 0]))
        * jnp.sin(np.pi * jnp.asarray(pts[:, 1]))
    ) * bc.free_mask
    kw = dict(dt=5e-3, theta=CRANK_NICOLSON, bc=bc, tol=1e-13)
    a = ThetaIntegrator(mass, stiff, **kw).rollout(u0, 3)
    b = ThetaIntegrator(mass, stiff, backend="ell", **kw).rollout(u0, 3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-10)


def test_grad_through_rollout_matches_finite_differences(heat_setup):
    """∂(trajectory loss)/∂κ through the scanned rollout (adjoint sparse
    solves) vs central finite differences — ≤1e-4 relative error."""
    m = unit_square_tri(5)
    sp = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(sp)
    bc = DirichletCondenser(asm, sp.boundary_dofs())
    mass = asm.assemble_mass()
    pts = sp.dof_points
    u0 = (
        jnp.sin(np.pi * jnp.asarray(pts[:, 0]))
        * jnp.sin(np.pi * jnp.asarray(pts[:, 1]))
    ) * bc.free_mask

    def loss(kappa):
        stiff = asm.assemble_stiffness(kappa)
        integ = ThetaIntegrator(mass, stiff, dt=0.01, theta=CRANK_NICOLSON,
                                bc=bc, tol=1e-13)
        return jnp.sum(integ.rollout(u0, 5) ** 2)

    kappa = jnp.ones(m.num_cells)
    grad = jax.grad(loss)(kappa)
    v = jnp.asarray(np.random.default_rng(0).normal(size=m.num_cells))
    eps = 1e-5
    fd = (loss(kappa + eps * v) - loss(kappa - eps * v)) / (2 * eps)
    ad = jnp.vdot(grad, v)
    assert abs(float(fd - ad)) / abs(float(fd)) < 1e-4


def test_checkpoint_segmentation_preserves_values_and_grads(heat_setup):
    m, sp, asm, bc, mass, stiff = heat_setup
    pts = sp.dof_points
    u0 = (
        jnp.sin(np.pi * jnp.asarray(pts[:, 0]))
        * jnp.sin(np.pi * jnp.asarray(pts[:, 1]))
    ) * bc.free_mask

    def loss(u0, ck):
        integ = ThetaIntegrator(mass, stiff, dt=0.01, theta=1.0, bc=bc, tol=1e-13)
        return jnp.sum(integ.rollout(u0, 8, checkpoint_every=ck) ** 2)

    np.testing.assert_allclose(
        float(loss(u0, None)), float(loss(u0, 4)), rtol=1e-14
    )
    ga = jax.grad(lambda u: loss(u, None))(u0)
    gb = jax.grad(lambda u: loss(u, 4))(u0)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), atol=1e-12)
    with pytest.raises(ValueError):
        segmented_scan(lambda c, _: (c, c), u0, None, 7, checkpoint_every=3)


def test_batched_rollout_vmap_under_jit(heat_setup):
    """A vmapped batch of 8 trajectories runs under jit and each row
    matches the unbatched rollout."""
    m, sp, asm, bc, mass, stiff = heat_setup
    integ = ThetaIntegrator(mass, stiff, dt=0.01, theta=1.0, bc=bc, tol=1e-12)
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    u0s = jax.vmap(
        lambda k: jax.random.normal(k, (sp.num_dofs,)) * bc.free_mask
    )(keys)
    batched = jax.jit(lambda b: batched_rollout(integ, b, 4))(u0s)
    assert batched.shape == (8, 4, sp.num_dofs)
    single = integ.rollout(u0s[3], 4)
    np.testing.assert_allclose(np.asarray(batched[3]), np.asarray(single), atol=1e-10)


# ---------------------------------------------------------------------------
# Newmark-β
# ---------------------------------------------------------------------------

def test_newmark_energy_conservation(heat_setup):
    """β=¼, γ=½ with F=0 conserves E = ½(vᵀMv + uᵀKu) to solver tolerance
    over 200 steps of the wave equation."""
    m, sp, asm, bc, mass, stiff = heat_setup
    pts = sp.dof_points
    u0 = (
        jnp.sin(np.pi * jnp.asarray(pts[:, 0]))
        * jnp.sin(np.pi * jnp.asarray(pts[:, 1]))
    ) * bc.free_mask
    nm = NewmarkIntegrator(mass, stiff, dt=0.01, bc=bc, tol=1e-12)
    u_traj, v_traj = nm.rollout(u0, 200, return_velocity=True)
    assert not bool(jnp.any(jnp.isnan(u_traj)))

    def energy(u, v):
        return 0.5 * (jnp.vdot(v, mass.matvec(v)) + jnp.vdot(u, stiff.matvec(u)))

    e0 = energy(u0, jnp.zeros_like(u0))
    es = jax.vmap(energy)(u_traj, v_traj)
    drift = float(jnp.abs(es - e0).max() / e0)
    assert drift < 1e-6, f"Newmark energy drift {drift}"


# ---------------------------------------------------------------------------
# Newton–Krylov (semilinear)
# ---------------------------------------------------------------------------

def test_newton_krylov_allen_cahn_residual_small(heat_setup):
    """BE+Newton on Allen–Cahn: the produced steps nearly zero the discrete
    residual, and the jvp-derived r′ matches the analytic Jacobian path."""
    m, sp, asm, bc, mass, stiff = heat_setup
    eps2 = 1.0
    reaction = lambda u: -eps2 * u * (u**2 - 1.0)
    pts = sp.dof_points
    u0 = (
        jnp.sin(np.pi * jnp.asarray(pts[:, 0]))
        * jnp.sin(np.pi * jnp.asarray(pts[:, 1]))
    ) * bc.free_mask

    nk = NewtonKrylovIntegrator(
        asm, mass, stiff, dt=1e-3, reaction=reaction,
        diffusion_scale=1e-2, bc=bc, newton_iters=4, tol=1e-12,
    )
    traj = nk.rollout(u0, 5)
    assert not bool(jnp.any(jnp.isnan(traj)))
    res = nk.residual(traj[-2], traj[-1])
    assert float(jnp.linalg.norm(res)) < 1e-8

    # jvp-derived derivative equals the closed form −ε²(3u²−1)
    u = jnp.linspace(-1.5, 1.5, 7)
    np.testing.assert_allclose(
        np.asarray(nk.reaction_prime(u)),
        np.asarray(-eps2 * (3 * u**2 - 1.0)),
        atol=1e-12,
    )


# ---------------------------------------------------------------------------
# DirichletCondenser lift (time-varying values API)
# ---------------------------------------------------------------------------

def test_condenser_lift_matches_apply(heat_setup):
    m, sp, asm, bc, mass, stiff = heat_setup
    f = jnp.asarray(np.random.default_rng(1).normal(size=sp.num_dofs))
    g = jnp.asarray(np.random.default_rng(2).normal(size=bc.bc_dofs.shape[0]))
    k_cond, f_cond = bc.apply(stiff, f, g)
    np.testing.assert_allclose(
        np.asarray(bc.lift(stiff, f, g)), np.asarray(f_cond), atol=1e-14
    )
    np.testing.assert_allclose(
        np.asarray(bc.apply_matrix_only(stiff).vals), np.asarray(k_cond.vals),
        atol=1e-14,
    )
    # full-field and scalar encodings agree with the (n_bc,) encoding
    full = jnp.zeros(sp.num_dofs).at[jnp.asarray(bc.bc_dofs)].set(g)
    np.testing.assert_allclose(
        np.asarray(bc.boundary_field(g)), np.asarray(bc.boundary_field(full)),
        atol=1e-14,
    )
    np.testing.assert_allclose(
        np.asarray(bc.boundary_field(2.0)),
        np.asarray(bc.boundary_field(jnp.full(bc.bc_dofs.shape[0], 2.0))),
        atol=1e-14,
    )
