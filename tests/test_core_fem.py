"""Unit + integration tests for the TensorGalerkin core (assembly, solvers, BCs)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
import scipy.sparse.linalg as spla

from repro.core import (
    CSR,
    DirichletCondenser,
    FacetAssembler,
    FunctionSpace,
    GalerkinAssembler,
    cg,
    bicgstab,
    csr_to_ell,
    disk_tri,
    hollow_cube_tet,
    jacobi_preconditioner,
    l_shape_tri,
    rectangle_tri,
    sparse_solve,
    unit_cube_tet,
    unit_square_tri,
)
from repro.core.elements import get_element
from repro.core.mesh import element_for_mesh
from repro.core.quadrature import triangle_rule, tetrahedron_rule, quad_rule


# ---------------------------------------------------------------------------
# quadrature + elements
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", [1, 2, 3, 4])
def test_triangle_rule_exactness(order):
    pts, w = triangle_rule(order)
    # integrate x^p y^q over unit triangle: p!q!/(p+q+2)!
    import math

    for p in range(order + 1):
        for q in range(order + 1 - p):
            exact = math.factorial(p) * math.factorial(q) / math.factorial(p + q + 2)
            approx = np.sum(w * pts[:, 0] ** p * pts[:, 1] ** q)
            np.testing.assert_allclose(approx, exact, rtol=1e-12, err_msg=f"{p},{q}")


@pytest.mark.parametrize("order", [1, 2, 3])
def test_tet_rule_exactness(order):
    import math

    pts, w = tetrahedron_rule(order)
    for p in range(order + 1):
        for q in range(order + 1 - p):
            for r in range(order + 1 - p - q):
                exact = (
                    math.factorial(p) * math.factorial(q) * math.factorial(r)
                    / math.factorial(p + q + r + 3)
                )
                approx = np.sum(w * pts[:, 0] ** p * pts[:, 1] ** q * pts[:, 2] ** r)
                np.testing.assert_allclose(approx, exact, rtol=1e-11)


@pytest.mark.parametrize(
    "name", ["P1_tri", "P2_tri", "P1_tet", "Q1_quad", "Q1_hex", "P1_line"]
)
def test_partition_of_unity(name):
    el = get_element(name)
    pts, _ = el.default_rule()
    vals = el.tabulate(pts)
    np.testing.assert_allclose(vals.sum(axis=1), 1.0, atol=1e-12)
    grads = el.tabulate_grad(pts)
    np.testing.assert_allclose(grads.sum(axis=1), 0.0, atol=1e-12)


def test_element_nodal_property():
    # φ_a(x̂_b) = δ_ab at the element's nodes
    el = get_element("P1_tri")
    nodes = np.array([[0.0, 0.0], [1.0, 0.0], [0.0, 1.0]])
    np.testing.assert_allclose(el.tabulate(nodes), np.eye(3), atol=1e-14)


# ---------------------------------------------------------------------------
# assembly correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_fn", [unit_square_tri, l_shape_tri])
def test_assembly_matches_loop_baseline(mesh_fn):
    m = mesh_fn(5)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k_mr = np.asarray(asm.assemble_stiffness().to_dense())
    k_loop = asm.assemble_stiffness_loop()
    np.testing.assert_allclose(k_mr, k_loop, atol=1e-12)


def test_assembly_scatter_baseline_agrees():
    m = unit_square_tri(6)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k1 = np.asarray(asm.assemble_stiffness().to_dense())
    k2 = np.asarray(asm.assemble_stiffness_scatter())
    np.testing.assert_allclose(k1, k2, atol=1e-12)


def test_reduce_modes_agree():
    m = unit_square_tri(7)
    space = FunctionSpace(m, element_for_mesh(m))
    a_sorted = GalerkinAssembler(space, reduce_mode="sorted")
    a_direct = GalerkinAssembler(space, reduce_mode="direct")
    np.testing.assert_allclose(
        np.asarray(a_sorted.assemble_stiffness().vals),
        np.asarray(a_direct.assemble_stiffness().vals),
        atol=1e-13,
    )


def test_assembly_deterministic():
    m = unit_square_tri(9)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    v1 = np.asarray(asm.assemble_stiffness().vals)
    v2 = np.asarray(asm.assemble_stiffness().vals)
    assert np.array_equal(v1, v2)  # bit-identical (paper's determinism claim)


def test_stiffness_symmetric_psd():
    m = unit_cube_tet(3)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k = np.asarray(asm.assemble_stiffness().to_dense())
    np.testing.assert_allclose(k, k.T, atol=1e-13)
    w = np.linalg.eigvalsh(k)
    assert w.min() > -1e-10  # PSD (singular until BCs applied)


def test_mass_matrix_total_volume():
    m = unit_square_tri(6)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    mass = np.asarray(asm.assemble_mass().to_dense())
    np.testing.assert_allclose(mass.sum(), 1.0, rtol=1e-12)  # ∫∫ 1 = |Ω|


def test_load_vector_total_integral():
    m = unit_cube_tet(4)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    f = asm.assemble_load(2.5)
    np.testing.assert_allclose(float(jnp.sum(f)), 2.5, rtol=1e-12)


def test_nodal_coefficient_interpolation():
    # ρ(x) = x+y nodal field must give same K as the callable version
    m = unit_square_tri(5)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k_callable = asm.assemble_stiffness(lambda x: x[..., 0] + x[..., 1])
    nodal = jnp.asarray(space.dof_points[:, 0] + space.dof_points[:, 1])
    k_nodal = asm.assemble_stiffness(nodal)
    np.testing.assert_allclose(
        np.asarray(k_callable.vals), np.asarray(k_nodal.vals), atol=1e-12
    )


def test_assembly_trace_is_o1_in_elements():
    """The paper's O(1)-graph property: jaxpr size independent of E."""
    sizes = []
    for n in (4, 16):
        m = unit_square_tri(n)
        space = FunctionSpace(m, element_for_mesh(m))
        asm = GalerkinAssembler(space)

        def assemble(coords, rho):
            ctx = asm.context(coords)
            from repro.core import forms
            from repro.core.assembly import reduce_matrix

            return reduce_matrix(forms.diffusion(ctx, rho), asm.mat_routing)

        jaxpr = jax.make_jaxpr(assemble)(asm.coords, jnp.ones(m.num_cells))
        sizes.append(len(jaxpr.jaxpr.eqns))
    assert sizes[0] == sizes[1], f"graph grew with E: {sizes}"


# ---------------------------------------------------------------------------
# solvers
# ---------------------------------------------------------------------------

def _poisson_system(n=8, dim=2):
    m = unit_square_tri(n) if dim == 2 else unit_cube_tet(n)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k = asm.assemble_stiffness()
    f = asm.assemble_load(1.0)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    return bc.apply(k, f) + (space,)


@pytest.mark.parametrize("method", [cg, bicgstab])
def test_krylov_matches_scipy(method):
    k, f, _ = _poisson_system()
    x, info = method(k.matvec, f, m=jacobi_preconditioner(k), tol=1e-12)
    x_ref = spla.spsolve(k.to_scipy().tocsc(), np.asarray(f))
    np.testing.assert_allclose(np.asarray(x), x_ref, atol=1e-9)
    assert float(info.residual) < 1e-9


def test_solver_residual_meets_paper_tolerance():
    # Paper SM B.1.2: relative residual < 1e-10
    k, f, _ = _poisson_system(10)
    x, _ = bicgstab(k.matvec, f, m=jacobi_preconditioner(k), tol=1e-10)
    rel = float(jnp.linalg.norm(k.matvec(x) - f) / jnp.linalg.norm(f))
    assert rel < 1e-10


def test_ell_spmv_matches_csr():
    k, f, _ = _poisson_system(7)
    ell = csr_to_ell(k)
    x = jnp.asarray(np.random.default_rng(0).normal(size=f.shape))
    np.testing.assert_allclose(
        np.asarray(ell.matvec(x)), np.asarray(k.matvec(x)), atol=1e-12
    )


def test_csr_matmat_batched_rhs():
    k, f, _ = _poisson_system(6)
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.normal(size=(f.shape[0], 5)))
    got = np.asarray(k.matmat(xs))
    want = k.to_scipy() @ np.asarray(xs)
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_sparse_solve_adjoint_gradient():
    k, f, _ = _poisson_system(6)

    def loss_vals(vals):
        kv = CSR(vals, k.indptr, k.indices, k.row_of_nnz, k.shape, k.diag_pos)
        return jnp.sum(sparse_solve(kv, f, "cg", 1e-12, 1e-12) ** 2)

    def loss_rhs(b):
        return jnp.sum(sparse_solve(k, b, "cg", 1e-12, 1e-12) ** 2)

    g_vals = jax.grad(loss_vals)(k.vals)
    g_rhs = jax.grad(loss_rhs)(f)
    rng = np.random.default_rng(2)
    nz = np.nonzero(np.abs(np.asarray(g_vals)) > 1e-6)[0]
    for i in rng.choice(nz, 3, replace=False):
        eps = 1e-6
        fd = (loss_vals(k.vals.at[i].add(eps)) - loss_vals(k.vals.at[i].add(-eps))) / (2 * eps)
        np.testing.assert_allclose(float(g_vals[i]), float(fd), rtol=5e-3)
    i = int(np.argmax(np.abs(np.asarray(g_rhs))))
    eps = 1e-6
    fd = (loss_rhs(f.at[i].add(eps)) - loss_rhs(f.at[i].add(-eps))) / (2 * eps)
    np.testing.assert_allclose(float(g_rhs[i]), float(fd), rtol=1e-5)


# ---------------------------------------------------------------------------
# boundary conditions
# ---------------------------------------------------------------------------

def test_inhomogeneous_dirichlet_exact_linear():
    # u = x solves Laplace; impose u=x on boundary, solution must be exact.
    m = unit_square_tri(6)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k = asm.assemble_stiffness()
    f = jnp.zeros(space.num_dofs)
    bdofs = space.boundary_dofs()
    bvals = jnp.asarray(space.dof_points[bdofs, 0])
    bc = DirichletCondenser(asm, bdofs)
    kc, fc = bc.apply(k, f, bvals)
    u, _ = cg(kc.matvec, fc, m=jacobi_preconditioner(kc), tol=1e-13)
    np.testing.assert_allclose(np.asarray(u), space.dof_points[:, 0], atol=1e-10)


def test_mixed_bc_analytic_disk():
    """Robin BC du/dn + u = g chosen so u = x² + y² − r²/2·… — simpler:
    verify pure-Neumann compatibility instead: −Δu = 0, du/dn = cos θ on the
    unit-ish disk has u = x (up to constant); pin one DoF."""
    m = disk_tri(10, center=(0.0, 0.0), radius=1.0)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k = asm.assemble_stiffness()
    facets = m.boundary_facets()
    fa = FacetAssembler(space, facets, volume_routing=asm.mat_routing)
    # du/dn on r=1 for u=x is x/r = x
    g = fa.neumann_load(lambda x: x[..., 0])
    # Robin with α=1: du/dn + u = 2x on the boundary → same solution u = x
    k_r = fa.add_robin(k, 1.0)
    g2 = fa.neumann_load(lambda x: 2.0 * x[..., 0])
    u, info = bicgstab(k_r.matvec, g2, m=jacobi_preconditioner(k_r), tol=1e-12)
    exact = space.dof_points[:, 0]
    err = np.linalg.norm(np.asarray(u) - exact) / np.linalg.norm(exact)
    assert err < 5e-3, err  # O(h²) discretization error on the polygonal disk
    assert float(info.residual) < 1e-9


# ---------------------------------------------------------------------------
# convergence (validates paper's accuracy claims)
# ---------------------------------------------------------------------------

def _poisson_error(n, degree):
    m = unit_square_tri(n)
    el = get_element("P1_tri" if degree == 1 else "P2_tri")
    space = FunctionSpace(m, el)
    asm = GalerkinAssembler(space)
    f = lambda x: 2 * np.pi**2 * jnp.sin(np.pi * x[..., 0]) * jnp.sin(np.pi * x[..., 1])
    k = asm.assemble_stiffness()
    load = asm.assemble_load(f)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    kc, fc = bc.apply(k, load)
    u, _ = cg(kc.matvec, fc, m=jacobi_preconditioner(kc), tol=1e-13)
    exact = np.sin(np.pi * space.dof_points[:, 0]) * np.sin(np.pi * space.dof_points[:, 1])
    # L2 norm via mass matrix
    mass = asm.assemble_mass() if degree == 1 else GalerkinAssembler(space).assemble_mass()
    e = jnp.asarray(np.asarray(u) - exact)
    return float(jnp.sqrt(e @ mass.matvec(e)))


def test_p1_h_convergence_rate():
    e1, e2 = _poisson_error(8, 1), _poisson_error(16, 1)
    rate = np.log2(e1 / e2)
    assert 1.8 < rate < 2.2, rate


def test_p2_more_accurate_than_p1():
    assert _poisson_error(8, 2) < 0.05 * _poisson_error(8, 1)


def test_3d_poisson_vs_scipy():
    m = unit_cube_tet(5)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    k = asm.assemble_stiffness()
    f = asm.assemble_load(1.0)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    kc, fc = bc.apply(k, f)
    u, _ = cg(kc.matvec, fc, m=jacobi_preconditioner(kc), tol=1e-12)
    u_ref = spla.spsolve(kc.to_scipy().tocsc(), np.asarray(fc))
    np.testing.assert_allclose(np.asarray(u), u_ref, atol=1e-9)


def test_elasticity_3d_hollow_cube_solves():
    m = hollow_cube_tet(6)
    space = FunctionSpace(m, element_for_mesh(m), value_size=3)
    asm = GalerkinAssembler(space)
    e_mod, nu = 1.0, 0.3
    lam = e_mod * nu / ((1 + nu) * (1 - 2 * nu))
    mu = e_mod / (2 * (1 + nu))
    k = asm.assemble_elasticity(lam, mu)
    f = asm.assemble_load(jnp.array([1.0, 1.0, 1.0]))
    bc = DirichletCondenser(asm, space.boundary_dofs())
    kc, fc = bc.apply(k, f)
    u, info = bicgstab(kc.matvec, fc, m=jacobi_preconditioner(kc), tol=1e-10)
    assert float(info.residual) < 1e-8
    assert float(jnp.abs(u).max()) > 0  # nontrivial interior displacement
    rel = float(jnp.linalg.norm(kc.matvec(u) - fc) / jnp.linalg.norm(fc))
    assert rel < 1e-8


def test_elasticity_rigid_body_nullspace():
    # translations are in the kernel of the unconstrained elasticity operator
    m = unit_square_tri(4)
    space = FunctionSpace(m, element_for_mesh(m), value_size=2)
    asm = GalerkinAssembler(space)
    k = asm.assemble_elasticity(1.0, 1.0)
    tx = jnp.zeros(space.num_dofs).at[0::2].set(1.0)
    np.testing.assert_allclose(np.asarray(k.matvec(tx)), 0.0, atol=1e-11)
