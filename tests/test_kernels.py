"""Pallas kernel tests: shape/dtype sweeps + allclose vs the ref.py oracles
(interpret=True executes kernel bodies in Python on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401  (x64 on)
from repro.core import FunctionSpace, GalerkinAssembler, csr_to_ell, unit_square_tri, unit_cube_tet
from repro.core.mesh import element_for_mesh
from repro.kernels import batch_map_stiffness, ell_matvec, ell_residual
from repro.kernels.local_assembly import local_stiffness_p1
from repro.kernels.ref import (
    galerkin_residual_ell_ref,
    local_stiffness_p1_ref,
    spmv_ell_ref,
)
from repro.kernels.spmv_ell import (
    autotune_stream,
    galerkin_residual_ell_stream,
    spmv_ell,
    spmv_ell_stream,
    stream_vmem_bytes,
)


def _random_simplices(rng, e, d, dtype):
    ident = np.concatenate([np.zeros((1, d)), np.eye(d)], axis=0)
    base = rng.normal(size=(e, 1, d))
    jitter = 0.15 * rng.normal(size=(e, d + 1, d))
    return jnp.asarray((base + ident[None] + jitter).astype(dtype))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("e", [1, 7, 129, 2048, 5000])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_local_assembly_sweep(d, e, dtype):
    rng = np.random.default_rng(e * d)
    coords = _random_simplices(rng, e, d, dtype)
    rho = jnp.asarray(rng.uniform(0.5, 2.0, size=e).astype(dtype))
    got = batch_map_stiffness(coords, rho, interpret=True)
    want = local_stiffness_p1_ref(coords, rho)
    tol = 2e-4 if dtype == np.float32 else 1e-11
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_e", [128, 512])
def test_local_assembly_block_size_invariance(block_e):
    rng = np.random.default_rng(3)
    coords = _random_simplices(rng, 700, 2, np.float64)
    rho = jnp.ones(700)
    a = local_stiffness_p1(coords, rho, interpret=True, block_e=block_e)
    b = local_stiffness_p1_ref(coords, rho)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-12)


def test_local_assembly_matches_full_assembler():
    """Kernel output → Sparse-Reduce must equal the einsum assembler's K."""
    from repro.core.assembly import reduce_matrix

    m = unit_cube_tet(4)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rho = jnp.asarray(np.random.default_rng(0).uniform(0.5, 2, m.num_cells))
    k_ref = asm.assemble_stiffness(rho)
    k_local = batch_map_stiffness(asm.coords, rho, interpret=True)
    vals = reduce_matrix(k_local, asm.mat_routing)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(k_ref.vals), atol=1e-12)


@pytest.mark.parametrize("n,l", [(5, 1), (100, 7), (4096, 16), (6000, 9)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_spmv_ell_sweep(n, l, dtype):
    rng = np.random.default_rng(n + l)
    vals = jnp.asarray(rng.normal(size=(n, l)).astype(dtype))
    cols = jnp.asarray(rng.integers(0, n, size=(n, l)))
    x = jnp.asarray(rng.normal(size=n).astype(dtype))
    got = spmv_ell(vals, cols, x, interpret=True)
    want = spmv_ell_ref(vals, cols, x)
    tol = 1e-4 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


def test_spmv_matches_csr_on_fem_matrix():
    m = unit_square_tri(15)
    space = FunctionSpace(m, element_for_mesh(m))
    k = GalerkinAssembler(space).assemble_stiffness()
    ell = csr_to_ell(k)
    x = jnp.asarray(np.random.default_rng(1).normal(size=k.shape[0]))
    np.testing.assert_allclose(
        np.asarray(ell_matvec(ell, x, interpret=True)),
        np.asarray(k.matvec(x)),
        atol=1e-12,
    )


def test_fused_residual():
    rng = np.random.default_rng(9)
    n, l = 513, 5
    vals = jnp.asarray(rng.normal(size=(n, l)))
    cols = jnp.asarray(rng.integers(0, n, size=(n, l)))
    u = jnp.asarray(rng.normal(size=n))
    f = jnp.asarray(rng.normal(size=n))
    got = ell_residual(
        type("E", (), {"vals": vals, "cols": np.asarray(cols)})(), u, f,
        interpret=True,
    )
    want = galerkin_residual_ell_ref(vals, cols, u, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


# ---------------------------------------------------------------------------
# streaming SpMV (HBM-resident x, double-buffered row blocks)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,l,block_n", [
    (1000, 7, 256),   # N not divisible by block_n
    (300, 1, 128),    # L = 1
    (100, 5, 4096),   # N < block_n
    (4096, 9, 1024),  # exact multiple
    (129, 3, 128),    # one full block + remainder of 1
])
@pytest.mark.parametrize("nbuf", [2, 3])
def test_spmv_stream_sweep(n, l, block_n, nbuf):
    rng = np.random.default_rng(n + l + nbuf)
    vals = jnp.asarray(rng.normal(size=(n, l)))
    cols = np.sort(rng.integers(0, n, size=(n, l)))  # FEM-like locality
    x = jnp.asarray(rng.normal(size=n))
    got = spmv_ell_stream(vals, cols, x, interpret=True,
                          block_n=block_n, nbuf=nbuf)
    want = spmv_ell_ref(vals, jnp.asarray(cols), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


def test_stream_matches_broadcast_on_fem_matrix():
    m = unit_square_tri(15)
    space = FunctionSpace(m, element_for_mesh(m))
    k = GalerkinAssembler(space).assemble_stiffness()
    ell = csr_to_ell(k)
    x = jnp.asarray(np.random.default_rng(1).normal(size=k.shape[0]))
    from repro.kernels import ell_matvec_stream

    np.testing.assert_allclose(
        np.asarray(ell_matvec_stream(ell, x, interpret=True, block_n=64)),
        np.asarray(k.matvec(x)),
        atol=1e-12,
    )


def test_fused_residual_stream():
    rng = np.random.default_rng(11)
    n, l = 513, 4
    vals = jnp.asarray(rng.normal(size=(n, l)))
    cols = np.sort(rng.integers(0, n, size=(n, l)))
    u = jnp.asarray(rng.normal(size=n))
    f = jnp.asarray(rng.normal(size=n))
    got = galerkin_residual_ell_stream(vals, cols, u, f, interpret=True,
                                       block_n=128)
    want = galerkin_residual_ell_ref(vals, jnp.asarray(cols), u, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


def test_stream_rejects_traced_cols():
    vals = jnp.ones((8, 2))
    x = jnp.ones(8)

    def f(cols):
        return spmv_ell_stream(vals, cols, x, interpret=True)

    with pytest.raises(TypeError, match="static column table"):
        jax.jit(f)(jnp.zeros((8, 2), dtype=jnp.int32))


def test_stream_vmem_independent_of_n():
    """The whole point: streaming VMEM footprint must not scale with N."""
    small = stream_vmem_bytes(10_000, 7, block_n=1024, nbuf=2, window=2048)
    large = stream_vmem_bytes(10_000_000, 7, block_n=1024, nbuf=2, window=2048)
    assert small == large


def test_autotune_stream_returns_valid_config():
    rng = np.random.default_rng(5)
    n, l = 600, 4
    vals = jnp.asarray(rng.normal(size=(n, l)))
    cols = np.sort(rng.integers(0, n, size=(n, l)))
    x = jnp.asarray(rng.normal(size=n))
    bn, nb = autotune_stream(vals, cols, x, block_candidates=(128, 256),
                             nbuf_candidates=(2,), interpret=True, iters=1)
    assert bn in (128, 256) and nb == 2
    # cached: same layout returns without re-measuring
    assert autotune_stream(vals, cols, x, interpret=True) == (bn, nb)


def test_interpret_default_resolution(monkeypatch):
    """interpret resolves from the active backend (off-TPU → interpret),
    with the env var overriding in both directions."""
    from repro.kernels.spmv_ell import _interpret_default

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert _interpret_default() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert _interpret_default() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert _interpret_default() is True


# ---------------------------------------------------------------------------
# property-based: kernel invariances (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # keep the non-property tests above runnable
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        e=st.integers(min_value=1, max_value=300),
        seed=st.integers(min_value=0, max_value=2**16),
        scale=st.floats(min_value=0.1, max_value=10.0),
    )
    def test_local_stiffness_properties(e, seed, scale):
        """Invariances of the P1 stiffness map: symmetry, zero row-sum
        (constants in kernel), translation invariance, ρ-linearity."""
        rng = np.random.default_rng(seed)
        coords = _random_simplices(rng, e, 2, np.float64)
        rho = jnp.asarray(rng.uniform(0.5, 2.0, size=e))
        k = batch_map_stiffness(coords, rho, interpret=True)
        k_np = np.asarray(k)
        # symmetry
        np.testing.assert_allclose(k_np, np.swapaxes(k_np, 1, 2), atol=1e-11)
        # row sums vanish (gradient of constant)
        np.testing.assert_allclose(k_np.sum(axis=2), 0.0, atol=1e-10)
        # translation invariance
        shifted = coords + jnp.asarray(rng.normal(size=(1, 1, 2)))
        k2 = batch_map_stiffness(shifted, rho, interpret=True)
        np.testing.assert_allclose(k_np, np.asarray(k2), atol=1e-9)
        # linearity in rho
        k3 = batch_map_stiffness(coords, rho * scale, interpret=True)
        np.testing.assert_allclose(np.asarray(k3), k_np * scale, rtol=1e-10, atol=1e-12)

else:

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_local_stiffness_properties():
        pass


# The ELL property tests run EITHER way: under hypothesis they draw shapes
# freely; without it they sweep a hand-picked edge-case grid (N < block_n,
# N % block_n ≠ 0, L = 1) so the contracts stay enforced in minimal CI
# environments too.
_ELL_EDGE_GRID = [
    (1, 1, 128, 0), (127, 1, 128, 1), (128, 1, 128, 2), (129, 4, 128, 3),
    (300, 9, 256, 4), (511, 3, 512, 5), (700, 7, 512, 6), (64, 2, 512, 7),
]


def _check_ell_edge_shapes(n, l, block_n, seed):
    """Both SpMV plans agree with the oracle for arbitrary (N, L, block_n)."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(n, l)))
    cols = np.sort(rng.integers(0, n, size=(n, l)))
    x = jnp.asarray(rng.normal(size=n))
    want = np.asarray(spmv_ell_ref(vals, jnp.asarray(cols), x))
    legacy = spmv_ell(vals, cols, x, interpret=True, block_n=block_n)
    stream = spmv_ell_stream(vals, cols, x, interpret=True, block_n=block_n)
    np.testing.assert_allclose(np.asarray(legacy), want, atol=1e-12)
    np.testing.assert_allclose(np.asarray(stream), want, atol=1e-12)


def _check_ell_padding_invariant(n, l, seed):
    """ELLPACK padding contract on both kernels: slots whose value is zero
    contribute nothing, whatever (valid) column they reference — so the
    layout builders' self-referencing padded columns never alias real
    entries."""
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(n, l))
    cols = np.sort(rng.integers(0, n, size=(n, l)))
    # zero out a random set of slots and retarget their columns at an
    # arbitrary row — the result must not change
    mask = rng.uniform(size=(n, l)) < 0.4
    vals_z = np.where(mask, 0.0, vals)
    cols_alias = np.where(
        mask, np.repeat(np.arange(n)[:, None], l, axis=1), cols
    )
    x = jnp.asarray(rng.normal(size=n))
    want = np.asarray(spmv_ell_ref(jnp.asarray(vals_z), jnp.asarray(cols), x))
    for kern in (spmv_ell, spmv_ell_stream):
        got = kern(jnp.asarray(vals_z), cols_alias, x, interpret=True,
                   block_n=128)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-12)


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=700),
        l=st.integers(min_value=1, max_value=9),
        block_n=st.sampled_from([128, 256, 512]),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_ell_kernels_edge_shapes(n, l, block_n, seed):
        _check_ell_edge_shapes(n, l, block_n, seed)

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=400),
        l=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_ell_padding_invariant(n, l, seed):
        _check_ell_padding_invariant(n, l, seed)

else:

    @pytest.mark.parametrize("n,l,block_n,seed", _ELL_EDGE_GRID)
    def test_ell_kernels_edge_shapes(n, l, block_n, seed):
        _check_ell_edge_shapes(n, l, block_n, seed)

    @pytest.mark.parametrize("n,l,seed",
                             [(2, 1, 0), (97, 3, 1), (256, 6, 2), (400, 4, 3)])
    def test_ell_padding_invariant(n, l, seed):
        _check_ell_padding_invariant(n, l, seed)
