"""Pallas kernel tests: shape/dtype sweeps + allclose vs the ref.py oracles
(interpret=True executes kernel bodies in Python on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401  (x64 on)
from repro.core import FunctionSpace, GalerkinAssembler, csr_to_ell, unit_square_tri, unit_cube_tet
from repro.core.mesh import element_for_mesh
from repro.kernels import batch_map_stiffness, ell_matvec, ell_residual
from repro.kernels.local_assembly import local_stiffness_p1
from repro.kernels.ref import (
    galerkin_residual_ell_ref,
    local_stiffness_p1_ref,
    spmv_ell_ref,
)
from repro.kernels.spmv_ell import spmv_ell


def _random_simplices(rng, e, d, dtype):
    ident = np.concatenate([np.zeros((1, d)), np.eye(d)], axis=0)
    base = rng.normal(size=(e, 1, d))
    jitter = 0.15 * rng.normal(size=(e, d + 1, d))
    return jnp.asarray((base + ident[None] + jitter).astype(dtype))


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("e", [1, 7, 129, 2048, 5000])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_local_assembly_sweep(d, e, dtype):
    rng = np.random.default_rng(e * d)
    coords = _random_simplices(rng, e, d, dtype)
    rho = jnp.asarray(rng.uniform(0.5, 2.0, size=e).astype(dtype))
    got = batch_map_stiffness(coords, rho, interpret=True)
    want = local_stiffness_p1_ref(coords, rho)
    tol = 2e-4 if dtype == np.float32 else 1e-11
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_e", [128, 512])
def test_local_assembly_block_size_invariance(block_e):
    rng = np.random.default_rng(3)
    coords = _random_simplices(rng, 700, 2, np.float64)
    rho = jnp.ones(700)
    a = local_stiffness_p1(coords, rho, interpret=True, block_e=block_e)
    b = local_stiffness_p1_ref(coords, rho)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-12)


def test_local_assembly_matches_full_assembler():
    """Kernel output → Sparse-Reduce must equal the einsum assembler's K."""
    from repro.core.assembly import reduce_matrix

    m = unit_cube_tet(4)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    rho = jnp.asarray(np.random.default_rng(0).uniform(0.5, 2, m.num_cells))
    k_ref = asm.assemble_stiffness(rho)
    k_local = batch_map_stiffness(asm.coords, rho, interpret=True)
    vals = reduce_matrix(k_local, asm.mat_routing)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(k_ref.vals), atol=1e-12)


@pytest.mark.parametrize("n,l", [(5, 1), (100, 7), (4096, 16), (6000, 9)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_spmv_ell_sweep(n, l, dtype):
    rng = np.random.default_rng(n + l)
    vals = jnp.asarray(rng.normal(size=(n, l)).astype(dtype))
    cols = jnp.asarray(rng.integers(0, n, size=(n, l)))
    x = jnp.asarray(rng.normal(size=n).astype(dtype))
    got = spmv_ell(vals, cols, x, interpret=True)
    want = spmv_ell_ref(vals, cols, x)
    tol = 1e-4 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


def test_spmv_matches_csr_on_fem_matrix():
    m = unit_square_tri(15)
    space = FunctionSpace(m, element_for_mesh(m))
    k = GalerkinAssembler(space).assemble_stiffness()
    ell = csr_to_ell(k)
    x = jnp.asarray(np.random.default_rng(1).normal(size=k.shape[0]))
    np.testing.assert_allclose(
        np.asarray(ell_matvec(ell, x, interpret=True)),
        np.asarray(k.matvec(x)),
        atol=1e-12,
    )


def test_fused_residual():
    rng = np.random.default_rng(9)
    n, l = 513, 5
    vals = jnp.asarray(rng.normal(size=(n, l)))
    cols = jnp.asarray(rng.integers(0, n, size=(n, l)))
    u = jnp.asarray(rng.normal(size=n))
    f = jnp.asarray(rng.normal(size=n))
    got = ell_residual(
        type("E", (), {"vals": vals, "cols": np.asarray(cols)})(), u, f,
        interpret=True,
    )
    want = galerkin_residual_ell_ref(vals, cols, u, f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-12)


# ---------------------------------------------------------------------------
# property-based: kernel invariances (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # keep the non-property tests above runnable
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        e=st.integers(min_value=1, max_value=300),
        seed=st.integers(min_value=0, max_value=2**16),
        scale=st.floats(min_value=0.1, max_value=10.0),
    )
    def test_local_stiffness_properties(e, seed, scale):
        """Invariances of the P1 stiffness map: symmetry, zero row-sum
        (constants in kernel), translation invariance, ρ-linearity."""
        rng = np.random.default_rng(seed)
        coords = _random_simplices(rng, e, 2, np.float64)
        rho = jnp.asarray(rng.uniform(0.5, 2.0, size=e))
        k = batch_map_stiffness(coords, rho, interpret=True)
        k_np = np.asarray(k)
        # symmetry
        np.testing.assert_allclose(k_np, np.swapaxes(k_np, 1, 2), atol=1e-11)
        # row sums vanish (gradient of constant)
        np.testing.assert_allclose(k_np.sum(axis=2), 0.0, atol=1e-10)
        # translation invariance
        shifted = coords + jnp.asarray(rng.normal(size=(1, 1, 2)))
        k2 = batch_map_stiffness(shifted, rho, interpret=True)
        np.testing.assert_allclose(k_np, np.asarray(k2), atol=1e-9)
        # linearity in rho
        k3 = batch_map_stiffness(coords, rho * scale, interpret=True)
        np.testing.assert_allclose(np.asarray(k3), k_np * scale, rtol=1e-10, atol=1e-12)

else:

    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_local_stiffness_properties():
        pass
