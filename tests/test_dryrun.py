"""Dry-run machinery tests on a host-sized mesh (the 512-device production
sweep runs via ``python -m repro.launch.dryrun``; these tests validate the
same lowering path + roofline analysis at 8 devices)."""

import dataclasses
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.analysis.roofline import analyze_compiled  # noqa: E402
from repro.configs import ARCHS, SHAPES, smoke_variant  # noqa: E402
from repro.configs.base import ShapeSpec  # noqa: E402
from repro.launch.dryrun import lower_cell, model_flops_for, should_skip  # noqa: E402
from repro.sharding.partitioning import RULES_SINGLE_POD, ShardingRules  # noqa: E402


def _mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(4, 2)


def _rules():
    return RULES_SINGLE_POD


SMOKE_SHAPES = {
    "train": ShapeSpec("train_s", "train", 64, 8),
    "prefill": ShapeSpec("prefill_s", "prefill", 128, 8),
    "decode": ShapeSpec("decode_s", "decode", 128, 8),
}


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_smoke_cell(arch, kind):
    cfg = dataclasses.replace(smoke_variant(ARCHS[arch]), remat=False)
    shape = SMOKE_SHAPES[kind]
    mesh = _mesh()
    compiled, lowered = lower_cell(cfg, shape, mesh, _rules())
    rep = analyze_compiled(
        compiled, arch=arch, shape=shape.name, mesh_name="4x2", chips=8,
        model_flops=model_flops_for(cfg, shape),
    )
    assert rep.flops > 0
    assert rep.hbm_bytes > 0
    assert rep.bottleneck in ("compute", "memory", "collective")
    # sharded program must contain at least one cross-device collective
    assert rep.collective_bytes > 0, (arch, kind)


def test_skip_rules():
    assert should_skip(ARCHS["qwen3-32b"], SHAPES["long_500k"]) is not None
    assert should_skip(ARCHS["rwkv6-1.6b"], SHAPES["long_500k"]) is None
    assert should_skip(ARCHS["zamba2-7b"], SHAPES["long_500k"]) is None
    assert should_skip(ARCHS["whisper-tiny"], SHAPES["decode_32k"]) is None


def test_model_flops_sanity():
    # train ≈ 6·N·tokens; moe uses active params < total
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    assert cfg.active_param_count() < 0.25 * cfg.param_count()
    f_train = model_flops_for(cfg, SHAPES["train_4k"])
    f_dec = model_flops_for(cfg, SHAPES["decode_32k"])
    assert f_train > 1000 * f_dec


def test_production_sweep_results_if_present():
    """When the 512-device sweep has been run, its JSON must show every
    non-skipped cell ok on both meshes."""
    import json

    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("production dry-run not yet executed")
    rows = json.load(open(path))
    seen = {(r["arch"], r["shape"], r["mesh"]): r["status"] for r in rows}
    fails = [k for k, v in seen.items() if v == "fail"]
    assert not fails, fails
    for mesh in ("16x16", "2x16x16"):
        present = [k for k in seen if k[2] == mesh]
        if present:
            # 10 archs × 4 shapes per completed mesh sweep
            archs = {k[0] for k in present}
            for a in archs:
                assert len([k for k in present if k[0] == a]) == 4, a
