"""repro.telemetry spans / SLOs / flight recorder — the PR-10 tentpole.

Acceptance-critical properties:

* spans are zero-cost no-ops when telemetry is disabled (NULL_SPAN) and
  toggling them never retraces a jitted function;
* a span tree propagates one trace_id root → children, folds every closed
  span into the ``span_us`` histogram, and streams valid JSONL rows;
* tag values that are tracers are dropped, never stored;
* ``record_event`` under an open span inherits its trace identity;
* ``_EVENTS`` / ``_HIST_LIMIT`` stay bounded under overflow, and
  concurrent recorders + exporters produce a valid one-row-per-line JSONL
  stream;
* SLO attainment / burn rate match hand-computed values and surface in
  ``snapshot()`` and ``report --slo``;
* the flight recorder ring is bounded and dumps on demand.
"""

import json
import threading

import jax
import jax.numpy as jnp
import pytest

from repro import telemetry
from repro.telemetry import events, metrics, report, spans


def _reset():
    telemetry.disable()
    telemetry.reset()
    telemetry.clear_events()
    telemetry.clear_slos()
    telemetry.clear_flight()
    spans._FLIGHT_PATH = None
    metrics._STATE.jsonl = None  # enable() keeps a stale stream otherwise


@pytest.fixture(autouse=True)
def _clean_telemetry():
    _reset()
    yield
    _reset()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_disabled_spans_are_null_and_record_nothing():
    root = telemetry.span_root("r", x=1)
    assert root is telemetry.NULL_SPAN
    assert not root
    child = root.child("c")
    assert child is telemetry.NULL_SPAN
    assert root.finish(outcome="ok") is telemetry.NULL_SPAN
    assert root.to_dict() is None
    with telemetry.span("ctx") as sp:
        assert sp is telemetry.NULL_SPAN
    assert telemetry.snapshot()["histograms"] == {}


def test_span_tree_trace_id_propagation_and_fold():
    telemetry.enable()
    root = telemetry.span_root("request", backend="csr")
    a = root.child("phase_a")
    a.finish()
    b = root.child("phase_b")
    ba = b.child("phase_b_inner")
    ba.finish()
    b.finish()
    root.finish(outcome="ok")
    assert a.trace_id == b.trace_id == ba.trace_id == root.trace_id
    assert ba.parent_id == b.span_id and b.parent_id == root.span_id
    d = root.to_dict()
    assert [c["name"] for c in d["children"]] == ["phase_a", "phase_b"]
    assert d["children"][1]["children"][0]["name"] == "phase_b_inner"
    assert d["tags"] == {"backend": "csr", "outcome": "ok"}
    assert d["wall_us"] >= d["children"][0]["wall_us"] >= 0
    snap = telemetry.snapshot()
    names = {k for k in snap["histograms"] if k.startswith("span_us")}
    assert {"span_us{span=request}", "span_us{span=phase_a}",
            "span_us{span=phase_b}", "span_us{span=phase_b_inner}"} <= names


def test_span_rows_streamed_as_jsonl(tmp_path):
    stream = str(tmp_path / "t.jsonl")
    telemetry.enable(jsonl=stream)
    root = telemetry.span_root("outer")
    root.child("inner").finish()
    root.finish()
    rows = [json.loads(line) for line in open(stream)]
    by_name = {r["name"]: r for r in rows}
    assert by_name["span/inner"]["trace_id"] == root.trace_id
    assert by_name["span/inner"]["parent_id"] == root.span_id
    assert by_name["span/outer"]["parent_id"] is None
    assert by_name["span/outer"]["us_per_call"] >= 0


def test_span_tags_drop_tracers():
    telemetry.enable()
    root = telemetry.span_root("r")

    @jax.jit
    def f(x):
        root.tag(leaked=x)
        return x * 2

    f(jnp.ones(3))
    root.finish(kept=7)
    assert "leaked" not in root.tags
    assert root.tags["kept"] == 7


def test_span_toggle_never_retraces():
    traces = []

    @jax.jit
    def f(x):
        traces.append(1)
        with telemetry.span("inside_jit"):
            return x + 1

    x = jnp.ones(4)
    f(x)
    telemetry.enable()
    f(x)
    telemetry.disable()
    f(x)
    assert len(traces) == 1


def test_record_event_inherits_current_span():
    telemetry.enable()
    with telemetry.span("driver") as sp:
        events.record_event("solve", "inner", wall_us=1.0, iterations=2)
    ev = telemetry.event_log()[-1]
    assert ev["trace_id"] == sp.trace_id
    assert ev["span_id"] == sp.span_id
    # outside any span: no trace identity attached
    events.record_event("solve", "outer", wall_us=1.0)
    assert "trace_id" not in telemetry.event_log()[-1]


def test_open_children_closed_with_parent():
    telemetry.enable()
    root = telemetry.span_root("r")
    dangling = root.child("dangling")
    root.finish()
    assert dangling.end_ns == root.end_ns


# ---------------------------------------------------------------------------
# bounds + thread-safety (satellite)
# ---------------------------------------------------------------------------

def test_event_log_bounded_under_overflow(monkeypatch):
    monkeypatch.setattr(events, "_EVENT_LIMIT", 16)
    telemetry.enable()
    for i in range(64):
        events.record_event("solve", f"e{i}", wall_us=1.0)
    log = telemetry.event_log()
    assert len(log) == 16
    assert log[0]["name"] == "e0"  # oldest kept, overflow dropped
    # the counter still sees every event even after the log saturates
    snap = telemetry.snapshot()
    assert snap["counters"]["events{kind=solve}"] == 64


def test_histogram_bounded_under_overflow(monkeypatch):
    monkeypatch.setattr(metrics, "_HIST_LIMIT", 8)
    telemetry.enable()
    for i in range(50):
        telemetry.histogram_observe("h", float(i))
    s = telemetry.snapshot()["histograms"]["h"]
    assert s["count"] == 8
    assert s["max"] == 7.0  # first _HIST_LIMIT observations kept


def test_concurrent_record_and_export_valid_jsonl(tmp_path):
    stream = str(tmp_path / "cc.jsonl")
    telemetry.enable(jsonl=stream)
    stop = threading.Event()
    errors = []

    def recorder(k):
        i = 0
        while not stop.is_set():
            try:
                events.record_event("solve", f"t{k}", wall_us=1.0, i=i)
                telemetry.histogram_observe("cc_us", float(i), thread=k)
                root = telemetry.span_root("cc")
                root.child("c").finish()
                root.finish()
            except Exception as e:  # pragma: no cover
                errors.append(e)
                return
            i += 1

    def exporter():
        while not stop.is_set():
            try:
                telemetry.export_jsonl()
                telemetry.event_log()
            except Exception as e:  # pragma: no cover
                errors.append(e)
                return

    threads = [threading.Thread(target=recorder, args=(k,)) for k in range(3)]
    threads.append(threading.Thread(target=exporter))
    for t in threads:
        t.start()
    import time as _time
    _time.sleep(0.4)
    stop.set()
    for t in threads:
        t.join()
    assert not errors
    n = 0
    with open(stream) as f:
        for line in f:
            row = json.loads(line)  # every line is one complete JSON row
            assert "name" in row
            n += 1
    assert n > 0


# ---------------------------------------------------------------------------
# SLOs (tentpole part 3)
# ---------------------------------------------------------------------------

def test_slo_attainment_and_burn_rate():
    telemetry.enable()
    # 97 fast + 3 slow observations against a 100us objective
    for _ in range(97):
        telemetry.histogram_observe("serve_e2e_us", 50.0, backend="csr")
    for _ in range(3):
        telemetry.histogram_observe("serve_e2e_us", 500.0, backend="csr")
    telemetry.define_slo("csr", p99_us=100.0, backend="csr")
    st = telemetry.slo_status()["csr"]
    assert st["count"] == 100
    assert st["attainment"] == pytest.approx(0.97)
    assert st["burn_rate"] == pytest.approx(3.0)  # 3% bad / 1% budget
    assert not st["met"]
    # label filter: a matfree-only SLO sees none of the csr series
    telemetry.define_slo("mf", p99_us=100.0, backend="matfree")
    st_mf = telemetry.slo_status()["mf"]
    assert st_mf["count"] == 0 and st_mf["met"] and st_mf["burn_rate"] == 0.0


def test_slo_window_uses_most_recent_observations():
    telemetry.enable()
    for _ in range(50):
        telemetry.histogram_observe("serve_e2e_us", 500.0)
    for _ in range(50):
        telemetry.histogram_observe("serve_e2e_us", 50.0)
    telemetry.define_slo("recent", p99_us=100.0, window=50)
    st = telemetry.slo_status()["recent"]
    assert st["attainment"] == pytest.approx(1.0)
    assert st["met"]


def test_slo_in_snapshot_and_rows_and_report(tmp_path, capsys):
    telemetry.enable()
    telemetry.histogram_observe("serve_e2e_us", 10.0)
    telemetry.define_slo("all", p99_us=1000.0)
    snap = telemetry.snapshot()
    assert snap["slo"]["all"]["met"]
    rows = telemetry.metric_rows()
    slo_rows = [r for r in rows if r["kind"] == "slo"]
    assert slo_rows and slo_rows[0]["name"] == "slo/all"
    stream = str(tmp_path / "s.jsonl")
    telemetry.export_jsonl(stream)
    assert report.main([stream, "--slo"]) == 0
    out = capsys.readouterr().out
    assert "SLOs" in out and "✓ met" in out
    assert report.main(["--snapshot", "--slo"]) == 0


def test_snapshot_has_no_slo_section_without_objectives():
    telemetry.enable()
    telemetry.histogram_observe("serve_e2e_us", 10.0)
    assert "slo" not in telemetry.snapshot()


# ---------------------------------------------------------------------------
# flight recorder (tentpole part 2)
# ---------------------------------------------------------------------------

def test_flight_ring_bounded_and_ordered():
    telemetry.enable()
    telemetry.configure_flight(capacity=4)
    root = telemetry.span_root("r")
    root.finish()
    for i in range(10):
        telemetry.flight_record(root, outcome="ok", seq=i)
    recs = telemetry.flight_records()
    assert [r["seq"] for r in recs] == [6, 7, 8, 9]
    assert recs[0]["trace"]["name"] == "r"


def test_flight_dump_and_autodump(tmp_path):
    telemetry.enable()
    path = str(tmp_path / "flight.jsonl")
    telemetry.configure_flight(capacity=8, path=path)
    root = telemetry.span_root("r")
    root.finish()
    telemetry.flight_record(root, outcome="nonconverged", request_id=7)
    n = telemetry.flight_autodump("nonconverged")
    assert n == 1
    lines = [json.loads(line) for line in open(path)]
    assert lines[0]["kind"] == "flight_dump"
    assert lines[0]["reason"] == "nonconverged"
    assert lines[1]["kind"] == "flight"
    assert lines[1]["request_id"] == 7
    # on-demand dump appends another block
    assert telemetry.flight_dump(path, reason="manual") == 1
    assert telemetry.snapshot()["counters"]["flight_dumps{reason=manual}"] == 1


def test_flight_autodump_without_path_is_noop():
    telemetry.enable()  # no jsonl stream, no explicit flight path
    root = telemetry.span_root("r")
    root.finish()
    telemetry.flight_record(root, outcome="shed")
    assert telemetry.flight_autodump("shed") == 0
    assert len(telemetry.flight_records()) == 1  # still held for later


def test_flight_disabled_records_nothing():
    root = telemetry.span_root("r")
    assert telemetry.flight_record(root, outcome="ok") is None
    assert telemetry.flight_records() == []
