"""Tests for the composable weak-form API (WeakForm terms, fused assembly)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DirichletCondenser,
    FacetAssembler,
    FunctionSpace,
    GalerkinAssembler,
    bicgstab,
    disk_tri,
    jacobi_preconditioner,
    unit_square_tri,
    weakform as wf,
)
from repro.core import forms
from repro.core.mesh import element_for_mesh
from repro.transient.stepping import axpy_csr


def _setup(n=6, mesh_fn=unit_square_tri):
    m = mesh_fn(n)
    space = FunctionSpace(m, element_for_mesh(m))
    return m, space, GalerkinAssembler(space)


# ---------------------------------------------------------------------------
# form algebra + composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_assemble_is_additive_on_shared_pattern(seed):
    """assemble(a + b).vals == assemble(a).vals + assemble(b).vals."""
    m, space, asm = _setup()
    rng = np.random.default_rng(seed)
    c1 = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    c2 = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    fused = asm.assemble(wf.diffusion(c1) + wf.mass(c2)).vals
    separate = asm.assemble(wf.diffusion(c1)).vals + asm.assemble(wf.mass(c2)).vals
    np.testing.assert_allclose(np.asarray(fused), np.asarray(separate), atol=1e-12)


def test_scalar_scaling_distributes():
    m, space, asm = _setup()
    a = wf.diffusion(2.0) + wf.mass(0.5)
    v1 = asm.assemble(3.0 * a).vals
    v2 = 3.0 * asm.assemble(a).vals
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-12)
    v3 = asm.assemble(a - wf.mass(0.5)).vals
    v4 = asm.assemble(wf.diffusion(2.0)).vals
    np.testing.assert_allclose(np.asarray(v3), np.asarray(v4), atol=1e-12)


def test_sum_builtin_builds_forms():
    m, space, asm = _setup()
    terms = [wf.diffusion(), wf.mass(), 0.5 * wf.mass()]
    v1 = asm.assemble(sum(terms)).vals
    v2 = asm.assemble(terms[0]).vals + 1.5 * asm.assemble(wf.mass()).vals
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-12)


def test_arity_mismatch_raises():
    m, space, asm = _setup(4)
    with pytest.raises(TypeError):
        asm.assemble(wf.source(1.0))
    with pytest.raises(TypeError):
        asm.assemble_rhs(wf.diffusion())
    with pytest.raises(ValueError):
        asm.assemble(wf.WeakForm())
    with pytest.raises(TypeError):
        wf.mass() * wf.diffusion()  # forms scale by scalars, combine with +


# ---------------------------------------------------------------------------
# fused θ-operator: the acceptance-criterion identity
# ---------------------------------------------------------------------------

def test_fused_theta_operator_matches_shim_path():
    """assemble(mass(c) + dt*diffusion(rho)) == M + dt·K to 1e-12."""
    m, space, asm = _setup(8)
    rng = np.random.default_rng(3)
    c = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    rho = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    dt = 7.3e-3
    fused = asm.assemble(wf.mass(c) + dt * wf.diffusion(rho))
    shim = axpy_csr(1.0, asm.assemble_mass(c), dt, asm.assemble_stiffness(rho))
    np.testing.assert_allclose(
        np.asarray(fused.vals), np.asarray(shim.vals), atol=1e-12
    )


def test_fused_assembly_compiles_once_across_coefficient_values():
    """Repeated assembly with new coefficient/dt values must not retrace."""
    m, space, asm = _setup(5)
    rho = jnp.ones(m.num_cells)
    asm.assemble(wf.mass(1.0) + 0.01 * wf.diffusion(rho))  # trace once
    n0 = asm.n_traces
    for dt in (0.02, 0.05, 0.1):
        asm.assemble(wf.mass(2.0 * dt) + dt * wf.diffusion(rho * dt))
    assert asm.n_traces == n0, "fused assembly retraced on new coefficient values"


# ---------------------------------------------------------------------------
# symmetry structure of the new kernels
# ---------------------------------------------------------------------------

def test_diffusion_plus_mass_symmetric_advection_not():
    m, space, asm = _setup()
    k_sym = np.asarray(asm.assemble(wf.diffusion() + wf.mass()).to_dense())
    np.testing.assert_allclose(k_sym, k_sym.T, atol=1e-13)
    k_adv = np.asarray(asm.assemble(wf.advection(jnp.array([1.0, 0.5]))).to_dense())
    assert np.abs(k_adv - k_adv.T).max() > 1e-6, "advection form should be nonsymmetric"
    # but the advection skew part integrates β·∇(uv): constants are in its kernel
    ones = np.ones(space.num_dofs)
    np.testing.assert_allclose(k_adv @ ones, 0.0, atol=1e-12)


def test_anisotropic_diffusion_identity_reduces_to_diffusion():
    m, space, asm = _setup()
    v1 = asm.assemble(wf.anisotropic_diffusion(jnp.eye(2))).vals
    v2 = asm.assemble(wf.diffusion()).vals
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-13)
    # scalar multiple of I == scaled isotropic diffusion
    v3 = asm.assemble(wf.anisotropic_diffusion(2.5 * jnp.eye(2))).vals
    np.testing.assert_allclose(np.asarray(v3), 2.5 * np.asarray(v2), atol=1e-12)


def test_anisotropic_diffusion_symmetric_tensor_gives_symmetric_matrix():
    m, space, asm = _setup()
    a = jnp.array([[2.0, 0.3], [0.3, 1.0]])
    k = np.asarray(asm.assemble(wf.anisotropic_diffusion(a)).to_dense())
    np.testing.assert_allclose(k, k.T, atol=1e-12)
    w = np.linalg.eigvalsh(k)
    assert w.min() > -1e-10  # A ≻ 0 → K PSD


# ---------------------------------------------------------------------------
# advection–diffusion MMS convergence (P1 L2 rate ≈ 2)
# ---------------------------------------------------------------------------

def _advdiff_error(n):
    """−Δu + β·∇u = f with u = sin(πx)sin(πy), β = (1, 1)."""
    from repro.fem import AdvectionDiffusionProblem

    pi = np.pi

    def f(x):
        sx, sy = jnp.sin(pi * x[..., 0]), jnp.sin(pi * x[..., 1])
        cx, cy = jnp.cos(pi * x[..., 0]), jnp.cos(pi * x[..., 1])
        return 2 * pi**2 * sx * sy + pi * cx * sy + pi * sx * cy

    prob = AdvectionDiffusionProblem(unit_square_tri(n))
    res = prob.solve(eps=1.0, beta=(1.0, 1.0), f=f, tol=1e-12)
    pts = prob.space.dof_points
    exact = np.sin(pi * pts[:, 0]) * np.sin(pi * pts[:, 1])
    e = jnp.asarray(np.asarray(res.u) - exact)
    mass = prob.asm.assemble(wf.mass())
    return float(jnp.sqrt(e @ mass.matvec(e)))


def test_advection_diffusion_mms_p1_rate():
    e1, e2 = _advdiff_error(8), _advdiff_error(16)
    rate = np.log2(e1 / e2)
    assert 1.8 < rate < 2.3, (e1, e2, rate)


# ---------------------------------------------------------------------------
# mixed volume + boundary forms → single CSR
# ---------------------------------------------------------------------------

def test_mixed_volume_robin_single_csr_matches_legacy_path():
    m = disk_tri(8, center=(0.0, 0.0), radius=1.0)
    space = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(space)
    fa = FacetAssembler(space, m.boundary_facets(), volume_routing=asm.mat_routing)
    alpha = 1.3
    fused = asm.assemble(wf.diffusion() + wf.robin(alpha, on=fa))
    legacy = fa.add_robin(asm.assemble_stiffness(), alpha)
    np.testing.assert_allclose(
        np.asarray(fused.vals), np.asarray(legacy.vals), atol=1e-13
    )
    # u = x is harmonic with du/dn = x on the unit circle, so the Robin data
    # du/dn + αu = (1 + α)x reproduces u = x
    g = lambda x: (1.0 + alpha) * x[..., 0]
    rhs = asm.assemble_rhs(wf.source(0.0) + wf.neumann(g, on=fa))
    np.testing.assert_allclose(
        np.asarray(rhs), np.asarray(fa.neumann_load(g)), atol=1e-13
    )
    # the fused system solves the analytic Robin problem (u = x)
    u, info = bicgstab(fused.matvec, rhs, m=jacobi_preconditioner(fused),
                       tol=1e-12)
    exact = space.dof_points[:, 0]
    err = np.linalg.norm(np.asarray(u) - exact) / np.linalg.norm(exact)
    assert err < 1e-2, err


# ---------------------------------------------------------------------------
# differentiability + pytree context
# ---------------------------------------------------------------------------

def test_fused_assembly_differentiable_wrt_coefficients():
    m, space, asm = _setup(5)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    f = asm.assemble_rhs(wf.source(1.0))

    def loss(kappa):
        k = bc.apply_matrix_only(asm.assemble(wf.mass(0.1) + wf.diffusion(kappa)))
        from repro.core import sparse_solve

        u = sparse_solve(k, bc.project_residual(f), "cg", 1e-12, 1e-12)
        return jnp.sum(u**2)

    kappa = jnp.ones(m.num_cells)
    g = jax.grad(loss)(kappa)
    assert np.all(np.isfinite(np.asarray(g)))
    i = int(np.argmax(np.abs(np.asarray(g))))
    eps = 1e-6
    fd = (loss(kappa.at[i].add(eps)) - loss(kappa.at[i].add(-eps))) / (2 * eps)
    np.testing.assert_allclose(float(g[i]), float(fd), rtol=1e-4)


def test_form_context_is_pytree_and_crosses_jit_vmap():
    m, space, asm = _setup(4)
    ctx = asm.context()
    leaves, treedef = jax.tree_util.tree_flatten(ctx)
    ctx2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(ctx2, forms.FormContext)
    np.testing.assert_array_equal(np.asarray(ctx2.detj), np.asarray(ctx.detj))

    # jit over a context argument
    k1 = jax.jit(lambda c: forms.diffusion(c, None))(ctx)
    np.testing.assert_allclose(
        np.asarray(k1), np.asarray(forms.diffusion(ctx, None)), atol=1e-14
    )

    # vmap over a batch of contexts (batched coords → batched geometry)
    coords = jnp.stack([asm.coords, 2.0 * asm.coords])
    batched_ctx = jax.vmap(asm.context)(coords)
    k_b = jax.vmap(lambda c: forms.mass(c, None))(batched_ctx)
    assert k_b.shape[0] == 2
    np.testing.assert_allclose(
        np.asarray(k_b[0]), np.asarray(forms.mass(asm.context(), None)), atol=1e-13
    )


def test_form_context_is_frozen():
    import dataclasses

    m, space, asm = _setup(4)
    ctx = asm.context()
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.detj = ctx.detj * 2.0


# ---------------------------------------------------------------------------
# shims stay exact
# ---------------------------------------------------------------------------

def test_deprecated_shims_match_form_api():
    m, space, asm = _setup(5)
    rho = jnp.asarray(np.random.default_rng(7).uniform(0.5, 2.0, m.num_cells))
    np.testing.assert_array_equal(
        np.asarray(asm.assemble_stiffness(rho).vals),
        np.asarray(asm.assemble(wf.diffusion(rho)).vals),
    )
    np.testing.assert_array_equal(
        np.asarray(asm.assemble_load(2.0)),
        np.asarray(asm.assemble_rhs(wf.source(2.0))),
    )
