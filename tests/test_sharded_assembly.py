"""shard_map element-parallel assembly: the Map stage partitions over the
named FEM mesh axis, the Reduce completes with one all-reduce of partial nnz
contributions — results must match single-device assembly to 1e-12.

Runs on however many devices the host exposes (1 locally); CI exercises the
real multi-device path with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    FacetAssembler,
    FunctionSpace,
    GalerkinAssembler,
    assemble,
    assemble_rhs,
    assemble_rhs_sharded,
    assemble_sharded,
    unit_square_tri,
    weakform as wf,
)
from repro.core.mesh import element_for_mesh
from repro.sharding.partitioning import FEM_MESH_AXIS, fem_mesh


def _setup(n=8, **kw):
    m = unit_square_tri(n)
    space = FunctionSpace(m, element_for_mesh(m), **kw)
    return m, space, GalerkinAssembler(space)


def test_fem_mesh_uses_named_element_axis():
    mesh = fem_mesh()
    assert mesh.axis_names == (FEM_MESH_AXIS,)
    assert mesh.shape[FEM_MESH_AXIS] == len(jax.devices())
    with pytest.raises(ValueError, match="available"):
        fem_mesh(n_devices=len(jax.devices()) + 1)


def test_sharded_matrix_matches_single_device():
    m, space, asm = _setup(8)
    rng = np.random.default_rng(0)
    rho = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    form = wf.diffusion(rho) + wf.mass(0.7)
    ref = assemble(asm.plan, form)
    sh = assemble_sharded(asm.plan, form, mesh=fem_mesh())
    np.testing.assert_allclose(np.asarray(sh.vals), np.asarray(ref.vals), atol=1e-12)


def test_sharded_handles_nondivisible_element_count():
    # E = 2·9² = 162 elements: not divisible by 2/4/8 devices → padding path
    m, space, asm = _setup(9)
    assert m.num_cells % 4 != 0
    ref = assemble(asm.plan, wf.diffusion())
    sh = assemble_sharded(asm.plan, wf.diffusion(), mesh=fem_mesh())
    np.testing.assert_allclose(np.asarray(sh.vals), np.asarray(ref.vals), atol=1e-12)


def test_sharded_coefficient_kinds():
    """Per-element leaves shard along the element axis; nodal fields and
    callables replicate — all must match the un-sharded reference."""
    m, space, asm = _setup(8)
    mesh = fem_mesh()
    nodal = jnp.asarray(space.dof_points[:, 0] + 0.5)
    per_elem = jnp.asarray(np.random.default_rng(1).uniform(0.5, 2.0, m.num_cells))

    for form in (
        wf.diffusion(nodal),
        wf.diffusion(per_elem) + wf.advection(jnp.array([1.0, 0.5])),
        wf.anisotropic_diffusion(jnp.array([[2.0, 0.3], [0.3, 1.0]])),
    ):
        ref = assemble(asm.plan, form)
        sh = assemble_sharded(asm.plan, form, mesh=mesh)
        np.testing.assert_allclose(
            np.asarray(sh.vals), np.asarray(ref.vals), atol=1e-12
        )


def test_sharded_rhs_matches_single_device():
    m, space, asm = _setup(8)
    mesh = fem_mesh()
    src = wf.source(lambda x: x[..., 0] * x[..., 1])
    ref = assemble_rhs(asm.plan, src)
    sh = assemble_rhs_sharded(asm.plan, src, mesh=mesh)
    np.testing.assert_allclose(np.asarray(sh), np.asarray(ref), atol=1e-12)


def test_sharded_vector_space_elasticity():
    m = unit_square_tri(6)
    space = FunctionSpace(m, element_for_mesh(m), value_size=2)
    asm = GalerkinAssembler(space)
    scale = jnp.asarray(np.random.default_rng(2).uniform(0.5, 1.0, m.num_cells))
    form = wf.elasticity(1.2, 0.8, scale=scale)
    ref = asm.assemble(form)
    sh = asm.assemble_sharded(form, mesh=fem_mesh())
    np.testing.assert_allclose(np.asarray(sh.vals), np.asarray(ref.vals), atol=1e-12)


def test_sharded_rejects_facet_terms():
    m, space, asm = _setup(5)
    fa = FacetAssembler(space, m.boundary_facets(), volume_routing=asm.mat_routing)
    with pytest.raises(NotImplementedError, match="volume terms only"):
        assemble_sharded(asm.plan, wf.diffusion() + wf.robin(1.0, on=fa))


def test_sharded_solution_matches_unsharded_poisson():
    """End-to-end: sharded-assembled operator solves to the same solution."""
    from repro.core import DirichletCondenser, sparse_solve

    m, space, asm = _setup(8)
    bc = DirichletCondenser(asm, space.boundary_dofs())
    f = bc.project_residual(assemble_rhs(asm.plan, wf.source(1.0)))
    k_ref = bc.apply_matrix_only(assemble(asm.plan, wf.diffusion()))
    k_sh = bc.apply_matrix_only(assemble_sharded(asm.plan, wf.diffusion(),
                                                 mesh=fem_mesh()))
    u_ref = sparse_solve(k_ref, f, "cg", 1e-12, 1e-12, 2000)
    u_sh = sparse_solve(k_sh, f, "cg", 1e-12, 1e-12, 2000)
    np.testing.assert_allclose(np.asarray(u_sh), np.asarray(u_ref), atol=1e-10)
