"""Hypothesis property tests on system invariants (routing, assembly,
sparse ops, MoE dispatch)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core  # noqa: F401  (x64)
from repro.core.routing import build_matrix_routing, build_vector_routing


@settings(max_examples=30, deadline=None)
@given(
    e=st.integers(1, 50),
    k=st.integers(1, 6),
    n=st.integers(6, 40),
    seed=st.integers(0, 2**16),
)
def test_matrix_routing_equals_scipy_coo(e, k, n, seed):
    """Sorted segment-sum reduce == scipy COO duplicate summation — the
    S_mat·vec(K_local) identity (paper Eq. 8)."""
    import scipy.sparse as sp

    rng = np.random.default_rng(seed)
    cell_dofs = rng.integers(0, n, size=(e, k))
    routing = build_matrix_routing(cell_dofs, None, n)
    vals = rng.normal(size=(e, k, k))

    from repro.core.assembly import reduce_matrix

    got = np.zeros((n, n))
    reduced = np.asarray(reduce_matrix(jnp.asarray(vals), routing))
    got[routing.row_of_nnz, routing.indices] = reduced

    rows = np.broadcast_to(cell_dofs[:, :, None], (e, k, k)).ravel()
    cols = np.broadcast_to(cell_dofs[:, None, :], (e, k, k)).ravel()
    want = sp.coo_matrix((vals.ravel(), (rows, cols)), shape=(n, n)).toarray()
    np.testing.assert_allclose(got, want, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(
    e=st.integers(1, 60),
    k=st.integers(1, 5),
    n=st.integers(5, 30),
    seed=st.integers(0, 2**16),
)
def test_vector_routing_equals_bincount(e, k, n, seed):
    rng = np.random.default_rng(seed)
    cell_dofs = rng.integers(0, n, size=(e, k))
    routing = build_vector_routing(cell_dofs, n)
    vals = rng.normal(size=(e, k))

    from repro.core.assembly import reduce_vector

    got = np.asarray(reduce_vector(jnp.asarray(vals), routing))
    want = np.bincount(cell_dofs.ravel(), weights=vals.ravel(), minlength=n)
    np.testing.assert_allclose(got, want, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    e=st.integers(1, 40),
    k=st.integers(1, 5),
    n=st.integers(5, 30),
    seed=st.integers(0, 2**16),
)
def test_reduce_linearity(e, k, n, seed):
    """Reduce is linear: R(a·x + b·y) == a·R(x) + b·R(y) (assembly
    linearity that justifies precomputed routing, paper §2)."""
    rng = np.random.default_rng(seed)
    cell_dofs = rng.integers(0, n, size=(e, k))
    routing = build_matrix_routing(cell_dofs, None, n)
    from repro.core.assembly import reduce_matrix

    x = jnp.asarray(rng.normal(size=(e, k, k)))
    y = jnp.asarray(rng.normal(size=(e, k, k)))
    lhs = reduce_matrix(2.5 * x - 1.5 * y, routing)
    rhs = 2.5 * reduce_matrix(x, routing) - 1.5 * reduce_matrix(y, routing)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-11)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 12), seed=st.integers(0, 2**16))
def test_csr_matvec_equals_dense(n, seed):
    rng = np.random.default_rng(seed)
    cell_dofs = rng.integers(0, n, size=(max(n // 2, 2), 3))
    routing = build_matrix_routing(cell_dofs, None, n)
    from repro.core.assembly import reduce_matrix
    from repro.core.sparse import CSR, csr_to_ell

    vals = reduce_matrix(
        jnp.asarray(rng.normal(size=(cell_dofs.shape[0], 3, 3))), routing
    )
    a = CSR(vals, routing.indptr, routing.indices, routing.row_of_nnz,
            (n, n), routing.diag_pos)
    x = jnp.asarray(rng.normal(size=n))
    dense = np.asarray(a.to_dense())
    np.testing.assert_allclose(np.asarray(a.matvec(x)), dense @ np.asarray(x), atol=1e-11)
    np.testing.assert_allclose(np.asarray(a.rmatvec(x)), dense.T @ np.asarray(x), atol=1e-11)
    ell = csr_to_ell(a)
    np.testing.assert_allclose(np.asarray(ell.matvec(x)), dense @ np.asarray(x), atol=1e-11)


_WF_CACHE = {}


def _wf_assembler(n):
    """One assembler per mesh size — keeps the jit/form caches warm across
    hypothesis examples (the property is about values, not compilation)."""
    if n not in _WF_CACHE:
        from repro.core import FunctionSpace, GalerkinAssembler, unit_square_tri
        from repro.core.mesh import element_for_mesh

        m = unit_square_tri(n)
        space = FunctionSpace(m, element_for_mesh(m))
        _WF_CACHE[n] = (m, GalerkinAssembler(space))
    return _WF_CACHE[n]


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(3, 6),
    scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**16),
)
def test_weakform_assembly_additive_and_homogeneous(n, scale, seed):
    """assemble(a + s·b).vals == assemble(a).vals + s·assemble(b).vals on the
    shared CSR pattern (linearity of the fused Map + single Reduce)."""
    from repro.core import weakform as wf

    m, asm = _wf_assembler(n)
    rng = np.random.default_rng(seed)
    c1 = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    c2 = jnp.asarray(rng.uniform(0.5, 2.0, m.num_cells))
    fused = asm.assemble(wf.diffusion(c1) + scale * wf.mass(c2)).vals
    separate = (
        np.asarray(asm.assemble(wf.diffusion(c1)).vals)
        + scale * np.asarray(asm.assemble(wf.mass(c2)).vals)
    )
    np.testing.assert_allclose(np.asarray(fused), separate, atol=1e-10, rtol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    tokens=st.integers(8, 64),
    seed=st.integers(0, 2**16),
)
def test_moe_combine_weights_sum_to_one_when_kept(tokens, seed):
    """Routing invariant: for every token, combine weights over (E, C) sum
    to ≤ 1 (== 1 when no capacity drop), and dispatch is 0/1."""
    import dataclasses

    from repro.configs import ARCHS, smoke_variant
    from repro.models import moe as moe_mod
    from repro.models.layers import init_params

    cfg = dataclasses.replace(
        smoke_variant(ARCHS["qwen3-moe-30b-a3b"]), moe_capacity_factor=8.0
    )
    params = init_params(moe_mod.moe_specs(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, tokens, cfg.d_model))
    out, aux = moe_mod.moe_apply(cfg, params, x)
    assert np.all(np.isfinite(np.asarray(out)))
    # generous capacity → no drops → output magnitude comparable to expert out
    assert float(jnp.abs(out).max()) > 0
