"""repro.serve — admission batching, executable cache, QoS error paths.

The acceptance-critical properties:

* B heterogeneous-coefficient requests through one admission batch are
  bitwise-close (<= 1e-12) to B sequential reference solves, on both the
  csr and matfree backends,
* after warmup, waves of compatible requests are pure executable-cache
  hits — zero ``jit_traces{kind=serve}`` retraces across >= 3 waves,
* deadline-expired, shed-at-admission and non-converged requests come back
  with typed errors (DeadlineExpired / Overloaded / NonConverged), never
  with a silent wrong answer.
"""

import dataclasses
import json
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve, telemetry
from repro.core import assemble, matfree_operator, matfree_solve, sparse_solve
from repro.serve import (
    DeadlineExpired,
    ExecutableCache,
    NonConverged,
    Overloaded,
    SolveService,
    admission_key,
    pad_bucket,
)
from repro.telemetry import ConvergenceWarning

RES = 6  # tiny shared Poisson workload (plan memoized inside serve.client)


def _wave(n, **kw):
    return serve.poisson_requests(n_requests=n, resolution=RES, **kw)


# ---------------------------------------------------------------------------
# units: pad buckets + compatibility keys
# ---------------------------------------------------------------------------

def test_pad_bucket():
    assert [pad_bucket(b) for b in (1, 2, 3, 5, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]
    with pytest.raises(ValueError):
        pad_bucket(0)


def test_admission_key_compatibility():
    a, b = _wave(2)
    # same plan/form signature/bc/knobs, different coefficient VALUES
    assert admission_key(a) == admission_key(b)
    assert not np.allclose(np.asarray(a.leaves[0]), np.asarray(b.leaves[0]))
    assert admission_key(dataclasses.replace(a, tol=1e-8)) != admission_key(a)
    assert admission_key(dataclasses.replace(a, maxiter=7)) != admission_key(a)
    mf = _wave(1, backend="matfree")[0]
    assert admission_key(mf) != admission_key(a)
    with pytest.raises(ValueError, match="unknown backend"):
        dataclasses.replace(a, backend="ell")


# ---------------------------------------------------------------------------
# parity: one admission batch vs B sequential reference solves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["csr", "matfree"])
def test_batched_requests_match_sequential(backend):
    reqs = _wave(5, backend=backend)  # 5 pads to bucket 8
    svc = SolveService(window=0.0)
    pend = [svc.submit(r) for r in reqs]
    assert not pend[0].done()
    assert svc.drain() == 5
    for rq, p in zip(reqs, pend):
        resp = p.response()
        assert resp.ok and resp.batch_size == 5
        assert resp.info is not None and bool(resp.info.converged)
        f = rq.rhs * rq.bc.free_mask
        if backend == "csr":
            k = rq.bc.apply_matrix_only(assemble(rq.plan, rq.form))
            u_ref = sparse_solve(k, f, rq.method, rq.tol, rq.tol, rq.maxiter)
        else:
            op = matfree_operator(rq.plan, rq.form).condensed(rq.bc)
            u_ref = matfree_solve(op, f, rq.method, rq.tol, rq.tol,
                                  rq.maxiter)
        err = float(jnp.max(jnp.abs(p.result() - u_ref)))
        assert err < 1e-12, f"{backend} request {rq.request_id}: {err:.3e}"


def test_mixed_backends_split_into_groups():
    reqs = _wave(2) + _wave(2, backend="matfree")
    svc = SolveService(window=0.0)
    pend = [svc.submit(r) for r in reqs]
    assert svc.drain() == 4
    resps = [p.response() for p in pend]
    assert all(r.ok for r in resps)
    # two incompatible groups of 2, not one batch of 4
    assert [r.batch_size for r in resps] == [2, 2, 2, 2]
    # csr and matfree answers agree on the same request family
    assert float(jnp.max(jnp.abs(resps[0].u - resps[2].u))) < 1e-9


def test_max_batch_chunks_one_group():
    reqs = _wave(5)
    svc = SolveService(window=0.0, max_batch=2)
    pend = [svc.submit(r) for r in reqs]
    assert svc.drain() == 5
    sizes = [p.response().batch_size for p in pend]
    assert sizes == [2, 2, 2, 2, 1]
    assert all(p.response().ok for p in pend)


# ---------------------------------------------------------------------------
# QoS paths: deadline, shedding, non-convergence policy
# ---------------------------------------------------------------------------

def test_deadline_expired_path():
    reqs = _wave(2, timeout=1e-3)
    svc = SolveService(window=0.0)
    pend = [svc.submit(r) for r in reqs]
    time.sleep(0.01)  # let both deadlines pass while queued
    assert svc.drain() == 2
    for p in pend:
        resp = p.response()
        assert resp.status == "expired" and resp.u is None
        with pytest.raises(DeadlineExpired):
            p.result()


def test_overload_shedding():
    reqs = _wave(4)
    svc = SolveService(window=0.0, queue_limit=2)
    pend = [svc.submit(r) for r in reqs]
    # beyond the bounded queue: resolved immediately, never queued
    assert pend[2].done() and pend[3].done()
    for p in pend[2:]:
        assert p.response().status == "overloaded"
        with pytest.raises(Overloaded):
            p.result()
    svc.drain()
    assert all(p.response().ok for p in pend[:2])


def test_nonconverged_raise_policy():
    reqs = [dataclasses.replace(r, maxiter=3) for r in _wave(2)]
    with telemetry.enabled(on_nonconverged="raise"):
        svc = SolveService(window=0.0)
        pend = [svc.submit(r) for r in reqs]
        svc.drain()
    for p in pend:
        resp = p.response()
        assert resp.status == "nonconverged" and resp.u is None
        assert not bool(resp.info.converged)
        with pytest.raises(NonConverged):
            p.result()


def test_nonconverged_warn_policy_answers_ok():
    reqs = [dataclasses.replace(r, maxiter=3) for r in _wave(2)]
    with telemetry.enabled(on_nonconverged="warn"):
        svc = SolveService(window=0.0)
        pend = [svc.submit(r) for r in reqs]
        with pytest.warns(ConvergenceWarning):
            svc.drain()
    assert all(p.response().ok and p.response().u is not None for p in pend)


# ---------------------------------------------------------------------------
# executable cache: warmup → zero retraces, LRU eviction, pinning
# ---------------------------------------------------------------------------

def test_zero_retraces_and_full_hit_rate_across_waves():
    with telemetry.enabled():
        svc = SolveService(window=0.0)
        svc.warmup(_wave(1)[0], batch_sizes=(4,))
        base = telemetry.jit_trace_total("serve")
        hits0, miss0 = svc.cache.hits, svc.cache.misses
        for w in range(3):
            pend = [svc.submit(r) for r in _wave(4, seed=w + 1)]
            svc.drain()
            assert all(p.response().ok and p.response().cache_hit
                       for p in pend)
        assert telemetry.jit_trace_total("serve") - base == 0
        assert svc.cache.misses == miss0, "cache missed after warmup"
        assert svc.cache.hits - hits0 == 3  # one lookup per wave, all hits


def test_cache_eviction_and_pinning():
    base = _wave(1)[0]
    variants = [dataclasses.replace(base, tol=10.0 ** -(6 + i))
                for i in range(4)]
    keys = [admission_key(v) for v in variants]
    cache = ExecutableCache(capacity=2)
    cache.pin(keys[0], 1)
    for v, k in zip(variants, keys):
        cache.get(k, 1, v)
    # 4 entries, 1 pinned, capacity 2 unpinned -> keys[1] (LRU unpinned) out
    assert len(cache) == 3 and cache.evictions == 1
    _, hit = cache.get(keys[0], 1, variants[0])
    assert hit, "pinned entry must survive eviction"
    _, hit = cache.get(keys[1], 1, variants[1])
    assert not hit, "LRU unpinned entry should have been evicted"
    cache.unpin(keys[0], 1)
    cache._evict()
    assert cache.hit_rate() == pytest.approx(1 / 6)


# ---------------------------------------------------------------------------
# threaded dispatch path (the production lifecycle)
# ---------------------------------------------------------------------------

def test_worker_thread_end_to_end():
    reqs = _wave(3)
    svc = SolveService(window=0.001)
    # submissions before start() queue up and dispatch on the first window
    early = svc.submit(reqs[0])
    with svc:
        pend = [svc.submit(r) for r in reqs[1:]]
        us = [p.result(timeout=60.0) for p in [early, *pend]]
    assert all(u.shape == reqs[0].rhs.shape for u in us)
    k = reqs[0].bc.apply_matrix_only(assemble(reqs[0].plan, reqs[0].form))
    u_ref = sparse_solve(k, reqs[0].rhs * reqs[0].bc.free_mask,
                         reqs[0].method, reqs[0].tol, reqs[0].tol,
                         reqs[0].maxiter)
    assert float(jnp.max(jnp.abs(us[0] - u_ref))) < 1e-12


def test_stop_drains_pending_requests():
    svc = SolveService(window=0.0)
    svc.start()
    pend = [svc.submit(r) for r in _wave(2)]
    svc.stop()  # must answer everything still queued
    assert all(p.done() and p.response().ok for p in pend)


def test_solve_convenience_inline():
    svc = SolveService(window=0.0)
    rq = _wave(1)[0]
    u = svc.solve(rq)  # no worker -> drained inline
    assert u.shape == rq.rhs.shape


# ---------------------------------------------------------------------------
# request tracing: span trees, flight recorder, attribution gauges (PR 10)
# ---------------------------------------------------------------------------

def test_response_span_tree_segments_sum_to_e2e():
    with telemetry.enabled():
        svc = SolveService(window=0.0)
        svc.warmup(_wave(1)[0], batch_sizes=(4,))
        pend = [svc.submit(r) for r in _wave(4, seed=3)]
        svc.drain()
        for p in pend:
            resp = p.response()
            assert resp.ok
            tree = resp.trace
            assert tree["name"] == "serve.request"
            assert tree["tags"]["outcome"] == "ok"
            assert tree["tags"]["request_id"] == p.request.request_id
            seg = resp.span_segments_us
            assert list(seg) == ["queue_wait", "dispatch", "solve", "slice"]
            # the acceptance criterion: segments cover the full lifetime
            e2e_us = 1e6 * resp.e2e_s
            assert sum(seg.values()) == pytest.approx(e2e_us, rel=0.05)
            # one trace id threads the whole tree
            ids = {tree["trace_id"]}
            for c in tree["children"]:
                ids.add(c["trace_id"])
            assert ids == {tree["trace_id"]}
        # distinct requests get distinct trace ids
        tids = {p.response().trace["trace_id"] for p in pend}
        assert len(tids) == 4


def test_disabled_responses_carry_no_trace():
    svc = SolveService(window=0.0)
    pend = [svc.submit(r) for r in _wave(2)]
    svc.drain()
    for p in pend:
        resp = p.response()
        assert resp.ok and resp.trace is None
        assert resp.span_segments_us == {}


def test_error_paths_carry_traces_and_flight_dumps(tmp_path):
    flight = str(tmp_path / "flight.jsonl")
    with telemetry.enabled(on_nonconverged="raise"):
        telemetry.configure_flight(capacity=32, path=flight)
        try:
            # expired
            svc = SolveService(window=0.0)
            pend = [svc.submit(r) for r in _wave(1, timeout=1e-3)]
            time.sleep(0.01)
            svc.drain()
            assert pend[0].response().trace["tags"]["outcome"] == "expired"
            # shed
            svc2 = SolveService(window=0.0, queue_limit=1)
            shed = [svc2.submit(r) for r in _wave(2)][1]
            assert shed.response().trace["tags"]["outcome"] == "shed"
            svc2.drain()
            # forced nonconverged
            bad = [dataclasses.replace(r, maxiter=3) for r in _wave(1)]
            p = svc2.submit(bad[0])
            svc2.drain()
            assert p.response().status == "nonconverged"
            assert p.response().trace["tags"]["outcome"] == "nonconverged"
        finally:
            rows = [json.loads(line) for line in open(flight)]
            reasons = {r["reason"] for r in rows if r["kind"] == "flight_dump"}
            assert {"expired", "shed", "nonconverged"} <= reasons
            outcomes = {r.get("outcome") for r in rows if r["kind"] == "flight"}
            assert {"expired", "shed", "nonconverged"} <= outcomes
            telemetry.clear_flight()
            from repro.telemetry import spans as _spans
            _spans._FLIGHT_PATH = None


def test_queue_depth_gauge_sampled_at_drain():
    with telemetry.enabled():
        telemetry.reset()  # metrics persist across enabled() scopes
        svc = SolveService(window=0.0)
        [svc.submit(r) for r in _wave(3)]
        svc.drain()
        snap = telemetry.snapshot()
        assert snap["gauges"]["serve_queue_depth"] == 3
        assert snap["histograms"]["serve_queue_depth"]["max"] == 3


def test_compile_and_memory_attribution_gauges():
    with telemetry.enabled():
        telemetry.reset()
        svc = SolveService(window=0.0)
        pend = [svc.submit(r) for r in _wave(2)]
        svc.drain()
        assert all(p.response().ok for p in pend)
        snap = telemetry.snapshot()
        compile_hists = [k for k in snap["histograms"]
                         if k.startswith("serve_compile_us")]
        assert compile_hists, "cache miss must record compile time"
        assert snap["histograms"][compile_hists[0]]["count"] == 1
        assert any(k.startswith("serve_exec_compile_us")
                   for k in snap["gauges"])
        assert snap["gauges"]["serve_exec_entries"] == len(svc.cache)
        # steady state: a second wave is a cache hit, no new compile rows
        pend = [svc.submit(r) for r in _wave(2, seed=5)]
        svc.drain()
        snap2 = telemetry.snapshot()
        assert snap2["histograms"][compile_hists[0]]["count"] == 1


def test_load_report_span_coverage():
    with telemetry.enabled():
        reqs = _wave(6)
        with SolveService(window=0.002) as svc:
            svc.warmup(reqs[0], batch_sizes=(1, 4))
            report = serve.open_loop_load(svc, reqs, rate=2000.0)
        assert report.ok == 6
        assert report.span_coverage == pytest.approx(1.0, rel=0.05)
        assert report.queue_depth_max >= 1
