"""Per-arch smoke tests (reduced configs, CPU, one fwd/train step — shapes +
no NaNs) plus algorithmic consistency checks: chunked linear-attention ==
exact recurrence, prefill+decode == full forward, MoE conservation."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, smoke_variant
from repro.models import build_model
from repro.models.layers import init_params

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, b=2, s=64, with_labels=True, key=KEY):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1]}
    if with_labels:
        batch["labels"] = tokens[:, 1:]
    if cfg.frontend == "patch_embed":
        n = cfg.num_frontend_tokens
        batch["tokens"] = batch["tokens"][:, : s - n]
        if with_labels:
            batch["labels"] = batch["labels"][:, : s - n]
        batch["vision_embeds"] = jax.random.normal(ks[1], (b, n, cfg.d_model))
    elif cfg.frontend == "audio_frames":
        batch["audio_embeds"] = jax.random.normal(ks[2], (b, 100, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_step(name):
    cfg = smoke_variant(ARCHS[name])
    model = build_model(cfg, tp_degree=1)
    params = init_params(model.param_specs(), KEY)
    batch = _smoke_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves), name
    # at least some gradient signal everywhere except possibly unused slots
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0, name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_serve_roundtrip(name):
    cfg = smoke_variant(ARCHS[name])
    model = build_model(cfg, tp_degree=1)
    params = init_params(model.param_specs(), KEY)
    s = 64
    batch = _smoke_batch(cfg, s=s, with_labels=False)
    logits, cache = model.prefill(params, batch, s)
    assert np.all(np.isfinite(np.asarray(logits))), name
    prompt_len = batch["tokens"].shape[1]
    dbatch = {
        "tokens": jnp.zeros((2, 1), jnp.int32),
        "cache_len": jnp.asarray(prompt_len, jnp.int32),
    }
    dlogits, _ = model.decode(params, dbatch, cache)
    assert dlogits.shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(dlogits))), name


def test_dense_decode_matches_full_forward():
    """Greedy continuation via (prefill + decode) must equal a full forward
    pass over the same tokens — validates cache correctness."""
    import dataclasses
    cfg = dataclasses.replace(smoke_variant(ARCHS["qwen3-4b"]), compute_dtype="float32")
    model = build_model(cfg, tp_degree=1)
    params = init_params(model.param_specs(), KEY)
    b, s = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab_size)

    from repro.models.transformer import decoder_forward

    full_logits, _ = decoder_forward(cfg, params, {"tokens": tokens})

    # prefill on the first s-1 tokens, decode token s-1
    logits_p, cache = model.prefill(params, {"tokens": tokens[:, : s - 1]}, s)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, s - 2]),
        rtol=2e-2, atol=2e-2,
    )
    dbatch = {"tokens": tokens[:, s - 1 :], "cache_len": jnp.asarray(s - 1, jnp.int32)}
    logits_d, _ = model.decode(params, dbatch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, s - 1]),
        rtol=2e-2, atol=2e-2,
    )


def test_rwkv6_chunked_matches_stepwise():
    """Chunk-parallel WKV == exact token-by-token recurrence (f32 compute —
    bf16 differs only by accumulation-order noise, checked separately)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_variant(ARCHS["rwkv6-1.6b"]), compute_dtype="float32")
    model = build_model(cfg, tp_degree=1)
    params = init_params(model.param_specs(), KEY)
    b, s = 2, 48
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)

    from repro.models.transformer import decoder_forward

    full_logits, _ = decoder_forward(cfg, params, {"tokens": tokens})

    # step token-by-token through decode
    logits_p, cache = model.prefill(params, {"tokens": tokens[:, :1]}, s)
    outs = [np.asarray(logits_p[:, 0])]
    for t in range(1, s):
        dbatch = {"tokens": tokens[:, t : t + 1],
                  "cache_len": jnp.asarray(t, jnp.int32)}
        lg, cache = model.decode(params, dbatch, cache)
        outs.append(np.asarray(lg[:, 0]))
    stepwise = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        stepwise, np.asarray(full_logits), rtol=1e-3, atol=1e-3
    )


def test_rwkv6_chunk_size_invariance():
    """Chunk size must not change the math (f32 — bf16 differs only by
    accumulation order, which is covered by the smoke tests)."""
    import dataclasses
    from repro.models.transformer import decoder_forward

    base = dataclasses.replace(
        smoke_variant(ARCHS["rwkv6-1.6b"]), compute_dtype="float32"
    )
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 40), 0, base.vocab_size)
    outs = []
    for chunk in (8, 16, 40):
        cfg = dataclasses.replace(base, ssm_chunk=chunk)
        params = init_params(build_model(cfg).param_specs(), KEY)
        lg, _ = decoder_forward(cfg, params, {"tokens": tokens})
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-3, atol=1e-3)


def test_mamba2_chunked_matches_stepwise():
    import dataclasses
    cfg = dataclasses.replace(smoke_variant(ARCHS["zamba2-7b"]), compute_dtype="float32")
    model = build_model(cfg, tp_degree=1)
    params = init_params(model.param_specs(), KEY)
    b, s = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab_size)

    from repro.models.hybrid import hybrid_forward

    full_logits = hybrid_forward(cfg, params, {"tokens": tokens})

    logits_p, cache = model.prefill(params, {"tokens": tokens[:, :1]}, s)
    outs = [np.asarray(logits_p[:, 0])]
    for t in range(1, s):
        dbatch = {"tokens": tokens[:, t : t + 1],
                  "cache_len": jnp.asarray(t, jnp.int32)}
        lg, cache = model.decode(params, dbatch, cache)
        outs.append(np.asarray(lg[:, 0]))
    stepwise = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        stepwise, np.asarray(full_logits), rtol=5e-2, atol=5e-2
    )


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention

    rng = np.random.default_rng(0)
    b, s, h, kv, d = 2, 37, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, chunk=8)

    # naive reference
    g = h // kv
    qg = np.asarray(q).reshape(b, s, kv, g, d)
    logits = np.einsum("bskgd,btkd->bkgst", qg, np.asarray(k)) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    logits = np.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    ref = np.einsum("bkgst,btkd->bskgd", np.asarray(p), np.asarray(v))
    ref = ref.reshape(b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_moe_routes_and_conserves():
    cfg = smoke_variant(ARCHS["qwen3-moe-30b-a3b"])
    from repro.models.moe import moe_apply, moe_specs

    specs = moe_specs(cfg)
    params = init_params(specs, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe_apply(cfg, params, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0
    # capacity honored: a much larger top-k load still yields finite outputs
    assert np.all(np.isfinite(np.asarray(out)))


def test_long_shape_skip_logic():
    for name, cfg in ARCHS.items():
        if cfg.sub_quadratic:
            assert name in ("rwkv6-1.6b", "zamba2-7b")
    assert not ARCHS["qwen3-32b"].sub_quadratic
