"""repro.telemetry — convergence diagnostics, metrics registry, tracing.

The PR-6 acceptance criteria:

* ``return_info=True`` on ``sparse_solve`` / ``matfree_solve`` is a
  *non-differentiated auxiliary output*: gradients through the
  info-returning path match the plain path to machine precision;
* transient rollouts stack per-step ``SolveInfo`` out of the scan —
  ``(n_steps,)`` iteration-count trajectories;
* the unified jit-trace counters agree with the legacy
  ``n_core_traces`` / ``n_matfree_traces`` accounting;
* telemetry disabled means zero cost: no extra retraces, nothing recorded,
  tracers never captured;
* silent non-convergence is dead: a ``maxiter`` exit warns (or raises
  under the ``raise`` policy) even with telemetry disabled;
* the JSONL export round-trips through the report CLI.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    assemble,
    assemble_rhs,
    build_plan,
    matfree_operator,
    matfree_solve,
    n_matfree_traces,
    sparse_solve,
    unit_square_tri,
    weakform as wf,
)
from repro.core.assembly import n_core_traces
from repro.core.mesh import element_for_mesh
from repro.telemetry import (
    ConvergenceWarning,
    NonConvergedError,
    events,
    report,
)
from repro.transient import ThetaIntegrator

RNG = np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts and ends with telemetry off and the registry empty —
    the suite must not leak recording into unrelated tests."""
    telemetry.disable()
    telemetry.reset()
    events.clear_events()
    yield
    telemetry.disable()
    telemetry.reset()
    events.clear_events()


@pytest.fixture(scope="module")
def problem():
    mesh = unit_square_tri(5)
    space = FunctionSpace(mesh, element_for_mesh(mesh))
    plan = build_plan(space)
    bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
    f = bc.project_residual(assemble_rhs(plan, wf.source(1.0)))
    rho0 = jnp.asarray(RNG.uniform(0.5, 2.0, mesh.num_cells))
    return plan, bc, f, rho0


def _csr_solve(plan, bc, f, rho, return_info=False):
    k = bc.apply_matrix_only(assemble(plan, wf.diffusion(rho)))
    return sparse_solve(k, f, "cg", 1e-12, 1e-12, 10000,
                        return_info=return_info)


def _mf_solve(plan, bc, f, rho, return_info=False):
    op = matfree_operator(plan, wf.diffusion(rho)).condensed(bc)
    return matfree_solve(op, f, "cg", 1e-12, 1e-12, 10000,
                         return_info=return_info)


# ---------------------------------------------------------------------------
# SolveInfo: converged flag + the info path is gradient-invisible
# ---------------------------------------------------------------------------

def test_solve_info_reports_convergence(problem):
    plan, bc, f, rho0 = problem
    u_plain = _csr_solve(plan, bc, f, rho0)
    u, info = _csr_solve(plan, bc, f, rho0, return_info=True)
    assert bool(info.converged)
    assert int(info.iters) > 0
    assert float(info.residual) < 1e-10
    np.testing.assert_array_equal(np.asarray(u), np.asarray(u_plain))


@pytest.mark.parametrize("solve", [_csr_solve, _mf_solve],
                         ids=["sparse_solve", "matfree_solve"])
def test_grad_parity_info_vs_plain(problem, solve):
    """grad through the return_info=True path matches the plain path to
    machine precision (the info leaves are stop-gradient)."""
    plan, bc, f, rho0 = problem

    def loss_plain(rho):
        return jnp.sum(solve(plan, bc, f, rho) ** 2)

    def loss_info(rho):
        u, info = solve(plan, bc, f, rho, return_info=True)
        return jnp.sum(u**2)

    g_plain = np.asarray(jax.grad(loss_plain)(rho0))
    g_info = np.asarray(jax.grad(loss_info)(rho0))
    scale = np.abs(g_plain).max()
    assert np.abs(g_info - g_plain).max() <= 1e-15 * max(scale, 1.0)


def test_grad_wrt_rhs_parity(problem):
    plan, bc, f, rho0 = problem
    g_plain = jax.grad(lambda b: jnp.sum(_csr_solve(plan, bc, b, rho0) ** 2))(f)
    g_info = jax.grad(
        lambda b: jnp.sum(_csr_solve(plan, bc, b, rho0, return_info=True)[0] ** 2)
    )(f)
    np.testing.assert_allclose(np.asarray(g_info), np.asarray(g_plain),
                               atol=1e-15)


# ---------------------------------------------------------------------------
# rollouts: per-step SolveInfo stacked out of the scan
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def heat():
    m = unit_square_tri(6)
    sp = FunctionSpace(m, element_for_mesh(m))
    asm = GalerkinAssembler(sp)
    bc = DirichletCondenser(asm, sp.boundary_dofs())
    mass = asm.assemble(wf.mass())
    stiff = asm.assemble(wf.diffusion(1.0))
    u0 = jnp.asarray(RNG.standard_normal(sp.num_dofs))
    return mass, stiff, bc, bc.project_residual(u0)


@pytest.mark.parametrize("backend", ["csr", "ell"])
def test_rollout_info_trajectory(heat, backend):
    mass, stiff, bc, u0 = heat
    integ = ThetaIntegrator(mass, stiff,
                            dt=0.01, theta=1.0, bc=bc, backend=backend)
    n_steps = 5
    traj_plain = integ.rollout(u0, n_steps)
    traj, info = integ.rollout(u0, n_steps, return_info=True)
    assert info.iters.shape == (n_steps,)
    assert info.residual.shape == (n_steps,)
    assert bool(info.converged.all())
    assert int(info.iters.min()) > 0
    np.testing.assert_array_equal(np.asarray(traj), np.asarray(traj_plain))


def test_rollout_grad_parity(heat):
    mass, stiff, bc, u0 = heat

    def loss(u, with_info):
        integ = ThetaIntegrator(mass, stiff, dt=0.01, theta=1.0, bc=bc)
        if with_info:
            traj, _ = integ.rollout(u, 4, return_info=True)
        else:
            traj = integ.rollout(u, 4)
        return jnp.sum(traj**2)

    g_plain = np.asarray(jax.grad(loss)(u0, False))
    g_info = np.asarray(jax.grad(loss)(u0, True))
    assert np.abs(g_info - g_plain).max() <= 1e-15 * max(np.abs(g_plain).max(), 1.0)


# ---------------------------------------------------------------------------
# unified jit-trace accounting vs the legacy counters
# ---------------------------------------------------------------------------

def test_trace_counters_agree_with_legacy():
    telemetry.enable()
    telemetry.reset()
    mesh = unit_square_tri(7)  # fresh static shape → genuinely new traces
    space = FunctionSpace(mesh, element_for_mesh(mesh))
    plan = build_plan(space)
    rho = jnp.asarray(RNG.uniform(0.5, 2.0, mesh.num_cells))
    x = jnp.asarray(RNG.standard_normal(space.num_dofs))

    core0, mf0 = n_core_traces(), n_matfree_traces()
    t_core0 = telemetry.jit_trace_total("assembly")
    t_mf0 = telemetry.jit_trace_total("matfree")

    k = assemble(plan, wf.diffusion(rho))
    jax.block_until_ready(k.vals)
    op = matfree_operator(plan, wf.diffusion(rho))
    jax.block_until_ready(op.matvec(x))
    # value-only updates must not retrace on either accounting
    jax.block_until_ready(assemble(plan, wf.diffusion(2.0 * rho)).vals)
    jax.block_until_ready(matfree_operator(plan, wf.diffusion(3.0 * rho)).matvec(x))

    d_core = n_core_traces() - core0
    d_mf = n_matfree_traces() - mf0
    assert d_core >= 1 and d_mf >= 1
    assert telemetry.jit_trace_total("assembly") - t_core0 == d_core
    assert telemetry.jit_trace_total("matfree") - t_mf0 == d_mf

    snap = telemetry.snapshot()
    cache = {k_: v for k_, v in snap["counters"].items()
             if k_.startswith("cache_lookups")}
    assert any("outcome=miss" in k_ for k_ in cache)
    assert any("outcome=hit" in k_ for k_ in cache)


# ---------------------------------------------------------------------------
# disabled = zero cost
# ---------------------------------------------------------------------------

def test_disabled_records_nothing_and_never_retraces(problem):
    plan, bc, f, rho0 = problem
    assert not telemetry.is_enabled()
    u, info = _csr_solve(plan, bc, f, rho0, return_info=True)
    assert bool(info.converged)
    assert telemetry.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert events.event_log() == []
    assert telemetry.jsonl_path() is None

    # toggling telemetry must not invalidate compiled executables: the same
    # (plan, form-signature) solve retraces neither accounting
    core0, mf0 = n_core_traces(), n_matfree_traces()
    with telemetry.enabled():
        _csr_solve(plan, bc, f, 2.0 * rho0, return_info=True)
        _mf_solve(plan, bc, f, 2.0 * rho0, return_info=True)
    assert n_core_traces() == core0
    assert n_matfree_traces() == mf0


def test_tracers_are_never_recorded():
    with telemetry.enabled():
        @jax.jit
        def f(x):
            telemetry.histogram_observe("h", x)
            telemetry.gauge_set("g", x)
            events.record_event("solve", "traced", wall_us=None, value=x)
            return 2.0 * x

        jax.block_until_ready(f(jnp.array(1.0)))
        snap = telemetry.snapshot()
        assert snap["histograms"] == {} and snap["gauges"] == {}
        assert all(e["name"] != "traced" for e in events.event_log())


# ---------------------------------------------------------------------------
# non-convergence is loud (with telemetry off too)
# ---------------------------------------------------------------------------

def test_nonconvergence_warns_by_default(heat):
    mass, stiff, bc, u0 = heat
    integ = ThetaIntegrator(mass, stiff, dt=0.01, theta=1.0, bc=bc, maxiter=1)
    assert not telemetry.is_enabled()
    with pytest.warns(ConvergenceWarning, match="did NOT converge"):
        _, info = integ.rollout(u0, 3, return_info=True)
    assert not bool(info.converged.all())


def test_nonconvergence_raise_policy(heat):
    mass, stiff, bc, u0 = heat
    integ = ThetaIntegrator(mass, stiff, dt=0.01, theta=1.0, bc=bc, maxiter=1)
    with telemetry.enabled(on_nonconverged="raise"):
        with pytest.raises(NonConvergedError, match="theta.rollout"):
            integ.rollout(u0, 3, return_info=True)


def test_check_convergence_is_noop_under_trace(problem):
    plan, bc, f, rho0 = problem

    @jax.jit
    def solve(rho):
        u, info = _csr_solve(plan, bc, f, rho, return_info=True)
        assert events.check_convergence(info, on_fail="raise") is None
        return u

    jax.block_until_ready(solve(rho0))


# ---------------------------------------------------------------------------
# events, JSONL export, report CLI
# ---------------------------------------------------------------------------

def test_events_stream_and_report_cli(problem, tmp_path, capsys):
    plan, bc, f, rho0 = problem
    jsonl = str(tmp_path / "telemetry.jsonl")
    with telemetry.enabled(jsonl=jsonl):
        from repro.fem import PoissonProblem

        prob = PoissonProblem(unit_square_tri(6))
        _, info = prob.solve(return_info=True)
        assert bool(info.converged)
        telemetry.export_jsonl(jsonl)

    kinds = {e["kind"] for e in events.event_log()}
    assert "solve" in kinds and "assembly" in kinds

    with open(jsonl) as fh:
        rows = [json.loads(line) for line in fh if line.strip()]
    solves = [r for r in rows if r.get("kind") == "solve"]
    assert solves and all(r["converged"] for r in solves)
    assert any(r.get("kind") == "assembly" for r in rows)
    assert any(r["name"].startswith("metric/counter/jit_traces") for r in rows)

    assert report.main([jsonl]) == 0
    out = capsys.readouterr().out
    assert "Solves" in out and "converged" in out
    assert report.main([str(tmp_path / "missing.jsonl")]) == 2


def test_capture_writes_profile(tmp_path):
    d = str(tmp_path / "trace")
    # the profiler serializes metadata for every live compiled executable;
    # late in a long pytest session that dump can abort the process, so the
    # capture must not depend on how many programs earlier tests compiled
    jax.clear_caches()
    with telemetry.enabled():
        with telemetry.capture(d):
            jax.block_until_ready(jnp.ones(64) @ jnp.ones((64, 8)))
    files = [os.path.join(dp, fn) for dp, _, fns in os.walk(d) for fn in fns]
    assert files, "profiler capture produced no files"
    assert any(e["kind"] == "profile" for e in events.event_log())


def test_gauges_record_memory_footprints():
    with telemetry.enabled():
        mesh = unit_square_tri(4)
        space = FunctionSpace(mesh, element_for_mesh(mesh))
        plan = build_plan(space)
        assemble(plan, wf.diffusion(1.0))
        matfree_operator(plan, wf.diffusion(1.0))
        gauges = telemetry.snapshot()["gauges"]
    assert any(k.startswith("plan_bytes") for k in gauges)
    assert any(k.startswith("csr_bytes") for k in gauges)
    assert any(k.startswith("operator_state_bytes") for k in gauges)
    assert all(v > 0 for v in gauges.values())
