"""Render a telemetry run (JSONL events + metrics) as markdown tables.

    PYTHONPATH=src python -m repro.telemetry.report telemetry_events.jsonl

Reads the JSON-lines stream written by an enabled telemetry session
(``telemetry.enable(jsonl=...)`` + ``telemetry.export_jsonl()``) and prints
a run summary in the style of :mod:`repro.analysis.report`: one table per
row family (solves, assemblies, counters/gauges, histograms).  With
``--snapshot`` it renders the **current process** registry instead — useful
at the end of an instrumented script.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from . import metrics, slo


def load_rows(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def _fmt(x, spec: str = "") -> str:
    if x is None:
        return "—"
    if isinstance(x, float):
        return format(x, spec or ".4g")
    return str(x)


def solve_table(rows: list[dict]) -> str:
    out = [
        "| solve | n | iters (Σ/max) | final residual | converged | wall |",
        "|---|---|---|---|---|---|",
    ]
    groups: dict[str, list[dict]] = defaultdict(list)
    for r in rows:
        if r.get("kind") == "solve":
            groups[r["name"]].append(r)
    for name, rs in groups.items():
        iters = [r.get("iterations", 0) for r in rs]
        res = [r.get("final_residual") for r in rs if r.get("final_residual") is not None]
        conv = all(r.get("converged", False) for r in rs)
        walls = [r["us_per_call"] for r in rs if r.get("us_per_call")]
        wall = f"{sum(walls) / len(walls):.0f}µs" if walls else "—"
        out.append(
            f"| {name} | {len(rs)} | {sum(iters)}/{max(iters) if iters else 0} "
            f"| {_fmt(max(res) if res else None, '.2e')} "
            f"| {'✓' if conv else '**✗**'} | {wall} |"
        )
    return "\n".join(out)


def assembly_table(rows: list[dict]) -> str:
    out = [
        "| assembly | n | dofs | nnz | cells | form |",
        "|---|---|---|---|---|---|",
    ]
    groups: dict[tuple, list[dict]] = defaultdict(list)
    for r in rows:
        if r.get("kind") == "assembly":
            groups[(r["name"], r.get("form"))].append(r)
    for (name, form), rs in groups.items():
        r0 = rs[-1]
        out.append(
            f"| {name} | {len(rs)} | {_fmt(r0.get('num_dofs'))} "
            f"| {_fmt(r0.get('nnz'))} | {_fmt(r0.get('num_cells'))} "
            f"| {form or '—'} |"
        )
    return "\n".join(out)


def metric_table(rows: list[dict]) -> str:
    out = ["| metric | value |", "|---|---|"]
    for r in rows:
        if r.get("kind") == "metric" and r.get("metric") in ("counter", "gauge"):
            out.append(f"| {r['name'].removeprefix('metric/')} | {_fmt(r.get('value'))} |")
    return "\n".join(out)


def histogram_table(rows: list[dict]) -> str:
    out = [
        "| histogram | count | mean | p50 | p90 | p99 | max |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("kind") == "metric" and r.get("metric") == "histogram":
            out.append(
                f"| {r['name'].removeprefix('metric/histogram/')} "
                f"| {_fmt(r.get('count'))} | {_fmt(r.get('mean'))} "
                f"| {_fmt(r.get('p50'))} | {_fmt(r.get('p90'))} "
                f"| {_fmt(r.get('p99'))} | {_fmt(r.get('max'))} |"
            )
    return "\n".join(out)


def span_table(rows: list[dict]) -> str:
    """Per-span-name timing summary plus the number of distinct traces —
    the aggregate view of a spans-instrumented run (use the raw ``span/``
    rows' ``trace_id`` to reassemble one request's timeline)."""
    out = [
        "| span | n | traces | mean | max |",
        "|---|---|---|---|---|",
    ]
    groups: dict[str, list[dict]] = defaultdict(list)
    for r in rows:
        if r.get("kind") == "span":
            groups[r["name"].removeprefix("span/")].append(r)
    for name, rs in groups.items():
        walls = [r["us_per_call"] for r in rs]
        traces = len({r.get("trace_id") for r in rs})
        out.append(
            f"| {name} | {len(rs)} | {traces} "
            f"| {sum(walls) / len(walls):.0f}µs | {max(walls):.0f}µs |"
        )
    return "\n".join(out)


def slo_table(rows: list[dict]) -> str:
    """Objective attainment / burn-rate view of ``kind="slo"`` rows (a
    repeated objective keeps its latest row)."""
    out = [
        "| SLO | objective p99 | observed p99 | n | attainment | burn rate | status |",
        "|---|---|---|---|---|---|---|",
    ]
    latest: dict[str, dict] = {}
    for r in rows:
        if r.get("kind") == "slo":
            latest[r["name"]] = r
    for name, r in latest.items():
        status = "✓ met" if r.get("met") else "**✗ BURNING**"
        out.append(
            f"| {name.removeprefix('slo/')} "
            f"| {_fmt(r.get('objective_us'), '.0f')}µs "
            f"| {_fmt(r.get('p99_us'), '.0f')}µs | {_fmt(r.get('count'))} "
            f"| {_fmt(r.get('attainment'), '.4f')} "
            f"| {_fmt(r.get('burn_rate'), '.2f')} | {status} |"
        )
    if len(out) == 2:
        out.append("| (no SLO rows) | — | — | — | — | — | — |")
    return "\n".join(out)


def render(rows: list[dict]) -> str:
    parts = []
    kinds = {r.get("kind") for r in rows}
    if "solve" in kinds:
        parts += ["### Solves\n", solve_table(rows), ""]
    if "assembly" in kinds:
        parts += ["### Assemblies\n", assembly_table(rows), ""]
    if "span" in kinds:
        parts += ["### Spans\n", span_table(rows), ""]
    if "slo" in kinds:
        parts += ["### SLOs\n", slo_table(rows), ""]
    if any(r.get("metric") in ("counter", "gauge") for r in rows):
        parts += ["### Counters & gauges\n", metric_table(rows), ""]
    if any(r.get("metric") == "histogram" for r in rows):
        parts += ["### Histograms\n", histogram_table(rows), ""]
    other = [r for r in rows
             if r.get("kind") not in ("solve", "assembly", "metric", "span",
                                      "slo", "flight", "flight_dump")]
    if other:
        parts.append("### Other events\n")
        for r in other:
            parts.append(f"- `{r.get('name', '?')}` {r.get('derived', '')}")
        parts.append("")
    if not parts:
        parts = ["(no telemetry rows)"]
    return "\n".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("path", nargs="?", default="telemetry_events.jsonl",
                    help="JSON-lines event file (default: %(default)s)")
    ap.add_argument("--snapshot", action="store_true",
                    help="render the current in-process metrics registry "
                         "instead of reading a file")
    ap.add_argument("--slo", action="store_true",
                    help="render only the SLO attainment / burn-rate table "
                         "(from kind=\"slo\" rows, or the live objectives "
                         "with --snapshot)")
    args = ap.parse_args(argv)
    if args.snapshot:
        rows = slo.slo_rows() if args.slo else metrics.metric_rows()
    else:
        try:
            rows = load_rows(args.path)
        except FileNotFoundError:
            print(f"no such file: {args.path} (run with telemetry.enable"
                  f"(jsonl=...) to produce one, or use --snapshot)",
                  file=sys.stderr)
            return 2
    if args.slo:
        print("### SLOs\n")
        print(slo_table(rows))
        return 0
    print(render(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
