"""Process-global runtime metrics registry (counters / gauges / histograms).

One registry for the whole stack, unifying the scattered per-subsystem
accounting (``assembly.n_core_traces`` / ``operator.n_matfree_traces``)
behind a single API:

* **counters** — monotone totals: jit traces and executable-cache hits of
  the assembly core and matrix-free applies, keyed on
  ``(PlanStatic, form signature, backend)`` via :func:`count_trace` /
  :func:`count_cache`; solve totals; matvec-backend selections.
* **gauges** — last-write-wins values: plan / operator / CSR memory
  footprints (:func:`gauge_set`).
* **histograms** — distributions with summary statistics: solver iteration
  counts and host-side wall times (:func:`histogram_observe`).

Telemetry is **disabled by default** and zero-cost when off: every
recording entry point returns after one boolean check, nothing is staged
into jaxprs (so toggling never retraces), and tracers are never stored —
values are converted to host scalars up front and recording is *skipped*
for abstract values (:func:`concrete_or_none`).

``snapshot()`` renders the registry as plain dicts; ``export_jsonl(path)``
appends one JSON object per metric in the ``BENCH_JSON`` row format of
``benchmarks/common.py`` (``{"name", "us_per_call", "derived", ...}``), so
dashboards ingest benchmark rows and telemetry rows through one parser.
Set ``REPRO_TELEMETRY=1`` (optionally ``REPRO_TELEMETRY_JSONL=<path>``) to
enable at import time.
"""

from __future__ import annotations

import contextlib
import json
import math
import os
import threading
from typing import Any

__all__ = [
    "enable",
    "disable",
    "is_enabled",
    "enabled",
    "jsonl_path",
    "nonconverged_policy",
    "concrete_or_none",
    "counter_inc",
    "gauge_set",
    "histogram_observe",
    "count_trace",
    "count_cache",
    "jit_trace_total",
    "histogram_values",
    "snapshot",
    "reset",
    "export_jsonl",
    "append_jsonl_row",
    "register_snapshot_section",
    "register_row_provider",
]

# one observation cap per histogram key: summaries stay exact for any run
# that fits, and a runaway loop cannot grow host memory without bound
_HIST_LIMIT = 65536


class _State:
    """The process-global telemetry switchboard (thread-safe registry)."""

    def __init__(self):
        self.enabled = False
        self.jsonl: str | None = None
        self.on_nonconverged = "warn"  # "warn" | "raise" | "ignore"
        self.lock = threading.Lock()
        self.counters: dict[tuple, float] = {}
        self.gauges: dict[tuple, float] = {}
        self.hists: dict[tuple, list] = {}


_STATE = _State()

# serializes JSONL appends across threads (events, spans, metric exports all
# share one stream file) — a row is always exactly one line
_IO_LOCK = threading.Lock()

# extension hooks: sibling modules (slo, spans) register here instead of
# being imported, keeping this module dependency-free within the package
_SNAPSHOT_SECTIONS: dict[str, Any] = {}
_ROW_PROVIDERS: list = []


def register_snapshot_section(name: str, fn) -> None:
    """Add a computed section to :func:`snapshot` — ``fn()`` returning a
    dict (or ``None``/falsy to omit the section this time)."""
    _SNAPSHOT_SECTIONS[name] = fn


def register_row_provider(fn) -> None:
    """Add a ``BENCH_JSON``-row source to :func:`metric_rows` — ``fn()``
    returning a list of row dicts."""
    _ROW_PROVIDERS.append(fn)


def enable(jsonl: str | None = None, on_nonconverged: str | None = None) -> None:
    """Turn telemetry recording on.

    ``jsonl``: stream structured events (see :mod:`repro.telemetry.events`)
    to this JSON-lines file as they are recorded.  ``on_nonconverged``
    selects the host-side policy when a solve reports ``converged=False``:
    ``"warn"`` (default), ``"raise"``, or ``"ignore"``.
    """
    if on_nonconverged is not None:
        if on_nonconverged not in ("warn", "raise", "ignore"):
            raise ValueError(
                f"on_nonconverged={on_nonconverged!r}: use 'warn', 'raise' "
                "or 'ignore'"
            )
        _STATE.on_nonconverged = on_nonconverged
    if jsonl is not None:
        _STATE.jsonl = jsonl
    _STATE.enabled = True


def disable() -> None:
    """Turn telemetry recording off (the registry contents are kept —
    call :func:`reset` to drop them)."""
    _STATE.enabled = False


def is_enabled() -> bool:
    return _STATE.enabled


@contextlib.contextmanager
def enabled(jsonl: str | None = None, on_nonconverged: str | None = None):
    """Scoped :func:`enable`: restores the previous on/off state on exit."""
    prev_enabled = _STATE.enabled
    prev_jsonl = _STATE.jsonl
    prev_policy = _STATE.on_nonconverged
    enable(jsonl=jsonl, on_nonconverged=on_nonconverged)
    try:
        yield
    finally:
        _STATE.enabled = prev_enabled
        _STATE.jsonl = prev_jsonl
        _STATE.on_nonconverged = prev_policy


def jsonl_path() -> str | None:
    return _STATE.jsonl if _STATE.enabled else None


def nonconverged_policy() -> str:
    return _STATE.on_nonconverged


# ---------------------------------------------------------------------------
# Tracer safety: telemetry must never capture abstract values into host state
# ---------------------------------------------------------------------------

def concrete_or_none(x) -> Any:
    """``x`` as a host scalar/bool/int, or ``None`` when it is a jax tracer
    (or otherwise not concretizable).  The single guard every recording path
    runs — an abstract value is *skipped*, never stored."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    import jax

    if isinstance(x, jax.core.Tracer):
        return None
    try:
        import numpy as np

        arr = np.asarray(x)
        if arr.ndim == 0:
            return arr.item()
        return arr
    except Exception:
        return None


def _key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


# ---------------------------------------------------------------------------
# Recording
# ---------------------------------------------------------------------------

def counter_inc(name: str, value: float = 1, **labels) -> None:
    if not _STATE.enabled:
        return
    v = concrete_or_none(value)
    if v is None:
        return
    k = _key(name, labels)
    with _STATE.lock:
        _STATE.counters[k] = _STATE.counters.get(k, 0) + v


def gauge_set(name: str, value: float, **labels) -> None:
    if not _STATE.enabled:
        return
    v = concrete_or_none(value)
    if v is None:
        return
    with _STATE.lock:
        _STATE.gauges[_key(name, labels)] = v


def histogram_observe(name: str, value: float, **labels) -> None:
    if not _STATE.enabled:
        return
    v = concrete_or_none(value)
    if v is None:
        return
    k = _key(name, labels)
    with _STATE.lock:
        h = _STATE.hists.setdefault(k, [])
        if len(h) < _HIST_LIMIT:
            h.append(float(v))


# -- the unified jit-trace / cache accounting --------------------------------

def _form_tag(spec) -> str:
    """Human-readable form signature: the ``+``-joined term kinds."""
    try:
        return "+".join(kind for kind, _, _ in spec)
    except Exception:
        return "?"


def _plan_tag(static) -> str:
    """Identity tag of a ``PlanStatic`` (plans hash by identity)."""
    return f"{id(static) & 0xFFFFFFFF:08x}"


def count_trace(kind: str, static=None, spec=None, backend: str | None = None) -> None:
    """One jaxpr trace of a jitted core function — bumped exactly where the
    legacy ``n_core_traces`` / ``n_matfree_traces`` counters bump, keyed on
    (plan identity, form signature, backend).  Runs at trace time with
    static data only: nothing here can capture a tracer."""
    if not _STATE.enabled:
        return
    labels = {"kind": kind}
    if static is not None:
        labels["plan"] = _plan_tag(static)
    if spec is not None:
        labels["form"] = _form_tag(spec)
    if backend is not None:
        labels["backend"] = backend
    counter_inc("jit_traces", 1, **labels)


def count_cache(kind: str, hit: bool) -> None:
    """Executable-cache lookup accounting (hit = compiled fn reused)."""
    if not _STATE.enabled:
        return
    counter_inc("cache_lookups", 1, kind=kind, outcome="hit" if hit else "miss")


def histogram_values(name: str) -> dict[tuple, list]:
    """Raw observations of every series of one histogram family:
    ``{labels_tuple: [values, oldest first]}`` — what the SLO evaluator
    windows over.  Copies, so callers never race the recording paths."""
    with _STATE.lock:
        return {
            labels: list(v)
            for (n, labels), v in _STATE.hists.items()
            if n == name
        }


def jit_trace_total(kind: str | None = None) -> int:
    """Sum of ``jit_traces`` counters, optionally restricted to one kind —
    comparable against the legacy per-subsystem counters."""
    with _STATE.lock:
        total = 0
        for (name, labels), v in _STATE.counters.items():
            if name != "jit_traces":
                continue
            if kind is not None and dict(labels).get("kind") != kind:
                continue
            total += v
        return int(total)


# ---------------------------------------------------------------------------
# Snapshot / export
# ---------------------------------------------------------------------------

def _percentile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return math.nan
    idx = min(len(sorted_vals) - 1, max(0, int(round(q * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def _hist_summary(vals: list) -> dict:
    s = sorted(vals)
    n = len(s)
    return {
        "count": n,
        "sum": sum(s),
        "min": s[0] if n else math.nan,
        "max": s[-1] if n else math.nan,
        "mean": (sum(s) / n) if n else math.nan,
        "p50": _percentile(s, 0.50),
        "p90": _percentile(s, 0.90),
        "p99": _percentile(s, 0.99),
    }


def snapshot() -> dict:
    """The registry as plain dicts: ``{"counters": {name{labels}: value},
    "gauges": {...}, "histograms": {name{labels}: summary}}``."""
    with _STATE.lock:
        counters = dict(_STATE.counters)
        gauges = dict(_STATE.gauges)
        hists = {k: list(v) for k, v in _STATE.hists.items()}
    snap = {
        "counters": {
            f"{name}{_label_str(labels)}": v for (name, labels), v in counters.items()
        },
        "gauges": {
            f"{name}{_label_str(labels)}": v for (name, labels), v in gauges.items()
        },
        "histograms": {
            f"{name}{_label_str(labels)}": _hist_summary(v)
            for (name, labels), v in hists.items()
        },
    }
    for name, fn in _SNAPSHOT_SECTIONS.items():
        section = fn()
        if section:
            snap[name] = section
    return snap


def reset() -> None:
    """Drop every recorded metric (the enabled flag is untouched)."""
    with _STATE.lock:
        _STATE.counters.clear()
        _STATE.gauges.clear()
        _STATE.hists.clear()


def metric_rows() -> list[dict]:
    """The registry as ``BENCH_JSON``-format rows (``name`` / ``us_per_call``
    / ``derived`` + extras): counters and gauges carry their value in the
    ``value`` extra; histograms put the mean in ``us_per_call`` (their
    natural unit for wall-time series) and the full summary in extras."""
    snap = snapshot()
    rows: list[dict] = []
    for name, v in snap["counters"].items():
        rows.append({
            "name": f"metric/counter/{name}", "us_per_call": 0.0,
            "derived": f"value={v}", "kind": "metric", "metric": "counter",
            "value": v,
        })
    for name, v in snap["gauges"].items():
        rows.append({
            "name": f"metric/gauge/{name}", "us_per_call": 0.0,
            "derived": f"value={v}", "kind": "metric", "metric": "gauge",
            "value": v,
        })
    for name, s in snap["histograms"].items():
        rows.append({
            "name": f"metric/histogram/{name}",
            "us_per_call": round(s["mean"], 1) if s["count"] else 0.0,
            "derived": f"count={s['count']};p50={s['p50']:.6g};p99={s['p99']:.6g}",
            "kind": "metric", "metric": "histogram", **s,
        })
    for provider in _ROW_PROVIDERS:
        rows.extend(provider())
    return rows


def append_jsonl_row(row: dict, path: str | None = None) -> None:
    """Append one row to the JSONL stream (default: the configured file)
    under the shared I/O lock — concurrent recorders always produce whole
    single-line rows.  No-op without a path."""
    path = path or _STATE.jsonl
    if not path:
        return
    line = json.dumps(row) + "\n"
    with _IO_LOCK:
        with open(path, "a") as f:
            f.write(line)


def export_jsonl(path: str | None = None) -> list[dict]:
    """Append the registry's :func:`metric_rows` to ``path`` (default: the
    configured streaming file) and return them.  With no path configured the
    rows are only returned."""
    rows = metric_rows()
    path = path or _STATE.jsonl
    if path:
        lines = "".join(json.dumps(row) + "\n" for row in rows)
        with _IO_LOCK:
            with open(path, "a") as f:
                f.write(lines)
    return rows


# env opt-in: REPRO_TELEMETRY=1 [REPRO_TELEMETRY_JSONL=<path>]
if os.environ.get("REPRO_TELEMETRY", "") not in ("", "0"):
    enable(jsonl=os.environ.get("REPRO_TELEMETRY_JSONL") or None)
