"""repro.telemetry — convergence diagnostics, named-phase profiler tracing,
and a unified runtime metrics/event layer.

The paper's claim is *efficiency*; this package is how the repo sees it:

* **Convergence diagnostics** — every Krylov solve carries a
  ``SolveInfo(iters, residual, converged)``; the ``return_info=True`` paths
  on :func:`repro.core.sparse_solve` / :func:`repro.core.matfree_solve` /
  the transient integrators expose it as a non-differentiated auxiliary
  output (stop-gradient leaves — gradients match the plain path to machine
  precision), and :func:`check_convergence` turns a silent ``maxiter`` exit
  into a warning or error.
* **Named-phase tracing** — :class:`annotate` stamps the Map / Reduce /
  gather / scatter / Pallas stages with names visible in a profile;
  :func:`capture` records a TensorBoard/Perfetto trace of any block.
* **Metrics & events** — a process-global registry (jit-trace and
  cache counters unifying ``n_core_traces``/``n_matfree_traces``, memory
  gauges, iteration/wall-time histograms) plus a structured event stream
  with JSON-lines export in the ``BENCH_JSON`` row format; rendered by
  ``python -m repro.telemetry.report``.
* **Request tracing** — :func:`span_root` / :func:`span` build host-side
  span trees with one propagated trace id per request (the serve tier
  opens one per ``submit()``; closed spans fold into ``span_us``
  histograms and stream as ``span/<name>`` rows); a bounded **flight
  recorder** (:func:`configure_flight` / :func:`flight_dump`) keeps the
  last K completed request traces and auto-dumps them on
  nonconverged/expired/shed; :func:`define_slo` tracks latency SLO
  attainment and burn rate against any histogram, surfaced in
  :func:`snapshot` and ``report --slo``.

Disabled by default and zero-cost when off: recording entry points return
after one boolean check, annotations are trace-time-only, nothing telemetry
does is ever staged into a jaxpr (so toggling cannot retrace), and tracers
are never captured into host state.  Enable with :func:`enable` (or
``REPRO_TELEMETRY=1`` in the environment).

This package deliberately imports nothing from :mod:`repro.core` — the core
imports *it*.
"""

from .events import (  # noqa: F401
    ConvergenceWarning,
    NonConvergedError,
    check_convergence,
    clear_events,
    event_log,
    record_assembly,
    record_event,
    record_solve,
)
from .metrics import (  # noqa: F401
    count_cache,
    count_trace,
    counter_inc,
    disable,
    enable,
    enabled,
    export_jsonl,
    gauge_set,
    histogram_observe,
    is_enabled,
    jit_trace_total,
    jsonl_path,
    metric_rows,
    nonconverged_policy,
    reset,
    snapshot,
)
from .slo import (  # noqa: F401
    SLO,
    clear_slos,
    define_slo,
    defined_slos,
    slo_status,
)
from .spans import (  # noqa: F401
    NULL_SPAN,
    Span,
    clear_flight,
    configure_flight,
    current_span,
    flight_autodump,
    flight_dump,
    flight_record,
    flight_records,
    span,
    span_root,
)
from .trace import annotate, capture  # noqa: F401

__all__ = [
    # switchboard
    "enable", "disable", "enabled", "is_enabled", "reset", "jsonl_path",
    "nonconverged_policy",
    # tracing
    "annotate", "capture",
    # metrics
    "counter_inc", "gauge_set", "histogram_observe", "count_trace",
    "count_cache", "jit_trace_total", "snapshot", "export_jsonl",
    "metric_rows",
    # spans / flight recorder
    "Span", "NULL_SPAN", "span", "span_root", "current_span",
    "configure_flight", "flight_record", "flight_records", "flight_dump",
    "flight_autodump", "clear_flight",
    # SLOs
    "SLO", "define_slo", "defined_slos", "clear_slos", "slo_status",
    # events / convergence
    "record_event", "record_solve", "record_assembly", "check_convergence",
    "event_log", "clear_events", "ConvergenceWarning", "NonConvergedError",
]
