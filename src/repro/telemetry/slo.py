"""Latency SLOs: declared objectives, attainment, and burn rate.

An :class:`SLO` declares a latency objective over one telemetry histogram
family — canonically ``serve_e2e_us`` for a tenant or admission class:

    telemetry.define_slo("checkout", p99_us=50_000)           # all e2e
    telemetry.define_slo("csr", p99_us=20_000, backend="csr") # one backend

``p99_us`` is the classic "99% of requests faster than X" objective: the
*good-event* fraction must stay ≥ 0.99 over the rolling ``window`` (the
most recent observations of the matched histogram series).  Status is
computed on demand from the registry — no extra recording cost on the hot
path, and the declarations work retroactively on whatever the histograms
already hold.

Definitions (Google SRE-workbook conventions):

* **attainment** — fraction of windowed observations ≤ ``p99_us``.
* **error budget** — the allowed bad fraction, ``1 − 0.99 = 0.01``.
* **burn rate** — observed bad fraction ÷ budget: ``1.0`` burns the budget
  exactly at the sustainable rate, ``> 1`` exhausts it early (a burn rate
  of 14.4 on a 30-day budget exhausts it in ~2 days — the classic page
  threshold), ``0`` means no violations in the window.

``slo_status()`` is surfaced in ``telemetry.snapshot()["slo"]`` (when any
SLO is defined), exported as ``kind="slo"`` rows by ``export_jsonl``, and
rendered by ``python -m repro.telemetry.report --slo``.
"""

from __future__ import annotations

import dataclasses
import math

from . import metrics

__all__ = [
    "SLO",
    "define_slo",
    "clear_slos",
    "defined_slos",
    "slo_status",
    "slo_rows",
]

# a p99 objective: 99% of requests must beat the target latency
_GOOD_FRACTION = 0.99


@dataclasses.dataclass(frozen=True)
class SLO:
    """One latency objective.

    ``labels`` restricts the histogram series the objective reads: a series
    matches when its label set contains every ``(k, v)`` pair (so
    ``backend="csr"`` matches ``serve_e2e_us{backend=csr}`` but not the
    matfree series; no labels matches every series of the family).
    """

    name: str
    p99_us: float
    window: int = 1024
    histogram: str = "serve_e2e_us"
    labels: tuple = ()

    def __post_init__(self):
        if self.p99_us <= 0:
            raise ValueError(f"p99_us must be > 0, got {self.p99_us}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")


_SLOS: dict[str, SLO] = {}


def define_slo(name: str, p99_us: float, *, window: int = 1024,
               histogram: str = "serve_e2e_us", **labels) -> SLO:
    """Declare (or replace) one objective.  Returns the :class:`SLO`."""
    slo = SLO(name=name, p99_us=float(p99_us), window=int(window),
              histogram=histogram, labels=tuple(sorted(labels.items())))
    _SLOS[name] = slo
    return slo


def clear_slos() -> None:
    _SLOS.clear()


def defined_slos() -> dict[str, SLO]:
    return dict(_SLOS)


def _matched_values(slo: SLO) -> list[float]:
    """Windowed observations: merge every series of ``slo.histogram`` whose
    labels cover ``slo.labels``, keep the most recent ``window``."""
    want = dict(slo.labels)
    merged: list[float] = []
    for labels, vals in metrics.histogram_values(slo.histogram).items():
        have = dict(labels)
        if all(have.get(k) == v for k, v in want.items()):
            merged.extend(vals)
    return merged[-slo.window:]


def _status_of(slo: SLO) -> dict:
    vals = _matched_values(slo)
    n = len(vals)
    if n == 0:
        return {
            "objective_us": slo.p99_us, "window": slo.window,
            "histogram": slo.histogram, "labels": dict(slo.labels),
            "count": 0, "p99_us": math.nan, "attainment": math.nan,
            "burn_rate": 0.0, "met": True,  # no traffic burns no budget
        }
    s = sorted(vals)
    p99 = s[min(n - 1, max(0, int(round(0.99 * (n - 1)))))]
    good = sum(1 for v in vals if v <= slo.p99_us)
    attainment = good / n
    bad_fraction = 1.0 - attainment
    burn_rate = bad_fraction / (1.0 - _GOOD_FRACTION)
    return {
        "objective_us": slo.p99_us, "window": slo.window,
        "histogram": slo.histogram, "labels": dict(slo.labels),
        "count": n, "p99_us": p99,
        "attainment": attainment, "burn_rate": burn_rate,
        "met": attainment >= _GOOD_FRACTION,
    }


def slo_status() -> dict[str, dict]:
    """Every defined objective → its current status dict (attainment, burn
    rate, observed p99, met).  Empty dict with nothing defined."""
    return {name: _status_of(slo) for name, slo in _SLOS.items()}


def slo_rows() -> list[dict]:
    """The status as ``BENCH_JSON`` rows (``kind="slo"``) for
    ``export_jsonl`` — the ``report --slo`` input format."""
    rows = []
    for name, st in slo_status().items():
        rows.append({
            "name": f"slo/{name}",
            "us_per_call": 0.0 if math.isnan(st["p99_us"]) else round(st["p99_us"], 1),
            "derived": (f"objective={st['objective_us']:g}"
                        f";attainment={st['attainment']:.4f}"
                        f";burn={st['burn_rate']:.2f}"
                        f";met={st['met']}"),
            "kind": "slo",
            "slo": name,
            **{k: v for k, v in st.items() if k != "labels"},
            "labels": st["labels"],
        })
    return rows


# surface SLO status in snapshot() / export_jsonl without metrics importing
# this module (registration keeps the dependency one-way)
metrics.register_snapshot_section("slo", lambda: slo_status() or None)
metrics.register_row_provider(slo_rows)
