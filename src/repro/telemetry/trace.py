"""Named-phase profiler tracing: FEM phases visible in Perfetto/TensorBoard.

A profile of a Galerkin solve is otherwise a wall of anonymous XLA fusions.
:class:`annotate` stamps a phase name onto everything traced (or executed)
under it by composing the two jax mechanisms that cover both worlds:

* ``jax.named_scope`` — pushes the name onto the jaxpr name stack, so the
  *compiled* HLO ops carry it (device timeline in a captured profile);
* ``jax.profiler.TraceAnnotation`` — a host TraceMe, so eager/host-side
  sections show up on the host timeline.

Inside jitted code both run at **trace time only**: annotating the Map /
Reduce / gather / scatter / Pallas stages costs nothing per call once the
executable is compiled, which is what lets the hot paths stay annotated
unconditionally (no telemetry flag, no retrace risk).

:func:`capture` wraps ``jax.profiler.trace``: everything run inside the
``with`` block lands in a TensorBoard/Perfetto-loadable profile directory
(``<path>/plugins/profile/<ts>/*.xplane.pb`` + ``*.trace.json.gz``).
"""

from __future__ import annotations

import contextlib
import functools
import os

import jax

from . import events

__all__ = ["annotate", "capture"]


class annotate:
    """Name a phase: context manager *and* decorator.

    ::

        with annotate("tg.reduce"):
            vals = segment_sum(...)

        @annotate("tg.map")
        def map_stage(...): ...
    """

    def __init__(self, name: str):
        self.name = name
        self._stack: contextlib.ExitStack | None = None

    def __enter__(self):
        self._stack = contextlib.ExitStack()
        self._stack.enter_context(jax.named_scope(self.name))
        self._stack.enter_context(jax.profiler.TraceAnnotation(self.name))
        return self

    def __exit__(self, *exc):
        stack, self._stack = self._stack, None
        return stack.__exit__(*exc)

    def __call__(self, fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            # a fresh instance per call: the context manager is one-shot
            with annotate(self.name):
                return fn(*args, **kwargs)

        return wrapped


@contextlib.contextmanager
def capture(path: str, *, create_perfetto_link: bool = False):
    """Capture a profiler trace of the enclosed block into ``path``.

    ::

        with telemetry.capture("/tmp/tg_profile"):
            u = prob.solve(backend="matfree")

    The directory is TensorBoard-loadable (``tensorboard --logdir path``)
    and contains a gzipped Chrome/Perfetto trace; phases wrapped in
    :class:`annotate` (Map, Reduce, gather/scatter, Pallas kernels, Krylov
    loops) appear by name instead of anonymous XLA ops.  Emits a
    ``trace_captured`` telemetry event when recording is enabled.
    """
    os.makedirs(path, exist_ok=True)
    with jax.profiler.trace(path, create_perfetto_link=create_perfetto_link):
        yield
    events.record_event("profile", "trace_captured", path=path)
