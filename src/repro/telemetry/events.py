"""Structured runtime events: solves, assemblies, captures — one stream.

An *event* is a host-side record emitted at an eager boundary (a problem
``.solve`` returning, an assembly producing a CSR, a profile capture
finishing).  Events are:

* appended to a bounded in-memory log (:func:`event_log`),
* folded into the metrics registry (solve-iteration and wall-time
  histograms, solve/assembly counters),
* streamed to the configured JSON-lines file in the ``BENCH_JSON`` row
  format (``{"name", "us_per_call", "derived", ...extras}``) when
  :func:`repro.telemetry.enable` was given a ``jsonl`` path.

Tracer discipline: every field runs through
:func:`~repro.telemetry.metrics.concrete_or_none`; a recording call made
from inside a traced context (a ``vmap``-ed solve, a ``lax.scan`` body)
silently records nothing — abstract values never leak into host state, and
toggling telemetry never changes a jaxpr.

Convergence policy lives here too: :func:`check_convergence` is the
host-side guard that turns a silently-garbage ``maxiter`` exit into a
:class:`ConvergenceWarning` (default) or :class:`NonConvergedError` — it
works with telemetry disabled, because a wrong answer should never need a
flag to be reported.
"""

from __future__ import annotations

import threading
import time
import warnings

import numpy as np

from . import metrics, spans

__all__ = [
    "ConvergenceWarning",
    "NonConvergedError",
    "record_event",
    "record_solve",
    "record_assembly",
    "check_convergence",
    "event_log",
    "clear_events",
]

_EVENTS: list[dict] = []
_EVENT_LIMIT = 65536
# guards _EVENTS across recorder threads (the serve dispatch worker records
# concurrently with driver-thread exports)
_EVENTS_LOCK = threading.Lock()


class ConvergenceWarning(UserWarning):
    """A Krylov solve exited at ``maxiter`` without reaching tolerance."""


class NonConvergedError(RuntimeError):
    """Raised (under the ``on_nonconverged="raise"`` policy) when a solve
    reports ``converged=False``."""


def event_log() -> list[dict]:
    """The in-memory event list (bounded; newest last)."""
    with _EVENTS_LOCK:
        return list(_EVENTS)


def clear_events() -> None:
    with _EVENTS_LOCK:
        _EVENTS.clear()


def _derived(fields: dict) -> str:
    return ";".join(f"{k}={v}" for k, v in fields.items() if v is not None)


def record_event(kind: str, name: str, *, wall_us: float | None = None,
                 **fields):
    """Record one structured event.  Returns the event dict, or ``None``
    when telemetry is disabled or any field is abstract (tracer-safe)."""
    if not metrics.is_enabled():
        return None
    clean: dict = {}
    for k, v in fields.items():
        c = metrics.concrete_or_none(v)
        if c is None and v is not None:
            return None  # a tracer snuck in: skip the whole event
        if isinstance(c, np.ndarray):
            c = c.tolist()
        if isinstance(c, np.generic):
            c = c.item()
        clean[k] = c
    wall = metrics.concrete_or_none(wall_us)
    ev = {"kind": kind, "name": name, "t": time.time(), **clean}
    if wall is not None:
        ev["wall_us"] = round(float(wall), 1)
    # span-awareness: an event recorded under an open span inherits its
    # trace identity, so per-request timelines include their solve events
    sp = spans.current_span()
    if sp is not None and sp is not spans.NULL_SPAN:
        ev["trace_id"] = sp.trace_id
        ev["span_id"] = sp.span_id
    with _EVENTS_LOCK:
        if len(_EVENTS) < _EVENT_LIMIT:
            _EVENTS.append(ev)
    metrics.counter_inc("events", 1, kind=kind)
    if metrics.jsonl_path():
        row = {
            "name": f"{kind}/{name}",
            "us_per_call": ev.get("wall_us", 0.0),
            "derived": _derived(clean),
            "kind": kind,
            **clean,
        }
        if "trace_id" in ev:
            row["trace_id"] = ev["trace_id"]
            row["span_id"] = ev["span_id"]
        metrics.append_jsonl_row(row)
    return ev


def _summarize_info(info):
    """Host scalars from a ``SolveInfo`` (possibly with batched / per-step
    leaves): total + max iterations, worst residual, all-converged.  Returns
    ``None`` if any leaf is abstract."""
    it = metrics.concrete_or_none(info.iters)
    res = metrics.concrete_or_none(info.residual)
    conv = metrics.concrete_or_none(getattr(info, "converged", True))
    if it is None or res is None or conv is None:
        return None
    it = np.asarray(it)
    res = np.asarray(res)
    conv = np.asarray(conv)
    return {
        "iterations": int(it.sum()),
        "iterations_max": int(it.max()),
        "n_solves": int(it.size),
        "final_residual": float(res.max()),
        "converged": bool(conv.all()),
    }


def check_convergence(info, where: str = "solve", on_fail: str | None = None):
    """Host-side non-convergence guard.  ``info`` is a ``SolveInfo`` (scalar
    or batched/stacked leaves).  If every leaf is concrete and any solve has
    ``converged=False``, apply the policy: ``"warn"`` (default, a
    :class:`ConvergenceWarning`), ``"raise"`` (:class:`NonConvergedError`),
    or ``"ignore"``.  Abstract leaves (called under trace) are a no-op.
    Returns the summary dict (or ``None`` when abstract).

    Works with telemetry disabled — silent garbage from a ``maxiter`` exit
    is a correctness bug, not an observability feature.
    """
    s = _summarize_info(info)
    if s is None or s["converged"]:
        return s
    policy = on_fail or metrics.nonconverged_policy()
    msg = (
        f"{where}: solver did NOT converge after {s['iterations_max']} "
        f"iterations (final residual {s['final_residual']:.3e}"
        + (f", {s['n_solves']} solves" if s["n_solves"] > 1 else "")
        + ") — the returned solution does not meet tolerance"
    )
    if policy == "raise":
        raise NonConvergedError(msg)
    if policy == "warn":
        warnings.warn(msg, ConvergenceWarning, stacklevel=3)
    return s


def record_solve(name: str, info, *, method: str | None = None,
                 backend: str | None = None, precond: str | None = None,
                 phase: str = "forward",
                 wall_us: float | None = None, **extra):
    """Record one solve event from a ``SolveInfo`` and fold it into the
    metrics (iteration histogram, optional wall-time histogram, solve
    counter).  ``precond`` labels the iteration histogram per
    preconditioner, so convergence regressions show up per backend.
    Tracer-safe no-op when disabled or under trace."""
    if not metrics.is_enabled():
        return None
    s = _summarize_info(info)
    if s is None:
        return None
    labels = {"solver": method or "?", "phase": phase}
    if backend:
        labels["backend"] = backend
    if precond:
        labels["precond"] = precond
    metrics.counter_inc("solves", s["n_solves"], **labels)
    metrics.histogram_observe("solve_iterations", s["iterations"], **labels)
    if wall_us is not None:
        w = metrics.concrete_or_none(wall_us)
        if w is not None:
            metrics.histogram_observe("solve_wall_us", float(w), **labels)
    return record_event(
        "solve", name, wall_us=wall_us, method=method, backend=backend,
        precond=precond, phase=phase, **s, **extra,
    )


def record_assembly(name: str, *, num_dofs: int | None = None,
                    nnz: int | None = None, num_cells: int | None = None,
                    form: str | None = None, wall_us: float | None = None,
                    **extra):
    """Record one assembly event (an eager ``assemble``/``assemble_rhs``
    producing a global operator or load vector)."""
    if not metrics.is_enabled():
        return None
    metrics.counter_inc("assemblies", 1, form=form or "?")
    if wall_us is not None:
        w = metrics.concrete_or_none(wall_us)
        if w is not None:
            metrics.histogram_observe("assembly_wall_us", float(w),
                                      form=form or "?")
    return record_event(
        "assembly", name, wall_us=wall_us, num_dofs=num_dofs, nnz=nnz,
        num_cells=num_cells, form=form, **extra,
    )
