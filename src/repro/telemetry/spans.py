"""Host-side span trees with propagated trace IDs + the flight recorder.

A :class:`Span` is a lightweight host-side timing record — ``(trace_id,
span_id, parent_id, name, tags, start_ns, end_ns)`` — organized into trees:
one root per traced operation (a serve request, an instrumented driver
loop), children for its phases (queue wait, dispatch, device solve, slice).
Trace IDs propagate with the root: every span of one request shares its
``trace_id``, so a JSONL stream from many concurrent requests reassembles
into per-request timelines.

Spans preserve the PR-5 telemetry invariants:

* **disabled ⇒ zero cost** — :func:`span_root` / :func:`span` return the
  process-wide :data:`NULL_SPAN` after one boolean check; every operation
  on it is a no-op, so instrumented code paths never branch on telemetry
  themselves.
* **nothing staged into jaxprs** — spans are pure host side effects
  (``time.monotonic_ns`` + dict appends); opening/closing one inside a
  traced region records trace-time walls but never changes the jaxpr.
* **tracers never stored** — tag values run through
  :func:`~repro.telemetry.metrics.concrete_or_none`; abstract values are
  dropped, never kept.

On :meth:`Span.finish` a span folds into the existing registry — one
``span_us{span=<name>}`` histogram observation — and, when a JSONL stream
is configured, appends one ``BENCH_JSON``-format row
(``{"name": "span/<name>", "us_per_call", "derived", "trace_id", ...}``).

The **flight recorder** is a bounded ring buffer of the last K completed
span trees plus caller context (admission key, bucket, ``SolveInfo``
summary, outcome).  The serve tier records every completed request into it
and auto-dumps the ring to JSONL on anomalies (non-convergence, deadline
expiry, shedding); :func:`flight_dump` dumps it on demand.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque

import numpy as np

from . import metrics

__all__ = [
    "Span",
    "NULL_SPAN",
    "span_root",
    "span",
    "current_span",
    "push_span",
    "pop_span",
    "configure_flight",
    "flight_record",
    "flight_records",
    "flight_dump",
    "flight_autodump",
    "clear_flight",
]

_TRACE_IDS = itertools.count(1)
_SPAN_IDS = itertools.count(1)
_TLS = threading.local()  # per-thread stack of open spans


def _clean_tag(v):
    """Host value for a span tag, or ``None`` for tracers/unconvertibles."""
    c = metrics.concrete_or_none(v)
    if isinstance(c, np.ndarray):
        c = c.tolist()
    if isinstance(c, np.generic):
        c = c.item()
    return c


class Span:
    """One timed phase.  Build children with :meth:`child`; close with
    :meth:`finish` (idempotent).  All times are ``time.monotonic_ns()``
    integers — the same clock as the serve tier's second-resolution
    timestamps, so span walls and ``t_done - t_submit`` agree."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "tags",
                 "start_ns", "end_ns", "children")

    def __init__(self, name: str, *, trace_id: int | None = None,
                 parent: "Span | None" = None, start_ns: int | None = None,
                 **tags):
        self.trace_id = next(_TRACE_IDS) if trace_id is None else trace_id
        self.span_id = next(_SPAN_IDS)
        self.parent_id = None if parent is None else parent.span_id
        self.name = name
        self.tags: dict = {}
        self.start_ns = (time.monotonic_ns() if start_ns is None
                         else int(start_ns))
        self.end_ns: int | None = None
        self.children: list[Span] = []
        if tags:
            self.tag(**tags)

    def __bool__(self) -> bool:
        return True

    def __repr__(self) -> str:
        state = "open" if self.end_ns is None else f"{self.wall_us:.1f}us"
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"id={self.span_id}, {state}, "
                f"children={len(self.children)})")

    @property
    def wall_us(self) -> float | None:
        """Closed wall time in µs (``None`` while the span is open)."""
        if self.end_ns is None:
            return None
        return (self.end_ns - self.start_ns) / 1e3

    def tag(self, **tags) -> "Span":
        """Attach host-safe tag values (tracers are silently dropped)."""
        for k, v in tags.items():
            c = _clean_tag(v)
            if c is not None or v is None:
                self.tags[k] = c
        return self

    def child(self, name: str, *, start_ns: int | None = None,
              **tags) -> "Span":
        """Open a child span inheriting this span's ``trace_id``."""
        c = Span(name, trace_id=self.trace_id, parent=self,
                 start_ns=start_ns, **tags)
        self.children.append(c)
        return c

    def finish(self, *, end_ns: int | None = None, **tags) -> "Span":
        """Close the span (idempotent): stamp ``end_ns``, fold the wall into
        the ``span_us`` histogram, and stream one JSONL row when a stream
        is configured.  Open children are closed at the same instant."""
        if tags:
            self.tag(**tags)
        if self.end_ns is not None:
            return self
        self.end_ns = time.monotonic_ns() if end_ns is None else int(end_ns)
        for c in self.children:
            if c.end_ns is None:
                c.finish(end_ns=self.end_ns)
        metrics.histogram_observe("span_us", self.wall_us, span=self.name)
        path = metrics.jsonl_path()
        if path:
            metrics.append_jsonl_row(self.to_row(), path)
        return self

    def to_dict(self) -> dict:
        """The span (sub)tree as plain dicts — what a
        :class:`~repro.serve.batching.SolveResponse` carries in ``trace``."""
        return {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "tags": dict(self.tags),
            "start_ns": self.start_ns,
            "end_ns": self.end_ns,
            "wall_us": None if self.wall_us is None else round(self.wall_us, 3),
            "children": [c.to_dict() for c in self.children],
        }

    def to_row(self) -> dict:
        """This span (no children) as one ``BENCH_JSON`` row."""
        wall = self.wall_us
        derived = (f"trace={self.trace_id};span={self.span_id}"
                   + (f";parent={self.parent_id}"
                      if self.parent_id is not None else ""))
        return {
            "name": f"span/{self.name}",
            "us_per_call": 0.0 if wall is None else round(wall, 1),
            "derived": derived,
            "kind": "span",
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "start_ns": self.start_ns,
            "end_ns": self.end_ns,
            **self.tags,
        }

    # -- context-manager protocol (pushes onto the thread-local stack) -----
    def __enter__(self) -> "Span":
        push_span(self)
        return self

    def __exit__(self, *exc) -> None:
        pop_span(self)
        self.finish()


class _NullSpan:
    """The disabled-telemetry span: every operation is a no-op, ``bool()``
    is ``False``, and ``to_dict()`` is ``None`` — instrumented code never
    needs its own enabled check."""

    __slots__ = ()
    trace_id = 0
    span_id = 0
    parent_id = None
    name = ""
    tags: dict = {}
    start_ns = 0
    end_ns = 0
    children: list = []
    wall_us = 0.0

    def __bool__(self) -> bool:
        return False

    def __repr__(self) -> str:
        return "NULL_SPAN"

    def tag(self, **tags) -> "_NullSpan":
        return self

    def child(self, name: str, **kw) -> "_NullSpan":
        return self

    def finish(self, **kw) -> "_NullSpan":
        return self

    def to_dict(self):
        return None

    def to_row(self):
        return None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_SPAN = _NullSpan()


def span_root(name: str, **tags):
    """A new root span with a fresh ``trace_id`` — or :data:`NULL_SPAN`
    when telemetry is disabled (the one boolean check)."""
    if not metrics.is_enabled():
        return NULL_SPAN
    return Span(name, **tags)


def span(name: str, **tags):
    """Context-manager span: a child of the current thread's open span (or
    a new root), pushed onto the thread-local stack for the block.  Returns
    :data:`NULL_SPAN` when disabled."""
    if not metrics.is_enabled():
        return NULL_SPAN
    parent = current_span()
    if parent is not None and parent is not NULL_SPAN:
        return parent.child(name, **tags)
    return Span(name, **tags)


def current_span():
    """The innermost open span on this thread's stack, or ``None``."""
    stack = getattr(_TLS, "stack", None)
    return stack[-1] if stack else None


def push_span(sp) -> None:
    """Manually push a span as this thread's current context (the serve
    dispatch worker uses this to parent ``record_solve`` events under the
    batch it is running)."""
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    stack.append(sp)


def pop_span(sp) -> None:
    stack = getattr(_TLS, "stack", None)
    if stack and stack[-1] is sp:
        stack.pop()
    elif stack and sp in stack:
        stack.remove(sp)


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------

_FLIGHT_LOCK = threading.Lock()
_FLIGHT_CAPACITY = 256
_FLIGHT: deque = deque(maxlen=_FLIGHT_CAPACITY)
_FLIGHT_PATH: str | None = None


def configure_flight(capacity: int | None = None,
                     path: str | None = None) -> None:
    """Size the ring (last ``capacity`` completed records) and/or set the
    auto-dump JSONL path.  With no explicit path, anomaly auto-dumps derive
    ``<stream>.flight.jsonl`` from the configured telemetry stream (and are
    silently skipped when neither exists)."""
    global _FLIGHT, _FLIGHT_CAPACITY, _FLIGHT_PATH
    with _FLIGHT_LOCK:
        if capacity is not None:
            if capacity < 1:
                raise ValueError(f"capacity must be >= 1, got {capacity}")
            _FLIGHT_CAPACITY = int(capacity)
            _FLIGHT = deque(_FLIGHT, maxlen=_FLIGHT_CAPACITY)
        if path is not None:
            _FLIGHT_PATH = path


def _flight_path() -> str | None:
    if _FLIGHT_PATH:
        return _FLIGHT_PATH
    stream = metrics.jsonl_path()
    return f"{stream}.flight.jsonl" if stream else None


def flight_record(trace, **context):
    """Append one completed record (a :class:`Span` tree or ``None``) plus
    caller context to the ring.  Tracer-safe, bounded, no-op when
    disabled.  Returns the record dict (or ``None``)."""
    if not metrics.is_enabled():
        return None
    clean = {}
    for k, v in context.items():
        c = _clean_tag(v)
        if c is None and v is not None:
            continue  # a tracer snuck in: drop the field, keep the record
        clean[k] = c
    rec = {
        "kind": "flight",
        "t": time.time(),
        "trace": trace.to_dict() if trace else None,
        **clean,
    }
    with _FLIGHT_LOCK:
        _FLIGHT.append(rec)
    return rec


def flight_records() -> list[dict]:
    """The ring contents, oldest first."""
    with _FLIGHT_LOCK:
        return list(_FLIGHT)


def clear_flight() -> None:
    with _FLIGHT_LOCK:
        _FLIGHT.clear()


def flight_dump(path: str | None = None, *, reason: str = "manual") -> int:
    """Dump the ring to a JSONL file (one header row ``kind=flight_dump``
    then one row per record, oldest first).  ``path`` defaults to the
    configured/derived flight path.  Returns the number of records written
    (0 when there is nowhere to write or nothing recorded)."""
    recs = flight_records()
    path = path or _flight_path()
    if not path or not recs:
        return 0
    header = {
        "name": f"flight_dump/{reason}",
        "us_per_call": 0.0,
        "derived": f"records={len(recs)};reason={reason}",
        "kind": "flight_dump",
        "reason": reason,
        "records": len(recs),
        "t": time.time(),
    }
    with open(path, "a") as f:
        f.write(json.dumps(header) + "\n")
        for rec in recs:
            f.write(json.dumps(rec) + "\n")
    metrics.counter_inc("flight_dumps", 1, reason=reason)
    return len(recs)


def flight_autodump(reason: str) -> int:
    """Anomaly-triggered dump (non-convergence / deadline expiry / shed):
    dump the ring to the auto path when one is configured or derivable.
    No-op (returns 0) otherwise — the ring still holds the history for an
    on-demand :func:`flight_dump`."""
    if not metrics.is_enabled():
        return 0
    if _flight_path() is None:
        return 0
    return flight_dump(reason=reason)
