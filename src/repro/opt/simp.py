"""TensorOpt — SIMP compliance minimization (paper §B.4).

2D cantilever: rectangular QUAD4 mesh, fixed left edge, downward load near
the bottom-right corner.  Compliance C(ρ) = FᵀU with K(ρ)U = F, SIMP
interpolation E(ρ) = E_min + ρᵖ(E_max − E_min), sensitivity via **autodiff
through the differentiable assembly + sparse solve** (the paper's point:
Eq. B.28 is *not* hand-coded — it falls out of the adjoint custom-vjp).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    CSR,
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    assemble_batched,
    weakform as wf,
)
from ..core.mesh import rectangle_quad
from ..core.mesh import element_for_mesh
from ..core.solvers import SolverSpec, sparse_solve

# SIMP compliance solves: CG+Jacobi at paper tolerance, deep maxiter for
# the nearly-void SIMP states near convergence
_SIMP_SPEC = SolverSpec(method="cg", tol=1e-10, atol=1e-10, maxiter=30000)

__all__ = ["CantileverProblem", "sensitivity_filter", "oc_update"]


def sensitivity_filter(centers: np.ndarray, rmin: float):
    """Classic sensitivity/density filter: sparse row-normalized weights
    w_ij = max(0, rmin − |x_i − x_j|) over element centers (precomputed)."""
    from scipy.spatial import cKDTree

    tree = cKDTree(centers)
    pairs = tree.query_pairs(rmin, output_type="ndarray")
    i = np.concatenate([pairs[:, 0], pairs[:, 1], np.arange(len(centers))])
    j = np.concatenate([pairs[:, 1], pairs[:, 0], np.arange(len(centers))])
    d = np.linalg.norm(centers[i] - centers[j], axis=-1)
    w = np.maximum(0.0, rmin - d)
    rowsum = np.zeros(len(centers))
    np.add.at(rowsum, i, w)
    i_j = jnp.asarray(i), jnp.asarray(j)
    w_j = jnp.asarray(w)
    rs = jnp.asarray(rowsum)

    def apply(x):
        num = jax.ops.segment_sum(w_j * x[i_j[1]], i_j[0], num_segments=len(centers))
        return num / rs

    return apply


class CantileverProblem:
    """60×30 QUAD4 cantilever (paper B.4.1 geometry & SIMP constants)."""

    def __init__(self, nx=60, ny=30, lx=60.0, ly=30.0,
                 e_max=70_000.0, e_min=70.0, nu=0.3, penal=3.0,
                 volfrac=0.5, rmin_factor=1.5, load=-100.0):
        self.mesh = rectangle_quad(nx, ny, lx, ly)
        self.space = FunctionSpace(self.mesh, element_for_mesh(self.mesh), value_size=2)
        self.asm = GalerkinAssembler(self.space)
        self.penal, self.e_max, self.e_min = penal, e_max, e_min
        self.volfrac = volfrac
        self.n_elem = self.mesh.num_cells

        # unit-modulus Lamé parameters (scaled per-element by SIMP E(ρ))
        self.lam1 = nu / ((1 + nu) * (1 - 2 * nu))
        self.mu1 = 1.0 / (2 * (1 + nu))

        # BCs: clamp left edge (x=0); traction on x=lx, 0<=y<=0.1*ly lumped
        # onto the corner nodes (consistent with the classic 88-line setup).
        pts = self.space.dof_points
        left = np.nonzero(pts[:, 0] < 1e-9)[0]
        bc_dofs = (left[:, None] * 2 + np.arange(2)).ravel()
        self.bc = DirichletCondenser(self.asm, bc_dofs)
        loaded = np.nonzero((pts[:, 0] > lx - 1e-9) & (pts[:, 1] <= 0.1 * ly + 1e-9))[0]
        f = np.zeros(self.space.num_dofs)
        f[loaded * 2 + 1] = load / len(loaded)
        self.f = jnp.asarray(f) * jnp.asarray(self.bc.free_mask)

        centers = self.mesh.points[self.mesh.cells].mean(axis=1)
        h = lx / nx
        self.filter = sensitivity_filter(centers, rmin_factor * h)

        # reference local stiffness at unit modulus (for the analytic
        # sensitivity check, Eq. B.28)
        from ..core import forms

        ctx = self.asm.context()
        self._k0_local = forms.elasticity(ctx, self.lam1, self.mu1)
        self._cell_dofs = jnp.asarray(self.space.cell_dofs)

    # -- differentiable forward -------------------------------------------------
    def simp_modulus(self, rho):
        return self.e_min + rho**self.penal * (self.e_max - self.e_min)

    @partial(jax.jit, static_argnums=(0,))
    def compliance(self, rho):
        # one fused assembly call: SIMP interpolation E(ρ) enters as the
        # traced per-element scale of the elasticity term
        scale = self.simp_modulus(rho)
        k = self.asm.assemble(wf.elasticity(self.lam1, self.mu1, scale=scale))
        kc = self.bc.apply_matrix_only(k)
        u = sparse_solve(kc, self.f, _SIMP_SPEC)
        return jnp.dot(self.f, u)

    @partial(jax.jit, static_argnums=(0,))
    def compliance_and_sensitivity(self, rho):
        c, grad = jax.value_and_grad(self.compliance)(rho)
        return c, grad

    # -- multi-start batched evaluation ----------------------------------------
    def _compliance_batch(self, rho_batch):
        # ONE batched assembly over the whole family: the B SIMP-interpolated
        # scale fields ride the batched leaf slot of the elasticity term, the
        # Dirichlet masks broadcast over (B, nnz), and the B adjoint solves
        # share one vmapped executable
        scale = self.simp_modulus(rho_batch)                   # (B, E)
        kb = assemble_batched(
            self.asm.plan,
            wf.elasticity(self.lam1, self.mu1, scale=scale[0]),
            leaves_batch=(None, None, scale, None),
        )
        kc = self.bc.apply_matrix_only(kb)

        def one(k):
            u = sparse_solve(k.as_csr(), self.f, _SIMP_SPEC)
            return jnp.dot(self.f, u)

        return jax.vmap(one)(kc)

    @partial(jax.jit, static_argnums=(0,))
    def compliance_batch(self, rho_batch):
        """Compliance of a batch of density fields ``(B, E) → (B,)`` — the
        multi-start evaluation: one fused batched assembly + one vmapped
        adjoint solve per family instead of B sequential pipelines."""
        return self._compliance_batch(rho_batch)

    @partial(jax.jit, static_argnums=(0,))
    def compliance_and_sensitivity_batch(self, rho_batch):
        """Per-instance compliances and sensitivities of a ``(B, E)`` family
        in one reverse pass (instances are independent, so the vjp against
        ones recovers each instance's gradient row)."""
        c, vjp = jax.vjp(self._compliance_batch, rho_batch)
        (grad,) = vjp(jnp.ones_like(c))
        return c, grad

    def multistart_step(self, rho_batch, move=0.1):
        """One OC update of every start in the family: batched
        compliance/sensitivity, vmapped sensitivity filter + OC bisection.
        Returns ``(rho_batch', compliances)``."""
        c, sens = self.compliance_and_sensitivity_batch(rho_batch)
        filt = jax.vmap(
            lambda r, s: self.filter(s * r) / jnp.maximum(r, 1e-3)
        )(rho_batch, sens)
        rho_new = jax.vmap(
            lambda r, s: oc_update(r, s, self.volfrac, move=move)
        )(rho_batch, filt)
        return rho_new, c

    def analytic_sensitivity(self, rho):
        """Closed-form Eq. B.28 — used only to validate the AD path."""
        scale = self.simp_modulus(rho)
        k = self.asm.assemble(wf.elasticity(self.lam1, self.mu1, scale=scale))
        kc = self.bc.apply_matrix_only(k)
        u = sparse_solve(kc, self.f, _SIMP_SPEC)
        u_e = u[self._cell_dofs]                                # (E, k)
        quad = jnp.einsum("ea,eab,eb->e", u_e, self._k0_local, u_e)
        return -self.penal * rho ** (self.penal - 1) * (self.e_max - self.e_min) * quad

    def volume(self, rho):
        return jnp.mean(rho)


def oc_update(rho, sens, volfrac, move=0.1, rho_min=1e-3,
              l1=1e-9, l2=1e9, iters=60):
    """Optimality-criteria update with bisection on the volume multiplier."""
    sens = jnp.minimum(sens, 0.0)  # compliance sensitivities are negative

    def body(_, bounds):
        l1, l2 = bounds
        lmid = 0.5 * (l1 + l2)
        b = rho * jnp.sqrt(-sens / lmid)
        new = jnp.clip(jnp.clip(b, rho - move, rho + move), rho_min, 1.0)
        too_much = jnp.mean(new) > volfrac
        return jnp.where(too_much, lmid, l1), jnp.where(too_much, l2, lmid)

    l1f, l2f = jax.lax.fori_loop(0, iters, body, (jnp.asarray(l1), jnp.asarray(l2)))
    lmid = 0.5 * (l1f + l2f)
    b = rho * jnp.sqrt(-sens / lmid)
    return jnp.clip(jnp.clip(b, rho - move, rho + move), rho_min, 1.0)
