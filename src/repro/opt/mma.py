"""Compact Method of Moving Asymptotes (Svanberg 1987) — single constraint.

The paper optimizes with MMA (§B.4.1).  This is the standard MMA
approximation with adaptive asymptotes and a dual bisection for the single
volume constraint; adequate for compliance minimization (monotone negative
objective sensitivities).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["MMAState", "mma_update"]


@dataclasses.dataclass
class MMAState:
    low: jnp.ndarray
    upp: jnp.ndarray
    x_prev1: jnp.ndarray | None = None
    x_prev2: jnp.ndarray | None = None


def mma_update(x, dfdx, g_constraint, dgdx, state: MMAState,
               move=0.1, x_min=1e-3, x_max=1.0,
               asy_init=0.5, asy_incr=1.2, asy_decr=0.7):
    """One MMA iteration for min f(x) s.t. g(x) ≤ 0, x∈[x_min, x_max].

    dfdx: objective sensitivity (≤0 for compliance); dgdx: constraint
    sensitivity (constant 1/n for mean-volume).  Returns (x_new, state).
    """
    n = x.shape[0]
    rng = x_max - x_min

    # asymptote update
    if state.x_prev1 is None or state.x_prev2 is None:
        low = x - asy_init * rng
        upp = x + asy_init * rng
    else:
        osc = (x - state.x_prev1) * (state.x_prev1 - state.x_prev2)
        factor = jnp.where(osc > 0, asy_incr, jnp.where(osc < 0, asy_decr, 1.0))
        low = x - factor * (state.x_prev1 - state.low)
        upp = x + factor * (state.upp - state.x_prev1)
        low = jnp.clip(low, x - 10 * rng, x - 0.01 * rng)
        upp = jnp.clip(upp, x + 0.01 * rng, x + 10 * rng)

    alpha = jnp.maximum(x_min, jnp.maximum(low + 0.1 * (x - low), x - move * rng))
    beta = jnp.minimum(x_max, jnp.minimum(upp - 0.1 * (upp - x), x + move * rng))

    # MMA approximation coefficients: f ≈ Σ p/(upp−x) + q/(x−low)
    df_pos = jnp.maximum(dfdx, 0.0)
    df_neg = jnp.maximum(-dfdx, 0.0)
    p0 = (upp - x) ** 2 * (1.001 * df_pos + 0.001 * df_neg + 1e-5 / rng)
    q0 = (x - low) ** 2 * (0.001 * df_pos + 1.001 * df_neg + 1e-5 / rng)
    dg_pos = jnp.maximum(dgdx, 0.0)
    dg_neg = jnp.maximum(-dgdx, 0.0)
    p1 = (upp - x) ** 2 * dg_pos
    q1 = (x - low) ** 2 * dg_neg
    # constant so the approximate constraint matches g at x
    r1 = g_constraint - jnp.sum(p1 / (upp - x) + q1 / (x - low))

    def x_of_lambda(lam):
        p = p0 + lam * p1
        q = q0 + lam * q1
        # stationary point of p/(upp−x)+q/(x−low): x* = (low√p + upp√q)/(√p+√q)
        sp, sq = jnp.sqrt(p), jnp.sqrt(q)
        xs = (low * sp + upp * sq) / (sp + sq + 1e-30)
        return jnp.clip(xs, alpha, beta)

    def g_of_lambda(lam):
        xs = x_of_lambda(lam)
        return r1 + jnp.sum(p1 / (upp - xs) + q1 / (xs - low))

    # dual bisection on λ ≥ 0
    def body(_, bounds):
        l1, l2 = bounds
        lmid = 0.5 * (l1 + l2)
        viol = g_of_lambda(lmid) > 0
        return jnp.where(viol, lmid, l1), jnp.where(viol, l2, lmid)

    l1, l2 = jax.lax.fori_loop(
        0, 80, body, (jnp.asarray(0.0), jnp.asarray(1e6))
    )
    x_new = x_of_lambda(0.5 * (l1 + l2))

    new_state = MMAState(low=low, upp=upp, x_prev1=x, x_prev2=state.x_prev1)
    return x_new, new_state
