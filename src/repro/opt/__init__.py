from .simp import CantileverProblem, oc_update, sensitivity_filter  # noqa: F401
from .mma import mma_update, MMAState  # noqa: F401
