"""Serving driver: prefill a batch of prompts, then autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --prompt-len 32 --gen 16 --batch 4

Exercises the exact code path the decode_32k / long_500k dry-run cells
lower: bf16 served weights, donated KV cache (in-place update), greedy
sampling.  On a pod the mesh axes change; nothing else does.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, smoke_variant
from ..models.layers import init_params, is_spec, P
from ..models.model_zoo import build_model
from ..sharding.partitioning import RULES_SINGLE_POD, make_shardings, use_rules
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = build_model(cfg, tp_degree=args.model_axis)
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    rules = RULES_SINGLE_POD
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32,
    )
    batch = {"tokens": tokens}
    if cfg.frontend == "audio_frames":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, 100, cfg.d_model)), jnp.float32
        )
    elif cfg.frontend == "patch_embed":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.num_frontend_tokens, cfg.d_model)),
            jnp.float32,
        )

    with mesh:
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        params = jax.tree.map(lambda x: x.astype(jnp.bfloat16)
                              if x.dtype == jnp.float32 else x, params)

        with use_rules(rules):
            t0 = time.perf_counter()
            logits, cache = model.prefill(params, batch, max_len)
            jax.block_until_ready(logits)
            t_prefill = time.perf_counter() - t0
            print(f"prefill {args.batch}×{args.prompt_len}: {t_prefill*1e3:.0f} ms")

            decode = jax.jit(model.decode, donate_argnums=(2,))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out_tokens = [np.asarray(tok)]
            t0 = time.perf_counter()
            for step in range(args.gen - 1):
                dbatch = {
                    "tokens": tok,
                    "cache_len": jnp.asarray(args.prompt_len + step, jnp.int32),
                }
                logits, cache = decode(params, dbatch, cache)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                out_tokens.append(np.asarray(tok))
            jax.block_until_ready(tok)
            dt = time.perf_counter() - t0
            print(
                f"decode {args.gen - 1} steps: {dt*1e3:.0f} ms "
                f"({dt / max(args.gen - 1, 1) * 1e3:.1f} ms/tok)"
            )
            gen = np.concatenate(out_tokens, axis=1)
            print("generated token ids (first row):", gen[0][:16])
            assert np.all(gen < cfg.vocab_size)
    return gen


if __name__ == "__main__":
    main()
