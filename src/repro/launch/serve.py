"""Serving driver: run the :mod:`repro.serve` solve service under a
synthetic open-loop load.

    PYTHONPATH=src python -m repro.launch.serve --smoke
    PYTHONPATH=src python -m repro.launch.serve --requests 64 --rate 500 \
        --resolution 24 --backend matfree --window-ms 5

Builds the canonical heterogeneous-coefficient Poisson workload on one
shared plan (:func:`repro.serve.poisson_requests`), warms up and pins the
executable cache for the expected batch buckets, then drives the
:class:`~repro.serve.service.SolveService` with Poisson arrivals at the
offered ``--rate``.  Latency percentiles, queue waits, batch sizes and
executable-cache hit rates all come out of :mod:`repro.telemetry`
(``--jsonl`` streams the metric rows in ``BENCH_JSON`` format).

``--smoke`` is the CI path: a tiny mesh, two waves, hard assertions that
every request is answered ``ok``, results match a sequential reference
solve, and the second wave retraces nothing.
"""

from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp


def _run_smoke() -> int:
    from .. import serve, telemetry
    from ..core import assemble, sparse_solve

    telemetry.enable()
    svc = serve.SolveService(window=0.002)
    reqs = serve.poisson_requests(n_requests=6, resolution=8)
    # a wave may split across admission windows → warm every bucket ≤ 8
    svc.warmup(reqs[0], batch_sizes=(1, 2, 4, 8))
    base_traces = telemetry.jit_trace_total("serve")

    with svc:
        report = serve.open_loop_load(svc, reqs, rate=2000.0)
        report2 = serve.open_loop_load(
            svc, serve.poisson_requests(n_requests=6, resolution=8, seed=1),
            rate=2000.0)
    assert report.ok == 6 and report2.ok == 6, (report, report2)
    retraces = telemetry.jit_trace_total("serve") - base_traces
    assert retraces == 0, f"warmup did not cover the smoke waves: {retraces}"

    # answer correctness vs one sequential reference solve
    rq = reqs[0]
    k = rq.bc.apply_matrix_only(assemble(rq.plan, rq.form))
    u_ref = sparse_solve(k, rq.rhs * rq.bc.free_mask, rq.spec)
    pend = svc.submit(rq)
    svc.drain()
    err = float(jnp.max(jnp.abs(pend.result() - u_ref)))
    assert err < 1e-12, f"served answer diverges from reference: {err:.3e}"

    print(f"serve smoke OK: {report.ok + report2.ok + 1} requests, "
          f"0 retraces after warmup, parity {err:.1e}, "
          f"e2e p99 {report2.e2e_p99_us:.0f}us")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run with hard correctness assertions")
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per wave")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--rate", type=float, default=500.0,
                    help="offered load [requests/s], Poisson arrivals")
    ap.add_argument("--resolution", type=int, default=16,
                    help="unit-square mesh resolution")
    ap.add_argument("--backend", default="csr", choices=("csr", "matfree"))
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="admission batching window")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--queue-limit", type=int, default=1024)
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request admission deadline [s]")
    ap.add_argument("--jsonl", default=None,
                    help="append telemetry metric rows (BENCH_JSON) here")
    args = ap.parse_args(argv)

    if args.smoke:
        return _run_smoke()

    from .. import serve, telemetry

    telemetry.enable(jsonl=args.jsonl)
    svc = serve.SolveService(window=args.window_ms * 1e-3,
                             max_batch=args.max_batch,
                             queue_limit=args.queue_limit)
    template = serve.poisson_requests(
        n_requests=1, resolution=args.resolution, backend=args.backend)[0]
    top = min(serve.pad_bucket(args.requests), args.max_batch)
    buckets = sorted({min(1 << i, top) for i in range(top.bit_length())})
    print(f"warmup: buckets {buckets} on resolution {args.resolution} "
          f"({args.backend})")
    svc.warmup(template, batch_sizes=buckets)

    with svc:
        for wave in range(args.waves):
            reqs = serve.poisson_requests(
                n_requests=args.requests, resolution=args.resolution,
                backend=args.backend, timeout=args.timeout, seed=wave)
            report = serve.open_loop_load(svc, reqs, rate=args.rate,
                                          seed=wave)
            print(f"wave {wave}: ok={report.ok} shed={report.shed} "
                  f"expired={report.expired} "
                  f"p50={report.e2e_p50_us:.0f}us "
                  f"p99={report.e2e_p99_us:.0f}us "
                  f"batch≈{report.batch_size_mean:.1f} "
                  f"hit-rate={report.cache_hit_rate:.2f} "
                  f"throughput={report.throughput:.0f}/s")
    if args.jsonl:
        rows = telemetry.export_jsonl(args.jsonl)
        print(f"exported {len(rows)} metric rows to {args.jsonl}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
