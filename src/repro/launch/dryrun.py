"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for the train/serve step inputs
     (params, optimizer state, batch, KV cache — zero allocation),
  3. ``jax.jit(step, in_shardings=…).lower(...).compile()``,
  4. records memory_analysis / cost_analysis / HLO-collective bytes into a
     JSON row consumed by the §Roofline table and benchmarks.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

Skip rules (recorded, not silently dropped):
  * ``long_500k`` needs sub-quadratic attention → only ssm/hybrid run it;
  * every skip lands in the JSON with its reason.
"""

import argparse
import dataclasses
import json
import os
import time
import traceback

import jax
import numpy as np

from ..analysis.roofline import analyze_compiled
from ..configs import ARCHS, SHAPES
from ..configs.base import ArchConfig, ShapeSpec
from ..models.layers import abstract_params
from ..models.model_zoo import build_model
from ..sharding.partitioning import (
    RULES_MULTI_POD,
    RULES_SINGLE_POD,
    ShardingRules,
    make_shardings,
    use_rules,
)
from ..train.serve_step import serve_param_specs
from ..train.train_step import make_train_state_specs, make_train_step
from .mesh import make_production_mesh


def force_host_devices(count: int = 512) -> None:
    """Configure XLA's host-platform device count for the dry-run mesh.

    Must run before jax initializes its backends, and only from a CLI entry
    point — importing this module for tooling must not reconfigure the
    process (the mutation used to happen at import time and leaked into
    every importer).
    """
    flag = f"--xla_force_host_platform_device_count={count}"
    flags = os.environ.get("XLA_FLAGS", "")
    if flag not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()


def should_skip(cfg: ArchConfig, shape: ShapeSpec) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k requires sub-quadratic attention (full-attn arch)"
    return None


def _abstract(tree_specs):
    return abstract_params(tree_specs)


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: ShardingRules,
               extra_flags: dict | None = None):
    """Returns (compiled, lowered, aux info dict)."""
    model = build_model(cfg, tp_degree=mesh.shape.get("model", 1))
    with mesh:
        if shape.kind == "train":
            state_specs = make_train_state_specs(cfg)
            state_abs = _abstract(state_specs)
            state_sh = make_shardings(state_specs, mesh, rules)
            batch_abs = model.input_specs(shape)
            batch_sh = make_shardings(model.batch_axes(shape), mesh, rules)
            step = make_train_step(cfg, shape)

            def fn(state, batch):
                with use_rules(rules):
                    return step(state, batch)

            jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            pspecs = serve_param_specs(cfg)
            params_abs = _abstract(pspecs)
            params_sh = make_shardings(pspecs, mesh, rules)
            batch_abs = model.input_specs(shape)
            batch_sh = make_shardings(model.batch_axes(shape), mesh, rules)

            def fn(params, batch):
                with use_rules(rules):
                    return model.prefill(params, batch, shape.seq_len)

            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            pspecs = serve_param_specs(cfg)
            params_abs = _abstract(pspecs)
            params_sh = make_shardings(pspecs, mesh, rules)
            cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
            cache_abs = _abstract(cspecs)
            cache_sh = make_shardings(cspecs, mesh, rules)
            batch_abs = model.input_specs(shape)
            batch_sh = make_shardings(model.batch_axes(shape), mesh, rules)

            def fn(params, batch, cache):
                with use_rules(rules):
                    return model.decode(params, batch, cache)

            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, batch_abs, cache_abs)

        compiled = lowered.compile()
    return compiled, lowered


def model_flops_for(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def _effective_rules(rules: ShardingRules, shape: ShapeSpec, mesh) -> ShardingRules:
    """Drop the batch mapping to replicated when the global batch doesn't
    divide the batch mesh axes (e.g. long_500k's batch of 1)."""
    bmap = rules.mapping.get("batch")
    if bmap is not None:
        axes = (bmap,) if isinstance(bmap, str) else tuple(bmap)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if shape.global_batch % size:
            rules = ShardingRules({**rules.mapping, "batch": None})
    return rules


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             rules: ShardingRules | None = None) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if skip:
        return {**base, "status": "skip", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or (RULES_MULTI_POD if multi_pod else RULES_SINGLE_POD)
    rules = _effective_rules(rules, shape, mesh)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()
    try:
        compiled, lowered = lower_cell(cfg, shape, mesh, rules)
    except Exception as e:
        return {
            **base, "status": "fail",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    dt = time.perf_counter() - t0
    report = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=model_flops_for(cfg, shape),
    )
    row = report.row()
    row.update(
        status="ok",
        compile_seconds=dt,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    try:
        ma = compiled.memory_analysis()
        row["memory_analysis"] = {
            "temp_GiB": getattr(ma, "temp_size_in_bytes", 0) / 2**30,
            "arg_GiB": getattr(ma, "argument_size_in_bytes", 0) / 2**30,
            "output_GiB": getattr(ma, "output_size_in_bytes", 0) / 2**30,
            "alias_GiB": getattr(ma, "alias_size_in_bytes", 0) / 2**30,
        }
    except Exception:
        pass
    return row


def main():
    force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    rows = []
    if args.append and os.path.exists(args.out):
        rows = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
    for a, s, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if (a, s, mesh_name) in done:
            continue
        row = run_cell(a, s, multi_pod=mp)
        status = row["status"]
        extra = (
            f"compile={row.get('compile_seconds', 0):.1f}s "
            f"bottleneck={row.get('bottleneck', '-')}"
            if status == "ok"
            else row.get("reason", row.get("error", ""))[:120]
        )
        print(f"[{status:4s}] {a:28s} {s:12s} {mesh_name:8s} {extra}", flush=True)
        rows.append(row)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    n_fail = sum(r["status"] == "fail" for r in rows)
    print(f"done: {n_ok} ok / {n_skip} skip / {n_fail} fail → {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
