"""Perf-iteration harness (§Perf): re-lower a dry-run cell under a named
optimization variant and diff the roofline terms against baseline.

Variants are *declarative* — a rules patch + a config patch — so each
hypothesis in EXPERIMENTS.md §Perf maps to one named entry here:

  seqpar        sequence parallelism: shard the seq dim of activations over
                'model' between blocks (Megatron-SP).  Hypothesis: cuts
                residual-stream HBM traffic and converts boundary
                all-reduces into RS/AG on 1/16-size shards.
  bigchunk      flash-attention KV chunk 1024 → 4096: 4× fewer accumulator
                round-trips (the dominant dus traffic in train cells).
  seqpar+bigchunk  both.
  seqcache      decode: shard the KV-cache *sequence* dim over 'model'
                instead of replicating kv heads to TP (memory ÷TP for the
                cache at the price of a logits all-gather).
  dp_attn       attention runs data-parallel (heads replicated), MLP keeps
                TP: removes the per-layer attention boundary collectives
                (for small-d models where TP=16 over-shards attention).
  gradbf16      bf16 gradient accumulation + all-reduce compression.
  nomicro       halve grad-accum microbatches (×2 microbatch size).
"""

import argparse
import dataclasses
import json
import os

from ..configs import ARCHS, SHAPES  # noqa: F401
from ..sharding.partitioning import RULES_SINGLE_POD, ShardingRules
from .dryrun import force_host_devices, run_cell


def _patched_rules(base: ShardingRules, patch: dict) -> ShardingRules:
    return ShardingRules({**base.mapping, **patch})


VARIANTS: dict = {
    "baseline": (dict(), dict()),
    "seqpar": ({"seq_act": "model"}, dict()),
    "bigchunk": (dict(), {"attn_chunk": 4096}),
    "seqpar+bigchunk": ({"seq_act": "model"}, {"attn_chunk": 4096}),
    "hugechunk": (dict(), {"attn_chunk": 8192}),
    "seqcache": ({"seq_cache": "model", "kv_cache": None}, dict()),
    "dp_attn": ({"heads": None, "kv": None, "kv_cache": None}, dict()),
    "gradbf16": (dict(), {"grad_dtype": "bfloat16"}),
    "nomicro": (dict(), "HALVE_MICRO"),
    "micro2": (dict(), "MICRO_2"),
    "dp_attn+bigchunk": ({"heads": None, "kv": None, "kv_cache": None},
                         {"attn_chunk": 4096}),
    "ssmchunk512": (dict(), {"ssm_chunk": 512}),
    "remat_dots": (dict(), {"remat_policy": "dots"}),
    "remat_dots+bigchunk": (dict(), {"remat_policy": "dots", "attn_chunk": 4096}),
    "ep_ffshard": ({"embed": None, "expert_mlp": "data"}, dict()),
    "ep_ffshard+micro2": ({"embed": None, "expert_mlp": "data"}, "MICRO_2"),
    "ssmchunk1024": (dict(), {"ssm_chunk": 1024}),
}


def run_variant(arch: str, shape: str, variant: str) -> dict:
    rules_patch, cfg_patch = VARIANTS[variant]
    cfg = ARCHS[arch]
    if cfg_patch == "HALVE_MICRO":
        mb = dict(cfg.microbatches)
        if shape in mb and mb[shape] > 1:
            mb[shape] = mb[shape] // 2
        cfg_patch = {"microbatches": mb}
    elif cfg_patch == "MICRO_2":
        cfg_patch = {"microbatches": {**dict(cfg.microbatches), shape: 2}}
    if cfg_patch == "MICRO_2":  # possible when combined patches use the tag
        cfg_patch = {"microbatches": {**dict(cfg.microbatches), shape: 2}}
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    rules = _patched_rules(RULES_SINGLE_POD, rules_patch)
    # run through the dryrun cell runner with the patched config snapshot
    saved = ARCHS[arch]
    ARCHS[arch] = cfg
    try:
        row = run_cell(arch, shape, multi_pod=False, rules=rules)
    finally:
        ARCHS[arch] = saved
    row["variant"] = variant
    return row


def main():
    force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()

    rows = []
    if os.path.exists(args.out):
        rows = json.load(open(args.out))
    for v in args.variants.split(","):
        row = run_variant(args.arch, args.shape, v)
        ok = row["status"] == "ok"
        print(
            f"[{row['status']}] {args.arch} {args.shape} {v:18s} "
            + (
                f"comp={row['t_compute_s']:.3g} mem={row['t_memory_s']:.3g} "
                f"coll={row['t_collective_s']:.3g} bneck={row['bottleneck']} "
                f"frac={row['roofline_fraction']:.4f}"
                if ok
                else row.get("error", "")[:160]
            ),
            flush=True,
        )
        rows = [
            r for r in rows
            if not (r["arch"] == args.arch and r["shape"] == args.shape
                    and r.get("variant") == v)
        ]
        rows.append(row)
        json.dump(rows, open(args.out, "w"), indent=1, default=str)


if __name__ == "__main__":
    main()
