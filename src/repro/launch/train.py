"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

Features exercised even at laptop scale (and required at pod scale):
  * sharded state + batch placement from the same P-spec system the dry-run
    uses (mesh degenerates to (1, 1) on one device),
  * grad-accum microbatching, mixed precision, cosine schedule,
  * synthetic token pipeline with checkpointable iterator state + prefetch,
  * **auto-resume**: on start, restores the latest committed checkpoint
    (params + optimizer + data-iterator state) — kill the process mid-run
    and relaunch to test (tests/test_train_loop.py does exactly that),
  * async checkpoint cadence + retention,
  * straggler/step-time watchdog: logs steps exceeding ``--slow-factor`` ×
    the rolling median (on real pods this feeds the controller that evicts
    slow hosts; here it is observability).
"""

from __future__ import annotations

import argparse
import statistics
import time

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import ARCHS, SHAPES, smoke_variant
from ..configs.base import ShapeSpec
from ..data import SyntheticLMData
from ..models.layers import init_params
from ..sharding.partitioning import RULES_SINGLE_POD, ShardingRules, make_shardings, use_rules
from ..train.train_step import make_train_state_specs, make_train_step
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--slow-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_variant(cfg)
    shape = ShapeSpec("custom", "train", args.seq_len, args.batch)

    mesh = make_host_mesh(args.data_axis, args.model_axis)
    rules = ShardingRules({**RULES_SINGLE_POD.mapping})

    state_specs = make_train_state_specs(cfg)
    state_sh = make_shardings(state_specs, mesh, rules)

    data = SyntheticLMData(cfg.vocab_size, args.seq_len, args.batch)
    from ..models.model_zoo import build_model

    model = build_model(cfg, tp_degree=args.model_axis)
    batch_sh = make_shardings(model.batch_axes(shape), mesh, rules)

    step_fn = make_train_step(cfg, shape, lr=args.lr, total_steps=args.steps)

    def wrapped(state, batch):
        with use_rules(rules):
            return step_fn(state, batch)

    with mesh:
        jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))

        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start_step = 0
        if mgr and mgr.latest_step() is not None:
            s = mgr.latest_step()
            print(f"[resume] restoring step {s} from {args.ckpt_dir}")
            from ..models.layers import abstract_params

            target = abstract_params(state_specs)
            state = mgr.restore(s, target, state_sh)
            manifest = mgr.restore_manifest(s)
            data.restore(manifest["extra"].get("data", {"step": 0, "seed": 0}))
            start_step = s
        else:
            print("[init] fresh parameters")
            state = init_params(state_specs, jax.random.PRNGKey(0))
            state = jax.device_put(state, state_sh)

        it = data.sharded_iterator(batch_sh)
        times: list[float] = []
        for i in range(start_step, args.steps):
            batch = next(it)
            t0 = time.perf_counter()
            state, metrics = jitted(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            if len(times) > 20:
                times.pop(0)
            med = statistics.median(times)
            if dt > args.slow_factor * med and len(times) > 5:
                print(f"[straggler-watchdog] step {i}: {dt:.2f}s vs median {med:.2f}s")
            if i % args.log_every == 0:
                print(
                    f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                    f"gnorm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f} ms"
                )
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state, extra={"data": data.state()})
        if mgr:
            mgr.save(args.steps, state, extra={"data": data.state()}, blocking=True)
        print(f"done at step {args.steps}; final loss {float(metrics['loss']):.4f}")
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
