"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the 'pod' axis carries
the slower inter-pod (DCN/ICI-bridge) links, so the rules place only
data-parallel (gradient reduce) traffic on it.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (device count is locked at first backend init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def _make_mesh(shape, axes):
    import jax.sharding as jsh

    if hasattr(jsh, "AxisType"):  # jax >= 0.5: explicit-sharding axis types
        return jax.make_mesh(shape, axes,
                             axis_types=(jsh.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return _make_mesh((data, model), ("data", "model"))
