from .optimizers import (  # noqa: F401
    adafactor_init_specs,
    adamw_init_specs,
    make_optimizer,
    cosine_schedule,
)
