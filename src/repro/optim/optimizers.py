"""Optimizers for the LM stack: AdamW + Adafactor (factored second moment).

State is described with the same P-spec system as parameters, so optimizer
state inherits the parameter sharding (fully-sharded states — ZeRO):
  * AdamW:     m, v  — same shape/axes as the parameter.
  * Adafactor: for rank≥2 params the second moment is factored into row/col
    accumulators (O(n+m) memory — the trick that lets the 340B/400B archs fit
    a 256-chip pod); 1-D params keep a full v.  β1 = 0 (no momentum) by
    default, matching the memory budget in configs/registry.py.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..models.layers import P, is_spec

__all__ = [
    "adamw_init_specs",
    "adafactor_init_specs",
    "make_optimizer",
    "cosine_schedule",
]


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init_specs(param_specs):
    def one(s: P):
        return {
            "m": P(s.shape, s.axes, "zeros", dtype=jnp.float32),
            "v": P(s.shape, s.axes, "zeros", dtype=jnp.float32),
        }

    return jax.tree.map(one, param_specs, is_leaf=is_spec)


def _adamw_update(p, g, st, lr, b1, b2, eps, wd, step):
    g = g.astype(jnp.float32)
    m = b1 * st["m"] + (1 - b1) * g
    v = b2 * st["v"] + (1 - b2) * g * g
    mh = m / (1 - b1**step)
    vh = v / (1 - b2**step)
    upd = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
    return (p - lr * upd.astype(p.dtype)).astype(p.dtype), {"m": m, "v": v}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored v, no momentum
# ---------------------------------------------------------------------------

def adafactor_init_specs(param_specs):
    def one(s: P):
        if len(s.shape) >= 2:
            row_shape = s.shape[:-1]
            col_shape = s.shape[:-2] + s.shape[-1:]
            return {
                "vr": P(row_shape, s.axes[:-1], "zeros", dtype=jnp.float32),
                "vc": P(col_shape, s.axes[:-2] + s.axes[-1:], "zeros",
                        dtype=jnp.float32),
            }
        return {"v": P(s.shape, s.axes, "zeros", dtype=jnp.float32)}

    return jax.tree.map(one, param_specs, is_leaf=is_spec)


def _adafactor_update(p, g, st, lr, b2, eps, wd, step):
    g = g.astype(jnp.float32)
    if "vr" in st:
        vr = b2 * st["vr"] + (1 - b2) * jnp.mean(g * g, axis=-1)
        vc = b2 * st["vc"] + (1 - b2) * jnp.mean(g * g, axis=-2)
        # factored precond: v ≈ vr vc / mean(vr)
        denom = jnp.clip(jnp.mean(vr, axis=-1, keepdims=True), 1e-30, None)
        vhat = vr[..., :, None] * vc[..., None, :] / denom[..., None]
        new_st = {"vr": vr, "vc": vc}
    else:
        vhat = b2 * st["v"] + (1 - b2) * g * g
        new_st = {"v": vhat}
    # bias correction on the 2nd moment
    vhat = vhat / (1 - b2**step)
    upd = g / (jnp.sqrt(vhat) + eps)
    # update clipping (RMS ≤ 1) — Adafactor's stabilizer
    rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms)
    upd = upd + wd * p.astype(jnp.float32)
    return (p - lr * upd.astype(p.dtype)).astype(p.dtype), new_st


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init_specs_fn: callable
    update_leaf: callable

    def init_specs(self, param_specs):
        return self.init_specs_fn(param_specs)

    def update(self, params, grads, state, lr, step, wd=0.01):
        """Tree-wide update; step is 1-based.  ``state`` mirrors ``params``
        with a small dict at each leaf — tree-prefix mapping hands the whole
        per-leaf dict to ``update_leaf``."""
        pairs = jax.tree.map(
            lambda p, g, st: self.update_leaf(p, g, st, lr, step=step, wd=wd),
            params, grads, state,
        )
        is_pair = lambda x: isinstance(x, tuple)
        new_params = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
        new_state = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
        return new_params, new_state


def make_optimizer(name: str, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    if name == "adamw":
        return Optimizer(
            "adamw",
            adamw_init_specs,
            lambda p, g, st, lr, step, wd: _adamw_update(
                p, g, st, lr, b1, b2, eps, wd, step
            ),
        )
    if name == "adafactor":
        return Optimizer(
            "adafactor",
            adafactor_init_specs,
            lambda p, g, st, lr, step, wd: _adafactor_update(
                p, g, st, lr, 0.999, 1e-30, wd, step
            ),
        )
    raise ValueError(name)
