"""Newmark-β time integration for second-order hyperbolic problems (wave,
elastodynamics).

Semidiscrete system:  M ü + K u = F(t)  (``K`` already carries any material
scaling, e.g. c² for the scalar wave equation).  The predictor–corrector
form solves for the acceleration each step:

    u*  = uⁿ + Δt vⁿ + ½Δt²(1−2β) aⁿ
    v*  = vⁿ + Δt(1−γ) aⁿ
    (M + βΔt²K) aⁿ⁺¹ = Fⁿ⁺¹ − K u*
    uⁿ⁺¹ = u* + βΔt² aⁿ⁺¹,   vⁿ⁺¹ = v* + γΔt aⁿ⁺¹

β = ¼, γ = ½ (average acceleration / trapezoidal) is unconditionally stable
and conserves the discrete energy ½(vᵀMv + uᵀKu) exactly for F = 0 — the
property the wave benchmarks check.  The effective operator is formed once;
the rollout is a ``lax.scan`` with one ``sparse_solve`` per step, hence
differentiable end-to-end (adjoint solves in the backward pass) with
optional ``jax.checkpoint`` segmentation.

Dirichlet: homogeneous (or fixed-in-time) constraints via a
:class:`DirichletCondenser` — accelerations and velocities vanish on
constrained DoFs, displacements keep their initial boundary values.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core.boundary import DirichletCondenser
from ..core.matvec import make_matvec
from ..core.solvers import SolverSpec, resolve_solver_spec, sparse_solve
from ..core.sparse import CSR
from ..telemetry import events
from .stepping import axpy_csr, segmented_scan

__all__ = ["NewmarkIntegrator"]


@dataclasses.dataclass
class NewmarkIntegrator:
    mass: CSR
    stiff: CSR
    dt: float
    beta: float = 0.25
    gamma: float = 0.5
    bc: DirichletCondenser | None = None
    spec: SolverSpec | None = None  # Krylov config (method/tol/precond/...)
    solver: str | None = None       # deprecated → spec.method
    tol: float | None = None        # deprecated → spec.tol (and atol)
    maxiter: int | None = None      # deprecated → spec.maxiter
    # inner K·u matvec backend (unified registry, repro.core.matvec): the
    # predictor RHS runs two stiffness applies per step — "ell"/"ell_pallas"
    # switch them to the padded layout / Pallas kernel (the solve itself
    # stays on the differentiable sparse_solve path)
    backend: str = "csr"

    def __post_init__(self):
        # M + βΔt²K is SPD → CG default
        self.spec = resolve_solver_spec(
            self.spec, method=self.solver, tol=self.tol, atol=self.tol,
            maxiter=self.maxiter, default=SolverSpec(method="cg"),
            where="NewmarkIntegrator")
        self.solver = self.spec.method
        self.tol = self.spec.tol
        self.maxiter = self.spec.maxiter
        self.lhs_full = axpy_csr(
            1.0, self.mass, self.beta * self.dt**2, self.stiff
        )
        self._stiff_mv = make_matvec(self.stiff, self.backend)
        if self.bc is not None:
            self.lhs = self.bc.apply_matrix_only(self.lhs_full)
            self.mass_c = self.bc.apply_matrix_only(self.mass)
        else:
            self.lhs = self.lhs_full
            self.mass_c = self.mass

    def _mask(self, r):
        return r if self.bc is None else self.bc.project_residual(r)

    def initial_acceleration(self, u0, load0=None):
        """Consistent a₀ from M a₀ = F(0) − K u₀ (condensed)."""
        r = -self._stiff_mv(u0)
        if load0 is not None:
            r = r + load0
        return sparse_solve(self.mass_c, self._mask(r), self.spec)

    def step(self, u, v, a, load=None, return_info=False):
        dt, beta, gamma = self.dt, self.beta, self.gamma
        u_star = u + dt * v + 0.5 * dt**2 * (1 - 2 * beta) * a
        v_star = v + dt * (1 - gamma) * a
        rhs = -self._stiff_mv(u_star)
        if load is not None:
            rhs = rhs + load
        out = sparse_solve(self.lhs, self._mask(rhs), self.spec,
                           return_info=return_info)
        a_new, info = out if return_info else (out, None)
        u_new = u_star + beta * dt**2 * a_new
        if self.bc is not None:
            # constrained DoFs stay at their (initial) boundary values
            u_new = u_new * self.bc.free_mask + u * (1.0 - self.bc.free_mask)
        v_new = v_star + gamma * dt * a_new
        if return_info:
            return u_new, v_new, a_new, info
        return u_new, v_new, a_new

    def rollout(self, u0, n_steps: int, *, v0=None, loads=None, load0=None,
                checkpoint_every: int | None = None,
                return_velocity: bool = False,
                return_info: bool = False):
        """Scan ``n_steps`` Newmark steps; returns ``(n_steps, N)``
        displacements (u0 excluded), or ``(u_traj, v_traj)`` when
        ``return_velocity``.  ``loads``: None | (N,) | (n_steps, N), where
        per-step row ``n`` is Fⁿ⁺¹.  ``load0`` is F(0) for the consistent
        initial acceleration; defaults to ``loads`` when static and to
        ``loads[0]`` when per-step (one Δt off — pass ``load0`` explicitly
        for rapidly varying forcing).

        ``return_info=True`` appends a per-step
        :class:`~repro.core.solvers.SolveInfo` with ``(n_steps,)`` leaves
        (stop-gradient — gradients through the trajectory are unchanged)."""
        v0 = jnp.zeros_like(u0) if v0 is None else v0
        loads = None if loads is None else jnp.asarray(loads)
        scan_loads = loads is not None and loads.ndim == 2
        if load0 is None and loads is not None:
            load0 = loads[0] if scan_loads else loads
        a0 = self.initial_acceleration(u0, load0)

        def body(carry, x):
            u, v, a = carry
            f = x if scan_loads else loads
            if return_info:
                u, v, a, info = self.step(u, v, a, load=f, return_info=True)
                return (u, v, a), (u, v, info)
            u, v, a = self.step(u, v, a, load=f)
            return (u, v, a), (u, v)

        _, ys = segmented_scan(
            body, (u0, v0, a0), loads if scan_loads else None,
            n_steps, checkpoint_every,
        )
        if return_info:
            u_traj, v_traj, info = ys
            events.check_convergence(info, where="newmark.rollout")
            events.record_solve("newmark.rollout", info,
                                method=self.spec.method, backend=self.backend,
                                precond=self.spec.precond_name)
            out = (u_traj, v_traj) if return_velocity else u_traj
            return out, info
        u_traj, v_traj = ys
        return (u_traj, v_traj) if return_velocity else u_traj
