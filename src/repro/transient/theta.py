"""θ-method time integration for parabolic problems (heat, diffusion).

Semidiscrete system:  M u̇ + K u = F(t),  u(0) = u₀, with the one-parameter
family

    (M + θ Δt K) uⁿ⁺¹ = (M − (1−θ) Δt K) uⁿ + Δt Fⁿ⁺ᶿ

θ = 1 is backward Euler (first order, L-stable), θ = ½ is Crank–Nicolson
(second order, A-stable).  Both effective operators share the sparsity
pattern of M and K, so they are formed **once** outside the time loop
(:func:`repro.transient.stepping.axpy_csr`) and the rollout is a
``lax.scan`` whose trace holds exactly one solve — the O(1)-graph property
extended to time stepping.

Differentiability: the per-step solve goes through
:func:`repro.core.sparse_solve` (adjoint sparse solve), so whole
trajectories differentiate w.r.t. the operator values (coefficients, mesh
coordinates via assembly) and the initial condition, with optional
``jax.checkpoint`` segmentation for long rollouts.  Dirichlet data may vary
per step: the condensed matrix is hoisted out of the loop and only the
cheap RHS lift (:meth:`DirichletCondenser.lift`) runs inside the scan.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.boundary import DirichletCondenser
from ..core.matvec import make_matvec
from ..core.solvers import (
    SolverSpec,
    _method,
    make_preconditioner,
    matfree_solve,
    resolve_solver_spec,
    sparse_solve,
)
from ..core.sparse import CSR
from ..telemetry import events
from .stepping import axpy_csr, segmented_scan

__all__ = ["ThetaIntegrator", "BACKWARD_EULER", "CRANK_NICOLSON"]

BACKWARD_EULER = 1.0
CRANK_NICOLSON = 0.5


@dataclasses.dataclass
class ThetaIntegrator:
    """One-step θ-method over pre-assembled CSR mass/stiffness operators.

    Construct *inside* a traced function to differentiate through the
    operator values — preferably via :meth:`from_form`, which builds both
    effective operators with fused weak-form assemblies
    (``assemble(mass(c) + θΔt·form)``) sharing one jit signature; the
    static sparsity pattern is reused across traces.

    ``backend`` selects the inner-loop apply from the unified registry
    (:mod:`repro.core.matvec`): ``"csr"`` (default) keeps the rollout
    differentiable via ``sparse_solve``; ``"ell"`` / ``"ell_pallas"`` run
    the inner matvecs on the ELLPACK layout with a plain CG loop — the fast
    inference path (``lax.while_loop`` is forward-only); ``"matfree"``
    (build via :meth:`from_form` with ``backend="matfree"``) steps on
    matrix-free operators through the differentiable
    :func:`~repro.core.solvers.matfree_solve` — no CSR values are ever
    materialized for the rollout; ``"matfree_sharded"`` additionally
    partitions every apply over the local device mesh
    (:meth:`~repro.core.operator.MatFreeOperator.sharded`), so each step's
    solve — and its adjoint — spans all devices.
    """

    mass: CSR | None
    stiff: CSR | None
    dt: float
    theta: float = BACKWARD_EULER
    bc: DirichletCondenser | None = None
    spec: SolverSpec | None = None  # Krylov config (method/tol/precond/...)
    solver: str | None = None       # deprecated → spec.method
    tol: float | None = None        # deprecated → spec.tol (and atol)
    maxiter: int | None = None      # deprecated → spec.maxiter
    backend: str = "csr"
    # effective operators; pass directly (see from_form) or leave None to
    # have them formed from mass/stiff (same pattern as M / K)
    lhs_full: CSR | None = None
    rhs_op: CSR | None = None

    def __post_init__(self):
        # M + θΔtK is SPD for θ ≥ 0 → CG default; legacy solver/tol/maxiter
        # fields fold into the spec (DeprecationWarning) and stay readable
        # as mirrors afterwards
        self.spec = resolve_solver_spec(
            self.spec, method=self.solver, tol=self.tol, atol=self.tol,
            maxiter=self.maxiter, default=SolverSpec(method="cg"),
            where="ThetaIntegrator")
        self.solver = self.spec.method
        self.tol = self.spec.tol
        self.maxiter = self.spec.maxiter
        if self.lhs_full is None:
            self.lhs_full = axpy_csr(1.0, self.mass, self.theta * self.dt, self.stiff)
        if self.rhs_op is None:
            self.rhs_op = axpy_csr(
                1.0, self.mass, -(1.0 - self.theta) * self.dt, self.stiff
            )
        if self.backend == "matfree_sharded":
            from ..core.operator import MatFreeOperator

            # partition both effective applies over the device mesh; every
            # step's solve (and its adjoint) then spans all local devices
            if isinstance(self.lhs_full, MatFreeOperator):
                self.lhs_full = self.lhs_full.sharded()
            if isinstance(self.rhs_op, MatFreeOperator):
                self.rhs_op = self.rhs_op.sharded()
        if self.bc is None:
            self.lhs = self.lhs_full
        elif isinstance(self.lhs_full, CSR):
            self.lhs = self.bc.apply_matrix_only(self.lhs_full)
        else:  # matrix-free operator: condensation as an apply wrapper
            self.lhs = self.lhs_full.condensed(self.bc)
        if self.backend not in ("csr", "matfree", "matfree_sharded"):
            self._lhs_mv = make_matvec(self.lhs, self.backend)
            self._rhs_mv = make_matvec(self.rhs_op, self.backend)
            self._precond = make_preconditioner(self.lhs, self.spec.precond)

    @classmethod
    def from_form(cls, asm, form, dt, *, theta: float = BACKWARD_EULER,
                  mass_coeff=None, bc=None, **kw) -> "ThetaIntegrator":
        """Build the θ-step operators with two *fused* assemblies over the
        weak-form API: ``lhs = assemble(mass(c) + θΔt·form)`` and
        ``rhs_op = assemble(mass(c) − (1−θ)Δt·form)``.

        ``form`` is the spatial bilinear form (e.g.
        ``weakform.diffusion(kappa)`` — or a multi-term
        ``diffusion(kappa) + advection(beta)``).  Both operators share one
        static signature, so a single XLA executable serves both calls and
        all subsequent ``dt``/coefficient updates.  Forms containing an
        advection term make the lhs nonsymmetric, so the solver defaults to
        BiCGStab for them (CG otherwise — pass ``solver=`` to override).

        ``backend="matfree"`` builds both effective operators matrix-free
        (:func:`repro.core.matfree_operator`) — no CSR values for either
        operator, steps stay differentiable via
        :func:`~repro.core.solvers.matfree_solve`.
        """
        from ..core import weakform as wf

        terms = wf._as_form(form).terms
        if kw.get("spec") is None and kw.get("solver") is None:
            # advection makes the lhs nonsymmetric → BiCGStab; CG otherwise
            kw["spec"] = SolverSpec(
                method="bicgstab"
                if any(t.kind == "advection" for t in terms) else "cg"
            )
        lhs_form = wf.mass(mass_coeff) + (theta * dt) * form
        rhs_form = wf.mass(mass_coeff) + (-(1.0 - theta) * dt) * form
        if kw.get("backend") in ("matfree", "matfree_sharded"):
            from ..core.operator import matfree_operator

            # matfree_sharded: __post_init__ wraps both in the sharded apply
            lhs = matfree_operator(asm.plan, lhs_form)
            rhs = matfree_operator(asm.plan, rhs_form)
        else:
            lhs = asm.assemble(lhs_form)
            rhs = asm.assemble(rhs_form)
        return cls(None, None, dt, theta=theta, bc=bc,
                   lhs_full=lhs, rhs_op=rhs, **kw)

    # -- one step --------------------------------------------------------------
    def step(self, u, load=None, bc_values=None, return_info=False):
        """Advance uⁿ → uⁿ⁺¹.  ``load`` is the assembled Fⁿ⁺ᶿ (already the
        θ-weighted quadrature of F if time-varying); ``bc_values`` the
        Dirichlet data at tⁿ⁺¹ (scalar, (n_bc,), or full field).

        ``return_info=True`` additionally returns the step's
        :class:`~repro.core.solvers.SolveInfo` as a non-differentiated
        auxiliary output (stop-gradient leaves)."""
        if self.backend in ("csr", "matfree", "matfree_sharded"):
            b = self.rhs_op.matvec(u)
        else:
            b = self._rhs_mv(u)
        if load is not None:
            b = b + self.dt * load
        if self.bc is None:
            if bc_values is not None:
                raise ValueError("bc_values given but no DirichletCondenser (bc=)")
        elif bc_values is None:
            # homogeneous Dirichlet: u_D = 0, so the full lift reduces to
            # masking — skips a dead K·u_D matvec on every scan step
            b = self.bc.project_residual(b)
        else:
            b = self.bc.lift(self.lhs_full, b, bc_values)
        if self.backend == "csr":
            return sparse_solve(self.lhs, b, self.spec,
                                return_info=return_info)
        if self.backend in ("matfree", "matfree_sharded"):
            # differentiable adjoint solve on the matrix-free operator
            # (sharded: the same solve with every apply spanning the mesh)
            return matfree_solve(self.lhs, b, self.spec,
                                 return_info=return_info)
        u_new, info = _method(self.spec.method)(
            self._lhs_mv, b, x0=u, tol=self.spec.tol, atol=self.spec.atol,
            maxiter=self.spec.maxiter, m=self._precond)
        if return_info:
            return u_new, jax.lax.stop_gradient(info)
        return u_new

    # -- rollout ---------------------------------------------------------------
    def rollout(self, u0, n_steps: int, *, loads=None, bc_values=None,
                checkpoint_every: int | None = None,
                return_info: bool = False) -> jnp.ndarray:
        """Scan ``n_steps`` steps from ``u0``; returns ``(n_steps, N)``
        (u0 excluded, matching the reference-integrator convention).

        ``loads``: None | (N,) static | (n_steps, N) per-step.
        ``bc_values``: None | scalar | (n_bc,) static | (n_steps, n_bc)
        per-step (time-varying Dirichlet data, evaluated at tⁿ⁺¹).

        ``return_info=True`` returns ``(traj, info)`` where ``info`` is a
        :class:`~repro.core.solvers.SolveInfo` with per-step ``(n_steps,)``
        leaves stacked out of the scan — the iteration-count trajectory of
        the rollout.  The leaves carry stop-gradients, so gradients through
        ``traj`` are unchanged.
        """
        loads = None if loads is None else jnp.asarray(loads)
        bcv = None if bc_values is None else jnp.asarray(bc_values)
        scan_loads = loads is not None and loads.ndim == 2
        scan_bcv = bcv is not None and bcv.ndim == 2
        if bcv is not None and self.bc is None:
            raise ValueError("bc_values given but no DirichletCondenser (bc=)")
        if bcv is not None:
            n_bc, n = self.bc.bc_dofs.shape[0], self.bc.num_dofs
            ok = (
                bcv.ndim == 0
                or (bcv.ndim == 1 and bcv.shape[0] in (n_bc, n))
                or (bcv.ndim == 2 and bcv.shape == (n_steps, n_bc))
            )
            if not ok:
                raise ValueError(
                    f"bc_values shape {bcv.shape} not understood: expected a "
                    f"scalar, ({n_bc},) / ({n},) static data, or "
                    f"({n_steps}, {n_bc}) per-step data"
                )

        xs = {}
        if scan_loads:
            xs["f"] = loads
        if scan_bcv:
            xs["g"] = bcv

        def body(u, x):
            f = x["f"] if scan_loads else loads
            g = x["g"] if scan_bcv else bcv
            if return_info:
                u_new, info = self.step(u, load=f, bc_values=g,
                                        return_info=True)
                return u_new, (u_new, info)
            u_new = self.step(u, load=f, bc_values=g)
            return u_new, u_new

        # u0 is taken as-is: with Dirichlet data it must satisfy u0[bc] = g(t0)
        _, out = segmented_scan(body, u0, xs or None, n_steps, checkpoint_every)
        if return_info:
            traj, info = out
            events.check_convergence(info, where="theta.rollout")
            events.record_solve("theta.rollout", info, method=self.spec.method,
                                backend=self.backend,
                                precond=self.spec.precond_name)
            return traj, info
        return out
