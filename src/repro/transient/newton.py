"""Newton–Krylov backward-Euler integration for semilinear parabolic
problems (Allen–Cahn and friends).

Semidiscrete system:  M u̇ + κ K u = R(u), where the reaction load
``R(u)_a = ∫ r(u) φ_a`` is assembled through the same Batch-Map +
Sparse-Reduce pipeline (:meth:`GalerkinAssembler.assemble_reaction_load`).
Each backward-Euler step solves

    G(u) = M (u − uⁿ)/Δt + κ K u − R(u) = 0

by a fixed number of Newton iterations (an inner ``lax.scan`` — fixed
iteration count keeps the trace O(1) and the rollout reverse-differentiable).
The Jacobian is exact and sparse-in-pattern:

    J(u) = M/Δt + κ K − M[r′(u)]

where ``M[c]`` is the mass matrix weighted by the nodal coefficient ``c`` —
re-assembled per iteration through the standard Map-Reduce (it shares the
mass pattern, so the linear solve reuses the CSR machinery and
``sparse_solve`` keeps the whole trajectory differentiable).  ``r′`` is
derived automatically from ``r`` with a pointwise ``jvp`` unless given.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..core import weakform as wf
from ..core.assembly import GalerkinAssembler
from ..core.boundary import DirichletCondenser
from ..core.solvers import SolveInfo, SolverSpec, resolve_solver_spec, sparse_solve
from ..core.sparse import CSR
from ..telemetry import events
from .stepping import axpy_csr, segmented_scan

__all__ = ["NewtonKrylovIntegrator"]


def _pointwise_derivative(fn: Callable) -> Callable:
    """r′(u) for a pointwise nonlinearity, via a ones-tangent jvp."""

    def fprime(u):
        return jax.jvp(fn, (u,), (jnp.ones_like(u),))[1]

    return fprime


@dataclasses.dataclass
class NewtonKrylovIntegrator:
    asm: GalerkinAssembler
    mass: CSR
    stiff: CSR
    dt: float
    reaction: Callable                      # pointwise r(u), e.g. −ε²u(u²−1)
    reaction_prime: Callable | None = None  # pointwise r′(u); jvp-derived if None
    diffusion_scale: float = 1.0            # κ multiplying K
    bc: DirichletCondenser | None = None
    newton_iters: int = 3
    spec: SolverSpec | None = None          # Krylov config
    solver: str | None = None               # deprecated → spec.method
    tol: float | None = None                # deprecated → spec.tol (and atol)
    maxiter: int | None = None              # deprecated → spec.maxiter

    def __post_init__(self):
        # J is symmetric (mass-weighted terms) → CG default
        self.spec = resolve_solver_spec(
            self.spec, method=self.solver, tol=self.tol, atol=self.tol,
            maxiter=self.maxiter, default=SolverSpec(method="cg"),
            where="NewtonKrylovIntegrator")
        self.solver = self.spec.method
        self.tol = self.spec.tol
        self.maxiter = self.spec.maxiter
        if self.reaction_prime is None:
            self.reaction_prime = _pointwise_derivative(self.reaction)
        # linear part of the Jacobian / residual operator: M/Δt + κK
        self.lin_op = axpy_csr(1.0 / self.dt, self.mass, self.diffusion_scale, self.stiff)

    def residual(self, u_prev, u):
        """G(u) at the implicit stage, projected to free DoFs."""
        react = self.asm.assemble_rhs(wf.reaction(u, self.reaction))
        r = (
            self.mass.matvec((u - u_prev) / self.dt)
            + self.diffusion_scale * self.stiff.matvec(u)
            - react
        )
        return r if self.bc is None else self.bc.project_residual(r)

    def _jacobian(self, u) -> CSR:
        # M[−r′(u)] shares the mass pattern: nodal-coefficient mass assembly
        jac_vals = self.asm.assemble(wf.mass(-self.reaction_prime(u))).vals
        jac = dataclasses.replace(self.lin_op, vals=self.lin_op.vals + jac_vals)
        return jac if self.bc is None else self.bc.apply_matrix_only(jac)

    def step(self, u_prev, return_info=False):
        """One backward-Euler step: ``newton_iters`` Newton updates.

        ``return_info=True`` additionally returns a
        :class:`~repro.core.solvers.SolveInfo` aggregated over the inner
        Newton iterations: total Krylov iterations, the last iteration's
        residual, and all-iterations-converged (stop-gradient leaves)."""

        def newton(u, _):
            res = self.residual(u_prev, u)
            jac = self._jacobian(u)
            out = sparse_solve(jac, res, self.spec,
                               return_info=return_info)
            du, info = out if return_info else (out, None)
            return u - du, info

        u, infos = jax.lax.scan(newton, u_prev, None, length=self.newton_iters)
        if self.bc is not None:
            u = u * self.bc.free_mask + u_prev * (1.0 - self.bc.free_mask)
        if return_info:
            # (newton_iters,) leaves → one per-step summary
            step_info = SolveInfo(
                iters=infos.iters.sum(),
                residual=infos.residual[-1],
                converged=infos.converged.all(),
            )
            return u, step_info
        return u

    def rollout(self, u0, n_steps: int, *,
                checkpoint_every: int | None = None,
                return_info: bool = False) -> jnp.ndarray:
        """Scan ``n_steps`` implicit steps; returns ``(n_steps, N)``.

        ``return_info=True`` returns ``(traj, info)`` with per-step
        ``(n_steps,)`` :class:`~repro.core.solvers.SolveInfo` leaves (each
        step's inner Newton iterations aggregated — see :meth:`step`)."""

        def body(u, _):
            if return_info:
                u_new, info = self.step(u, return_info=True)
                return u_new, (u_new, info)
            u_new = self.step(u)
            return u_new, u_new

        _, out = segmented_scan(body, u0, None, n_steps, checkpoint_every)
        if return_info:
            traj, info = out
            events.check_convergence(info, where="newton.rollout")
            events.record_solve("newton.rollout", info,
                                method=self.spec.method, backend="csr",
                                precond=self.spec.precond_name)
            return traj, info
        return out
