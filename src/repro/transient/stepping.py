"""Shared rollout machinery for the transient integrators.

* :func:`segmented_scan` — ``lax.scan`` with optional ``jax.checkpoint``
  segmentation: long rollouts are split into segments whose intermediate
  states are recomputed (not stored) during the backward pass, bounding
  autodiff memory at O(T/segment + segment) instead of O(T).
* :func:`axpy_csr` — combine two same-pattern CSR operators into a third
  (``α·A + β·B``) without touching the static pattern; this is how the
  θ-method / Newmark effective operators are formed once, outside the loop.

The inner-matvec backend dispatch that used to live here
(``make_matvec`` / ``MATVEC_BACKENDS``) moved to the unified registry in
:mod:`repro.core.matvec` — every solver, integrator and loss now consumes
one dispatch point, and the ELL layout derivation is cached per sparsity
pattern instead of re-derived per call site.  The old names still resolve
from this module but emit a ``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax

from ..core.sparse import CSR

__all__ = ["segmented_scan", "axpy_csr", "make_matvec", "MATVEC_BACKENDS"]


def segmented_scan(step, init, xs, length: int, checkpoint_every: int | None = None):
    """``lax.scan(step, init, xs, length)`` with gradient-checkpoint segments.

    ``checkpoint_every=None`` is a plain scan.  Otherwise ``length`` must be
    divisible by ``checkpoint_every``; the rollout becomes an outer scan over
    ``length // checkpoint_every`` segments, each an inner scan wrapped in
    ``jax.checkpoint`` — the O(√T) memory trick for differentiating long
    trajectories.
    """
    if checkpoint_every is None or checkpoint_every >= length:
        return jax.lax.scan(step, init, xs, length=length)
    n_seg, rem = divmod(length, checkpoint_every)
    if rem:
        raise ValueError(
            f"checkpoint_every={checkpoint_every} must divide length={length}"
        )
    if xs is not None:
        xs = jax.tree_util.tree_map(
            lambda x: x.reshape(n_seg, checkpoint_every, *x.shape[1:]), xs
        )

    @jax.checkpoint
    def segment(carry, seg_xs):
        return jax.lax.scan(step, carry, seg_xs, length=checkpoint_every)

    carry, ys = jax.lax.scan(segment, init, xs, length=n_seg)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape(length, *y.shape[2:]), ys
    )
    return carry, ys


def axpy_csr(alpha, a: CSR, beta, b: CSR) -> CSR:
    """``α·A + β·B`` for two CSR operators sharing one sparsity pattern."""
    assert a.indices.shape == b.indices.shape, "CSR patterns must match"
    return dataclasses.replace(a, vals=alpha * a.vals + beta * b.vals)


def __getattr__(name):
    # deprecated backend-dispatch names, forwarded to the unified registry
    if name in ("make_matvec", "MATVEC_BACKENDS"):
        from ..core import matvec as _registry

        warnings.warn(
            f"repro.transient.stepping.{name} is deprecated; use "
            f"repro.core.matvec.{name} (the unified matvec-backend registry)",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(_registry, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
