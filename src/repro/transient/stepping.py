"""Shared rollout machinery for the transient integrators.

* :func:`segmented_scan` — ``lax.scan`` with optional ``jax.checkpoint``
  segmentation: long rollouts are split into segments whose intermediate
  states are recomputed (not stored) during the backward pass, bounding
  autodiff memory at O(T/segment + segment) instead of O(T).
* :func:`axpy_csr` — combine two same-pattern CSR operators into a third
  (``α·A + β·B``) without touching the static pattern; this is how the
  θ-method / Newmark effective operators are formed once, outside the loop.
* :func:`make_matvec` — backend dispatch for the inner matvec: ``"csr"``
  (gather + sorted segment-sum; differentiable), ``"ell"`` (padded ELLPACK
  gather, pure jnp), or ``"ell_pallas"`` (the Pallas SpMV kernel —
  TPU fast path via :func:`repro.kernels.ell_matvec`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..core.sparse import CSR, ELL, csr_to_ell

__all__ = ["segmented_scan", "axpy_csr", "make_matvec", "MATVEC_BACKENDS"]

MATVEC_BACKENDS = ("csr", "ell", "ell_pallas")


def segmented_scan(step, init, xs, length: int, checkpoint_every: int | None = None):
    """``lax.scan(step, init, xs, length)`` with gradient-checkpoint segments.

    ``checkpoint_every=None`` is a plain scan.  Otherwise ``length`` must be
    divisible by ``checkpoint_every``; the rollout becomes an outer scan over
    ``length // checkpoint_every`` segments, each an inner scan wrapped in
    ``jax.checkpoint`` — the O(√T) memory trick for differentiating long
    trajectories.
    """
    if checkpoint_every is None or checkpoint_every >= length:
        return jax.lax.scan(step, init, xs, length=length)
    n_seg, rem = divmod(length, checkpoint_every)
    if rem:
        raise ValueError(
            f"checkpoint_every={checkpoint_every} must divide length={length}"
        )
    if xs is not None:
        xs = jax.tree_util.tree_map(
            lambda x: x.reshape(n_seg, checkpoint_every, *x.shape[1:]), xs
        )

    @jax.checkpoint
    def segment(carry, seg_xs):
        return jax.lax.scan(step, carry, seg_xs, length=checkpoint_every)

    carry, ys = jax.lax.scan(segment, init, xs, length=n_seg)
    ys = jax.tree_util.tree_map(
        lambda y: y.reshape(length, *y.shape[2:]), ys
    )
    return carry, ys


def axpy_csr(alpha, a: CSR, beta, b: CSR) -> CSR:
    """``α·A + β·B`` for two CSR operators sharing one sparsity pattern."""
    assert a.indices.shape == b.indices.shape, "CSR patterns must match"
    return dataclasses.replace(a, vals=alpha * a.vals + beta * b.vals)


def make_matvec(op: CSR, backend: str = "csr") -> Callable:
    """Return ``x ↦ op @ x`` for the chosen inner-loop backend.

    ``"csr"`` keeps the differentiable segment-sum path; ``"ell"`` /
    ``"ell_pallas"`` convert once to the padded ELLPACK layout (the
    bounded-valence FEM format) and run the gather either in pure jnp or
    through the Pallas SpMV kernel.
    """
    if backend == "csr":
        return op.matvec
    if backend == "ell":
        ell = csr_to_ell(op)
        return ell.matvec
    if backend == "ell_pallas":
        from ..kernels import ell_matvec

        ell = csr_to_ell(op)
        return lambda x: ell_matvec(ell, x)
    raise ValueError(f"unknown matvec backend {backend!r}; use {MATVEC_BACKENDS}")
