"""repro.transient — differentiable time integration over TensorGalerkin
operators.

Module map
----------
* :mod:`~repro.transient.stepping` — shared rollout machinery:
  checkpoint-segmented ``lax.scan``, same-pattern CSR combination,
  matvec-backend dispatch (CSR / ELL / Pallas-ELL).
* :mod:`~repro.transient.theta` — :class:`ThetaIntegrator`: the θ-method
  for parabolic problems (θ=1 backward Euler, θ=½ Crank–Nicolson), with
  per-step time-varying loads and Dirichlet data inside the scan.
* :mod:`~repro.transient.newmark` — :class:`NewmarkIntegrator`: Newmark-β
  for second-order hyperbolic problems (β=¼, γ=½ conserves discrete
  energy — the wave benchmark's integrator).
* :mod:`~repro.transient.newton` — :class:`NewtonKrylovIntegrator`:
  backward Euler + Newton–Krylov for semilinear problems, with the
  reaction term and its exact mass-weighted Jacobian assembled through the
  Batch-Map + Sparse-Reduce pipeline (Allen–Cahn).

Every rollout is a ``lax.scan`` with O(1) trace size over pre-assembled
CSR operators; per-step solves go through ``sparse_solve`` (adjoint
backward pass), so trajectories differentiate w.r.t. coefficients, initial
conditions, and mesh coordinates.  :func:`batched_rollout` vmaps a rollout
over a batch of initial conditions; to batch over coefficient fields,
construct the integrator *inside* the vmapped function::

    def traj(kappa, u0):
        # fused θ operators, one jit signature across the batch trace
        integ = ThetaIntegrator.from_form(asm, weakform.diffusion(kappa),
                                          dt=dt, theta=0.5, bc=bc)
        return integ.rollout(u0, n_steps)

    trajs = jax.vmap(traj)(kappa_batch, u0_batch)   # (B, T, N)
"""

from __future__ import annotations

import jax

from .newmark import NewmarkIntegrator
from .newton import NewtonKrylovIntegrator
from .stepping import axpy_csr, make_matvec, segmented_scan
from .theta import BACKWARD_EULER, CRANK_NICOLSON, ThetaIntegrator

__all__ = [
    "ThetaIntegrator",
    "NewmarkIntegrator",
    "NewtonKrylovIntegrator",
    "BACKWARD_EULER",
    "CRANK_NICOLSON",
    "batched_rollout",
    "segmented_scan",
    "axpy_csr",
    "make_matvec",
]


def batched_rollout(integrator, u0_batch, n_steps: int, **rollout_kwargs):
    """vmap ``integrator.rollout`` over a leading batch of initial
    conditions: ``(B, N) → (B, n_steps, N)``.  Keyword args (loads,
    bc_values, checkpoint_every, ...) are shared across the batch."""
    return jax.vmap(
        lambda u0: integrator.rollout(u0, n_steps, **rollout_kwargs)
    )(u0_batch)
