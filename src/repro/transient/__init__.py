"""repro.transient — differentiable time integration over TensorGalerkin
operators.

Module map
----------
* :mod:`~repro.transient.stepping` — shared rollout machinery:
  checkpoint-segmented ``lax.scan``, same-pattern CSR combination.  The
  inner-matvec backend dispatch lives in the unified registry
  :mod:`repro.core.matvec` (CSR / ELL / Pallas-ELL / matrix-free).
* :mod:`~repro.transient.theta` — :class:`ThetaIntegrator`: the θ-method
  for parabolic problems (θ=1 backward Euler, θ=½ Crank–Nicolson), with
  per-step time-varying loads and Dirichlet data inside the scan.
* :mod:`~repro.transient.newmark` — :class:`NewmarkIntegrator`: Newmark-β
  for second-order hyperbolic problems (β=¼, γ=½ conserves discrete
  energy — the wave benchmark's integrator).
* :mod:`~repro.transient.newton` — :class:`NewtonKrylovIntegrator`:
  backward Euler + Newton–Krylov for semilinear problems, with the
  reaction term and its exact mass-weighted Jacobian assembled through the
  Batch-Map + Sparse-Reduce pipeline (Allen–Cahn).

Every rollout is a ``lax.scan`` with O(1) trace size over pre-assembled
CSR operators; per-step solves go through ``sparse_solve`` (adjoint
backward pass), so trajectories differentiate w.r.t. coefficients, initial
conditions, and mesh coordinates.  :func:`batched_rollout` vmaps a rollout
over a batch of initial conditions; to batch over coefficient fields,
assemble the per-instance effective operators in ONE call
(``repro.core.assemble_batched`` → :class:`~repro.core.sparse.BatchedCSR`)
and roll the family out with :func:`batched_theta_rollout`::

    lhs = assemble_batched(plan, wf.mass(1.0) + (theta * dt) * wf.diffusion(k0),
                           leaves_batch=(None, None, kappa_batch, None))
    rhs = assemble_batched(plan, wf.mass(1.0) - ((1 - theta) * dt) * wf.diffusion(k0),
                           leaves_batch=(None, None, kappa_batch, None))
    trajs = batched_theta_rollout(lhs, rhs, u0_batch, n_steps, dt=dt,
                                  theta=theta, bc=bc)       # (B, T, N)
"""

from __future__ import annotations

import jax

from ..core.matvec import make_matvec  # unified registry (compat re-export)
from .newmark import NewmarkIntegrator
from .newton import NewtonKrylovIntegrator
from .stepping import axpy_csr, segmented_scan
from ..core.solvers import SolverSpec
from .theta import BACKWARD_EULER, CRANK_NICOLSON, ThetaIntegrator

__all__ = [
    "ThetaIntegrator",
    "NewmarkIntegrator",
    "NewtonKrylovIntegrator",
    "BACKWARD_EULER",
    "CRANK_NICOLSON",
    "batched_rollout",
    "batched_theta_rollout",
    "segmented_scan",
    "axpy_csr",
    "make_matvec",
]


def batched_rollout(integrator, u0_batch, n_steps: int, **rollout_kwargs):
    """vmap ``integrator.rollout`` over a leading batch of initial
    conditions: ``(B, N) → (B, n_steps, N)``.  Keyword args (loads,
    bc_values, checkpoint_every, ...) are shared across the batch."""
    return jax.vmap(
        lambda u0: integrator.rollout(u0, n_steps, **rollout_kwargs)
    )(u0_batch)


def batched_theta_rollout(lhs_full, rhs_op, u0_batch, n_steps: int, *, dt,
                          theta: float = BACKWARD_EULER, bc=None, loads=None,
                          bc_values=None, checkpoint_every: int | None = None,
                          **integrator_kwargs):
    """θ-rollouts for a *family* of problem instances over
    :class:`~repro.core.sparse.BatchedCSR` effective operators.

    ``lhs_full`` / ``rhs_op`` hold the B per-instance operators
    ``M + θΔtK_b`` / ``M − (1−θ)ΔtK_b`` on one shared static pattern (from
    ``assemble_batched``); the whole family rolls out in one vmapped
    ``lax.scan`` — a single XLA executable, no per-instance re-vmapping of
    raw value vectors.  ``u0_batch: (B, N) → (B, n_steps, N)``; ``loads`` /
    ``bc_values`` are shared across the batch.

    Both operators may instead be
    :class:`~repro.core.operator.MatFreeFamily` (from
    :func:`repro.core.matfree_family` on the two effective forms): the
    family rolls out matrix-free — per-step solves through
    ``matfree_solve``, zero CSR values materialized for the whole batch.
    """
    if hasattr(lhs_full, "in_axes"):  # MatFreeFamily pair
        integrator_kwargs.setdefault("backend", "matfree")
        if integrator_kwargs.get("solver") is None:
            integrator_kwargs.setdefault("spec", SolverSpec(method="cg"))

        def one_mf(lhs_op, rhs_op_b, u0):
            integ = ThetaIntegrator(
                None, None, dt, theta=theta, bc=bc,
                lhs_full=lhs_op, rhs_op=rhs_op_b, **integrator_kwargs,
            )
            return integ.rollout(u0, n_steps, loads=loads, bc_values=bc_values,
                                 checkpoint_every=checkpoint_every)

        return jax.vmap(
            one_mf, in_axes=(lhs_full.in_axes(), rhs_op.in_axes(), 0)
        )(lhs_full.op, rhs_op.op, u0_batch)

    def one(lhs_b, rhs_b, u0):
        integ = ThetaIntegrator(
            None, None, dt, theta=theta, bc=bc,
            lhs_full=lhs_b.as_csr(), rhs_op=rhs_b.as_csr(), **integrator_kwargs,
        )
        return integ.rollout(u0, n_steps, loads=loads, bc_values=bc_values,
                             checkpoint_every=checkpoint_every)

    return jax.vmap(one)(lhs_full, rhs_op, u0_batch)
