"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts each ``while`` body **once**, independent
of trip count (verified empirically — see EXPERIMENTS.md §Dry-run), which
under-counts scan-over-layers / grad-accum programs by orders of magnitude.
This module re-derives FLOPs / HBM bytes / collective bytes by walking the
*optimized, partitioned* HLO text:

  * ``while`` ops multiply body+condition cost by the trip count read from
    XLA's ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the
    ``compare(ind, constant(N)), direction=LT`` pattern in the condition;
    loops with dynamic trip counts fall back to 1 and are counted in
    ``dynamic_loops``),
  * FLOPs: ``dot`` = 2·|result|·K (K = product of lhs contracting extents,
    resolved through a per-computation symbol table since operand shapes are
    not repeated in optimized HLO); elementwise/reduce ops = |result| (VPU),
  * bytes (primary, TPU-projected): dot/conv operands+results (the traffic
    that must stream through HBM around MXU ops), collective results, and
    dynamic-update-slice results (KV-cache writes).  The CPU backend emits
    many more, smaller fusions than a TPU compiler would, so counting all
    fusion boundaries over-states TPU HBM traffic ~10–20×; that number is
    still recorded as ``bytes_upper`` (as-compiled upper bound).  The primary
    model is self-consistent with the machine-balance analysis in
    EXPERIMENTS.md §Roofline,
  * collectives: result bytes (operand bytes for reduce-scatter) × enclosing
    trip counts, split by kind.
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HloCost", "analyze_hlo_text"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt", "cbrt",
    "tanh", "maximum", "minimum", "compare", "select", "and", "or", "xor",
    "not", "negate", "abs", "convert", "reduce", "cosine", "sine",
    "logistic", "floor", "ceil", "sign", "remainder", "atan2", "clamp",
    "reduce-window",
}
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "copy-start", "copy-done", "partition-id",
    "replica-id", "opt-barrier",
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _shape_list(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


def _bytes(shapes) -> int:
    tot = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


def _elems(shapes) -> int:
    tot = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n
    return tot


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0          # primary (TPU-projected) HBM traffic
    bytes_upper: float = 0.0    # all fusion-boundary operands+results
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    dynamic_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.bytes_upper += mult * other.bytes_upper
        self.collective_bytes += mult * other.collective_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = (
                self.collective_by_kind.get(k, 0.0) + mult * v
            )
        self.dynamic_loops += other.dynamic_loops


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str           # operand list + attributes (from the opening paren)


def _parse(hlo: str):
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps, entry


def _operands(rest: str) -> list[str]:
    args = rest.split(")", 1)[0]
    return re.findall(r"%([\w.\-]+)", args)


def _called(rest: str, attr: str) -> list[str]:
    m = re.search(rf"{attr}=(%[\w.\-]+|\{{[^}}]*\}})", rest)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def analyze_hlo_text(hlo: str) -> HloCost:
    comps, entry = _parse(hlo)
    memo: dict[str, HloCost] = {}

    def comp_cost(name: str, depth: int = 0) -> HloCost:
        if name in memo:
            return memo[name]
        cost = HloCost()
        if depth > 128 or name not in comps:
            memo[name] = cost
            return cost
        shapes: dict[str, list] = {}
        seen_reads: set[str] = set()
        for op in comps[name]:
            res_shapes = _shape_list(op.type_str)
            shapes[op.name] = res_shapes
            res_b = _bytes(res_shapes)
            res_n = _elems(res_shapes)
            operand_b = sum(_bytes(shapes.get(o, [])) for o in _operands(op.rest))
            # primary model reads each value once per computation execution
            # (VMEM/register reuse within a loop body or fusion region)
            fresh = [o for o in _operands(op.rest) if o not in seen_reads]
            operand_b_dedup = sum(_bytes(shapes.get(o, [])) for o in fresh)

            if op.kind == "while":
                m = _TRIP_RE.search(op.rest)
                trips = None
                if m:
                    trips = int(m.group(1))
                else:
                    conds = _called(op.rest, "condition")
                    if conds and conds[0] in comps:
                        trips = _trip_from_condition(comps[conds[0]])
                if trips is None:
                    trips = 1
                    cost.dynamic_loops += 1
                inner = HloCost()
                for sub in _called(op.rest, "body") + _called(op.rest, "condition"):
                    inner.add(comp_cost(sub, depth + 1))
                cost.add(inner, mult=float(trips))
                continue

            if op.kind == "fusion":
                for sub in _called(op.rest, "calls"):
                    inner = comp_cost(sub, depth + 1)
                    cost.flops += inner.flops
                    cost.collective_bytes += inner.collective_bytes
                    for k, v in inner.collective_by_kind.items():
                        cost.collective_by_kind[k] = (
                            cost.collective_by_kind.get(k, 0.0) + v
                        )
                    cost.dynamic_loops += inner.dynamic_loops
                    cost.bytes += inner.bytes
                    cost.bytes_upper += inner.bytes_upper
                cost.bytes_upper += res_b + operand_b
                continue

            if op.kind in ("call", "custom-call", "map", "sort", "scatter",
                           "reduce", "reduce-window", "select-and-scatter"):
                for sub in (_called(op.rest, "calls") + _called(op.rest, "to_apply")):
                    cost.add(comp_cost(sub, depth + 1))
                cost.bytes_upper += res_b + operand_b
                if op.kind in ("scatter", "sort"):
                    cost.bytes += res_b + operand_b
                if op.kind == "reduce":
                    cost.flops += max(_elems([s for o in _operands(op.rest)
                                              for s in shapes.get(o, [])]), res_n)
                continue

            if op.kind == "conditional":
                branches = _called(op.rest, "branch_computations") or (
                    _called(op.rest, "true_computation")
                    + _called(op.rest, "false_computation")
                )
                if branches:
                    worst = max(
                        (comp_cost(b, depth + 1) for b in branches),
                        key=lambda c: c.flops + c.bytes,
                    )
                    cost.add(worst)
                cost.bytes_upper += res_b + operand_b
                continue

            coll = None
            for c in _COLLECTIVES:
                if op.kind in (c, f"{c}-start"):
                    coll = c
                    break
            if coll:
                size = operand_b if coll == "reduce-scatter" else (
                    res_b if not op.kind.endswith("-start") else max(
                        (_bytes([s]) for s in res_shapes), default=0
                    )
                )
                cost.collective_bytes += size
                cost.collective_by_kind[coll] = (
                    cost.collective_by_kind.get(coll, 0.0) + size
                )
                cost.bytes += res_b
                cost.bytes_upper += res_b
                continue
            if op.kind.endswith("-done") or op.kind in _FREE_OPS:
                continue

            if op.kind == "dot":
                k = 1
                mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                ops = _operands(op.rest)
                if mdims and ops and ops[0] in shapes and shapes[ops[0]]:
                    lhs_dims = shapes[ops[0]][0][1]
                    for ci in mdims.group(1).split(","):
                        if ci != "" and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                cost.flops += 2.0 * res_n * k
                cost.bytes += res_b + operand_b_dedup
                seen_reads.update(_operands(op.rest))
                cost.bytes_upper += res_b + operand_b
                continue

            if op.kind == "convolution":
                cost.flops += 2.0 * res_n  # frontends are stubbed; conv is rare
                cost.bytes += res_b + operand_b_dedup
                seen_reads.update(_operands(op.rest))
                cost.bytes_upper += res_b + operand_b
                continue

            if op.kind == "dynamic-update-slice":
                # in-place buffer update: only the *update* operand moves
                # (the result aliases the input buffer — counting it would
                # charge the whole KV cache / ys stack per loop iteration)
                ops_ = _operands(op.rest)
                upd_b = _bytes(shapes.get(ops_[1], [])) if len(ops_) > 1 else res_b
                cost.bytes += upd_b
            elif op.kind in ("dynamic-slice", "gather"):
                # slab reads: the *slice* (= result) moves
                cost.bytes += res_b
            if op.kind in _ELEMENTWISE:
                cost.flops += res_n
            cost.bytes_upper += res_b + operand_b

        memo[name] = cost
        return cost

    def _trip_from_condition(ops: list[_Op]):
        const_val = None
        has_lt = False
        for op in ops:
            m = re.search(r"constant\((\d+)\)", f"{op.kind}({op.rest}")
            if op.kind == "constant":
                m2 = re.search(r"^(\d+)", op.rest)
                # constants print as  %c = s32[] constant(8)
            if "direction=LT" in op.rest:
                has_lt = True
            mm = re.search(r"constant\((\d+)\)", op.rest)
        # simpler: scan raw rest strings
        for op in ops:
            if op.kind == "constant":
                mm = re.match(r"(\d+)\)", op.rest)
                if mm:
                    const_val = int(mm.group(1))
        return const_val if has_lt and const_val is not None else None

    if entry is None and comps:
        entry = list(comps)[-1]
    return comp_cost(entry) if entry else HloCost()
