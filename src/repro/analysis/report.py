"""Render dryrun/perf JSON into the EXPERIMENTS.md markdown tables.

    PYTHONPATH=src python -m repro.analysis.report dryrun_results.json
"""

from __future__ import annotations

import json
import sys


def _fmt_t(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(rows: list[dict], mesh: str) -> str:
    out = [
        f"| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        f"| useful FLOPs | roofline frac | HBM/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | *skip* "
                f"| — | — | {r['reason'][:46]} |"
            )
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        ma = r.get("memory_analysis", {})
        hbm = ma.get("temp_GiB", 0) + ma.get("arg_GiB", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(r['t_compute_s'])} "
            f"| {_fmt_t(r['t_memory_s'])} | {_fmt_t(r['t_collective_s'])} "
            f"| **{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {hbm:.1f} GiB |"
        )
    return "\n".join(out)


def collective_summary(rows: list[dict], mesh: str) -> str:
    out = ["| arch | shape | AG | AR | RS | A2A | CP |", "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        bk = r["collectives"]["by_kind"]
        g = lambda k: f"{bk.get(k, 0)/2**30:.1f}G" if bk.get(k, 0) else "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {g('all-gather')} | {g('all-reduce')} "
            f"| {g('reduce-scatter')} | {g('all-to-all')} | {g('collective-permute')} |"
        )
    return "\n".join(out)


def perf_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | variant | t_compute | t_memory | t_collective "
        "| bottleneck | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r.get('variant','?')} | FAIL: "
                f"{r.get('error','')[:60]} | | | | |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('variant','baseline')} "
            f"| {_fmt_t(r['t_compute_s'])} | {_fmt_t(r['t_memory_s'])} "
            f"| {_fmt_t(r['t_collective_s'])} | {r['bottleneck']} "
            f"| {r['roofline_fraction']:.4f} |"
        )
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rows = json.load(open(path))
    if rows and "variant" in rows[0]:
        print(perf_table(rows))
        return
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh}\n")
        print(roofline_table(rows, mesh))
        print(f"\n#### Collective bytes/chip ({mesh})\n")
        print(collective_summary(rows, mesh))


if __name__ == "__main__":
    main()
