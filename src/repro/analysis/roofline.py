"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs      / (chips × peak_FLOP/s)
    memory     = HLO_bytes      / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` supplies flops & bytes; collective bytes are
parsed from the *partitioned* HLO text (operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.

Interpretation notes (validated empirically in tests/test_roofline.py):
  * under SPMD, cost_analysis reports the *per-device* program, so we divide
    by per-chip peaks, not pod aggregates;
  * collective bytes are summed over instruction operands per device; each
    byte must traverse at least one link, so bytes/link_bw is the standard
    single-hop lower bound (ring latency factors are reported separately as
    ``ring_factor`` for all-gather/reduce-scatter style ops).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = ["HW", "RooflineReport", "collective_bytes_from_hlo", "analyze_compiled"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per ICI link


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches e.g.  bf16[16,4096,1152]{2,1,0} — the result/operand shapes
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    if not dims:
        return nbytes
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * nbytes


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, by kind.

    Uses the *result* shape of each collective instruction (for all-gather
    the result is the gathered tensor; for reduce-scatter the larger operand
    is counted instead, as the data traversing links is the unscattered one).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for kind in _COLLECTIVES:
            if f"{kind}-done(" in line:
                break  # data counted at the matching -start
            tok = None
            for cand in (f"{kind}-start(", f"{kind}("):
                if cand in line:
                    tok = cand
                    break
            if tok is None:
                continue
            pos = line.find(tok)
            lhs_shapes = _SHAPE_RE.findall(line[:pos])      # result shape(s)
            rhs_shapes = _SHAPE_RE.findall(line[pos:])      # operand shape(s)
            if kind == "reduce-scatter":
                # the unscattered operand traverses the links
                size = max((_shape_bytes(d, s) for d, s in rhs_shapes), default=0)
            elif tok.endswith("-start("):
                # async form: result is a (operand-alias, result) tuple —
                # count the largest element once
                size = max((_shape_bytes(d, s) for d, s in lhs_shapes), default=0)
            else:
                size = sum(_shape_bytes(d, s) for d, s in lhs_shapes)
            out[kind] += size
            counts[kind] += 1
            break
    return {"by_kind": out, "counts": counts, "total": sum(out.values())}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    collective_bytes: float      # per-device collective bytes
    collective_detail: dict
    model_flops: float           # 6·N·D (or 6·N_active·D)
    peak_memory_bytes: float = 0.0
    hw: HW = dataclasses.field(default_factory=HW)

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops across chips — remat/redundancy."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: how close the dominant term lets
        us get to ideal MODEL_FLOPS/peak execution."""
        ideal = self.model_flops / (self.chips * self.hw.peak_flops)
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / bound if bound else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_chip": self.flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_GiB": self.peak_memory_bytes / 2**30,
            "collectives": self.collective_detail,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float, hw: HW = HW()) -> RooflineReport:
    """Per-device roofline terms from the compiled (partitioned) module.

    Primary source is the loop-aware HLO walker (``hlo_cost``) — XLA's own
    ``cost_analysis()`` counts while bodies once regardless of trip count
    (verified; see EXPERIMENTS.md §Dry-run) which under-counts scanned
    programs by O(layers × microbatches).  XLA's numbers are kept in the
    report as ``xla_*`` cross-check fields.
    """
    from .hlo_cost import analyze_hlo_text

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    hlo = compiled.as_text()
    walked = analyze_hlo_text(hlo)
    flops = float(walked.flops)
    hbm_bytes = float(walked.bytes)
    coll = {
        "by_kind": {k: float(v) for k, v in walked.collective_by_kind.items()},
        "total": float(walked.collective_bytes),
        "dynamic_loops": walked.dynamic_loops,
        "xla_flops": float(cost.get("flops", 0.0)),
        "xla_bytes": float(cost.get("bytes accessed", 0.0)),
        "single_shot": collective_bytes_from_hlo(hlo)["by_kind"],
    }
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem_bytes = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        mem_bytes = 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops=flops, hbm_bytes=hbm_bytes,
        collective_bytes=float(coll["total"]),
        collective_detail=coll,
        model_flops=model_flops,
        peak_memory_bytes=mem_bytes, hw=hw,
    )


def validate_loop_accounting():
    """Self-check used by tests: the walker must scale with scan length."""
    import jax
    import jax.numpy as jnp
    from .hlo_cost import analyze_hlo_text

    def make(k):
        def f(x):
            c, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=k)
            return c
        return f

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    f1 = analyze_hlo_text(jax.jit(make(1)).lower(x).compile().as_text()).flops
    f8 = analyze_hlo_text(jax.jit(make(8)).lower(x).compile().as_text()).flops
    return f1, f8
