from .roofline import RooflineReport, analyze_compiled, collective_bytes_from_hlo  # noqa: F401
