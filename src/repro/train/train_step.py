"""Sharded training step: grad accumulation, mixed precision, fused update.

* **Grad accumulation** — ``lax.scan`` over microbatches bounds activation
  memory (the knob that fits the 340B/400B archs on a 256-chip pod); the
  accumulator dtype is ``cfg.grad_dtype`` (bf16 = compressed accumulation
  buffers; actual collective dtypes are verified from the dry-run HLO).
* **Mixed precision** — params are stored in ``cfg.param_dtype`` and cast to
  ``cfg.compute_dtype`` inside the forward; logits/loss in f32.
* **In-place update** — the caller donates the state buffers
  (``donate_argnums=0``) so params/optimizer state update in place.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..models.layers import P, is_spec
from ..models.model_zoo import build_model
from ..optim import cosine_schedule, make_optimizer
from ..sharding.partitioning import ShardingRules, make_shardings, use_rules

__all__ = ["TrainState", "make_train_state_specs", "make_train_step"]

TrainState = dict  # {"params": tree, "opt": tree, "step": scalar}


def make_train_state_specs(cfg: ArchConfig):
    model = build_model(cfg)
    pspecs = model.param_specs()
    opt = make_optimizer(cfg.optimizer)
    ospecs = opt.init_specs(pspecs)
    return {
        "params": pspecs,
        "opt": ospecs,
        "step": P((), (), "zeros", dtype=jnp.int32),
    }


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape((n, b // n) + x.shape[1:])

    return {k: split(v) if getattr(v, "ndim", 0) > 0 else v for k, v in batch.items()}


def make_train_step(cfg: ArchConfig, shape: ShapeSpec, *, lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000,
                    weight_decay: float = 0.01):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (un-jitted —
    the caller jits with shardings; see launch/dryrun.py and launch/train.py).
    """
    model = build_model(cfg)
    opt = make_optimizer(cfg.optimizer)
    schedule = cosine_schedule(lr, warmup, total_steps)
    n_micro = cfg.grad_accum(shape.name)
    gdt = jnp.dtype(cfg.grad_dtype)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state: TrainState, batch: dict):
        params = state["params"]

        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_microbatches(batch, n_micro)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params
            )

            def micro(carry, mb):
                acc, loss_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(lambda a, x: a + x.astype(gdt), acc, g)
                return (acc, loss_acc + loss), None

            (grads, loss), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / n_micro, grads)

        step = state["step"] + 1
        cur_lr = schedule(step)
        new_params, new_opt = opt.update(
            params, grads, state["opt"], cur_lr, step.astype(jnp.float32),
            wd=weight_decay,
        )
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        new_state = {"params": new_params, "opt": new_opt, "step": step}
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": cur_lr}

    return train_step


def jit_train_step(cfg, shape, mesh, rules: ShardingRules, **kw):
    """Fully-jitted sharded train step + all the specs the launcher needs."""
    state_specs = make_train_state_specs(cfg)
    model = build_model(cfg)
    step_fn = make_train_step(cfg, shape, **kw)

    state_sh = make_shardings(state_specs, mesh, rules)
    batch_axes = model.batch_axes(shape)
    batch_sh = make_shardings(batch_axes, mesh, rules)

    def wrapped(state, batch):
        with use_rules(rules):
            return step_fn(state, batch)

    jitted = jax.jit(
        wrapped,
        in_shardings=(state_sh, batch_sh),
        donate_argnums=(0,),
    )
    return jitted, state_specs, state_sh, batch_sh
