"""Serving steps: prefill (prompt → cache) and decode (one token vs cache).

Served weights are bf16 copies of the training params; the KV cache is
donated on decode so it updates in place (no per-step reallocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..models.layers import P, is_spec
from ..models.model_zoo import build_model
from ..sharding.partitioning import ShardingRules, make_shardings, use_rules

__all__ = ["serve_param_specs", "make_prefill_fn", "make_decode_fn"]


def serve_param_specs(cfg: ArchConfig):
    """bf16 copies of the parameter specs (weights as served)."""
    model = build_model(cfg)

    def to_bf16(s: P) -> P:
        return P(s.shape, s.axes, s.init, s.scale, jnp.bfloat16)

    return jax.tree.map(to_bf16, model.param_specs(), is_leaf=is_spec)


def make_prefill_fn(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: ShardingRules):
    model = build_model(cfg)
    max_len = shape.seq_len

    def prefill(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch, max_len)

    pspecs = serve_param_specs(cfg)
    param_sh = make_shardings(pspecs, mesh, rules)
    batch_sh = make_shardings(model.batch_axes(shape), mesh, rules)
    return jax.jit(prefill, in_shardings=(param_sh, batch_sh)), pspecs


def make_decode_fn(cfg: ArchConfig, shape: ShapeSpec, mesh, rules: ShardingRules):
    model = build_model(cfg)

    def decode(params, batch, cache):
        with use_rules(rules):
            return model.decode(params, batch, cache)

    pspecs = serve_param_specs(cfg)
    cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
    param_sh = make_shardings(pspecs, mesh, rules)
    cache_sh = make_shardings(cspecs, mesh, rules)
    batch_axes = model.batch_axes(shape)
    batch_sh = make_shardings(batch_axes, mesh, rules)
    jitted = jax.jit(
        decode,
        in_shardings=(param_sh, batch_sh, cache_sh),
        donate_argnums=(2,),
    )
    return jitted, pspecs, cspecs
