from .train_step import TrainState, make_train_state_specs, make_train_step  # noqa: F401
from .serve_step import make_decode_fn, make_prefill_fn  # noqa: F401
