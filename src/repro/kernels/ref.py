"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["local_stiffness_p1_ref", "spmv_ell_ref", "galerkin_residual_ell_ref"]


def local_stiffness_p1_ref(coords: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """Batched P1 simplex stiffness: coords (E, k, d) with k = d+1,
    rho (E,) → (E, k, k).  K_e = |e| ρ_e G Gᵀ with constant gradients."""
    e, k, d = coords.shape
    assert k == d + 1
    edges = coords[:, 1:, :] - coords[:, :1, :]          # (E, d, d) rows = edges
    jac = jnp.swapaxes(edges, 1, 2)                      # J columns = edge vectors
    det = jnp.linalg.det(jac)
    jinv = jnp.linalg.inv(jac)
    gradhat = jnp.concatenate(
        [-jnp.ones((1, d), coords.dtype), jnp.eye(d, dtype=coords.dtype)], axis=0
    )                                                    # (k, d)
    g = jnp.einsum("eji,aj->eai", jinv, gradhat)         # J^{-T} ĝ
    w = 1.0 / {1: 1.0, 2: 2.0, 3: 6.0}[d]                # reference simplex volume
    scale = w * jnp.abs(det) * rho                       # (E,)
    return jnp.einsum("e,eai,ebi->eab", scale, g, g)


def spmv_ell_ref(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """ELL SpMV: vals/cols (N, L), x (N,) → (N,)."""
    return jnp.sum(vals * x[cols], axis=1)


def galerkin_residual_ell_ref(vals, cols, u, f) -> jnp.ndarray:
    """Fused TensorPILS residual r = K u − f on the ELL operator."""
    return spmv_ell_ref(vals, cols, u) - f
