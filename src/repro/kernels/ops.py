"""Public jit'd wrappers for the Pallas kernels, with backend dispatch.

``interpret`` resolves inside :mod:`repro.kernels.spmv_ell` from the active
JAX backend (Mosaic on TPU, the DMA-emulating interpreter elsewhere), with
``REPRO_PALLAS_INTERPRET`` / per-call ``interpret=`` overrides.

Two SpMV memory plans back the ELL operators (kernel module docstring):
the broadcast plan (:func:`ell_matvec`) replicates ``x`` into VMEM per row
block — fastest while N fits; the streaming plan (:func:`ell_matvec_stream`)
keeps every operand HBM-resident with double-buffered DMA — VMEM use is
independent of N, so million-DOF solves fit.
"""

from __future__ import annotations

from .local_assembly import local_stiffness_p1
from .spmv_ell import (
    _interpret_default,
    autotune_stream,
    galerkin_residual_ell,
    galerkin_residual_ell_stream,
    spmv_ell,
    spmv_ell_stream,
)

__all__ = [
    "batch_map_stiffness",
    "ell_matvec",
    "ell_residual",
    "ell_matvec_stream",
    "ell_residual_stream",
    "autotune_ell_stream",
]


def batch_map_stiffness(coords, rho, *, interpret: bool | None = None):
    """Stage-I Batch-Map for P1 simplices: (E,k,d),(E,) → (E,k,k)."""
    itp = _interpret_default() if interpret is None else interpret
    return local_stiffness_p1(coords, rho, interpret=itp)


def ell_matvec(ell, x, *, interpret: bool | None = None):
    """SpMV on a :class:`repro.core.sparse.ELL` operator (broadcast plan).

    The static column table is staged (int32 cast + block padding + device
    transfer) once per layout inside the kernel module's id-keyed cache."""
    return spmv_ell(ell.vals, ell.cols, x, interpret=interpret)


def ell_residual(ell, u, f, *, interpret: bool | None = None):
    return galerkin_residual_ell(ell.vals, ell.cols, u, f, interpret=interpret)


def ell_matvec_stream(ell, x, *, interpret: bool | None = None,
                      block_n: int | None = None, nbuf: int | None = None):
    """Streaming SpMV on an ELL operator: HBM-resident ``x``, double-buffered
    ``vals``/``cols`` row blocks — N bounded by HBM, not VMEM."""
    kw = {}
    if block_n is not None:
        kw["block_n"] = block_n
    if nbuf is not None:
        kw["nbuf"] = nbuf
    return spmv_ell_stream(ell.vals, ell.cols, x, interpret=interpret, **kw)


def ell_residual_stream(ell, u, f, *, interpret: bool | None = None,
                        block_n: int | None = None, nbuf: int | None = None):
    """Fused streaming residual ``r = K·u − f`` on an ELL operator."""
    kw = {}
    if block_n is not None:
        kw["block_n"] = block_n
    if nbuf is not None:
        kw["nbuf"] = nbuf
    return galerkin_residual_ell_stream(ell.vals, ell.cols, u, f,
                                        interpret=interpret, **kw)


def autotune_ell_stream(ell, x, **kw):
    """Pick the fastest ``(block_n, nbuf)`` for this layout by measurement —
    results are cached and recorded through :mod:`repro.telemetry`."""
    return autotune_stream(ell.vals, ell.cols, x, **kw)
