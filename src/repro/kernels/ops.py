"""Public jit'd wrappers for the Pallas kernels, with backend dispatch.

``interpret=True`` (Python interpretation of the kernel body) is used on CPU
for validation; on a real TPU backend the same ``pallas_call`` lowers to
Mosaic.  The wrappers auto-select unless forced.
"""

from __future__ import annotations

import jax

from .local_assembly import local_stiffness_p1
from .spmv_ell import galerkin_residual_ell, spmv_ell

__all__ = ["batch_map_stiffness", "ell_matvec", "ell_residual"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def batch_map_stiffness(coords, rho, *, interpret: bool | None = None):
    """Stage-I Batch-Map for P1 simplices: (E,k,d),(E,) → (E,k,k)."""
    itp = _interpret_default() if interpret is None else interpret
    return local_stiffness_p1(coords, rho, interpret=itp)


def _cols_dev(cols):
    # stage the static column table once per layout (the core's device-mirror
    # cache), not per call — an (N, L) host→device transfer on every matvec
    # of a solve loop otherwise dominates the kernel itself
    from ..core.sparse import _dev

    return _dev(cols)


def ell_matvec(ell, x, *, interpret: bool | None = None):
    """SpMV on a :class:`repro.core.sparse.ELL` operator."""
    itp = _interpret_default() if interpret is None else interpret

    return spmv_ell(ell.vals, _cols_dev(ell.cols), x, interpret=itp)


def ell_residual(ell, u, f, *, interpret: bool | None = None):
    itp = _interpret_default() if interpret is None else interpret

    return galerkin_residual_ell(ell.vals, _cols_dev(ell.cols), u, f, interpret=itp)
