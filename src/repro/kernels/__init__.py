from .ops import (  # noqa: F401
    autotune_ell_stream,
    batch_map_stiffness,
    ell_matvec,
    ell_matvec_stream,
    ell_residual,
    ell_residual_stream,
)
