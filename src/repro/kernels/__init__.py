from .ops import batch_map_stiffness, ell_matvec, ell_residual  # noqa: F401
