"""Pallas TPU kernels: ELL SpMV (+ fused Galerkin residual), two memory plans.

The iterative-solver hot loop is ``y = K·x`` on the assembled operator.  FEM
meshes have bounded valence, so ELLPACK (fixed nnz/row ``L``, padded) is the
TPU-friendly layout: the row dimension rides sublanes/grid, the ``L`` slots
are a small unrolled reduction, and the only awkward op — the gather
``x[cols]`` — is a 1-D dynamic gather.

Two kernels share the layout:

* :func:`spmv_ell` / :func:`galerkin_residual_ell` — the **broadcast** plan:
  ``x`` rides a VMEM BlockSpec replicated to every row block.  VMEM is
  (2·BN·L + N + BN) elements, so N is capped at VMEM scale (~1e5–1e6 f32).
* :func:`spmv_ell_stream` / :func:`galerkin_residual_ell_stream` — the
  **streaming** plan: every operand lives in HBM (``memory_space=ANY``); row
  blocks of ``vals``/``cols`` (and the per-block window of ``x``) are
  double-buffered into VMEM scratch with ``make_async_copy``, results DMA
  back out per block.  VMEM is ``nbuf·(BN·L·(w+4) + W·w) + BN·w`` bytes for
  element width ``w`` — independent of N, so N is bounded by HBM only.

The streaming gather needs each row block's columns inside a bounded window
``[start_b, start_b + W)``: static per-block windows are precomputed from the
column table (see :func:`_stream_plan`) and ``W`` is the widest one.  FEM
meshes with locality-preserving DoF orderings (the structured meshes here are
lexicographic) keep ``W`` near the matrix bandwidth; a scrambled ordering
inflates ``W`` toward N and the plan degenerates to the broadcast one —
``stream_window`` is recorded through :mod:`repro.telemetry` so regressions
are visible.

``interpret`` resolves from the active JAX backend: the Mosaic path on TPU,
the (DMA-emulating) interpreter elsewhere — so CPU CI runs the same kernel
logic and real hardware never silently interprets.  Override per call
(``interpret=``) or per process (``REPRO_PALLAS_INTERPRET=0/1``).

The fused residual variants compute ``r = K·u − f`` in the same kernel — the
TensorPILS training objective's inner op (one pass, no extra HBM round-trip).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import telemetry
from ..telemetry import annotate

__all__ = [
    "spmv_ell",
    "galerkin_residual_ell",
    "spmv_ell_stream",
    "galerkin_residual_ell_stream",
    "stream_vmem_bytes",
    "autotune_stream",
]

BLOCK_N = 4096
N_BUFFERS = 2           # double buffering: DMA block b+1 while computing b
_LANE = 128             # 1-D window length granularity (TPU lane count)


def _interpret_default() -> bool:
    """Interpret only off-TPU; ``REPRO_PALLAS_INTERPRET=0/1`` overrides."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    if env not in ("", None):
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret: bool | None) -> bool:
    return _interpret_default() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# Broadcast-plan kernels (x replicated into VMEM per row block)
# ---------------------------------------------------------------------------

def _spmv_kernel(vals_ref, cols_ref, x_ref, out_ref):
    vals = vals_ref[...]                     # (BN, L)
    cols = cols_ref[...]                     # (BN, L)
    x = x_ref[...]                           # (N,)
    gathered = jnp.take(x, cols, axis=0)     # 1-D dynamic gather
    out_ref[...] = jnp.sum(vals * gathered, axis=1)


def _residual_kernel(vals_ref, cols_ref, x_ref, f_ref, out_ref):
    vals = vals_ref[...]
    cols = cols_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, axis=0)
    out_ref[...] = jnp.sum(vals * gathered, axis=1) - f_ref[...]


def _pad_rows(a, n_pad, fill=0):
    return jnp.pad(a, ((0, n_pad - a.shape[0]),) + ((0, 0),) * (a.ndim - 1),
                   constant_values=fill)


# static column tables staged once per (layout, block_n): int32 cast + row
# padding hoisted out of the solve loop (the id-keyed host arrays are kept
# alive by the cache entry, FIFO-bounded like the core's device mirrors)
_STAGED_COLS: dict[tuple[int, int], tuple] = {}
_STAGED_LIMIT = 128


def _staged_cols(cols, block_n: int):
    """``cols`` → (padded int32 device array, n_pad); cached for static
    (non-tracer) column tables, traced fallback otherwise."""
    n = cols.shape[0]
    n_pad = -(-n // block_n) * block_n
    if isinstance(cols, jax.core.Tracer):
        return _pad_rows(cols.astype(jnp.int32), n_pad), n_pad
    key = (id(cols), block_n)
    hit = _STAGED_COLS.get(key)
    if hit is not None:
        return hit[1], n_pad
    staged = jnp.asarray(_pad_host_cols(np.asarray(cols), n_pad))
    while len(_STAGED_COLS) >= _STAGED_LIMIT:
        _STAGED_COLS.pop(next(iter(_STAGED_COLS)))
    _STAGED_COLS[key] = (cols, staged)
    return staged, n_pad


def _pad_host_cols(cols_np: np.ndarray, n_pad: int) -> np.ndarray:
    n, l = cols_np.shape
    out = np.empty((n_pad, l), dtype=np.int32)
    out[:n] = cols_np
    # padded rows self-reference (row index < n_pad); their vals are zero
    out[n:] = np.arange(n, n_pad, dtype=np.int32)[:, None]
    return out


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def _spmv_ell_padded(vals, cols_p, x, *, interpret: bool, block_n: int):
    n, l = vals.shape
    n_pad = cols_p.shape[0]
    vals_p = _pad_rows(vals, n_pad)
    x_p = _pad_rows(x, n_pad)  # padded cols may self-reference rows ≥ n
    grid = (n_pad // block_n,)
    with annotate("tg.pallas.spmv_ell"):
        out = pl.pallas_call(
            _spmv_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((n_pad,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((n_pad,), vals.dtype),
            interpret=interpret,
        )(vals_p, cols_p, x_p)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def _residual_ell_padded(vals, cols_p, u, f, *, interpret: bool, block_n: int):
    n, l = vals.shape
    n_pad = cols_p.shape[0]
    vals_p = _pad_rows(vals, n_pad)
    u_p = _pad_rows(u, n_pad)
    f_p = jnp.pad(f, (0, n_pad - n))
    grid = (n_pad // block_n,)
    with annotate("tg.pallas.galerkin_residual_ell"):
        out = pl.pallas_call(
            _residual_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((n_pad,), lambda i: (0,)),
                pl.BlockSpec((block_n,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((n_pad,), vals.dtype),
            interpret=interpret,
        )(vals_p, cols_p, u_p, f_p)
    return out[:n]


def spmv_ell(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray, *,
             interpret: bool | None = None, block_n: int = BLOCK_N):
    """vals/cols (N, L), x (N,) → y (N,) — broadcast plan.  Padded cols must
    self-reference rows with zero vals (the ELL builder guarantees this)."""
    itp = _resolve_interpret(interpret)
    cols_p, _ = _staged_cols(cols, block_n)
    return _spmv_ell_padded(vals, cols_p, x, interpret=itp, block_n=block_n)


def galerkin_residual_ell(vals, cols, u, f, *, interpret: bool | None = None,
                          block_n: int = BLOCK_N):
    """Fused r = K·u − f (TensorPILS inner op) — broadcast plan."""
    itp = _resolve_interpret(interpret)
    cols_p, _ = _staged_cols(cols, block_n)
    return _residual_ell_padded(vals, cols_p, u, f, interpret=itp,
                                block_n=block_n)


# ---------------------------------------------------------------------------
# Streaming-plan kernels: HBM-resident operands, DMA double buffering
# ---------------------------------------------------------------------------

class _StreamPlan:
    """Static per-(layout, block_n) streaming schedule: rebased column
    blocks, per-block x-window starts and the uniform window width W."""

    __slots__ = ("cols_local", "starts", "window", "n_pad", "x_len", "_keep")

    def __init__(self, cols_np: np.ndarray, block_n: int):
        n, l = cols_np.shape
        n_blocks = -(-n // block_n)
        n_pad = n_blocks * block_n
        cols_pad = np.empty((n_pad, l), dtype=np.int64)
        cols_pad[:n] = cols_np
        if n_pad > n:
            # padded rows get in-window dummies patched below (vals are zero)
            cols_pad[n:] = cols_np[n - 1, 0]
        blocks = cols_pad.reshape(n_blocks, block_n * l)
        lo = blocks.min(axis=1)
        hi = blocks.max(axis=1)
        width = int((hi - lo + 1).max()) if n_blocks else 1
        window = -(-width // _LANE) * _LANE
        starts = lo.astype(np.int32)
        local = (cols_pad - starts.astype(np.int64).repeat(block_n)[:, None])
        self.cols_local = local.astype(np.int32)           # in [0, W)
        self.starts = starts                               # (n_blocks,)
        self.window = window                               # W
        self.n_pad = n_pad
        self.x_len = int(max(n, (starts.astype(np.int64) + window).max()
                             if n_blocks else n))
        self._keep = None  # set by the cache: pins the id-keyed key object


_STREAM_PLANS: dict[tuple[int, int], _StreamPlan] = {}
_STREAM_PLANS_LIMIT = 64


def _stream_plan(cols, block_n: int) -> _StreamPlan:
    key = (id(cols), block_n)
    hit = _STREAM_PLANS.get(key)
    if hit is not None:
        return hit
    plan = _StreamPlan(np.asarray(cols), block_n)
    plan._keep = cols  # id stays valid while the entry lives
    while len(_STREAM_PLANS) >= _STREAM_PLANS_LIMIT:
        _STREAM_PLANS.pop(next(iter(_STREAM_PLANS)))
    _STREAM_PLANS[key] = plan
    telemetry.gauge_set("ell_stream_window", plan.window, block_n=block_n)
    return plan


def stream_vmem_bytes(n_rows: int, l: int, *, block_n: int = BLOCK_N,
                      nbuf: int = N_BUFFERS, window: int | None = None,
                      itemsize: int = 8) -> int:
    """VMEM footprint of the streaming kernel (independent of N): buffered
    vals + int32 cols + x windows, plus the output staging block."""
    w = window if window is not None else block_n + _LANE
    return nbuf * (block_n * l * (itemsize + 4) + w * itemsize) \
        + block_n * itemsize


def _stream_kernel(residual: bool, nbuf: int, block_n: int, window: int,
                   n_blocks: int, l: int,
                   starts_ref, vals_hbm, cols_hbm, x_hbm, *rest):
    if residual:
        f_hbm, out_hbm, vals_buf, cols_buf, x_buf, f_buf, out_buf, \
            sem_in, sem_out = rest
    else:
        out_hbm, vals_buf, cols_buf, x_buf, out_buf, sem_in, sem_out = rest
        f_hbm = f_buf = None

    def copies(j, slot):
        row0 = j * block_n
        cps = [
            pltpu.make_async_copy(vals_hbm.at[pl.ds(row0, block_n)],
                                  vals_buf.at[slot], sem_in.at[slot, 0]),
            pltpu.make_async_copy(cols_hbm.at[pl.ds(row0, block_n)],
                                  cols_buf.at[slot], sem_in.at[slot, 1]),
            pltpu.make_async_copy(x_hbm.at[pl.ds(starts_ref[j], window)],
                                  x_buf.at[slot], sem_in.at[slot, 2]),
        ]
        if residual:
            cps.append(
                pltpu.make_async_copy(f_hbm.at[pl.ds(row0, block_n)],
                                      f_buf.at[slot], sem_in.at[slot, 3])
            )
        return cps

    # warm-up: fill the pipeline (static unroll — nbuf, n_blocks are Python)
    for j in range(min(nbuf, n_blocks)):
        for cp in copies(j, j % nbuf):
            cp.start()

    def body(b, _):
        slot = jax.lax.rem(b, nbuf)
        for cp in copies(b, slot):
            cp.wait()
        gathered = jnp.take(x_buf[slot], cols_buf[slot], axis=0)  # (BN, L)
        y = jnp.sum(vals_buf[slot] * gathered, axis=1)
        if residual:
            y = y - f_buf[slot]
        # overlap: block b's buffers are consumed above — refill the slot
        # with block b+nbuf while the store below drains
        @pl.when(b + nbuf < n_blocks)
        def _prefetch():
            for cp in copies(b + nbuf, slot):
                cp.start()
        out_buf[...] = y
        out_cp = pltpu.make_async_copy(
            out_buf, out_hbm.at[pl.ds(b * block_n, block_n)], sem_out
        )
        out_cp.start()
        out_cp.wait()  # out_buf is reused next iteration
        return 0

    jax.lax.fori_loop(0, n_blocks, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_n", "nbuf", "window"))
def _spmv_stream_padded(vals, cols_local, x, starts, *, interpret: bool,
                        block_n: int, nbuf: int, window: int):
    n, l = vals.shape
    n_pad = cols_local.shape[0]
    n_blocks = n_pad // block_n
    x_len = x.shape[0]
    vals_p = _pad_rows(vals, n_pad)
    kernel = functools.partial(_stream_kernel, False, nbuf, block_n, window,
                               n_blocks, l)
    with annotate("tg.pallas.spmv_ell_stream"):
        out = pl.pallas_call(
            kernel,
            grid=(),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),     # starts
                pl.BlockSpec(memory_space=pltpu.ANY),      # vals (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),      # cols (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),      # x    (HBM)
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
            out_shape=jax.ShapeDtypeStruct((n_pad,), vals.dtype),
            scratch_shapes=[
                pltpu.VMEM((nbuf, block_n, l), vals.dtype),
                pltpu.VMEM((nbuf, block_n, l), jnp.int32),
                pltpu.VMEM((nbuf, window), x.dtype),
                pltpu.VMEM((block_n,), vals.dtype),
                pltpu.SemaphoreType.DMA((nbuf, 3)),
                pltpu.SemaphoreType.DMA(()),
            ],
            interpret=interpret,
        )(starts, vals_p, cols_local, x)
    return out[:n]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_n", "nbuf", "window"))
def _residual_stream_padded(vals, cols_local, u, f, starts, *,
                            interpret: bool, block_n: int, nbuf: int,
                            window: int):
    n, l = vals.shape
    n_pad = cols_local.shape[0]
    n_blocks = n_pad // block_n
    vals_p = _pad_rows(vals, n_pad)
    f_p = jnp.pad(f, (0, n_pad - n))
    kernel = functools.partial(_stream_kernel, True, nbuf, block_n, window,
                               n_blocks, l)
    with annotate("tg.pallas.galerkin_residual_ell_stream"):
        out = pl.pallas_call(
            kernel,
            grid=(),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),      # f (HBM)
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
            out_shape=jax.ShapeDtypeStruct((n_pad,), vals.dtype),
            scratch_shapes=[
                pltpu.VMEM((nbuf, block_n, l), vals.dtype),
                pltpu.VMEM((nbuf, block_n, l), jnp.int32),
                pltpu.VMEM((nbuf, window), u.dtype),
                pltpu.VMEM((nbuf, block_n), f.dtype),
                pltpu.VMEM((block_n,), vals.dtype),
                pltpu.SemaphoreType.DMA((nbuf, 4)),
                pltpu.SemaphoreType.DMA(()),
            ],
            interpret=interpret,
        )(starts, vals_p, cols_local, u, f_p)
    return out[:n]


def _stream_x(x, plan: _StreamPlan):
    n = x.shape[0]
    return x if plan.x_len == n else jnp.pad(x, (0, plan.x_len - n))


def spmv_ell_stream(vals: jnp.ndarray, cols, x: jnp.ndarray, *,
                    interpret: bool | None = None, block_n: int = BLOCK_N,
                    nbuf: int = N_BUFFERS):
    """Streaming SpMV: vals/cols (N, L), x (N,) → y (N,) with every operand
    HBM-resident and VMEM usage independent of N (module docstring).
    ``cols`` must be a static (non-tracer) column table — the streaming
    schedule is a host precompute on it."""
    if isinstance(cols, jax.core.Tracer):
        raise TypeError(
            "spmv_ell_stream needs a static column table (the streaming "
            "window schedule is a host precompute); pass the ELL layout's "
            "numpy cols, or use spmv_ell for traced columns"
        )
    itp = _resolve_interpret(interpret)
    plan = _stream_plan(cols, block_n)
    return _spmv_stream_padded(
        vals, jnp.asarray(plan.cols_local), _stream_x(x, plan),
        jnp.asarray(plan.starts), interpret=itp, block_n=block_n, nbuf=nbuf,
        window=plan.window,
    )


def galerkin_residual_ell_stream(vals, cols, u, f, *,
                                 interpret: bool | None = None,
                                 block_n: int = BLOCK_N,
                                 nbuf: int = N_BUFFERS):
    """Fused streaming residual r = K·u − f (see :func:`spmv_ell_stream`)."""
    if isinstance(cols, jax.core.Tracer):
        raise TypeError(
            "galerkin_residual_ell_stream needs a static column table; use "
            "galerkin_residual_ell for traced columns"
        )
    itp = _resolve_interpret(interpret)
    plan = _stream_plan(cols, block_n)
    return _residual_stream_padded(
        vals, jnp.asarray(plan.cols_local), _stream_x(u, plan), f,
        jnp.asarray(plan.starts), interpret=itp, block_n=block_n, nbuf=nbuf,
        window=plan.window,
    )


# ---------------------------------------------------------------------------
# Autotune hook: pick (block_n, nbuf) by measurement, record via telemetry
# ---------------------------------------------------------------------------

_AUTOTUNED: dict[tuple[int, int], tuple[int, int]] = {}


def autotune_stream(vals, cols, x, *,
                    block_candidates=(1024, 4096, 8192),
                    nbuf_candidates=(2, 3),
                    interpret: bool | None = None,
                    iters: int = 3) -> tuple[int, int]:
    """Measure :func:`spmv_ell_stream` over ``block_n × nbuf`` candidates and
    return the fastest pair.  Results are cached per (layout, N) and every
    measurement lands in the telemetry registry
    (``histogram ell_stream_autotune_us`` with block_n/nbuf labels;
    ``gauge ell_stream_block_n`` / ``ell_stream_nbuf`` hold the winner) so
    tuning sweeps are inspectable offline."""
    import time

    key = (id(cols), vals.shape[0])
    hit = _AUTOTUNED.get(key)
    if hit is not None:
        return hit
    n = vals.shape[0]
    best, best_t = None, float("inf")
    for bn in block_candidates:
        if bn > max(n, _LANE):
            continue
        for nb in nbuf_candidates:
            out = spmv_ell_stream(vals, cols, x, interpret=interpret,
                                  block_n=bn, nbuf=nb)
            jax.block_until_ready(out)  # compile outside the timed loop
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(
                    spmv_ell_stream(vals, cols, x, interpret=interpret,
                                    block_n=bn, nbuf=nb)
                )
            us = (time.perf_counter() - t0) / iters * 1e6
            telemetry.histogram_observe("ell_stream_autotune_us", us,
                                        block_n=bn, nbuf=nb)
            if us < best_t:
                best, best_t = (bn, nb), us
    if best is None:
        best = (min(BLOCK_N, max(_LANE, n)), N_BUFFERS)
    telemetry.gauge_set("ell_stream_block_n", best[0])
    telemetry.gauge_set("ell_stream_nbuf", best[1])
    _AUTOTUNED[key] = best
    return best
