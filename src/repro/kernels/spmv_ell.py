"""Pallas TPU kernel: ELL SpMV (+ fused Galerkin residual).

The iterative-solver hot loop is ``y = K·x`` on the assembled operator.  FEM
meshes have bounded valence, so ELLPACK (fixed nnz/row ``L``, padded) is the
TPU-friendly layout: the row dimension rides sublanes/grid, the ``L`` slots
are a small unrolled reduction, and the only awkward op — the gather
``x[cols]`` — is a 1-D dynamic gather from a VMEM-resident ``x``.

Grid:       (ceil(N / BN),)
BlockSpecs: vals/cols (BN, L) VMEM;  x broadcast (N,) VMEM; out (BN,) VMEM.
VMEM: (2·BN·L + N + BN)·4B — for N = 1e6, L = 16, BN = 4096: ≈ 4.5 MB.
For N beyond VMEM, rows would be processed against an HBM-resident x with
explicit DMA; out of scope here (documented trade-off).

The fused variant computes ``r = K·u − f`` in the same kernel — the
TensorPILS training objective's inner op (one pass, no extra HBM round-trip).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..telemetry import annotate

__all__ = ["spmv_ell", "galerkin_residual_ell"]

BLOCK_N = 4096


def _spmv_kernel(vals_ref, cols_ref, x_ref, out_ref):
    vals = vals_ref[...]                     # (BN, L)
    cols = cols_ref[...]                     # (BN, L)
    x = x_ref[...]                           # (N,)
    gathered = jnp.take(x, cols, axis=0)     # 1-D dynamic gather
    out_ref[...] = jnp.sum(vals * gathered, axis=1)


def _residual_kernel(vals_ref, cols_ref, x_ref, f_ref, out_ref):
    vals = vals_ref[...]
    cols = cols_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, axis=0)
    out_ref[...] = jnp.sum(vals * gathered, axis=1) - f_ref[...]


def _pad_rows(a, n_pad, fill=0):
    return jnp.pad(a, ((0, n_pad - a.shape[0]),) + ((0, 0),) * (a.ndim - 1),
                   constant_values=fill)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def spmv_ell(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray, *,
             interpret: bool = True, block_n: int = BLOCK_N):
    """vals/cols (N, L), x (N,) → y (N,). Padded cols must self-reference
    rows with zero vals (the ELL builder guarantees this)."""
    n, l = vals.shape
    n_pad = -(-n // block_n) * block_n
    vals_p = _pad_rows(vals, n_pad)
    cols_p = _pad_rows(cols.astype(jnp.int32), n_pad)
    grid = (n_pad // block_n,)
    with annotate("tg.pallas.spmv_ell"):
        out = pl.pallas_call(
            _spmv_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((n,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((n_pad,), vals.dtype),
            interpret=interpret,
        )(vals_p, cols_p, x)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def galerkin_residual_ell(vals, cols, u, f, *, interpret: bool = True,
                          block_n: int = BLOCK_N):
    """Fused r = K·u − f (TensorPILS inner op)."""
    n, l = vals.shape
    n_pad = -(-n // block_n) * block_n
    vals_p = _pad_rows(vals, n_pad)
    cols_p = _pad_rows(cols.astype(jnp.int32), n_pad)
    f_p = jnp.pad(f, (0, n_pad - n))
    grid = (n_pad // block_n,)
    with annotate("tg.pallas.galerkin_residual_ell"):
        out = pl.pallas_call(
            _residual_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((n,), lambda i: (0,)),
                pl.BlockSpec((block_n,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((n_pad,), vals.dtype),
            interpret=interpret,
        )(vals_p, cols_p, u, f_p)
    return out[:n]
