"""Pallas TPU kernel: Stage-II Sparse-Reduce as a regular gather-sum.

Variable-length segment reduction is TPU-hostile; FEM gives us a bound —
each global nnz entry receives at most ``L`` local contributions (L =
max element valence of an edge/vertex pair).  At routing build time the
sorted segment layout is repacked into a padded ``(nnz, L)`` index table
(pad slots point at a zeroed sentinel), turning the Reduce into the same
lane-parallel gather+sum shape as the ELL SpMV kernel:

    vals[n] = Σ_l  vec(K_local ‖ 0)[ idx[n, l] ]

Grid: (ceil(nnz / BN),); blocks (BN, L) indices + broadcast source vector.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..telemetry import annotate

__all__ = ["build_padded_reduce", "seg_reduce"]

BLOCK_N = 4096


def build_padded_reduce(routing) -> np.ndarray:
    """(nnz, L) indices into vec(K_local) with pad → index E·k² (sentinel)."""
    n_in = routing.perm.shape[0]
    counts = np.bincount(routing.seg_ids, minlength=routing.nnz)
    l_max = int(counts.max()) if counts.size else 1
    idx = np.full((routing.nnz, l_max), n_in, dtype=np.int32)  # sentinel
    slot = np.zeros(routing.nnz, dtype=np.int64)
    for pos, seg in zip(routing.perm, routing.seg_ids):
        idx[seg, slot[seg]] = pos
        slot[seg] += 1
    return idx


def _kernel(idx_ref, src_ref, out_ref):
    idx = idx_ref[...]                   # (BN, L)
    src = src_ref[...]                   # (n_in + 1,) zero-padded source
    out_ref[...] = jnp.sum(jnp.take(src, idx, axis=0), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def seg_reduce(local_vals: jnp.ndarray, padded_idx: jnp.ndarray, *,
               interpret: bool = True, block_n: int = BLOCK_N):
    """local_vals: (E, ka, kb) or flat (E·ka·kb,) → (nnz,) global CSR vals."""
    v = local_vals.reshape(-1)
    src = jnp.concatenate([v, jnp.zeros((1,), v.dtype)])       # sentinel 0
    nnz, l = padded_idx.shape
    n_pad = -(-nnz // block_n) * block_n
    idx = jnp.pad(jnp.asarray(padded_idx, jnp.int32),
                  ((0, n_pad - nnz), (0, 0)), constant_values=v.shape[0])
    with annotate("tg.pallas.seg_reduce"):
        out = pl.pallas_call(
            _kernel,
            grid=(n_pad // block_n,),
            in_specs=[
                pl.BlockSpec((block_n, l), lambda i: (i, 0)),
                pl.BlockSpec((src.shape[0],), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((n_pad,), v.dtype),
            interpret=interpret,
        )(idx, src)
    return out[:nnz]
