"""Pallas TPU kernel: Stage-I Batch-Map for P1 simplex stiffness.

TPU adaptation of the paper's fused-einsum Map stage (DESIGN.md §2): the
GPU-natural array-of-structs ``(E, k, d)`` layout is transposed to
structure-of-arrays ``(k·d, E)`` so that the element index rides the 128-wide
*lane* dimension.  Each grid step processes a ``(k·d, BE)`` tile resident in
VMEM; the 2×2 / 3×3 Jacobian inverse (closed-form adjugate), determinant, and
the ``G Gᵀ`` contraction are all element-wise VPU ops across lanes — zero
transposes, zero MXU dependency (per-element k≤4 matrices are too small for
the systolic array; lane-parallelism is the TPU-idiomatic fusion).

Grid:      (ceil(E / BE),)
BlockSpecs: coords (k·d, BE) VMEM;  rho (1, BE) VMEM;  out (k², BE) VMEM.
BE = 2048 lanes → VMEM footprint ≈ (kd + 1 + k²)·BE·4B ≈ 210 KB (tri, f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["local_stiffness_p1_kernel", "local_stiffness_p1"]

BLOCK_E = 2048


def _tri_kernel(coords_ref, rho_ref, out_ref):
    """P1 triangle: coords rows are [x0,y0,x1,y1,x2,y2] (k·d = 6)."""
    c = coords_ref[...]
    x0, y0 = c[0], c[1]
    x1, y1 = c[2], c[3]
    x2, y2 = c[4], c[5]
    e1x, e1y = x1 - x0, y1 - y0
    e2x, e2y = x2 - x0, y2 - y0
    det = e1x * e2y - e2x * e1y
    inv_det = 1.0 / det
    # G_a = J^{-T} ĝ_a ;  J = [[e1x, e2x], [e1y, e2y]]
    g1x, g1y = e2y * inv_det, -e2x * inv_det
    g2x, g2y = -e1y * inv_det, e1x * inv_det
    g0x, g0y = -(g1x + g2x), -(g1y + g2y)
    scale = 0.5 * jnp.abs(det) * rho_ref[0]
    gx = (g0x, g1x, g2x)
    gy = (g0y, g1y, g2y)
    for a in range(3):
        for b in range(3):
            out_ref[a * 3 + b, :] = scale * (gx[a] * gx[b] + gy[a] * gy[b])


def _tet_kernel(coords_ref, rho_ref, out_ref):
    """P1 tetrahedron: coords rows [x0,y0,z0, ..., x3,y3,z3] (k·d = 12)."""
    c = coords_ref[...]
    p = [(c[3 * a], c[3 * a + 1], c[3 * a + 2]) for a in range(4)]
    # J columns = edge vectors p_a − p_0
    a1 = tuple(p[1][i] - p[0][i] for i in range(3))
    a2 = tuple(p[2][i] - p[0][i] for i in range(3))
    a3 = tuple(p[3][i] - p[0][i] for i in range(3))
    # J = [[a1x,a2x,a3x],[a1y,a2y,a3y],[a1z,a2z,a3z]]
    j = ((a1[0], a2[0], a3[0]), (a1[1], a2[1], a3[1]), (a1[2], a2[2], a3[2]))
    det = (
        j[0][0] * (j[1][1] * j[2][2] - j[1][2] * j[2][1])
        - j[0][1] * (j[1][0] * j[2][2] - j[1][2] * j[2][0])
        + j[0][2] * (j[1][0] * j[2][1] - j[1][1] * j[2][0])
    )
    inv_det = 1.0 / det
    # adjugate → J^{-1}; rows of J^{-T} are columns of J^{-1}
    adj = [
        [
            j[1][1] * j[2][2] - j[1][2] * j[2][1],
            j[0][2] * j[2][1] - j[0][1] * j[2][2],
            j[0][1] * j[1][2] - j[0][2] * j[1][1],
        ],
        [
            j[1][2] * j[2][0] - j[1][0] * j[2][2],
            j[0][0] * j[2][2] - j[0][2] * j[2][0],
            j[0][2] * j[1][0] - j[0][0] * j[1][2],
        ],
        [
            j[1][0] * j[2][1] - j[1][1] * j[2][0],
            j[0][1] * j[2][0] - j[0][0] * j[2][1],
            j[0][0] * j[1][1] - j[0][1] * j[1][0],
        ],
    ]
    # ĝ_a for a=1..3 are unit vectors: G_a = (J^{-T})·e_a = row a of J^{-1} scaled
    g = [None] * 4
    g[1] = tuple(adj[0][i] * inv_det for i in range(3))
    g[2] = tuple(adj[1][i] * inv_det for i in range(3))
    g[3] = tuple(adj[2][i] * inv_det for i in range(3))
    g[0] = tuple(-(g[1][i] + g[2][i] + g[3][i]) for i in range(3))
    scale = (1.0 / 6.0) * jnp.abs(det) * rho_ref[0]
    for a in range(4):
        for b in range(4):
            out_ref[a * 4 + b, :] = scale * (
                g[a][0] * g[b][0] + g[a][1] * g[b][1] + g[a][2] * g[b][2]
            )


@functools.partial(jax.jit, static_argnames=("interpret", "block_e"))
def local_stiffness_p1(coords: jnp.ndarray, rho: jnp.ndarray, *,
                       interpret: bool = True, block_e: int = BLOCK_E):
    """coords (E, k, d) AoS, rho (E,) → (E, k, k); dispatches on d."""
    e, k, d = coords.shape
    assert k == d + 1 and d in (2, 3)
    kernel = _tri_kernel if d == 2 else _tet_kernel

    e_pad = -(-e // block_e) * block_e
    soa = jnp.moveaxis(coords.reshape(e, k * d), 0, 1)     # (k·d, E)
    soa = jnp.pad(soa, ((0, 0), (0, e_pad - e)), constant_values=1.0)
    # padded elements: degenerate coords would give det=0 → 1/0; overwrite
    # with the identity simplex so the pad lanes stay finite.
    if e_pad != e:
        ident = jnp.moveaxis(
            jnp.concatenate(
                [jnp.zeros((1, d)), jnp.eye(d)], axis=0
            ).reshape(1, k * d).astype(coords.dtype), 0, 1,
        )
        soa = soa.at[:, e:].set(ident)
    rho_p = jnp.pad(rho, (0, e_pad - e))[None, :]           # (1, E)

    grid = (e_pad // block_e,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k * d, block_e), lambda i: (0, i)),
            pl.BlockSpec((1, block_e), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((k * k, block_e), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k * k, e_pad), coords.dtype),
        interpret=interpret,
    )(soa, rho_p)
    return jnp.moveaxis(out[:, :e], 0, 1).reshape(e, k, k)


# alias used by tests / benchmarks
local_stiffness_p1_kernel = local_stiffness_p1
