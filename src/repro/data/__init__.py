from .pipeline import SyntheticLMData  # noqa: F401
