"""Token data pipeline.

Production shape: a host-side iterator that yields globally-sharded device
arrays (each host feeds only its addressable shards —
``jax.make_array_from_process_local_data``) with double-buffered prefetch.
Here (single host) the same code path degenerates gracefully.

The iterator state (rng counter) is part of the checkpoint, so restarts are
bitwise-reproducible (fault-tolerance requirement).

Synthetic corpus: a mixture of Zipfian unigram draws and repeated n-gram
motifs — enough signal for a real loss to fall during the example training
runs without shipping a dataset.
"""

from __future__ import annotations

import dataclasses
import threading
import queue

import jax
import numpy as np

__all__ = ["SyntheticLMData"]


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 64
    prefetch: int = 2

    def __post_init__(self):
        self._step = 0
        rng = np.random.default_rng(self.seed)
        self._motifs = rng.integers(
            1, self.vocab_size, size=(self.n_motifs, self.motif_len)
        )
        self._queue: queue.Queue | None = None

    # -- checkpointable state --------------------------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: dict):
        self._step = int(state["step"])

    # -- batch synthesis ---------------------------------------------------------
    def _make_batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        ranks = rng.zipf(self.zipf_a, size=(b, s + 1))
        tokens = np.minimum(ranks, self.vocab_size - 1).astype(np.int32)
        # splice motifs for learnable structure
        n_splice = max(1, s // (4 * self.motif_len))
        for bi in range(b):
            for _ in range(n_splice):
                m = self._motifs[rng.integers(self.n_motifs)]
                at = rng.integers(0, s + 1 - self.motif_len)
                tokens[bi, at : at + self.motif_len] = m
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self._make_batch(self._step)
        self._step += 1
        return batch

    # -- device placement ----------------------------------------------------------
    def sharded_iterator(self, shardings: dict):
        """Yield device arrays placed per the given shardings, with a
        background prefetch thread (overlaps host synthesis with step time)."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = object()

        def worker():
            while True:
                host = next(self)
                dev = {
                    k: jax.device_put(v, shardings[k]) for k, v in host.items()
                }
                q.put(dev)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            yield q.get()
