"""Client helpers: request factories and a synthetic open-loop load driver.

Open-loop means arrivals do NOT wait for completions — requests arrive on a
Poisson process at a fixed offered rate, exactly the regime where admission
batching pays: a loaded service sees many compatible requests inside one
window and answers them with one vmapped executable.  (A closed-loop driver
would serialize and never expose the batching win.)

The report reads its latency percentiles from the telemetry histograms the
*service* recorded (``serve_e2e_us`` / ``serve_queue_wait_us``) — the
client adds no timing machinery of its own.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .. import telemetry
from .batching import PendingSolve, SolveRequest

__all__ = ["LoadReport", "open_loop_load", "poisson_requests"]


_WORKLOADS: dict = {}


def _poisson_workload(resolution: int):
    """The shared (plan, bc, rhs) of the canonical Poisson workload, built
    once per resolution: request *waves* must share the plan identity or
    they would never be admission-compatible (plans enter the key by
    identity, like every core jit cache)."""
    if resolution not in _WORKLOADS:
        from ..core import (
            DirichletCondenser,
            FunctionSpace,
            assemble_rhs,
            build_plan,
            unit_square_tri,
            weakform as wf,
        )
        from ..core.mesh import element_for_mesh

        mesh = unit_square_tri(resolution)
        space = FunctionSpace(mesh, element_for_mesh(mesh, 1))
        plan = build_plan(space)
        bc = DirichletCondenser(plan.static.mat_routing, space.boundary_dofs())
        rhs = assemble_rhs(plan, wf.source(1.0))
        _WORKLOADS[resolution] = (plan, bc, rhs)
    return _WORKLOADS[resolution]


def poisson_requests(*, n_requests: int = 16, resolution: int = 16,
                     backend: str = "csr", spec=None, method: str | None = None,
                     tol: float | None = None, timeout: float | None = None,
                     seed: int = 0,
                     coeff_range=(0.5, 2.0)) -> list[SolveRequest]:
    """A family of heterogeneous-coefficient Poisson requests on ONE shared
    plan — the canonical compatible workload: −∇·(ρ_i ∇u) = f with a
    per-request piecewise-constant ρ_i and shared homogeneous Dirichlet
    boundary.  All requests of a resolution carry the same admission key
    (the plan/bc are process-cached), so the service batches them into a
    single executable and later waves hit the same cache entries."""
    from ..core import weakform as wf

    plan, bc, rhs = _poisson_workload(resolution)
    n_elems = plan.static.scalar_cell_dofs.shape[0]
    rng = np.random.default_rng(seed)
    lo, hi = coeff_range
    return [
        SolveRequest(
            plan=plan,
            form=wf.diffusion(rng.uniform(lo, hi, size=n_elems)),
            rhs=rhs, bc=bc, backend=backend, spec=spec, method=method,
            tol=tol, timeout=timeout,
        )
        for _ in range(n_requests)
    ]


@dataclasses.dataclass
class LoadReport:
    """Outcome of one open-loop run.  Percentiles come from the service's
    telemetry histograms; counts from the resolved responses."""

    offered: int
    ok: int
    shed: int
    expired: int
    nonconverged: int
    failed: int
    duration_s: float
    e2e_p50_us: float
    e2e_p99_us: float
    queue_wait_p50_us: float
    batch_size_mean: float
    cache_hit_rate: float
    queue_depth_max: float = float("nan")
    # median over answered requests of (Σ top-level span segment walls) /
    # (t_done - t_submit): ≈1.0 when the span trees account for the full
    # request lifetime; NaN with telemetry off (no traces carried)
    span_coverage: float = float("nan")

    @property
    def throughput(self) -> float:
        return self.ok / self.duration_s if self.duration_s > 0 else 0.0


def _hist(snap: dict, name: str, field: str, default=float("nan")) -> float:
    """One field of a telemetry histogram summary, merged over label
    variants (the service labels by backend)."""
    vals, counts = [], []
    for key, s in snap["histograms"].items():
        if key == name or key.startswith(name + "{"):
            vals.append(s[field])
            counts.append(s["count"])
    if not vals:
        return default
    if field in ("count", "sum"):
        return sum(vals)
    # weighted merge is overkill for a report: take the largest population
    return vals[int(np.argmax(counts))]


def open_loop_load(service, requests, *, rate: float,
                   seed: int = 0) -> LoadReport:
    """Drive ``service`` with ``requests`` arriving as a Poisson process of
    ``rate`` requests/second (exponential inter-arrivals), then wait for
    every response.  Telemetry must be enabled for the percentile fields —
    with it disabled they come back NaN and only the counts are filled."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(requests))
    t0 = time.monotonic()
    pendings: list[PendingSolve] = []
    for req, gap in zip(requests, gaps):
        time.sleep(gap)
        pendings.append(service.submit(req))
    responses = [p.response() for p in pendings]
    duration = time.monotonic() - t0

    by_status: dict[str, int] = {}
    for r in responses:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    snap = telemetry.snapshot() if telemetry.is_enabled() else {
        "histograms": {}, "counters": {}, "gauges": {}}
    coverages = [
        sum(r.span_segments_us.values()) / (1e6 * r.e2e_s)
        for r in responses
        if r.trace and r.span_segments_us and r.e2e_s > 0
    ]
    coverage = float(np.median(coverages)) if coverages else float("nan")
    return LoadReport(
        offered=len(requests),
        ok=by_status.get("ok", 0),
        shed=by_status.get("overloaded", 0),
        expired=by_status.get("expired", 0),
        nonconverged=by_status.get("nonconverged", 0),
        failed=by_status.get("failed", 0),
        duration_s=duration,
        e2e_p50_us=_hist(snap, "serve_e2e_us", "p50"),
        e2e_p99_us=_hist(snap, "serve_e2e_us", "p99"),
        queue_wait_p50_us=_hist(snap, "serve_queue_wait_us", "p50"),
        batch_size_mean=_hist(snap, "serve_batch_size", "mean"),
        cache_hit_rate=service.cache.hit_rate(),
        queue_depth_max=_hist(snap, "serve_queue_depth", "max"),
        span_coverage=coverage,
    )
