"""`SolveService` — the multi-tenant batched PDE solve front-end.

Request lifecycle::

    submit() ──▶ admission queue ──▶ [window] ──▶ group by admission key
       │              │                               │
       │ queue full   │ deadline passed               ▼
       ▼              ▼                      pad to bucket, fetch/compile
    "overloaded"   "expired"                 executable, ONE vmapped solve
                                                      │
                                                      ▼
                                        per-request slice → PendingSolve

The admission window is open-ended batching: the dispatch worker wakes on
the first queued request, sleeps ``window`` seconds while compatible
requests accumulate, then drains the queue grouped by
:func:`~repro.serve.batching.admission_key` — each group becomes one
:class:`~repro.core.sparse.BatchedCSR` assembly+solve or one
:class:`~repro.core.operator.MatFreeFamily` solve, padded to a power-of-two
bucket so wave-to-wave size jitter never recompiles.

All accounting goes through :mod:`repro.telemetry` — no timing machinery of
its own:

* ``serve_queue_wait_us`` / ``serve_e2e_us`` histograms (p50/p90/p99 via
  ``telemetry.snapshot()``; the SLO gate reads these),
* ``serve_batch_size`` histogram,
* ``serve_requests{outcome=...}`` counters (ok / shed / expired /
  nonconverged),
* ``cache_lookups{kind=serve_exec}`` + ``jit_traces{kind=serve}`` — the
  executable-cache hit rate and the zero-retrace-after-warmup proof,
* ``record_solve("serve.dispatch", ...)`` — Krylov iteration stats and
  solve wall time per dispatched batch,
* ``serve_queue_depth`` gauge + histogram — admission depth sampled at
  every drain (separates overload from a slow executable),
* **span trees** — every request gets a root span at :meth:`submit`
  (trace-ID minted there) with ``queue_wait`` / ``dispatch`` / ``solve`` /
  ``slice`` children summing exactly to its end-to-end latency; the tree
  rides back on ``SolveResponse.trace`` and every completed request is
  recorded in the :mod:`~repro.telemetry.spans` flight recorder, which
  auto-dumps on shed / expiry / non-convergence / failure.

Non-converged solves follow the PR-5 policy
(``telemetry.nonconverged_policy()``): ``"warn"`` answers ``"ok"`` with a
:class:`~repro.telemetry.ConvergenceWarning`; ``"raise"`` answers
``"nonconverged"`` with a typed :class:`~repro.serve.batching.NonConverged`
error on exactly the requests whose instance hit ``maxiter``; ``"ignore"``
stays silent.
"""

from __future__ import annotations

import threading
import time
import warnings
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from .. import telemetry
from ..telemetry.events import ConvergenceWarning
from .batching import (
    DeadlineExpired,
    NonConverged,
    Overloaded,
    PendingSolve,
    SolveRequest,
    SolveResponse,
    admission_key,
    pad_bucket,
)
from .cache import ExecutableCache

__all__ = ["SolveService"]


class SolveService:
    """Admission-batched solve service over one or more assembly plans.

    ``window``: seconds the dispatcher waits after the first queued request
    before draining (the batching window — higher amortizes better, costs
    p50 latency).  ``max_batch`` bounds one dispatched family;
    ``queue_limit`` bounds the admission queue (submissions beyond it are
    shed with an ``"overloaded"`` response).  ``cache_capacity`` sizes the
    unpinned part of the executable cache.

    Use as a context manager (starts/stops the dispatch thread), or leave
    it unstarted and call :meth:`drain` for synchronous, deterministic
    dispatch (tests, batch jobs).
    """

    def __init__(self, *, window: float = 0.002, max_batch: int = 64,
                 queue_limit: int = 1024, cache_capacity: int = 32):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.window = float(window)
        self.max_batch = int(max_batch)
        self.queue_limit = int(queue_limit)
        self.cache = ExecutableCache(cache_capacity)
        self._queue: list[tuple[PendingSolve, float, float | None]] = []
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._worker: threading.Thread | None = None
        self._stopping = False

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "SolveService":
        """Start the dispatch thread (idempotent).  Requests submitted
        before ``start()`` sit in the queue and dispatch on the first
        window after it."""
        with self._lock:
            if self._worker is not None:
                return self
            self._stopping = False
            self._worker = threading.Thread(
                target=self._worker_loop, name="repro-serve-dispatch",
                daemon=True)
        self._worker.start()
        return self

    def stop(self) -> None:
        """Drain the queue, then stop the dispatch thread."""
        with self._lock:
            worker, self._worker = self._worker, None
            self._stopping = True
            self._wake.notify_all()
        if worker is not None:
            worker.join()
        self.drain()

    def __enter__(self) -> "SolveService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission ---------------------------------------------------------
    def submit(self, request: SolveRequest) -> PendingSolve:
        """Admit one request.  Returns immediately with a
        :class:`PendingSolve`; if the admission queue is full the future is
        already resolved with an ``"overloaded"`` response (typed
        :class:`Overloaded` error from ``result()``) — overload is shed, not
        queued."""
        now_ns = time.monotonic_ns()
        now = now_ns / 1e9
        pending = PendingSolve(request)
        # root of the request's span tree: trace_id minted here, carried to
        # the response via the dispatch path (NULL_SPAN when telemetry off)
        pending.span = telemetry.span_root(
            "serve.request", start_ns=now_ns,
            request_id=request.request_id, backend=request.backend,
            method=request.spec.method)
        deadline = None if request.timeout is None else now + request.timeout
        with self._lock:
            if len(self._queue) >= self.queue_limit:
                telemetry.counter_inc("serve_requests", outcome="shed")
                root = pending.span.finish(end_ns=now_ns, outcome="shed")
                telemetry.flight_record(
                    root, outcome="shed", request_id=request.request_id,
                    backend=request.backend, queue_limit=self.queue_limit)
                telemetry.flight_autodump("shed")
                pending._resolve(SolveResponse(
                    status="overloaded",
                    error=Overloaded(
                        f"admission queue full ({self.queue_limit} pending)"),
                    t_submit=now, t_dispatch=now, t_done=now,
                    trace=root.to_dict(),
                ))
                return pending
            self._queue.append((pending, now, deadline))
            self._wake.notify_all()
        return pending

    def solve(self, request: SolveRequest, timeout: float | None = None):
        """Convenience synchronous path: submit and wait.  With no worker
        running the queue is drained inline."""
        pending = self.submit(request)
        if self._worker is None and not pending.done():
            self.drain()
        return pending.result(timeout)

    # -- dispatch ----------------------------------------------------------
    def drain(self) -> int:
        """Synchronously dispatch everything queued right now (no window
        wait).  Returns the number of requests answered — the deterministic
        path used by tests and by :meth:`stop`."""
        with self._lock:
            batch, self._queue = self._queue, []
        self._sample_queue_depth(len(batch))
        return self._dispatch(batch)

    def _sample_queue_depth(self, depth: int) -> None:
        """Admission queue depth at drain time — the gauge that separates
        'the service is loaded' (depth grows) from 'one executable is slow'
        (depth normal, queue-wait p99 grows)."""
        telemetry.gauge_set("serve_queue_depth", depth)
        telemetry.histogram_observe("serve_queue_depth", depth)

    def _worker_loop(self) -> None:
        while True:
            with self._lock:
                while not self._queue and not self._stopping:
                    self._wake.wait()
                if self._stopping:
                    return
            # open the admission window: compatible requests accumulate
            if self.window > 0:
                time.sleep(self.window)
            with self._lock:
                batch, self._queue = self._queue, []
            self._sample_queue_depth(len(batch))
            self._dispatch(batch)

    def _dispatch(self, entries) -> int:
        """Group → pad → run → slice → resolve.  ``entries`` are
        ``(pending, t_submit, deadline)`` triples."""
        if not entries:
            return 0
        now_ns = time.monotonic_ns()
        now = now_ns / 1e9
        groups: OrderedDict = OrderedDict()
        n_done = 0
        for pending, t_submit, deadline in entries:
            if deadline is not None and now > deadline:
                telemetry.counter_inc("serve_requests", outcome="expired")
                root = pending.span
                root.child("queue_wait",
                           start_ns=root.start_ns).finish(end_ns=now_ns)
                root.finish(end_ns=now_ns, outcome="expired")
                telemetry.flight_record(
                    root, outcome="expired",
                    request_id=pending.request.request_id,
                    backend=pending.request.backend,
                    waited_s=round(now - t_submit, 4))
                telemetry.flight_autodump("expired")
                pending._resolve(SolveResponse(
                    status="expired",
                    error=DeadlineExpired(
                        f"request {pending.request.request_id} expired after "
                        f"{now - t_submit:.3f}s in the admission queue"),
                    t_submit=t_submit, t_dispatch=now, t_done=now,
                    trace=root.to_dict(),
                ))
                n_done += 1
                continue
            key = admission_key(pending.request)
            groups.setdefault(key, []).append((pending, t_submit))
        for key, members in groups.items():
            for start in range(0, len(members), self.max_batch):
                chunk = members[start:start + self.max_batch]
                self._run_group(key, chunk)
                n_done += len(chunk)
        return n_done

    def _run_group(self, key, members) -> None:
        pendings = [p for p, _ in members]
        submits = [t for _, t in members]
        template = pendings[0].request
        b = len(pendings)
        padded = min(pad_bucket(b), self.max_batch)
        t_dispatch_ns = time.monotonic_ns()
        t_dispatch = t_dispatch_ns / 1e9
        roots = [p.span for p in pendings]
        # segment 1: queue_wait — submit (the root's start) → dispatch
        for t, root in zip(submits, roots):
            telemetry.histogram_observe(
                "serve_queue_wait_us", 1e6 * (t_dispatch - t),
                backend=template.backend)
            root.child("queue_wait",
                       start_ns=root.start_ns).finish(end_ns=t_dispatch_ns)
        telemetry.histogram_observe("serve_batch_size", b,
                                    backend=template.backend)
        try:
            fn, cache_hit = self.cache.get(key, padded, template)
            t_lookup_ns = time.monotonic_ns()
            leaves = tuple(
                _stack_padded([p.request.leaves[j] for p in pendings], padded)
                for j in range(len(template.leaves))
            )
            rhs = _stack_padded([p.request.rhs for p in pendings], padded)
            t_solve_ns = time.monotonic_ns()
            # segment 2: dispatch — cache lookup + pad/stack to the bucket
            # (the batch-level walls are duplicated into every member's
            # tree: each response carries its complete timeline)
            for root in roots:
                d = root.child("dispatch", start_ns=t_dispatch_ns,
                               batch=b, padded=padded, cache_hit=cache_hit)
                d.child("cache_lookup",
                        start_ns=t_dispatch_ns).finish(end_ns=t_lookup_ns)
                d.child("pad",
                        start_ns=t_lookup_ns).finish(end_ns=t_solve_ns)
                d.finish(end_ns=t_solve_ns)
            x_pad, info_pad = fn(template.plan, leaves, rhs)
            x = np.asarray(x_pad)[:b]
            converged = np.asarray(info_pad.converged)[:b]
            iters = np.asarray(info_pad.iters)[:b]
            residual = np.asarray(info_pad.residual)[:b]
            # segment 3: solve — the vmapped device solve incl. the host
            # transfer that synchronizes on it (compiled on a cache miss)
            t_solved_ns = time.monotonic_ns()
            for root in roots:
                root.child("solve", start_ns=t_solve_ns,
                           compiled=not cache_hit).finish(end_ns=t_solved_ns)
        except Exception as err:  # compile/solve failure → fail the batch
            t_done_ns = time.monotonic_ns()
            t_done = t_done_ns / 1e9
            telemetry.counter_inc("serve_requests", value=b, outcome="failed")
            for (p, t), root in zip(members, roots):
                root.finish(end_ns=t_done_ns, outcome="failed",
                            error=type(err).__name__)
                telemetry.flight_record(
                    root, outcome="failed", request_id=p.request.request_id,
                    admission=_key_tag(key), bucket=padded, batch=b,
                    error=repr(err))
                p._resolve(SolveResponse(
                    status="failed", error=err, batch_size=b,
                    t_submit=t, t_dispatch=t_dispatch, t_done=t_done,
                    trace=root.to_dict()))
            telemetry.flight_autodump("failed")
            return
        info_b = jax.tree_util.tree_map(
            lambda leaf: np.asarray(leaf)[:b], info_pad)
        t_done_ns = time.monotonic_ns()
        t_done = t_done_ns / 1e9
        telemetry.record_solve(
            "serve.dispatch", info_b, method=template.spec.method,
            precond=template.spec.precond_name,
            backend=template.backend,
            wall_us=1e-3 * (t_done_ns - t_dispatch_ns),
            batch=b, padded=padded, cache_hit=cache_hit)
        policy = telemetry.nonconverged_policy()
        any_nonconverged = False
        for i, (p, t) in enumerate(members):
            root = roots[i]
            # segment 4: slice — per-request extraction from the padded
            # batch; ends at t_done, so the four segments sum exactly to
            # the response's end-to-end latency (t_done - t_submit)
            root.child("slice", start_ns=t_solved_ns).finish(end_ns=t_done_ns)
            resp = SolveResponse(
                status="ok", u=jnp.asarray(x[i]),
                info=jax.tree_util.tree_map(lambda leaf: leaf[i], info_b),
                batch_size=b, cache_hit=cache_hit,
                t_submit=t, t_dispatch=t_dispatch, t_done=t_done,
            )
            if not converged[i]:
                msg = (f"request {p.request.request_id}: solve not converged "
                       f"after {int(iters[i])} iterations "
                       f"(residual {float(residual[i]):.3e})")
                if policy == "raise":
                    resp.status = "nonconverged"
                    resp.error = NonConverged(msg)
                    resp.u = None
                    telemetry.counter_inc("serve_requests",
                                          outcome="nonconverged")
                    any_nonconverged = True
                else:
                    if policy == "warn":
                        warnings.warn(msg, ConvergenceWarning, stacklevel=2)
                    telemetry.counter_inc("serve_requests", outcome="ok")
            else:
                telemetry.counter_inc("serve_requests", outcome="ok")
            telemetry.histogram_observe(
                "serve_e2e_us", 1e6 * (t_done - t),
                backend=template.backend)
            root.finish(end_ns=t_done_ns, outcome=resp.status,
                        converged=bool(converged[i]), iters=int(iters[i]))
            resp.trace = root.to_dict()
            telemetry.flight_record(
                root, outcome=resp.status,
                request_id=p.request.request_id, admission=_key_tag(key),
                bucket=padded, batch=b, backend=template.backend,
                cache_hit=cache_hit, iterations=int(iters[i]),
                final_residual=float(residual[i]),
                converged=bool(converged[i]))
            p._resolve(resp)
        if any_nonconverged:
            telemetry.flight_autodump("nonconverged")

    # -- warmup ------------------------------------------------------------
    def warmup(self, request: SolveRequest, batch_sizes=(1,),
               pin: bool = True) -> None:
        """Pre-compile (and optionally pin) the executables a production
        signature needs: one padded-bucket executable per entry of
        ``batch_sizes``.  The request's coefficient values are only a
        template — warmup runs real (cold) solves on copies of it so the
        first tenant wave is a pure cache hit."""
        key = admission_key(request)
        with telemetry.span("serve.warmup", backend=request.backend,
                            buckets=len(tuple(batch_sizes))):
            for bs in batch_sizes:
                padded = min(pad_bucket(int(bs)), self.max_batch)
                if pin:
                    self.cache.pin(key, padded)
                fn, hit = self.cache.get(key, padded, request)
                if not hit:
                    leaves = tuple(
                        _stack_padded([request.leaves[j]], padded)
                        for j in range(len(request.leaves))
                    )
                    rhs = _stack_padded([request.rhs], padded)
                    x, _ = fn(request.plan, leaves, rhs)
                    jax.block_until_ready(x)


def _key_tag(key) -> str:
    """Short printable admission-key tag for flight-recorder context (the
    raw key holds object ids and a lowered form signature — not JSON)."""
    plan_id, _form, _bc, backend, spec = key
    return (f"plan={plan_id & 0xFFFFFFFF:08x};backend={backend};"
            f"method={spec.method}")


def _stack_padded(arrays, padded: int) -> jnp.ndarray:
    """Stack per-request arrays to ``(padded, ...)``, repeating the last
    entry into the padding rows (padding solves then converge like real
    ones instead of iterating on garbage)."""
    out = jnp.stack([jnp.asarray(a) for a in arrays])
    if out.shape[0] < padded:
        reps = jnp.broadcast_to(
            out[-1], (padded - out.shape[0],) + out.shape[1:])
        out = jnp.concatenate([out, reps], axis=0)
    return out
