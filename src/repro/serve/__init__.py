"""repro.serve — multi-tenant batched PDE solve service.

The serving tier over the TensorGalerkin core: one-shot ``.solve()`` calls
become admitted requests that an admission batcher groups — same
``(PlanStatic, form signature, backend)`` within a configurable window —
into ONE vmapped family solve (:class:`~repro.core.sparse.BatchedCSR`
assembly+solve or a :class:`~repro.core.operator.MatFreeFamily`), served
from a persistent executable cache with warmup/pinning and LRU eviction.

Module map
----------
* :mod:`~repro.serve.batching` — :class:`SolveRequest` /
  :class:`SolveResponse` / :class:`PendingSolve`, admission-compatibility
  keys, power-of-two padding buckets, the typed error family
  (:class:`Overloaded`, :class:`DeadlineExpired`, :class:`NonConverged`).
* :mod:`~repro.serve.cache` — :class:`ExecutableCache`: per-entry jitted
  batched-solve closures (eviction really frees the executable), pinning.
* :mod:`~repro.serve.service` — :class:`SolveService`: bounded admission
  queue, dispatch worker, deadline/shedding/non-convergence policies, all
  accounting through :mod:`repro.telemetry`.
* :mod:`~repro.serve.client` — request factories and the synthetic
  open-loop (Poisson-arrival) load driver + :class:`LoadReport`.

Quick start::

    from repro import serve, telemetry
    telemetry.enable()
    reqs = serve.poisson_requests(n_requests=16, backend="csr")
    with serve.SolveService(window=0.002) as svc:
        svc.warmup(reqs[0], batch_sizes=(16,))
        report = serve.open_loop_load(svc, reqs, rate=2000.0)
    print(report.e2e_p99_us, report.cache_hit_rate)
"""

from .batching import (  # noqa: F401
    DeadlineExpired,
    NonConverged,
    Overloaded,
    PendingSolve,
    SolveRequest,
    SolveResponse,
    admission_key,
    pad_bucket,
)
from .cache import ExecutableCache  # noqa: F401
from .client import LoadReport, open_loop_load, poisson_requests  # noqa: F401
from .service import SolveService  # noqa: F401

__all__ = [
    "SolveService",
    "SolveRequest",
    "SolveResponse",
    "PendingSolve",
    "ExecutableCache",
    "Overloaded",
    "DeadlineExpired",
    "NonConverged",
    "admission_key",
    "pad_bucket",
    "LoadReport",
    "open_loop_load",
    "poisson_requests",
]
