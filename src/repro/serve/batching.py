"""Admission-batching data model: requests, responses, compatibility keys.

A :class:`SolveRequest` is one tenant's PDE solve: a plan reference, a
:class:`~repro.core.weakform.WeakForm` (whose traced leaves carry the
tenant's coefficients), an assembled RHS vector, an optional Dirichlet
condenser, and solve/QoS knobs.  Two requests are *compatible* — batchable
into one vmapped executable — exactly when they share the admission key

    (plan.static identity, lowered form signature, bc identity,
     backend, SolverSpec)

i.e. the same jit signature the core assembly/operator caches key on: only
the coefficient leaf *values* and the RHS differ across a batch, so B
compatible requests run as ONE :class:`~repro.core.sparse.BatchedCSR`
assembly+solve or one :class:`~repro.core.operator.MatFreeFamily` solve.

The response side is deliberately boring: a :class:`PendingSolve` is a
minimal future (threading.Event + slot) resolved by the service worker with
a :class:`SolveResponse` whose ``status`` is one of ``"ok"``,
``"overloaded"`` (shed at admission), ``"expired"`` (deadline passed before
dispatch) or ``"nonconverged"`` (Krylov maxiter exit under the
``on_nonconverged="raise"`` policy).  ``result()`` raises the typed error;
``response()`` never raises.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any

import jax.numpy as jnp

from ..core import weakform
from ..core.solvers import SolverSpec, resolve_solver_spec
from ..telemetry.spans import NULL_SPAN

__all__ = [
    "SolveRequest",
    "SolveResponse",
    "PendingSolve",
    "Overloaded",
    "DeadlineExpired",
    "NonConverged",
    "admission_key",
    "pad_bucket",
]

_REQUEST_IDS = itertools.count()


class Overloaded(RuntimeError):
    """Request shed at admission: the bounded queue was full."""


class DeadlineExpired(TimeoutError):
    """Request expired in the admission queue before dispatch."""


class NonConverged(RuntimeError):
    """The request's Krylov solve exited at ``maxiter`` and the service
    runs under the ``on_nonconverged="raise"`` policy."""


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One tenant solve: ``A(form) u = rhs`` on ``plan``, condensed by ``bc``.

    ``form``'s traced leaves are the tenant's coefficient values; ``rhs`` is
    the *assembled* load vector ``(n,)`` (use ``assemble_rhs(plan,
    wf.source(f))``).  Dirichlet conditions are homogeneous (condensation
    masks the RHS); ``timeout`` is the seconds the request may wait in the
    admission queue before it is answered ``"expired"`` instead of solved.
    """

    plan: Any                      # AssemblyPlan (shared across a batch)
    form: Any                      # WeakForm — per-tenant coefficient leaves
    rhs: jnp.ndarray               # assembled (n,) load vector
    bc: Any = None                 # DirichletCondenser | None (homogeneous)
    backend: str = "csr"           # "csr" | "matfree"
    spec: SolverSpec | None = None  # Krylov config; part of the admission key
    method: str | None = None      # deprecated → spec.method
    tol: float | None = None       # deprecated → spec.tol (and atol)
    maxiter: int | None = None     # deprecated → spec.maxiter
    timeout: float | None = None   # admission-queue deadline [s]
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQUEST_IDS))

    def __post_init__(self):
        if self.backend not in ("csr", "matfree"):
            raise ValueError(
                f"unknown backend {self.backend!r}: expected 'csr' or 'matfree'"
            )
        # fold legacy per-field knobs into one hashable SolverSpec (the
        # admission key carries the spec object, so every solver knob —
        # including precond — separates compatibility classes)
        spec = resolve_solver_spec(
            self.spec, method=self.method, tol=self.tol, atol=self.tol,
            maxiter=self.maxiter,
            default=SolverSpec(method="cg", tol=1e-10, atol=1e-10,
                               maxiter=10000),
            where="SolveRequest")
        object.__setattr__(self, "spec", spec)
        object.__setattr__(self, "method", spec.method)
        object.__setattr__(self, "tol", spec.tol)
        object.__setattr__(self, "maxiter", spec.maxiter)
        form_sig, leaves = weakform.lower(self.form, weakform.MATRIX)
        object.__setattr__(self, "_form_sig", form_sig)
        object.__setattr__(
            self, "_leaves", tuple(jnp.asarray(lf) for lf in leaves))

    @property
    def form_sig(self):
        """The lowered (hashable) form signature — the batching key part."""
        return self._form_sig

    @property
    def leaves(self) -> tuple:
        """The traced coefficient leaves, in lowering slot order."""
        return self._leaves


@dataclasses.dataclass
class SolveResponse:
    """What a :class:`PendingSolve` resolves to.  ``u``/``info`` are set for
    ``status == "ok"`` (and ``"nonconverged"``); ``error`` carries the typed
    exception otherwise.  Timestamps are ``time.monotonic()`` seconds (the
    service's clock) so clients can cross-check the telemetry histograms."""

    status: str                    # "ok" | "overloaded" | "expired" | "nonconverged"
    u: jnp.ndarray | None = None
    info: Any = None               # per-request SolveInfo slice
    error: Exception | None = None
    batch_size: int = 0            # admission batch the request rode in
    cache_hit: bool | None = None  # executable-cache outcome of that batch
    t_submit: float = 0.0
    t_dispatch: float = 0.0
    t_done: float = 0.0
    trace: dict | None = None      # span tree (telemetry on) — see spans.py

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def span_segments_us(self) -> dict:
        """Top-level segment walls (µs) of the carried span tree — e.g.
        ``{"queue_wait": ..., "dispatch": ..., "solve": ..., "slice": ...}``
        summing to the end-to-end latency.  Empty without telemetry."""
        if not self.trace:
            return {}
        return {
            c["name"]: c["wall_us"]
            for c in self.trace.get("children", ())
            if c.get("wall_us") is not None
        }

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.t_dispatch - self.t_submit)

    @property
    def e2e_s(self) -> float:
        return max(0.0, self.t_done - self.t_submit)


class PendingSolve:
    """A minimal future for one submitted request."""

    def __init__(self, request: SolveRequest):
        self.request = request
        # the request's root span, set by SolveService.submit() when
        # telemetry is on (NULL_SPAN otherwise: every span call is a no-op)
        self.span = NULL_SPAN
        self._event = threading.Event()
        self._response: SolveResponse | None = None

    def _resolve(self, response: SolveResponse) -> None:
        self._response = response
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def response(self, timeout: float | None = None) -> SolveResponse:
        """Block until the service answers; never raises on error statuses."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id} not answered within "
                f"{timeout}s"
            )
        return self._response

    def result(self, timeout: float | None = None) -> jnp.ndarray:
        """The solution vector; raises the typed error on non-``ok`` statuses
        (:class:`Overloaded` / :class:`DeadlineExpired` /
        :class:`NonConverged`)."""
        resp = self.response(timeout)
        if resp.error is not None:
            raise resp.error
        return resp.u


def admission_key(req: SolveRequest) -> tuple:
    """The compatibility key: requests with equal keys batch into one
    executable.  Plan and condenser enter by *identity* (same convention as
    the core jit caches — ``PlanStatic`` is identity-hashed); the frozen
    :class:`~repro.core.SolverSpec` enters by value, so every solver knob
    (method, tolerances, preconditioner) separates compatibility classes."""
    return (
        id(req.plan.static),
        req.form_sig,
        id(req.bc) if req.bc is not None else None,
        req.backend,
        req.spec,
    )


def pad_bucket(b: int) -> int:
    """Round a batch size up to the next power of two.  Padding admission
    batches to bucket sizes keeps the executable cache small and stable:
    waves of 9, 13 and 16 requests all reuse the B=16 executable instead of
    compiling three."""
    if b < 1:
        raise ValueError(f"batch size must be >= 1, got {b}")
    return 1 << (b - 1).bit_length()
