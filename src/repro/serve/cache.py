"""Persistent executable cache for the solve service.

One cache entry is one *independently jitted* batched-solve closure keyed on
``(admission key, padded batch size)``.  ``jax.jit`` is applied per entry
(not at module level), so evicting an entry really drops its compiled
executable — the global module-level jit caches the core uses would keep
every signature alive forever, which is the wrong lifetime for a
multi-tenant service where old plans come and go.

Entries survive across requests and waves (that's the point: after a warmup
wave every subsequent wave is a pure cache hit — zero retraces, verified by
the ``jit_traces{kind=serve}`` telemetry counters).  ``pin()``-ed entries
(e.g. from :meth:`~repro.serve.service.SolveService.warmup`) are exempt
from LRU eviction.

Every lookup is accounted through ``telemetry.count_cache("serve_exec",
hit)`` and every (re)compilation bumps the trace counter via
``telemetry.count_trace("serve", static, spec, backend=...)`` inside the
traced body — the exact counters the serving SLO gate reads.
"""

from __future__ import annotations

import time
from collections import OrderedDict

import jax

from .. import telemetry
from ..core.operator import matfree_family
from ..core.solvers import matfree_solve_batched, sparse_solve_batched

__all__ = ["ExecutableCache"]


def _entry_tag(full_key) -> str:
    """Stable short label for one cache entry's gauges."""
    (key, padded) = full_key
    return f"{hash(key) & 0xFFFFFFFF:08x}/B{padded}"


def _sample_device_memory() -> None:
    """Record live device-memory gauges (``device_bytes_in_use`` etc.) from
    ``Device.memory_stats()`` where the backend provides it — CPU devices
    typically return ``None``/``{}`` and are skipped (graceful fallback)."""
    if not telemetry.is_enabled():
        return
    try:
        devices = jax.local_devices()
    except Exception:
        return
    for d in devices:
        stats_fn = getattr(d, "memory_stats", None)
        if stats_fn is None:
            continue
        try:
            stats = stats_fn()
        except Exception:
            continue
        if not stats:
            continue
        label = f"{d.platform}:{d.id}"
        for field in ("bytes_in_use", "peak_bytes_in_use", "bytes_limit"):
            if field in stats:
                telemetry.gauge_set(f"device_{field}", float(stats[field]),
                                    device=label)


def _instrument_compile(fn, full_key, backend):
    """Wrap a freshly built executable so its first invocation — the one
    that compiles — is attributed: ``serve_compile_us`` histogram, a
    per-entry ``serve_exec_compile_us`` gauge, and a device-memory sample
    once the executable is resident.  Steady-state calls pay one list
    check."""
    pending = [True]

    def wrapper(plan, leaves, rhs):
        if not pending:
            return fn(plan, leaves, rhs)
        pending.clear()
        t0 = time.perf_counter()
        out = fn(plan, leaves, rhs)
        jax.block_until_ready(out)
        wall_us = 1e6 * (time.perf_counter() - t0)
        telemetry.histogram_observe("serve_compile_us", wall_us,
                                    backend=backend)
        telemetry.gauge_set("serve_exec_compile_us", wall_us,
                            entry=_entry_tag(full_key))
        _sample_device_memory()
        return out

    return wrapper


def _build_executable(template, key):
    """One batched-solve closure for a compatibility class, built from a
    representative request.  Signature: ``fn(plan, leaves, rhs) -> (X, info)``
    with every coefficient leaf batched ``(B, ...)`` and ``rhs: (B, n)``.

    The template's *values* never leak into later batches — the lowered form
    only contributes its static signature; all traced leaves are replaced by
    the stacked per-request arrays.
    """
    form, bc, backend = template.form, template.bc, template.backend
    spec, form_sig = template.spec, template.form_sig

    if backend == "matfree":

        def _run(plan, leaves, rhs):
            telemetry.count_trace("serve", plan.static, form_sig,
                                  backend=backend)
            fam = matfree_family(plan, form, leaves_batch=leaves)
            if bc is not None:
                fam = fam.condensed(bc)
                rhs = rhs * bc.free_mask
            return matfree_solve_batched(fam, rhs, spec, return_info=True)

    else:
        from ..core.assembly import assemble_batched

        def _run(plan, leaves, rhs):
            telemetry.count_trace("serve", plan.static, form_sig,
                                  backend=backend)
            kb = assemble_batched(plan, form, leaves_batch=leaves)
            if bc is not None:
                kb = bc.apply_matrix_only(kb)
                rhs = rhs * bc.free_mask
            return sparse_solve_batched(kb, rhs, spec, return_info=True)

    return jax.jit(_run)


class ExecutableCache:
    """LRU cache of jitted batched-solve executables with pinning.

    ``capacity`` bounds the number of *unpinned* entries; pinned entries
    (warmed-up production signatures) never count against it and never
    evict.  Thread-safe use is the caller's job — the service only touches
    the cache from its single dispatch thread.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self._pinned: set = set()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple, padded_batch: int, template):
        """The executable for ``(key, padded_batch)``, building (and
        possibly evicting) on miss."""
        full_key = (key, padded_batch)
        hit = full_key in self._entries
        telemetry.count_cache("serve_exec", hit)
        if hit:
            self.hits += 1
            self._entries.move_to_end(full_key)
            return self._entries[full_key], True
        self.misses += 1
        fn = _instrument_compile(
            _build_executable(template, key), full_key, template.backend)
        self._entries[full_key] = fn
        telemetry.gauge_set("serve_exec_entries", len(self._entries))
        self._evict()
        return fn, False

    def pin(self, key: tuple, padded_batch: int) -> None:
        """Exempt an entry from eviction (idempotent; the entry need not
        exist yet — pinning is by key)."""
        self._pinned.add((key, padded_batch))

    def unpin(self, key: tuple, padded_batch: int) -> None:
        self._pinned.discard((key, padded_batch))
        self._evict()

    def _evict(self) -> None:
        unpinned = [k for k in self._entries if k not in self._pinned]
        evicted = False
        while len(unpinned) > self.capacity:
            victim = unpinned.pop(0)  # least recently used unpinned entry
            del self._entries[victim]
            self.evictions += 1
            evicted = True
            telemetry.counter_inc("serve_cache_evictions")
            telemetry.gauge_set("serve_exec_compile_us", 0.0,
                                entry=_entry_tag(victim))
        if evicted:
            telemetry.gauge_set("serve_exec_entries", len(self._entries))
            _sample_device_memory()

    def clear(self) -> None:
        self._entries.clear()
        self._pinned.clear()

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
