"""Stage-II precompute: topology-aware routing for the Sparse-Reduce.

The paper's routing matrices ``S_mat ∈ {0,1}^{N_nnz × Ek²}`` and
``S_vec ∈ {0,1}^{N × Ek}`` have exactly one nonzero per column — i.e. they are
*functions* from local slots to global slots.  On TPU we realize them as a
sort-based deterministic reduction (see DESIGN.md §2):

* setup (numpy, once per mesh topology):  lexsort the ``Ek²`` COO coordinates,
  extract the unique CSR sparsity pattern, and store the permutation ``perm``
  plus sorted segment ids ``seg_ids``;
* runtime (jax, inside jit):  ``csr_vals = segment_sum(vec(K_local)[perm],
  seg_ids)`` — mathematically identical to ``S_mat · vec(K_local)``,
  deterministic, no atomics.

A "direct" variant (unsorted ``segment_sum``, i.e. one XLA scatter-add) is
kept for benchmarking the two lowering strategies.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["MatrixRouting", "VectorRouting", "build_matrix_routing", "build_vector_routing"]


@dataclasses.dataclass(frozen=True)
class MatrixRouting:
    """Precomputed Sparse-Reduce for stiffness-matrix assembly.

    The numpy fields are the host-side precompute (consumed by further numpy
    setup: injections, condensers); the ``*_dev`` mirrors are the same arrays
    staged to device once at construction, so every assembly trace reuses one
    constant instead of re-staging an ``E·k²``-sized host array per trace.
    """

    num_dofs: int
    nnz: int
    indptr: np.ndarray       # (num_dofs + 1,) CSR row pointers
    indices: np.ndarray      # (nnz,) CSR column indices
    perm: np.ndarray         # (E*ka*kb,) sort permutation of local slots
    seg_ids: np.ndarray      # (E*ka*kb,) sorted segment ids (into nnz)
    seg_ids_unsorted: np.ndarray  # (E*ka*kb,) direct (scatter) segment ids
    row_of_nnz: np.ndarray   # (nnz,) row index of each stored entry
    diag_pos: np.ndarray     # (num_dofs,) position of (i,i) in vals, -1 if absent

    def __post_init__(self):
        object.__setattr__(self, "perm_dev", jnp.asarray(self.perm))
        object.__setattr__(self, "seg_ids_dev", jnp.asarray(self.seg_ids))
        object.__setattr__(
            self, "seg_ids_unsorted_dev", jnp.asarray(self.seg_ids_unsorted)
        )


@dataclasses.dataclass(frozen=True)
class VectorRouting:
    """Precomputed Sparse-Reduce for load-vector assembly (device mirrors as
    in :class:`MatrixRouting`)."""

    num_dofs: int
    perm: np.ndarray
    seg_ids: np.ndarray
    seg_ids_unsorted: np.ndarray
    touched: np.ndarray      # (n_touched,) global dofs receiving contributions

    def __post_init__(self):
        object.__setattr__(self, "perm_dev", jnp.asarray(self.perm))
        object.__setattr__(self, "seg_ids_dev", jnp.asarray(self.seg_ids))
        object.__setattr__(
            self, "seg_ids_unsorted_dev", jnp.asarray(self.seg_ids_unsorted)
        )
        object.__setattr__(self, "touched_dev", jnp.asarray(self.touched))


def build_matrix_routing(
    row_dofs: np.ndarray, col_dofs: np.ndarray | None, num_dofs: int
) -> MatrixRouting:
    """Routing for local matrices with rows ``row_dofs: (E, ka)`` and columns
    ``col_dofs: (E, kb)`` (defaults to ``row_dofs`` — Galerkin)."""
    row_dofs = np.asarray(row_dofs, dtype=np.int64)
    col_dofs = row_dofs if col_dofs is None else np.asarray(col_dofs, dtype=np.int64)
    e, ka = row_dofs.shape
    kb = col_dofs.shape[1]

    rows = np.broadcast_to(row_dofs[:, :, None], (e, ka, kb)).ravel()
    cols = np.broadcast_to(col_dofs[:, None, :], (e, ka, kb)).ravel()
    key = rows * num_dofs + cols

    perm = np.argsort(key, kind="stable")
    sorted_key = key[perm]
    new_seg = np.empty(sorted_key.shape[0], dtype=bool)
    new_seg[0] = True
    new_seg[1:] = sorted_key[1:] != sorted_key[:-1]
    seg_ids = np.cumsum(new_seg) - 1
    nnz = int(seg_ids[-1]) + 1 if seg_ids.size else 0

    uniq_key = sorted_key[new_seg]
    uniq_rows = (uniq_key // num_dofs).astype(np.int64)
    uniq_cols = (uniq_key % num_dofs).astype(np.int64)
    indptr = np.zeros(num_dofs + 1, dtype=np.int64)
    np.add.at(indptr, uniq_rows + 1, 1)
    indptr = np.cumsum(indptr)

    seg_unsorted = np.empty_like(seg_ids)
    seg_unsorted[perm] = seg_ids

    diag_pos = -np.ones(num_dofs, dtype=np.int64)
    is_diag = uniq_rows == uniq_cols
    diag_pos[uniq_rows[is_diag]] = np.nonzero(is_diag)[0]

    return MatrixRouting(
        num_dofs=num_dofs,
        nnz=nnz,
        indptr=indptr,
        indices=uniq_cols,
        perm=perm,
        seg_ids=seg_ids,
        seg_ids_unsorted=seg_unsorted,
        row_of_nnz=uniq_rows,
        diag_pos=diag_pos,
    )


def build_vector_routing(row_dofs: np.ndarray, num_dofs: int) -> VectorRouting:
    """Routing for local vectors ``(E, k)`` onto a global ``(num_dofs,)``."""
    rows = np.asarray(row_dofs, dtype=np.int64).ravel()
    perm = np.argsort(rows, kind="stable")
    srt = rows[perm]
    new_seg = np.empty(srt.shape[0], dtype=bool)
    new_seg[0] = True
    new_seg[1:] = srt[1:] != srt[:-1]
    # segment ids index *touched* dofs, then scatter to the full vector once.
    seg_ids = np.cumsum(new_seg) - 1
    touched = srt[new_seg]
    seg_unsorted = np.empty_like(seg_ids)
    seg_unsorted[perm] = seg_ids
    return VectorRouting(
        num_dofs=num_dofs,
        perm=perm,
        seg_ids=seg_ids,
        seg_ids_unsorted=seg_unsorted,
        touched=touched,
    )
