"""TensorGalerkin: Batch-Map + Sparse-Reduce assembly (the paper's core).

* :func:`geometry_context` — Stage-I geometry: batched Jacobians, closed-form
  inverses/determinants, push-forward gradients (Alg. 1, lines 1–3).
* :class:`GalerkinAssembler` — owns one mesh topology: quadrature tables,
  routing (Stage-II precompute), and the jit-cached
  :meth:`~GalerkinAssembler.assemble` / :meth:`~GalerkinAssembler.assemble_rhs`
  entry points over :mod:`~repro.core.weakform` forms.  A multi-term form
  traces **one fused Map** (all volume kernels against a shared geometry
  context, built inside the jit boundary) and **one Reduce**; facet terms
  inject into the volume CSR pattern.  Jaxprs contain no element-indexed
  Python constructs — the JAX analogue of the O(1)-graph property.
* Deprecated shims ``assemble_stiffness`` / ``assemble_mass`` /
  ``assemble_elasticity`` / ``assemble_load`` / ``assemble_reaction_load``
  forward to the form API one term at a time.
* Baselines for the paper's comparison: a Python per-element scatter-add loop
  (the "white box" of Fig. 1) and a dense ``.at[].add()`` scatter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import forms, weakform
from .elements import get_element
from .mesh import FunctionSpace, Mesh
from .routing import MatrixRouting, VectorRouting, build_matrix_routing, build_vector_routing
from .sparse import CSR

__all__ = ["GalerkinAssembler", "geometry_context", "facet_context"]


# ---------------------------------------------------------------------------
# Stage I geometry helpers (closed-form small-matrix linear algebra: these
# shapes (d ≤ 3) would be crippled by generic LU on TPU; adjugate formulas
# keep everything element-parallel on the VPU)
# ---------------------------------------------------------------------------

def _det(j: jnp.ndarray) -> jnp.ndarray:
    d = j.shape[-1]
    if d == 1:
        return j[..., 0, 0]
    if d == 2:
        return j[..., 0, 0] * j[..., 1, 1] - j[..., 0, 1] * j[..., 1, 0]
    if d == 3:
        return (
            j[..., 0, 0] * (j[..., 1, 1] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 1])
            - j[..., 0, 1] * (j[..., 1, 0] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 0])
            + j[..., 0, 2] * (j[..., 1, 0] * j[..., 2, 1] - j[..., 1, 1] * j[..., 2, 0])
        )
    raise ValueError(d)


def _inv(j: jnp.ndarray, det: jnp.ndarray) -> jnp.ndarray:
    d = j.shape[-1]
    if d == 1:
        return 1.0 / j
    if d == 2:
        adj = jnp.stack(
            [
                jnp.stack([j[..., 1, 1], -j[..., 0, 1]], -1),
                jnp.stack([-j[..., 1, 0], j[..., 0, 0]], -1),
            ],
            -2,
        )
        return adj / det[..., None, None]
    if d == 3:
        c00 = j[..., 1, 1] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 1]
        c01 = j[..., 0, 2] * j[..., 2, 1] - j[..., 0, 1] * j[..., 2, 2]
        c02 = j[..., 0, 1] * j[..., 1, 2] - j[..., 0, 2] * j[..., 1, 1]
        c10 = j[..., 1, 2] * j[..., 2, 0] - j[..., 1, 0] * j[..., 2, 2]
        c11 = j[..., 0, 0] * j[..., 2, 2] - j[..., 0, 2] * j[..., 2, 0]
        c12 = j[..., 0, 2] * j[..., 1, 0] - j[..., 0, 0] * j[..., 1, 2]
        c20 = j[..., 1, 0] * j[..., 2, 1] - j[..., 1, 1] * j[..., 2, 0]
        c21 = j[..., 0, 1] * j[..., 2, 0] - j[..., 0, 0] * j[..., 2, 1]
        c22 = j[..., 0, 0] * j[..., 1, 1] - j[..., 0, 1] * j[..., 1, 0]
        adj = jnp.stack(
            [
                jnp.stack([c00, c01, c02], -1),
                jnp.stack([c10, c11, c12], -1),
                jnp.stack([c20, c21, c22], -1),
            ],
            -2,
        )
        return adj / det[..., None, None]
    raise ValueError(d)


def geometry_context(
    coords: jnp.ndarray,
    geo_phi: jnp.ndarray,
    geo_grad: jnp.ndarray,
    phi: jnp.ndarray,
    gradhat: jnp.ndarray,
    w: jnp.ndarray,
    scalar_cell_dofs=None,
) -> forms.FormContext:
    """Build the Stage-I :class:`FormContext` from batched coordinates.

    coords: (E, nv_geo, d); geo_phi/geo_grad: geometric element tables
    (Q, nv_geo[, d]); phi/gradhat: field element tables (Q, k[, d]).
    Fully differentiable w.r.t. ``coords`` (shape optimization).
    """
    # J_eqij = Σ_a X_eai ĝeo_qaj     (Alg. 1 line 1)
    j = jnp.einsum("eai,qaj->eqij", coords, geo_grad)
    det = _det(j)
    jinv = _inv(j, det)
    detj = jnp.abs(det)
    # push-forward 𝒢_eqai = Σ_j (J⁻¹)_ji ĝ_qaj   (Alg. 1 line 2)
    grad = jnp.einsum("eqji,qaj->eqai", jinv, gradhat)
    xq = jnp.einsum("qa,eai->eqi", geo_phi, coords)
    return forms.FormContext(
        w=w, phi=phi, detj=detj, grad=grad, xq=xq,
        scalar_cell_dofs=scalar_cell_dofs,
    )


def facet_context(
    coords: jnp.ndarray, phi: jnp.ndarray, gradhat: jnp.ndarray, w: jnp.ndarray,
    scalar_facet_dofs=None,
) -> forms.FormContext:
    """Geometry for (d-1)-facets embedded in R^d: surface measure
    √det(JᵀJ) replaces |det J| (used for Neumann/Robin boundary terms, which
    route through the *same* Map-Reduce pipeline — paper SM B.1.5)."""
    j = jnp.einsum("eai,qaj->eqij", coords, gradhat)     # (F, Q, d, d-1)
    jtj = jnp.einsum("eqij,eqik->eqjk", j, j)
    measure = jnp.sqrt(_det(jtj))
    xq = jnp.einsum("qa,eai->eqi", phi, coords)
    return forms.FormContext(
        w=w, phi=phi, detj=measure, grad=None, xq=xq,
        scalar_cell_dofs=scalar_facet_dofs,
    )


# ---------------------------------------------------------------------------
# Stage II reduce
# ---------------------------------------------------------------------------

def reduce_matrix(k_local: jnp.ndarray, routing: MatrixRouting, mode: str = "sorted"):
    """``S_mat · vec(K_local)`` as a deterministic segment reduction."""
    v = k_local.reshape(-1)
    if mode == "sorted":
        vals = jax.ops.segment_sum(
            v[jnp.asarray(routing.perm)],
            jnp.asarray(routing.seg_ids),
            num_segments=routing.nnz,
            indices_are_sorted=True,
        )
    else:  # direct scatter-add (one XLA scatter; benchmark comparison)
        vals = jax.ops.segment_sum(
            v, jnp.asarray(routing.seg_ids_unsorted), num_segments=routing.nnz
        )
    return vals


def reduce_vector(f_local: jnp.ndarray, routing: VectorRouting, mode: str = "sorted"):
    """``S_vec · vec(F_local)`` — reduce to touched dofs, scatter once."""
    v = f_local.reshape(-1)
    if mode == "sorted":
        packed = jax.ops.segment_sum(
            v[jnp.asarray(routing.perm)],
            jnp.asarray(routing.seg_ids),
            num_segments=routing.touched.shape[0],
            indices_are_sorted=True,
        )
    else:
        packed = jax.ops.segment_sum(
            v, jnp.asarray(routing.seg_ids_unsorted),
            num_segments=routing.touched.shape[0],
        )
    out = jnp.zeros((routing.num_dofs,), dtype=v.dtype)
    return out.at[jnp.asarray(routing.touched)].set(packed)


# ---------------------------------------------------------------------------
# The assembler
# ---------------------------------------------------------------------------

class GalerkinAssembler:
    """One instance per (mesh topology × element × quadrature) signature.

    All numpy tables built here are compile-time constants of the jitted
    assembly closures — re-instantiating for a same-signature mesh reuses
    XLA executables via jit's cache (shape-bucketed compilation, DESIGN §2).
    """

    def __init__(self, space: FunctionSpace, quad_order: int | None = None,
                 reduce_mode: str = "direct"):
        # reduce_mode: 'direct' lowers to one XLA scatter-add (2.5× faster on
        # CPU, still deterministic — no atomics in XLA); 'sorted' is the
        # gather + sorted-segment-sum path (TPU-preferred layout).  Both are
        # bit-reproducible; see EXPERIMENTS.md §Perf-FEM.
        self.space = space
        self.mesh = space.mesh
        self.element = space.element
        self.reduce_mode = reduce_mode

        pts, w = self.element.default_rule(quad_order)
        self.w = jnp.asarray(w)
        self.phi = jnp.asarray(self.element.tabulate(pts))
        self.gradhat = jnp.asarray(self.element.tabulate_grad(pts))

        # geometry element: vertices of the cell (affine/bilinear map)
        geo_name = {"tri": "P1_tri", "tet": "P1_tet", "quad": "Q1_quad"}[
            self.mesh.cell_type
        ]
        geo = get_element(geo_name)
        self.geo_phi = jnp.asarray(geo.tabulate(pts))
        self.geo_grad = jnp.asarray(geo.tabulate_grad(pts))

        self.coords = jnp.asarray(self.mesh.points[self.mesh.cells])  # (E, nv, d)
        # scalar cell dofs (coefficient interpolation uses the scalar space)
        if space.value_size == 1:
            self._scalar_cell_dofs = jnp.asarray(space.cell_dofs)
        else:
            self._scalar_cell_dofs = jnp.asarray(
                space.cell_dofs[:, :: space.value_size] // space.value_size
            )

        self.mat_routing = build_matrix_routing(
            space.cell_dofs, None, space.num_dofs
        )
        self.vec_routing = build_vector_routing(space.cell_dofs, space.num_dofs)

        # jit cache for the form API: one compiled executable per static form
        # signature (term kinds × domains × coefficient structure); all
        # coefficient values are traced leaves.  n_traces counts retraces —
        # repeated assembly with new coefficient *values* must not grow it.
        # Callable coefficients are part of the signature (identity-keyed):
        # per-call lambdas each compile fresh, so the cache is FIFO-bounded —
        # evicting an entry drops its jit wrapper and with it the compiled
        # executable — and hot loops should reuse stable function objects.
        self._form_cache: dict = {}
        self._form_cache_limit = 128
        self.n_traces = 0

    # -- context -------------------------------------------------------------
    def context(self, coords: jnp.ndarray | None = None) -> forms.FormContext:
        coords = self.coords if coords is None else coords
        return geometry_context(
            coords, self.geo_phi, self.geo_grad, self.phi, self.gradhat, self.w,
            scalar_cell_dofs=self._scalar_cell_dofs,
        )

    def csr(self, vals: jnp.ndarray) -> CSR:
        r = self.mat_routing
        return CSR(
            vals=vals,
            indptr=r.indptr,
            indices=r.indices,
            row_of_nnz=r.row_of_nnz,
            shape=(r.num_dofs, r.num_dofs),
            diag_pos=r.diag_pos,
        )

    # -- form API: one fused Map, one Reduce, jit-cached per signature --------
    def assemble(self, form, coords=None) -> CSR:
        """Assemble a bilinear :class:`~repro.core.weakform.WeakForm` into a
        CSR on the volume pattern.

        All volume terms are evaluated in **one fused Map** against a shared
        geometry context (built from ``coords`` inside the jit boundary),
        summed element-wise, and reduced **once**; facet terms (e.g.
        ``robin(alpha, on=facets)``) reduce through their facet routing and
        are injected into the volume CSR pattern.  Coefficients and scale
        factors are traced — a θ-step ``mass(c) + dt*diffusion(kappa)`` or a
        SIMP-interpolated ``elasticity(lam, mu, scale=rho**p)`` compiles one
        XLA executable reused across coefficient values.
        """
        return self.csr(self._assemble_vals(form, weakform.MATRIX, coords))

    def assemble_rhs(self, form, coords=None) -> jnp.ndarray:
        """Assemble a linear form (``source`` / ``neumann`` / ``reaction``
        terms) into a global ``(num_dofs,)`` vector — same fused pipeline."""
        return self._assemble_vals(form, weakform.VECTOR, coords)

    def _assemble_vals(self, form, arity: str, coords=None):
        spec, leaves = weakform.lower(form, arity)
        if coords is not None and any(domain is not None for _, domain, _ in spec):
            # facet geometry comes from the FacetAssembler's construction-time
            # coords; silently mixing it with overridden volume coords would
            # give inconsistent values and zero boundary coordinate gradients
            raise NotImplementedError(
                "assemble(form, coords=...) does not support facet terms: "
                "boundary geometry is fixed at FacetAssembler construction"
            )
        fn = self._form_cache.get((arity, spec))
        if fn is None:
            while len(self._form_cache) >= self._form_cache_limit:
                self._form_cache.pop(next(iter(self._form_cache)))
            fn = self._build_form_fn(spec, arity)
            self._form_cache[(arity, spec)] = fn
        return fn(leaves, self.coords if coords is None else coords)

    def _build_form_fn(self, spec, arity: str):
        """Close over one static form signature; jit over (leaves, coords)."""
        vs = self.space.value_size
        # facet-domain precompute (numpy, once per signature): injection of
        # each facet pattern into the volume CSR pattern
        injections = {}
        for _, domain, _ in spec:
            if domain is not None and arity == weakform.MATRIX:
                if domain not in injections:
                    injections[domain] = jnp.asarray(
                        domain.injection_into(self.mat_routing)
                    )

        def run(leaves, coords):
            self.n_traces += 1
            ctx = self.context(coords)
            leaf = iter(leaves)
            facet_ctxs: dict = {}
            local_sum = None            # fused volume Map accumulator
            facet_sums: dict = {}       # domain -> facet Map accumulator
            for kind, domain, desc in spec:
                vals = [next(leaf) if d == weakform.TRACED else d[1] for d in desc]
                *coeffs, scale = vals
                if domain is None:
                    tctx = ctx
                else:
                    if domain not in facet_ctxs:
                        facet_ctxs[domain] = domain.context()
                    tctx = facet_ctxs[domain]
                kern = weakform.KERNELS[kind].fn
                local = kern(tctx, vs, *coeffs) * jnp.asarray(scale)
                if domain is None:
                    if local_sum is not None and local_sum.shape != local.shape:
                        raise ValueError(
                            f"term '{kind}' local shape {local.shape} does not "
                            f"match earlier terms {local_sum.shape} — scalar "
                            "and vector-valued kernels cannot be fused"
                        )
                    local_sum = local if local_sum is None else local_sum + local
                else:
                    prev = facet_sums.get(domain)
                    facet_sums[domain] = local if prev is None else prev + local

            if arity == weakform.MATRIX:
                out = (
                    reduce_matrix(local_sum, self.mat_routing, self.reduce_mode)
                    if local_sum is not None
                    else jnp.zeros((self.mat_routing.nnz,))
                )
                for domain, loc in facet_sums.items():
                    fvals = reduce_matrix(loc, domain.mat_routing, self.reduce_mode)
                    out = out.at[injections[domain]].add(fvals.astype(out.dtype))
                return out
            out = (
                reduce_vector(local_sum, self.vec_routing, self.reduce_mode)
                if local_sum is not None
                else jnp.zeros((self.space.num_dofs,))
            )
            for domain, loc in facet_sums.items():
                out = out + reduce_vector(loc, domain.vec_routing, self.reduce_mode)
            return out

        return jax.jit(run)

    # -- deprecated shims over the form API -----------------------------------
    def assemble_stiffness(self, rho=None, coords=None) -> CSR:
        """Deprecated: use ``assemble(weakform.diffusion(rho))``."""
        return self.assemble(weakform.diffusion(rho), coords)

    def assemble_mass(self, c=None, coords=None) -> CSR:
        """Deprecated: use ``assemble(weakform.mass(c))``."""
        return self.assemble(weakform.mass(c), coords)

    def assemble_elasticity(self, lam: float, mu: float, scale=None, coords=None) -> CSR:
        """Deprecated: use ``assemble(weakform.elasticity(lam, mu, scale))``."""
        return self.assemble(weakform.elasticity(lam, mu, scale), coords)

    def assemble_load(self, f=None, coords=None) -> jnp.ndarray:
        """Deprecated: use ``assemble_rhs(weakform.source(f))``."""
        return self.assemble_rhs(weakform.source(f), coords)

    def assemble_reaction_load(self, u_nodal, fn) -> jnp.ndarray:
        """Deprecated: use ``assemble_rhs(weakform.reaction(u_nodal, fn))``."""
        return self.assemble_rhs(weakform.reaction(u_nodal, fn))

    # -- baselines (paper Fig. 1 "white box") ----------------------------------
    def assemble_stiffness_scatter(self, rho=None) -> jnp.ndarray:
        """Dense scatter-add baseline: K.at[rows, cols].add(k_local)."""
        ctx = self.context(None)
        k_local = forms.diffusion(ctx, rho)
        n = self.space.num_dofs
        cd = jnp.asarray(self.space.cell_dofs)
        rows = jnp.broadcast_to(cd[:, :, None], k_local.shape).reshape(-1)
        cols = jnp.broadcast_to(cd[:, None, :], k_local.shape).reshape(-1)
        return jnp.zeros((n, n)).at[rows, cols].add(k_local.reshape(-1))

    def assemble_stiffness_loop(self, rho=None) -> np.ndarray:
        """Python per-element loop (the classical Alg.; O(E) graph/time).
        numpy, small meshes only — exists to quantify the paper's claim."""
        el, mesh, sp = self.element, self.mesh, self.space
        pts, w = el.default_rule(None)
        gradhat = el.tabulate_grad(pts)
        k = np.zeros((sp.num_dofs, sp.num_dofs))
        pts_np = np.asarray(self.coords)
        geo_grad = np.asarray(self.geo_grad)
        for e in range(mesh.num_cells):
            x = pts_np[e]
            j = np.einsum("ai,qaj->qij", x, geo_grad)
            det = np.abs(np.linalg.det(j))
            jinv = np.linalg.inv(j)
            g = np.einsum("qji,qaj->qai", jinv, gradhat)
            ke = np.einsum("q,q,qai,qbi->ab", w, det, g, g)
            dofs = sp.cell_dofs[e]
            for a in range(len(dofs)):
                for b in range(len(dofs)):
                    k[dofs[a], dofs[b]] += ke[a, b]
        return k
