"""TensorGalerkin: Batch-Map + Sparse-Reduce assembly (the paper's core).

The assembly subsystem is a **functional core** behind a thin class facade:

* :func:`geometry_context` — Stage-I geometry: batched Jacobians, closed-form
  inverses/determinants, push-forward gradients (Alg. 1, lines 1–3).
* :class:`AssemblyPlan` — a frozen, pytree-registered value holding one
  (mesh topology × element × quadrature) signature: the static quadrature /
  element tables and Stage-II routing live in identity-hashed aux data
  (:class:`PlanStatic`), the traced ``coords`` array is the single pytree
  leaf.  Plans cross ``jit`` / ``vmap`` / ``grad`` boundaries like any other
  value; build one with :func:`build_plan`.
* Pure top-level entry points that close over **nothing**:
  :func:`assemble` / :func:`assemble_rhs` (single instance, jit-cached per
  form signature), :func:`assemble_batched` / :func:`assemble_rhs_batched`
  (one fused Map over ``(B, E, ...)`` and one Reduce per instance via
  ``vmap`` — B coefficient-sets / geometries in a single XLA executable,
  zero retraces across the batch), and :func:`assemble_sharded` /
  :func:`assemble_rhs_sharded` (opt-in ``shard_map`` partitioning of the
  element axis of the Map stage across devices, Reduce completed by one
  all-reduce over partial nnz contributions).
* :class:`GalerkinAssembler` — the cache-owning facade over a plan: every
  historical call site keeps working; new code may use the plan functions
  directly.  A multi-term form traces **one fused Map** (all volume kernels
  against a shared geometry context, built inside the jit boundary) and
  **one Reduce**; facet terms inject into the volume CSR pattern.  Jaxprs
  contain no element-indexed Python constructs — the JAX analogue of the
  O(1)-graph property.
* Deprecated shims ``assemble_stiffness`` / ``assemble_mass`` /
  ``assemble_elasticity`` / ``assemble_load`` / ``assemble_reaction_load``
  forward to the form API one term at a time (with a ``DeprecationWarning``).
* Baselines for the paper's comparison: a Python per-element scatter-add loop
  (the "white box" of Fig. 1) and a dense ``.at[].add()`` scatter.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import telemetry
from ..telemetry import annotate
from . import forms, weakform
from .elements import get_element
from .mesh import FunctionSpace
from .routing import MatrixRouting, VectorRouting, build_matrix_routing, build_vector_routing
from .sparse import CSR, BatchedCSR

__all__ = [
    "AssemblyPlan",
    "PlanStatic",
    "build_plan",
    "assemble",
    "assemble_rhs",
    "assemble_batched",
    "assemble_rhs_batched",
    "assemble_sharded",
    "assemble_rhs_sharded",
    "GalerkinAssembler",
    "geometry_context",
    "facet_context",
    "clear_assembly_caches",
    "n_core_traces",
]


# ---------------------------------------------------------------------------
# Stage I geometry helpers (closed-form small-matrix linear algebra: these
# shapes (d ≤ 3) would be crippled by generic LU on TPU; adjugate formulas
# keep everything element-parallel on the VPU)
# ---------------------------------------------------------------------------

def _det(j: jnp.ndarray) -> jnp.ndarray:
    d = j.shape[-1]
    if d == 1:
        return j[..., 0, 0]
    if d == 2:
        return j[..., 0, 0] * j[..., 1, 1] - j[..., 0, 1] * j[..., 1, 0]
    if d == 3:
        return (
            j[..., 0, 0] * (j[..., 1, 1] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 1])
            - j[..., 0, 1] * (j[..., 1, 0] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 0])
            + j[..., 0, 2] * (j[..., 1, 0] * j[..., 2, 1] - j[..., 1, 1] * j[..., 2, 0])
        )
    raise ValueError(d)


def _inv(j: jnp.ndarray, det: jnp.ndarray) -> jnp.ndarray:
    d = j.shape[-1]
    if d == 1:
        return 1.0 / j
    if d == 2:
        adj = jnp.stack(
            [
                jnp.stack([j[..., 1, 1], -j[..., 0, 1]], -1),
                jnp.stack([-j[..., 1, 0], j[..., 0, 0]], -1),
            ],
            -2,
        )
        return adj / det[..., None, None]
    if d == 3:
        c00 = j[..., 1, 1] * j[..., 2, 2] - j[..., 1, 2] * j[..., 2, 1]
        c01 = j[..., 0, 2] * j[..., 2, 1] - j[..., 0, 1] * j[..., 2, 2]
        c02 = j[..., 0, 1] * j[..., 1, 2] - j[..., 0, 2] * j[..., 1, 1]
        c10 = j[..., 1, 2] * j[..., 2, 0] - j[..., 1, 0] * j[..., 2, 2]
        c11 = j[..., 0, 0] * j[..., 2, 2] - j[..., 0, 2] * j[..., 2, 0]
        c12 = j[..., 0, 2] * j[..., 1, 0] - j[..., 0, 0] * j[..., 1, 2]
        c20 = j[..., 1, 0] * j[..., 2, 1] - j[..., 1, 1] * j[..., 2, 0]
        c21 = j[..., 0, 1] * j[..., 2, 0] - j[..., 0, 0] * j[..., 2, 1]
        c22 = j[..., 0, 0] * j[..., 1, 1] - j[..., 0, 1] * j[..., 1, 0]
        adj = jnp.stack(
            [
                jnp.stack([c00, c01, c02], -1),
                jnp.stack([c10, c11, c12], -1),
                jnp.stack([c20, c21, c22], -1),
            ],
            -2,
        )
        return adj / det[..., None, None]
    raise ValueError(d)


def geometry_context(
    coords: jnp.ndarray,
    geo_phi: jnp.ndarray,
    geo_grad: jnp.ndarray,
    phi: jnp.ndarray,
    gradhat: jnp.ndarray,
    w: jnp.ndarray,
    scalar_cell_dofs=None,
) -> forms.FormContext:
    """Build the Stage-I :class:`FormContext` from batched coordinates.

    coords: (E, nv_geo, d); geo_phi/geo_grad: geometric element tables
    (Q, nv_geo[, d]); phi/gradhat: field element tables (Q, k[, d]).
    Fully differentiable w.r.t. ``coords`` (shape optimization).
    """
    # J_eqij = Σ_a X_eai ĝeo_qaj     (Alg. 1 line 1)
    j = jnp.einsum("eai,qaj->eqij", coords, geo_grad)
    det = _det(j)
    jinv = _inv(j, det)
    detj = jnp.abs(det)
    # push-forward 𝒢_eqai = Σ_j (J⁻¹)_ji ĝ_qaj   (Alg. 1 line 2)
    grad = jnp.einsum("eqji,qaj->eqai", jinv, gradhat)
    xq = jnp.einsum("qa,eai->eqi", geo_phi, coords)
    return forms.FormContext(
        w=w, phi=phi, detj=detj, grad=grad, xq=xq,
        scalar_cell_dofs=scalar_cell_dofs,
    )


def facet_context(
    coords: jnp.ndarray, phi: jnp.ndarray, gradhat: jnp.ndarray, w: jnp.ndarray,
    scalar_facet_dofs=None,
) -> forms.FormContext:
    """Geometry for (d-1)-facets embedded in R^d: surface measure
    √det(JᵀJ) replaces |det J| (used for Neumann/Robin boundary terms, which
    route through the *same* Map-Reduce pipeline — paper SM B.1.5)."""
    j = jnp.einsum("eai,qaj->eqij", coords, gradhat)     # (F, Q, d, d-1)
    jtj = jnp.einsum("eqij,eqik->eqjk", j, j)
    measure = jnp.sqrt(_det(jtj))
    xq = jnp.einsum("qa,eai->eqi", phi, coords)
    return forms.FormContext(
        w=w, phi=phi, detj=measure, grad=None, xq=xq,
        scalar_cell_dofs=scalar_facet_dofs,
    )


# ---------------------------------------------------------------------------
# Stage II reduce
# ---------------------------------------------------------------------------

def reduce_matrix(k_local: jnp.ndarray, routing: MatrixRouting, mode: str = "sorted"):
    """``S_mat · vec(K_local)`` as a deterministic segment reduction."""
    v = k_local.reshape(-1)
    if mode == "sorted":
        vals = jax.ops.segment_sum(
            v[routing.perm_dev],
            routing.seg_ids_dev,
            num_segments=routing.nnz,
            indices_are_sorted=True,
        )
    else:  # direct scatter-add (one XLA scatter; benchmark comparison)
        vals = jax.ops.segment_sum(
            v, routing.seg_ids_unsorted_dev, num_segments=routing.nnz
        )
    return vals


def reduce_vector(f_local: jnp.ndarray, routing: VectorRouting, mode: str = "sorted"):
    """``S_vec · vec(F_local)`` — reduce to touched dofs, scatter once."""
    v = f_local.reshape(-1)
    if mode == "sorted":
        packed = jax.ops.segment_sum(
            v[routing.perm_dev],
            routing.seg_ids_dev,
            num_segments=routing.touched.shape[0],
            indices_are_sorted=True,
        )
    else:
        packed = jax.ops.segment_sum(
            v, routing.seg_ids_unsorted_dev,
            num_segments=routing.touched.shape[0],
        )
    out = jnp.zeros((routing.num_dofs,), dtype=v.dtype)
    return out.at[routing.touched_dev].set(packed)


# ---------------------------------------------------------------------------
# The assembly plan: static tables as identity-hashed aux, coords as the leaf
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class PlanStatic:
    """Compile-time constants of one assembly signature.

    ``eq=False`` keeps identity hashing, so a ``PlanStatic`` is a valid jit
    static argument and pytree aux datum: two plans compare equal exactly
    when they share tables, which is what executable reuse needs.
    """

    w: jnp.ndarray                       # (Q,) quadrature weights
    phi: jnp.ndarray                     # (Q, k) field basis values
    gradhat: jnp.ndarray                 # (Q, k, d) reference gradients
    geo_phi: jnp.ndarray                 # (Q, nv_geo) geometry basis
    geo_grad: jnp.ndarray                # (Q, nv_geo, d) geometry gradients
    scalar_cell_dofs: jnp.ndarray | None  # (E, k_scalar) for nodal coeffs
    mat_routing: MatrixRouting
    vec_routing: VectorRouting
    num_dofs: int
    value_size: int
    reduce_mode: str = "direct"
    cell_dofs: jnp.ndarray | None = None  # (E, k) full DoF map (matrix-free gather)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class AssemblyPlan:
    """One (mesh topology × element × quadrature) assembly signature as a
    value: ``coords`` is the single traced pytree leaf (differentiable —
    shape optimization, batched geometries), everything else is aux data.

    ``eq=False``: plans compare/hash by identity — the generated field-wise
    ``__eq__``/``__hash__`` would choke on the traced coords array."""

    coords: jnp.ndarray                  # (E, nv_geo, d) — the ONLY leaf
    static: PlanStatic

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return (self.coords,), self.static

    @classmethod
    def tree_unflatten(cls, aux, children):
        (coords,) = children
        return cls(coords, aux)

    # -- derived ---------------------------------------------------------
    @property
    def num_dofs(self) -> int:
        return self.static.num_dofs

    @property
    def nnz(self) -> int:
        return self.static.mat_routing.nnz

    @property
    def num_cells(self) -> int:
        return int(self.coords.shape[0])

    def with_coords(self, coords: jnp.ndarray) -> "AssemblyPlan":
        return AssemblyPlan(coords, self.static)

    def context(self, coords: jnp.ndarray | None = None) -> forms.FormContext:
        st = self.static
        return geometry_context(
            self.coords if coords is None else coords,
            st.geo_phi, st.geo_grad, st.phi, st.gradhat, st.w,
            scalar_cell_dofs=st.scalar_cell_dofs,
        )

    def csr(self, vals: jnp.ndarray) -> CSR:
        r = self.static.mat_routing
        telemetry.gauge_set(
            "csr_bytes",
            int(r.nnz) * vals.dtype.itemsize + r.indptr.nbytes + r.indices.nbytes,
        )
        return CSR(
            vals=vals,
            indptr=r.indptr,
            indices=r.indices,
            row_of_nnz=r.row_of_nnz,
            shape=(r.num_dofs, r.num_dofs),
            diag_pos=r.diag_pos,
        )

    def batched_csr(self, vals: jnp.ndarray) -> BatchedCSR:
        r = self.static.mat_routing
        return BatchedCSR(
            vals=vals,
            indptr=r.indptr,
            indices=r.indices,
            row_of_nnz=r.row_of_nnz,
            shape=(r.num_dofs, r.num_dofs),
            diag_pos=r.diag_pos,
        )


def build_plan(space: FunctionSpace, quad_order: int | None = None,
               reduce_mode: str = "direct") -> AssemblyPlan:
    """Precompute one :class:`AssemblyPlan` for a function space.

    ``reduce_mode``: 'direct' lowers to one XLA scatter-add (2.5× faster on
    CPU, still deterministic — no atomics in XLA); 'sorted' is the gather +
    sorted-segment-sum path (TPU-preferred layout).  Both are
    bit-reproducible; see EXPERIMENTS.md §Perf-FEM.
    """
    mesh, element = space.mesh, space.element
    pts, w = element.default_rule(quad_order)
    geo_name = {
        "tri": "P1_tri", "tet": "P1_tet", "quad": "Q1_quad", "hex": "Q1_hex",
    }[mesh.cell_type]
    geo = get_element(geo_name)

    if space.value_size == 1:
        scalar_cell_dofs = jnp.asarray(space.cell_dofs)
    else:
        scalar_cell_dofs = jnp.asarray(
            space.cell_dofs[:, :: space.value_size] // space.value_size
        )

    static = PlanStatic(
        w=jnp.asarray(w),
        phi=jnp.asarray(element.tabulate(pts)),
        gradhat=jnp.asarray(element.tabulate_grad(pts)),
        geo_phi=jnp.asarray(geo.tabulate(pts)),
        geo_grad=jnp.asarray(geo.tabulate_grad(pts)),
        scalar_cell_dofs=scalar_cell_dofs,
        mat_routing=build_matrix_routing(space.cell_dofs, None, space.num_dofs),
        vec_routing=build_vector_routing(space.cell_dofs, space.num_dofs),
        num_dofs=space.num_dofs,
        value_size=space.value_size,
        reduce_mode=reduce_mode,
        cell_dofs=jnp.asarray(space.cell_dofs),
    )
    telemetry.gauge_set("plan_bytes", _plan_nbytes(static))
    return AssemblyPlan(jnp.asarray(mesh.points[mesh.cells]), static)


def _plan_nbytes(static: PlanStatic) -> int:
    """Host+device footprint of a plan's static tables and routing arrays."""

    def nb(x) -> int:
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            return sum(nb(getattr(x, f.name)) for f in dataclasses.fields(x))
        return int(getattr(x, "nbytes", 0) or 0)

    return nb(static)


# ---------------------------------------------------------------------------
# The functional core: pure form evaluation (closes over nothing)
# ---------------------------------------------------------------------------

_N_CORE_TRACES = [0]


def n_core_traces() -> int:
    """Global trace counter of the functional core — bumped once per jaxpr
    trace of :func:`_eval_form`.  Repeated (batched) assembly with new
    coefficient *values* must not grow it (zero-retrace property)."""
    return _N_CORE_TRACES[0]


def _map_stage(static: PlanStatic, ctx: forms.FormContext, spec, leaves):
    """One fused Map: evaluate every term of ``spec`` against the shared
    volume context (facet terms against their domain's facet context) and
    accumulate local matrices/vectors term-wise."""
    vs = static.value_size
    leaf = iter(leaves)
    facet_ctxs: dict = {}
    local_sum = None            # fused volume Map accumulator
    facet_sums: dict = {}       # domain -> facet Map accumulator
    for kind, domain, desc in spec:
        vals = [next(leaf) if d == weakform.TRACED else d[1] for d in desc]
        *coeffs, scale = vals
        if domain is None:
            tctx = ctx
        else:
            if domain not in facet_ctxs:
                facet_ctxs[domain] = domain.context()
            tctx = facet_ctxs[domain]
        kern = weakform.KERNELS[kind].fn
        local = kern(tctx, vs, *coeffs) * jnp.asarray(scale)
        if domain is None:
            if local_sum is not None and local_sum.shape != local.shape:
                raise ValueError(
                    f"term '{kind}' local shape {local.shape} does not "
                    f"match earlier terms {local_sum.shape} — scalar "
                    "and vector-valued kernels cannot be fused"
                )
            local_sum = local if local_sum is None else local_sum + local
        else:
            prev = facet_sums.get(domain)
            facet_sums[domain] = local if prev is None else prev + local
    return local_sum, facet_sums


def _zero_fallback_dtype(coords, facet_sums):
    """dtype of the all-facet (no volume term) zero fallback: derived from
    the traced inputs, NOT the jax default — a float32 plan must not
    silently upcast facet-only forms to float64."""
    dts = [loc.dtype for loc in facet_sums.values()]
    return jnp.result_type(*dts) if dts else coords.dtype


def _eval_form(static: PlanStatic, coords, spec, leaves, arity: str):
    """Pure fused Map + Reduce over one lowered form.  All closure-free:
    ``static`` carries the tables, ``coords``/``leaves`` are the traced
    inputs, ``spec`` is the static signature."""
    _N_CORE_TRACES[0] += 1
    telemetry.count_trace("assembly", static, spec)
    with annotate("tg.map"):
        ctx = geometry_context(
            coords, static.geo_phi, static.geo_grad, static.phi,
            static.gradhat, static.w,
            scalar_cell_dofs=static.scalar_cell_dofs,
        )
        local_sum, facet_sums = _map_stage(static, ctx, spec, leaves)
    mode = static.reduce_mode

    if arity == weakform.MATRIX:
        with annotate("tg.reduce"):
            out = (
                reduce_matrix(local_sum, static.mat_routing, mode)
                if local_sum is not None
                else jnp.zeros(
                    (static.mat_routing.nnz,),
                    dtype=_zero_fallback_dtype(coords, facet_sums),
                )
            )
        with annotate("tg.facet_inject"):
            for domain, loc in facet_sums.items():
                fvals = reduce_matrix(loc, domain.mat_routing, mode)
                # numpy precompute on static data, cached per (domain, routing)
                inj = jnp.asarray(domain.injection_into(static.mat_routing))
                out = out.at[inj].add(fvals.astype(out.dtype))
        return out

    with annotate("tg.reduce"):
        out = (
            reduce_vector(local_sum, static.vec_routing, mode)
            if local_sum is not None
            else jnp.zeros(
                (static.num_dofs,),
                dtype=_zero_fallback_dtype(coords, facet_sums),
            )
        )
    with annotate("tg.facet_inject"):
        for domain, loc in facet_sums.items():
            out = out + reduce_vector(loc, domain.vec_routing, mode)
    return out


def _check_facet_coords(spec, coords):
    if coords is not None and any(domain is not None for _, domain, _ in spec):
        # facet geometry comes from the FacetAssembler's construction-time
        # coords; silently mixing it with overridden volume coords would
        # give inconsistent values and zero boundary coordinate gradients
        raise NotImplementedError(
            "assemble(form, coords=...) does not support facet terms: "
            "boundary geometry is fixed at FacetAssembler construction"
        )


# -- single-instance entry points (jit-cached per (plan, signature)) ---------
#
# One jitted wrapper per static key, held in a module-level FIFO-bounded
# dict shared by the facade and the pure entry points.  The bound matters
# for identity-keyed callable coefficients: per-call lambdas mint a fresh
# signature each call, and evicting the wrapper drops its compiled
# executable — an unbounded jax.jit static-arg cache would retain them all
# (hot loops should still reuse stable function objects).

_FORM_FNS: dict = {}
_FORM_FNS_LIMIT = 256


def _cached_form_fn(key, build):
    fn = _FORM_FNS.get(key)
    telemetry.count_cache("assembly_form_fn", hit=fn is not None)
    if fn is None:
        while len(_FORM_FNS) >= _FORM_FNS_LIMIT:
            _FORM_FNS.pop(next(iter(_FORM_FNS)))
        fn = jax.jit(build())
        _FORM_FNS[key] = fn
    return fn


def _assemble_flat(coords, leaves, *, static, spec, arity):
    fn = _cached_form_fn(
        ("single", static, spec, arity),
        lambda: lambda c, lv: _eval_form(static, c, spec, lv, arity),
    )
    if not telemetry.is_enabled():
        return fn(coords, leaves)
    t0 = time.perf_counter()
    out = fn(coords, leaves)
    is_mat = arity == weakform.MATRIX
    telemetry.record_assembly(
        "assemble" if is_mat else "assemble_rhs",
        num_dofs=static.num_dofs,
        nnz=static.mat_routing.nnz if is_mat else None,
        num_cells=int(coords.shape[0]),
        form="+".join(kind for kind, _, _ in spec),
        wall_us=(time.perf_counter() - t0) * 1e6,
    )
    return out


def assemble(plan: AssemblyPlan, form, coords=None) -> CSR:
    """Assemble a bilinear :class:`~repro.core.weakform.WeakForm` into a CSR
    on the plan's volume pattern — the pure-function twin of
    :meth:`GalerkinAssembler.assemble`.

    One fused Map over a shared geometry context (built from ``coords``
    inside the jit boundary), one Reduce; facet terms (``robin(alpha,
    on=facets)``) reduce through their facet routing and are injected into
    the volume CSR pattern.  Coefficients and scale factors are traced, so
    re-assembly with new *values* reuses the compiled executable.
    """
    spec, leaves = weakform.lower(form, weakform.MATRIX)
    _check_facet_coords(spec, coords)
    c = plan.coords if coords is None else coords
    vals = _assemble_flat(c, leaves, static=plan.static, spec=spec,
                          arity=weakform.MATRIX)
    return plan.csr(vals)


def assemble_rhs(plan: AssemblyPlan, form, coords=None) -> jnp.ndarray:
    """Assemble a linear form into a global ``(num_dofs,)`` vector — same
    fused pipeline as :func:`assemble`."""
    spec, leaves = weakform.lower(form, weakform.VECTOR)
    _check_facet_coords(spec, coords)
    c = plan.coords if coords is None else coords
    return _assemble_flat(c, leaves, static=plan.static, spec=spec,
                          arity=weakform.VECTOR)


# -- vmap-batched multi-instance assembly ------------------------------------

def _assemble_batched_flat(coords, leaves, *, static, spec, arity, axes):
    coords_ax, leaf_axes = axes

    def build():
        return lambda c, lv: jax.vmap(
            lambda ci, lvi: _eval_form(static, ci, spec, lvi, arity),
            in_axes=(coords_ax, leaf_axes),
        )(c, lv)

    fn = _cached_form_fn(("batched", static, spec, arity, axes), build)
    if not telemetry.is_enabled():
        return fn(coords, leaves)
    t0 = time.perf_counter()
    out = fn(coords, leaves)
    is_mat = arity == weakform.MATRIX
    telemetry.record_assembly(
        "assemble_batched" if is_mat else "assemble_rhs_batched",
        num_dofs=static.num_dofs,
        nnz=static.mat_routing.nnz if is_mat else None,
        form="+".join(kind for kind, _, _ in spec),
        wall_us=(time.perf_counter() - t0) * 1e6,
    )
    return out


def _lower_batched(plan, form, arity, coords_batch, leaves_batch):
    spec, leaves0 = weakform.lower(form, arity)
    if any(domain is not None for _, domain, _ in spec):
        raise NotImplementedError(
            "batched assembly supports volume terms only: facet geometry is "
            "fixed at FacetAssembler construction and cannot vary per instance"
        )
    if leaves_batch is None:
        leaves_batch = (None,) * len(leaves0)
    elif not isinstance(leaves_batch, (tuple, list)):
        # single-array convenience: batch the first traced slot
        leaves_batch = (leaves_batch,) + (None,) * (len(leaves0) - 1)
    if len(leaves_batch) != len(leaves0):
        raise ValueError(
            f"leaves_batch has {len(leaves_batch)} slots but the form lowers "
            f"to {len(leaves0)} traced leaves (per term: coefficients, then "
            "the scale factor) — pass None for slots shared across the batch"
        )
    merged = tuple(
        b if b is not None else l0 for b, l0 in zip(leaves_batch, leaves0)
    )
    leaf_axes = tuple(0 if b is not None else None for b in leaves_batch)
    coords_ax = 0 if coords_batch is not None else None
    sizes = {int(jnp.shape(b)[0]) for b in leaves_batch if b is not None}
    if coords_batch is not None:
        sizes.add(int(jnp.shape(coords_batch)[0]))
    if not sizes:
        raise ValueError(
            "nothing is batched: pass coords_batch and/or batched leaves"
        )
    if len(sizes) > 1:
        raise ValueError(f"inconsistent batch sizes {sorted(sizes)}")
    coords = plan.coords if coords_batch is None else coords_batch
    return spec, merged, coords, (coords_ax, leaf_axes)


def assemble_batched(plan: AssemblyPlan, form, coords_batch=None,
                     leaves_batch=None) -> BatchedCSR:
    """Assemble B problem instances in ONE fused Map over ``(B, E, ...)`` and
    one Reduce per instance via ``vmap`` — a single XLA executable for the
    whole batch, zero retraces across batch *values*.

    ``form`` is the template form (its own coefficient values fill any slot
    not batched).  ``coords_batch: (B, E, nv, d)`` batches the geometry;
    ``leaves_batch`` batches coefficients/scales — a tuple aligned with the
    form's traced leaves in slot order (per term: coefficients, then the
    scale factor), each entry either ``None`` (shared) or an array with a
    leading batch axis.  A bare array batches the first traced slot::

        kb = assemble_batched(plan, wf.diffusion(rho_b[0]),
                              leaves_batch=(rho_b, None))   # (B, E) coeffs

    Returns a :class:`~repro.core.sparse.BatchedCSR` — shared static pattern,
    ``(B, nnz)`` values — composing with ``vmap``-ed
    :func:`~repro.core.solvers.sparse_solve`
    (:func:`~repro.core.solvers.sparse_solve_batched`).
    """
    spec, merged, coords, axes = _lower_batched(
        plan, form, weakform.MATRIX, coords_batch, leaves_batch
    )
    vals = _assemble_batched_flat(coords, merged, static=plan.static,
                                  spec=spec, arity=weakform.MATRIX, axes=axes)
    return plan.batched_csr(vals)


def assemble_rhs_batched(plan: AssemblyPlan, form, coords_batch=None,
                         leaves_batch=None) -> jnp.ndarray:
    """Batched linear-form assembly → ``(B, num_dofs)`` (see
    :func:`assemble_batched` for the batching conventions)."""
    spec, merged, coords, axes = _lower_batched(
        plan, form, weakform.VECTOR, coords_batch, leaves_batch
    )
    return _assemble_batched_flat(coords, merged, static=plan.static,
                                  spec=spec, arity=weakform.VECTOR, axes=axes)


# -- shard_map element-parallel assembly -------------------------------------
#
# The named FEM mesh axis is registered in repro.sharding.partitioning
# (FEM_MESH_AXIS / fem_mesh); it is resolved lazily here so importing the
# core never drags in the LM sharding stack.

def _fem_axis_name() -> str:
    from ..sharding.partitioning import FEM_MESH_AXIS

    return FEM_MESH_AXIS


def _default_fem_mesh(axis_name: str):
    from ..sharding.partitioning import fem_mesh

    return fem_mesh(axis_name=axis_name)


def _assemble_sharded_flat(coords, leaves, *, static, spec, arity, mesh, axis_name):
    fn = _cached_form_fn(
        ("sharded", static, spec, arity, mesh, axis_name),
        lambda: partial(_sharded_impl, static=static, spec=spec, arity=arity,
                        mesh=mesh, axis_name=axis_name),
    )
    if not telemetry.is_enabled():
        return fn(coords, leaves)
    t0 = time.perf_counter()
    out = fn(coords, leaves)
    is_mat = arity == weakform.MATRIX
    telemetry.record_assembly(
        "assemble_sharded" if is_mat else "assemble_rhs_sharded",
        num_dofs=static.num_dofs,
        nnz=static.mat_routing.nnz if is_mat else None,
        num_cells=int(coords.shape[0]),
        form="+".join(kind for kind, _, _ in spec),
        wall_us=(time.perf_counter() - t0) * 1e6,
    )
    return out


def _sharded_impl(coords, leaves, *, static, spec, arity, mesh, axis_name):
    """Partition the element axis of the Map stage over ``mesh[axis_name]``;
    each device reduces its element block to *partial* global contributions
    (full nnz / touched-dof length) and one all-reduce completes the Reduce.

    Elements are zero-cost padded to a multiple of the device count: padded
    rows replicate the last element's geometry but carry out-of-range
    segment ids, which ``segment_sum`` drops.

    The per-shard reduce always uses the direct (unsorted scatter-add)
    segment ids regardless of ``plan.static.reduce_mode``: the sorted
    layout's global permutation interleaves elements across shards and does
    not decompose into per-shard sorted runs.  Both modes are deterministic
    and the psum of partials is bit-stable, so results still match the
    single-device path.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    _N_CORE_TRACES[0] += 1
    telemetry.count_trace("assembly", static, spec, backend="sharded")
    ndev = mesh.shape[axis_name]
    e = coords.shape[0]
    pad = (-e) % ndev
    routing = static.mat_routing if arity == weakform.MATRIX else static.vec_routing
    n_seg = routing.nnz if arity == weakform.MATRIX else routing.touched.shape[0]
    slots = routing.seg_ids_unsorted.shape[0] // e

    # static numpy precompute (host constants baked per trace)
    seg = routing.seg_ids_unsorted.reshape(e, slots)
    seg = np.concatenate([seg, np.full((pad, slots), n_seg, dtype=seg.dtype)])

    def pad_rows(x):
        return jnp.concatenate(
            [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])]
        ) if pad else x

    coords_p = pad_rows(coords)
    scd = static.scalar_cell_dofs
    scd_p = pad_rows(scd) if scd is not None else None

    # shard leaves whose leading axis is the element axis; replicate the
    # rest (scalars, nodal fields, constant vectors) — mirrors the shape
    # resolution order of forms.eval_coefficient
    leaf_flags = tuple(
        jnp.ndim(lv) >= 1 and jnp.shape(lv)[0] == e for lv in leaves
    )
    leaves_p = tuple(
        pad_rows(jnp.asarray(lv)) if flag else jnp.asarray(lv)
        for lv, flag in zip(leaves, leaf_flags)
    )
    leaf_specs = tuple(P(axis_name) if flag else P() for flag in leaf_flags)
    scd_args = (scd_p,) if scd_p is not None else ()
    scd_specs = ((P(axis_name),) if scd_p is not None else ())

    def body(coords_s, seg_s, *rest):
        scd_s = rest[0] if scd_p is not None else None
        leaf_s = rest[1:] if scd_p is not None else rest
        ctx = geometry_context(
            coords_s, static.geo_phi, static.geo_grad, static.phi,
            static.gradhat, static.w, scalar_cell_dofs=scd_s,
        )
        local_sum, facet_sums = _map_stage(static, ctx, spec, leaf_s)
        assert not facet_sums, "sharded assembly is volume-only (checked above)"
        part = jax.ops.segment_sum(
            local_sum.reshape(-1), seg_s.reshape(-1), num_segments=n_seg
        )
        return jax.lax.psum(part, axis_name)

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_name), P(axis_name)) + scd_specs + leaf_specs,
        out_specs=P(),
        check_rep=False,
    )
    packed = sharded(coords_p, jnp.asarray(seg), *scd_args, *leaves_p)
    if arity == weakform.MATRIX:
        return packed
    out = jnp.zeros((routing.num_dofs,), dtype=packed.dtype)
    return out.at[routing.touched_dev].set(packed)


def _assemble_sharded_vals(plan, form, arity, mesh, axis_name, coords):
    spec, leaves = weakform.lower(form, arity)
    if any(domain is not None for _, domain, _ in spec):
        raise NotImplementedError(
            "sharded assembly supports volume terms only — assemble facet "
            "terms separately and inject (FacetAssembler.injection_into)"
        )
    if axis_name is None:
        axis_name = _fem_axis_name()
    if mesh is None:
        mesh = _default_fem_mesh(axis_name)
    c = plan.coords if coords is None else coords
    return _assemble_sharded_flat(c, leaves, static=plan.static, spec=spec,
                                  arity=arity, mesh=mesh, axis_name=axis_name)


def assemble_sharded(plan: AssemblyPlan, form, mesh=None,
                     axis_name: str | None = None, coords=None) -> CSR:
    """Opt-in multi-device assembly: the element axis of the Map stage is
    ``shard_map``-partitioned over ``mesh[axis_name]`` (default: the FEM
    mesh from :func:`repro.sharding.partitioning.fem_mesh` over all local
    devices); the segment-sum Reduce is completed by a single all-reduce
    over partial nnz contributions.  Matches single-device assembly to
    machine precision."""
    vals = _assemble_sharded_vals(plan, form, weakform.MATRIX, mesh,
                                  axis_name, coords)
    return plan.csr(vals)


def assemble_rhs_sharded(plan: AssemblyPlan, form, mesh=None,
                         axis_name: str | None = None,
                         coords=None) -> jnp.ndarray:
    """Sharded linear-form assembly (see :func:`assemble_sharded`)."""
    return _assemble_sharded_vals(plan, form, weakform.VECTOR, mesh,
                                  axis_name, coords)


def clear_assembly_caches():
    """Drop the functional core's compiled-executable cache.

    The pure entry points key executables on identity-hashed ``PlanStatic``
    aux + form signature in a module-level FIFO-bounded cache (shared with
    the :class:`GalerkinAssembler` facade), so each cached entry retains its
    plan's tables and executable.  The bound caps growth automatically;
    sweeps that mint many short-lived plans (mesh-refinement studies) can
    call this to release everything at once.  Also drops the sparse
    pattern-array device mirrors, which pin host+device copies of each
    pattern that flowed through matvec/solve.
    """
    from .sparse import clear_device_mirrors

    _FORM_FNS.clear()
    clear_device_mirrors()


# ---------------------------------------------------------------------------
# The assembler facade (cache-owning; every pre-plan call site keeps working)
# ---------------------------------------------------------------------------

class GalerkinAssembler:
    """Thin cache-owning facade over an :class:`AssemblyPlan`.

    One instance per (mesh topology × element × quadrature) signature.  All
    tables live in ``self.plan`` — the class adds only the per-signature jit
    cache (`n_traces` retrace accounting) and the historical method surface.
    Re-instantiating for a same-signature mesh reuses XLA executables via
    jit's cache (shape-bucketed compilation, DESIGN §2).
    """

    def __init__(self, space: FunctionSpace, quad_order: int | None = None,
                 reduce_mode: str = "direct"):
        self.space = space
        self.mesh = space.mesh
        self.element = space.element
        self.reduce_mode = reduce_mode

        self.plan = build_plan(space, quad_order, reduce_mode)
        st = self.plan.static
        # compatibility aliases onto the plan's static tables
        self.w, self.phi, self.gradhat = st.w, st.phi, st.gradhat
        self.geo_phi, self.geo_grad = st.geo_phi, st.geo_grad
        self.coords = self.plan.coords
        self._scalar_cell_dofs = st.scalar_cell_dofs
        self.mat_routing = st.mat_routing
        self.vec_routing = st.vec_routing

        # One compiled executable per (plan, form signature), owned by the
        # module-level jit cache and SHARED with the pure assemble()/
        # assemble_rhs() entry points.  n_traces counts retraces — repeated
        # assembly with new coefficient *values* must not grow it.  Callable
        # coefficients are part of the signature (identity-keyed): per-call
        # lambdas each compile fresh, so hot loops should reuse stable
        # function objects (or pre-evaluate callables to quadrature arrays,
        # as MixedBCPoisson does); clear_assembly_caches() releases the
        # accumulated executables.
        self.n_traces = 0

    # -- context -------------------------------------------------------------
    def context(self, coords: jnp.ndarray | None = None) -> forms.FormContext:
        return self.plan.context(coords)

    def csr(self, vals: jnp.ndarray) -> CSR:
        return self.plan.csr(vals)

    # -- form API: one fused Map, one Reduce, jit-cached per signature --------
    def assemble(self, form, coords=None) -> CSR:
        """Assemble a bilinear :class:`~repro.core.weakform.WeakForm` into a
        CSR on the volume pattern.

        All volume terms are evaluated in **one fused Map** against a shared
        geometry context (built from ``coords`` inside the jit boundary),
        summed element-wise, and reduced **once**; facet terms (e.g.
        ``robin(alpha, on=facets)``) reduce through their facet routing and
        are injected into the volume CSR pattern.  Coefficients and scale
        factors are traced — a θ-step ``mass(c) + dt*diffusion(kappa)`` or a
        SIMP-interpolated ``elasticity(lam, mu, scale=rho**p)`` compiles one
        XLA executable reused across coefficient values.
        """
        return self.csr(self._assemble_vals(form, weakform.MATRIX, coords))

    def assemble_rhs(self, form, coords=None) -> jnp.ndarray:
        """Assemble a linear form (``source`` / ``neumann`` / ``reaction``
        terms) into a global ``(num_dofs,)`` vector — same fused pipeline."""
        return self._assemble_vals(form, weakform.VECTOR, coords)

    def assemble_batched(self, form, coords_batch=None,
                         leaves_batch=None) -> BatchedCSR:
        """Batched multi-instance assembly — see :func:`assemble_batched`."""
        return assemble_batched(self.plan, form, coords_batch, leaves_batch)

    def assemble_rhs_batched(self, form, coords_batch=None,
                             leaves_batch=None) -> jnp.ndarray:
        """Batched linear forms — see :func:`assemble_rhs_batched`."""
        return assemble_rhs_batched(self.plan, form, coords_batch, leaves_batch)

    def assemble_sharded(self, form, mesh=None,
                         axis_name: str | None = None) -> CSR:
        """Element-parallel multi-device assembly — see
        :func:`assemble_sharded`."""
        return assemble_sharded(self.plan, form, mesh, axis_name)

    def _assemble_vals(self, form, arity: str, coords=None):
        """Delegate to the module-level jitted core so the facade and the
        pure ``assemble(plan, form)`` entry point share ONE executable per
        (plan, signature); ``n_traces`` is derived from the core's trace
        counter (a delta of zero means the executable was reused)."""
        spec, leaves = weakform.lower(form, arity)
        _check_facet_coords(spec, coords)
        before = n_core_traces()
        out = _assemble_flat(
            self.plan.coords if coords is None else coords, leaves,
            static=self.plan.static, spec=spec, arity=arity,
        )
        self.n_traces += n_core_traces() - before
        return out

    # -- deprecated shims over the form API -----------------------------------
    @staticmethod
    def _warn_deprecated(name: str, replacement: str):
        warnings.warn(
            f"GalerkinAssembler.{name} is deprecated; use {replacement}",
            DeprecationWarning,
            stacklevel=3,
        )

    def assemble_stiffness(self, rho=None, coords=None) -> CSR:
        """Deprecated: use ``assemble(weakform.diffusion(rho))``."""
        self._warn_deprecated("assemble_stiffness", "assemble(weakform.diffusion(rho))")
        return self.assemble(weakform.diffusion(rho), coords)

    def assemble_mass(self, c=None, coords=None) -> CSR:
        """Deprecated: use ``assemble(weakform.mass(c))``."""
        self._warn_deprecated("assemble_mass", "assemble(weakform.mass(c))")
        return self.assemble(weakform.mass(c), coords)

    def assemble_elasticity(self, lam: float, mu: float, scale=None, coords=None) -> CSR:
        """Deprecated: use ``assemble(weakform.elasticity(lam, mu, scale))``."""
        self._warn_deprecated(
            "assemble_elasticity", "assemble(weakform.elasticity(lam, mu, scale))"
        )
        return self.assemble(weakform.elasticity(lam, mu, scale), coords)

    def assemble_load(self, f=None, coords=None) -> jnp.ndarray:
        """Deprecated: use ``assemble_rhs(weakform.source(f))``."""
        self._warn_deprecated("assemble_load", "assemble_rhs(weakform.source(f))")
        return self.assemble_rhs(weakform.source(f), coords)

    def assemble_reaction_load(self, u_nodal, fn) -> jnp.ndarray:
        """Deprecated: use ``assemble_rhs(weakform.reaction(u_nodal, fn))``."""
        self._warn_deprecated(
            "assemble_reaction_load", "assemble_rhs(weakform.reaction(u_nodal, fn))"
        )
        return self.assemble_rhs(weakform.reaction(u_nodal, fn))

    # -- baselines (paper Fig. 1 "white box") ----------------------------------
    def assemble_stiffness_scatter(self, rho=None) -> jnp.ndarray:
        """Dense scatter-add baseline: K.at[rows, cols].add(k_local)."""
        ctx = self.context(None)
        k_local = forms.diffusion(ctx, rho)
        n = self.space.num_dofs
        cd = jnp.asarray(self.space.cell_dofs)
        rows = jnp.broadcast_to(cd[:, :, None], k_local.shape).reshape(-1)
        cols = jnp.broadcast_to(cd[:, None, :], k_local.shape).reshape(-1)
        return jnp.zeros((n, n)).at[rows, cols].add(k_local.reshape(-1))

    def assemble_stiffness_loop(self, rho=None) -> np.ndarray:
        """Python per-element loop (the classical Alg.; O(E) graph/time).
        numpy, small meshes only — exists to quantify the paper's claim."""
        el, mesh, sp = self.element, self.mesh, self.space
        pts, w = el.default_rule(None)
        gradhat = el.tabulate_grad(pts)
        k = np.zeros((sp.num_dofs, sp.num_dofs))
        pts_np = np.asarray(self.coords)
        geo_grad = np.asarray(self.geo_grad)
        for e in range(mesh.num_cells):
            x = pts_np[e]
            j = np.einsum("ai,qaj->qij", x, geo_grad)
            det = np.abs(np.linalg.det(j))
            jinv = np.linalg.inv(j)
            g = np.einsum("qji,qaj->qai", jinv, gradhat)
            ke = np.einsum("q,q,qai,qbi->ab", w, det, g, g)
            dofs = sp.cell_dofs[e]
            for a in range(len(dofs)):
                for b in range(len(dofs)):
                    k[dofs[a], dofs[b]] += ke[a, b]
        return k
