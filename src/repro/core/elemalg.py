"""Element tensor-algebra layer: dense local algebra on ``(E, k, k)`` tensors.

TensorGalerkin's Map stage materializes every per-element tensor ``K_e``
on-device; the global solvers normally consume them only through a flat
scatter (assembly) or a gather→action→scatter apply (matrix-free).  This
module — the JAX analogue of Firedrake's Slate — treats the same tensors as
a *batch of dense matrices* and does linear algebra on them directly:

* :func:`factorize` / :class:`ElementFactors` — batched Cholesky (kernels
  declared ``spd`` in :data:`repro.core.weakform.KERNELS`) or LU (advection,
  general anisotropic tensors) over all E elements at once, with
  :meth:`ElementFactors.solve` back-substitution.
* :func:`block_partition` — static row/column sub-blocks ``K_e[rows, cols]``.
* **Static condensation** (:func:`vertex_split` → :func:`condense` →
  :func:`condensed_solve`): split the higher-order (edge/bubble) DOFs of a
  P2/P3 space from the vertex interface DOFs and solve the Schur complement
  ``S = K_bb − K_bi K_ii⁻¹ K_ib`` on the interface only — a strictly
  smaller global system with better conditioning (for P2 the interface is
  ~1/4 of the DOFs), applied entirely through per-element blocks (no global
  matrix), with exact recovery of the interior unknowns and ``custom_vjp``
  gradients identical to the uncondensed adjoint.
* Two matrix-free **preconditioners**, registered into the
  :func:`repro.core.solvers.register_preconditioner` registry on import:

  - ``"ebe"`` (:func:`ebe_preconditioner`): element-by-element additive
    Schwarz — the diagonally-scaled, regularized element matrices
    ``C_e = I + s K_e s`` (``s = diag(A)^{-1/2}``) are Cholesky/LU-factorized
    once, and each application solves all E local systems batched and
    scatters through the existing vector routing.  Never forms a global
    matrix; SPD by construction, so CG-safe.
  - ``"chebyshev"`` (:func:`chebyshev_preconditioner`): a fixed-degree
    Chebyshev polynomial in ``D⁻¹A`` over an eigenvalue window estimated by
    a few power iterations (run once at factory time, before the Krylov
    ``while_loop``).  Works for any operator with ``matvec``/``diagonal``
    (CSR included); costs ``degree`` extra matvecs per application and cuts
    the CG iteration count by roughly that factor.

Everything here is trace-compatible and differentiable; nothing ever
materializes a global matrix, so the ``operator_state_bytes`` gauge of a
matrix-free solve is unchanged by preconditioning.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..telemetry import annotate, events
from .assembly import reduce_vector
from .solvers import (
    SolverSpec,
    _info_aux,
    _method,
    register_preconditioner,
)
from .sparse import _dev, cached_diagonal

__all__ = [
    "ElementFactors",
    "factorize",
    "block_partition",
    "masked_element_matrices",
    "DofSplit",
    "dof_split",
    "vertex_split",
    "CondensedSystem",
    "condense",
    "condensed_solve",
    "ebe_preconditioner",
    "chebyshev_preconditioner",
]


# ---------------------------------------------------------------------------
# Batched factorize / solve / block-partition primitives
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ElementFactors:
    """A batched factorization of ``(E, k, k)`` element tensors.

    ``piv is None`` ⇒ Cholesky factors (lower-triangular ``(E, k, k)``);
    otherwise LU factors with ``(E, k)`` pivots.  A pytree, so factors can
    cross jit/vmap boundaries."""

    data: jnp.ndarray
    piv: jnp.ndarray | None = None

    def tree_flatten(self):
        return (self.data, self.piv), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def is_cholesky(self) -> bool:
        return self.piv is None

    def solve(self, rhs: jnp.ndarray) -> jnp.ndarray:
        """Back-substitute all E local systems at once: ``rhs`` is ``(E, k)``
        or ``(E, k, m)``; returns the same shape."""
        vec = rhs.ndim == 2
        r = rhs[..., None] if vec else rhs
        if self.piv is None:
            y = jax.scipy.linalg.solve_triangular(self.data, r, lower=True)
            x = jax.scipy.linalg.solve_triangular(
                jnp.swapaxes(self.data, -1, -2), y, lower=False
            )
        else:
            x = jax.vmap(
                lambda lu, piv, b: jax.scipy.linalg.lu_solve((lu, piv), b)
            )(self.data, self.piv, r)
        return x[..., 0] if vec else x


def factorize(k_e: jnp.ndarray, spd: bool = False) -> ElementFactors:
    """Factorize a batch of element tensors: Cholesky when ``spd`` (the
    kernel-declared route — diffusion/mass/elasticity), batched LU with
    partial pivoting otherwise (advection, general anisotropic tensors)."""
    if spd:
        return ElementFactors(jnp.linalg.cholesky(k_e), None)
    lu, piv = jax.vmap(jax.scipy.linalg.lu_factor)(k_e)
    return ElementFactors(lu, piv)


def block_partition(k_e: jnp.ndarray, rows, cols=None) -> jnp.ndarray:
    """The static sub-block ``K_e[rows, cols]`` of every element tensor —
    ``rows``/``cols`` are local-slot index arrays (``cols`` defaults to
    ``rows``).  Returns ``(E, len(rows), len(cols))``."""
    rows = np.asarray(rows)
    cols = rows if cols is None else np.asarray(cols)
    return k_e[:, rows[:, None], cols[None, :]]


def masked_element_matrices(op) -> jnp.ndarray:
    """``op.element_matrices()`` with Dirichlet rows/columns zeroed per the
    operator's ``free_mask`` (matching the condensed apply's
    ``y = m·A(m·x) + (1−m)·x`` up to the unit diagonal, which callers
    reinstate globally)."""
    base = op if hasattr(op, "element_matrices") else getattr(op, "op", op)
    k_e = base.element_matrices()
    fm = getattr(base, "free_mask", None)
    if fm is None:
        return k_e
    me = fm.astype(k_e.dtype)[_dev(base.static.cell_dofs)]
    return k_e * me[:, :, None] * me[:, None, :]


def _base_op(op):
    """Unwrap to the element-tensor-bearing operator (a sharded wrapper
    delegates to its inner MatFreeOperator)."""
    if hasattr(op, "element_matrices"):
        return op
    inner = getattr(op, "op", None)
    if inner is not None and hasattr(inner, "element_matrices"):
        return inner
    raise TypeError(
        f"{type(op).__name__} carries no element tensors — element-level "
        "algebra (ebe preconditioner, static condensation) needs a "
        "matrix-free operator (repro.core.matfree_operator); assembled CSR "
        "solves can use precond='jacobi' or 'chebyshev'"
    )


# ---------------------------------------------------------------------------
# Static condensation: interface/interior split + Schur-complement system
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class DofSplit:
    """An interface/interior partition of a space's DOFs that is *uniform in
    local slots*: every element sees the same local slots as interface
    (kept in the condensed system) and interior (eliminated).  Identity-
    hashed (``eq=False``) so it can ride as a jit static argument."""

    interface_mask: np.ndarray   # (n,) bool — True = interface DOF
    interface_slots: np.ndarray  # (kb,) local slots holding interface DOFs
    interior_slots: np.ndarray   # (ki,) local slots holding interior DOFs


def dof_split(cell_dofs, interface_mask) -> DofSplit:
    """Build a :class:`DofSplit` from the element DOF map and a boolean
    interface mask, checking the split is slot-uniform across elements
    (true for the vertex/higher-order split of any nodal element here)."""
    cd = np.asarray(cell_dofs)
    im = np.asarray(interface_mask, dtype=bool)
    slot_if = im[cd]                      # (E, k)
    col_if = slot_if.all(axis=0)
    col_in = (~slot_if).all(axis=0)
    if not (col_if | col_in).all():
        bad = np.where(~(col_if | col_in))[0]
        raise ValueError(
            f"interface split is not slot-uniform: local slots {bad.tolist()} "
            "mix interface and interior DOFs across elements"
        )
    if not col_in.any():
        raise ValueError(
            "no interior DOFs to condense — static condensation needs a "
            "degree ≥ 2 space (P2/P3: edge/bubble DOFs)"
        )
    return DofSplit(im, np.where(col_if)[0], np.where(col_in)[0])


def vertex_split(space) -> DofSplit:
    """The canonical condensation split of a P2/P3 space: vertex DOFs are
    the interface, every higher-order (edge/bubble) DOF is interior."""
    nv = space.mesh.num_vertices
    v = space.value_size
    im = (np.arange(space.num_dofs) // v) < nv
    return dof_split(space.cell_dofs, im)


@dataclasses.dataclass(frozen=True, eq=False)
class _Scaffold:
    """Host-built static tables of one condensed system: compact interface/
    interior numberings and the per-element gather maps into them (index
    ``nb``/``ni`` is the padding segment for Dirichlet-constrained DOFs,
    whose element rows/columns are already masked to zero)."""

    cell_b: np.ndarray        # (E, kb) compact interface ids, nb = padding
    cell_i: np.ndarray        # (E, ki) compact interior ids, ni = padding
    interface_dofs: np.ndarray  # (nb,) global ids of free interface DOFs
    interior_dofs: np.ndarray   # (ni,) global ids of free interior DOFs
    nb: int
    ni: int
    n: int


def _build_scaffold(static, split: DofSplit, free_mask) -> _Scaffold:
    cd = np.asarray(static.cell_dofs)
    n = static.num_dofs
    free = (
        np.ones(n, dtype=bool) if free_mask is None
        else np.asarray(free_mask) > 0
    )
    b_dofs = np.where(split.interface_mask & free)[0]
    i_dofs = np.where(~split.interface_mask & free)[0]
    nb, ni = b_dofs.shape[0], i_dofs.shape[0]
    lut_b = np.full(n, nb, dtype=np.int64)
    lut_b[b_dofs] = np.arange(nb)
    lut_i = np.full(n, ni, dtype=np.int64)
    lut_i[i_dofs] = np.arange(ni)
    return _Scaffold(
        cell_b=lut_b[cd[:, split.interface_slots]],
        cell_i=lut_i[cd[:, split.interior_slots]],
        interface_dofs=b_dofs, interior_dofs=i_dofs, nb=nb, ni=ni, n=n,
    )


# scaffold per (plan static, split, bc mask) identity — strong refs keep the
# keys alive so ids cannot be recycled, same idiom as sparse._DEVICE_MIRRORS
_SCAFFOLDS: dict[tuple, tuple] = {}
_SCAFFOLDS_LIMIT = 64


def _scaffold(op, split: DofSplit) -> _Scaffold:
    key = (id(op.static), id(split), id(op.free_mask))
    hit = _SCAFFOLDS.get(key)
    if hit is not None:
        return hit[1]
    sc = _build_scaffold(op.static, split, op.free_mask)
    while len(_SCAFFOLDS) >= _SCAFFOLDS_LIMIT:
        _SCAFFOLDS.pop(next(iter(_SCAFFOLDS)))
    _SCAFFOLDS[key] = ((op.static, split, op.free_mask), sc)
    return sc


def _gather(x, idx_dev):
    """Pad-gather: compact vector + one trailing zero, indexed by a map that
    sends constrained DOFs to the padding slot."""
    return jnp.concatenate([x, jnp.zeros((1,), x.dtype)])[idx_dev]


def _scatter(y_local, idx_dev, num):
    out = jax.ops.segment_sum(
        y_local.reshape(-1), idx_dev.reshape(-1), num_segments=num + 1
    )
    return out[:num]


_INNER_DEFAULT = SolverSpec(method="cg", tol=1e-12, atol=1e-12, maxiter=2000,
                            precond="jacobi")


@dataclasses.dataclass(frozen=True, eq=False)
class CondensedSystem:
    """The interface Schur-complement system of a matrix-free operator,
    applied entirely through per-element blocks.

    ``S x_b = (K_bb − K_bi K_ii⁻¹ K_ib) x_b`` where every block apply is a
    gather → batched ``(E, ·, ·)`` block product → compact scatter, and
    ``K_ii⁻¹`` is an inner CG on the (well-conditioned, for P2 the
    edge-edge block) interior system — preconditioned element-by-element
    with the Cholesky/LU-factorized interior blocks.  Nothing global is
    ever formed; ``shape`` is ``(nb, nb)`` with ``nb < n``.
    """

    op: object                 # the (bc-condensed) MatFreeOperator
    split: DofSplit
    kbb: jnp.ndarray           # (E, kb, kb)
    kbi: jnp.ndarray           # (E, kb, ki)
    kib: jnp.ndarray           # (E, ki, kb)
    kii: jnp.ndarray           # (E, ki, ki)
    ii_factors: ElementFactors  # factorized regularized interior blocks
    diag_b: jnp.ndarray        # (nb,) assembled interface diagonal
    diag_i: jnp.ndarray        # (ni,) assembled interior diagonal
    sc: _Scaffold
    inner: SolverSpec

    @property
    def shape(self) -> tuple[int, int]:
        return (self.sc.nb, self.sc.nb)

    @property
    def full_shape(self) -> tuple[int, int]:
        return (self.sc.n, self.sc.n)

    # -- block applies ----------------------------------------------------
    def _apply_block(self, block, x, idx_in, idx_out, num_out):
        xe = _gather(x, idx_in)
        ye = jnp.einsum("eab,eb->ea", block, xe)
        return _scatter(ye, idx_out, num_out)

    def kbb_matvec(self, xb):
        cb = _dev(self.sc.cell_b)
        return self._apply_block(self.kbb, xb, cb, cb, self.sc.nb)

    def kii_matvec(self, xi):
        ci = _dev(self.sc.cell_i)
        return self._apply_block(self.kii, xi, ci, ci, self.sc.ni)

    def kib_matvec(self, xb):
        return self._apply_block(
            self.kib, xb, _dev(self.sc.cell_b), _dev(self.sc.cell_i),
            self.sc.ni)

    def kbi_matvec(self, xi):
        return self._apply_block(
            self.kbi, xi, _dev(self.sc.cell_i), _dev(self.sc.cell_b),
            self.sc.nb)

    # -- interior solve (inner Krylov, EbE-preconditioned) ----------------
    def _ii_precond(self):
        inv = jnp.where(jnp.abs(self.diag_i) > 0, 1.0 / self.diag_i, 1.0)
        if self.inner.precond == "ebe":
            dinv_sqrt = jnp.sqrt(jnp.abs(inv))
            ci = _dev(self.sc.cell_i)
            fac = self.ii_factors

            def m(x):
                xs = _gather(x * dinv_sqrt, ci)
                return _scatter(fac.solve(xs), ci, self.sc.ni) * dinv_sqrt
            return m
        if self.inner.precond in ("identity", "none"):
            return lambda x: x
        return lambda x: inv * x  # jacobi (default)

    def ii_solve(self, fi, x0=None):
        solver = _method(self.inner.method)
        return solver(self.kii_matvec, fi, x0, tol=self.inner.tol,
                      atol=self.inner.atol, maxiter=self.inner.maxiter,
                      m=self._ii_precond())

    # -- the Schur apply --------------------------------------------------
    def matvec(self, xb):
        with annotate("tg.elemalg.schur_apply"):
            yi, _ = self.ii_solve(self.kib_matvec(xb))
            return self.kbb_matvec(xb) - self.kbi_matvec(yi)

    rmatvec = matvec  # condensation requires a symmetric operator

    def diagonal(self):
        # diag(K_bb): the Jacobi surrogate for diag(S) (S's true diagonal
        # would cost nb interior solves)
        return self.diag_b

    # -- rhs reduction / interior recovery --------------------------------
    def reduce_rhs(self, b):
        fb = b[_dev(self.sc.interface_dofs)]
        fi = b[_dev(self.sc.interior_dofs)]
        wi, _ = self.ii_solve(fi)
        return fb - self.kbi_matvec(wi)

    def recover(self, xb, b):
        """Exact interior recovery ``u_i = K_ii⁻¹ (f_i − K_ib u_b)`` and
        re-expansion to the full DOF vector (constrained DOFs take their
        lifted values from ``b``, matching the uncondensed condensed-operator
        solve)."""
        fi = b[_dev(self.sc.interior_dofs)]
        ui, _ = self.ii_solve(fi - self.kib_matvec(xb))
        x = jnp.zeros(self.sc.n, dtype=xb.dtype)
        x = x.at[_dev(self.sc.interface_dofs)].set(xb)
        x = x.at[_dev(self.sc.interior_dofs)].set(ui)
        fm = getattr(self.op, "free_mask", None)
        if fm is not None:
            m = fm.astype(x.dtype)
            x = m * x + (1.0 - m) * b
        return x

    def solve(self, b, spec: SolverSpec | None = None):
        """Full condensed solve: reduce the rhs, run the outer Krylov on the
        interface Schur system, recover the interior.  Returns
        ``(x_full, SolveInfo)`` — the info counts *outer* iterations."""
        spec = _COND_DEFAULT if spec is None else spec
        g = self.reduce_rhs(b)
        if spec.precond in ("identity", "none"):
            m = lambda x: x  # noqa: E731
        else:
            inv = jnp.where(jnp.abs(self.diag_b) > 0, 1.0 / self.diag_b, 1.0)
            m = lambda x: inv * x  # noqa: E731
        xb, info = _method(spec.method)(
            self.matvec, g, tol=spec.tol, atol=spec.atol,
            maxiter=spec.maxiter, m=m)
        return self.recover(xb, b), info


_COND_DEFAULT = SolverSpec(method="cg", tol=1e-10, atol=1e-10, maxiter=10000,
                           precond="jacobi")


def condense(op, split: DofSplit, inner: SolverSpec | None = None,
             transpose: bool = False) -> CondensedSystem:
    """Build the interface Schur-complement system of ``op`` (a matrix-free
    operator, normally already ``.condensed(bc)``) for a
    :class:`DofSplit` — see :class:`CondensedSystem`."""
    base = _base_op(op)
    sc = _scaffold(base, split)
    with annotate("tg.elemalg.condense"):
        k_e = masked_element_matrices(base)
        if transpose:
            k_e = jnp.swapaxes(k_e, -1, -2)
        bs, is_ = split.interface_slots, split.interior_slots
        kbb = block_partition(k_e, bs)
        kbi = block_partition(k_e, bs, is_)
        kib = block_partition(k_e, is_, bs)
        kii = block_partition(k_e, is_)
        diag = cached_diagonal(base)
        diag_b = diag[_dev(sc.interface_dofs)]
        diag_i = diag[_dev(sc.interior_dofs)]
        # regularized interior blocks for the inner EbE preconditioner:
        # I + s K_ii s is symmetric positive definite whenever K_e is PSD
        inv_i = jnp.where(jnp.abs(diag) > 0, 1.0 / jnp.abs(diag), 1.0)
        s_e = jnp.sqrt(_gather(inv_i[_dev(sc.interior_dofs)], _dev(sc.cell_i)))
        c_e = jnp.eye(kii.shape[-1], dtype=kii.dtype) + (
            s_e[:, :, None] * kii * s_e[:, None, :]
        )
        ii_factors = factorize(c_e, spd=base.is_spd())
    return CondensedSystem(
        op=base, split=split, kbb=kbb, kbi=kbi, kib=kib, kii=kii,
        ii_factors=ii_factors, diag_b=diag_b, diag_i=diag_i, sc=sc,
        inner=_INNER_DEFAULT if inner is None else inner,
    )


# ---------------------------------------------------------------------------
# Differentiable condensed solve: same adjoint structure as matfree_solve
# ---------------------------------------------------------------------------

def _cond_impl(op, b, spec, inner, split, transpose=False):
    return condense(op, split, inner=inner, transpose=transpose).solve(b, spec)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _condensed_solve(op, b, spec, inner, split, return_info):
    x, info = _cond_impl(op, b, spec, inner, split)
    return (x, _info_aux(info)) if return_info else x


def _cond_fwd(op, b, spec, inner, split, return_info):
    x, info = _cond_impl(op, b, spec, inner, split)
    out = (x, _info_aux(info)) if return_info else x
    return out, (op, x)


def _cond_bwd(spec, inner, split, return_info, res, g):
    op, x = res
    gx = g[0] if return_info else g
    # adjoint Aᵀλ = ḡ through the *condensed* path (A symmetric up to the
    # element-tensor transpose, handled explicitly)
    lam, adj_info = _cond_impl(op, gx, spec, inner, split, transpose=True)
    events.record_solve("condensed_solve.adjoint", adj_info,
                        method=spec.method, precond="condensed",
                        phase="adjoint")
    # operator cotangent exactly as matfree_solve: vjp of the full apply —
    # independent of how the forward system was solved
    _, pullback = jax.vjp(lambda o: o.matvec(x), op)
    (d_op,) = pullback(-lam)
    return (d_op, lam)


_condensed_solve.defvjp(_cond_fwd, _cond_bwd)


def condensed_solve(op, b, spec: SolverSpec | None = None, *,
                    split: DofSplit | None = None, space=None,
                    inner_spec: SolverSpec | None = None,
                    return_info: bool = False):
    """Solve ``A x = b`` by static condensation: eliminate the higher-order
    interior DOFs element-wise and run the Krylov iteration on the interface
    Schur complement only.

    ``op`` is a (bc-condensed) :class:`~repro.core.operator.MatFreeOperator`
    of a symmetric form on a degree ≥ 2 space; pass the ``split`` from
    :func:`vertex_split`/:func:`dof_split` (or ``space=`` to derive it).
    The solution matches the uncondensed solve to solver tolerance, interior
    unknowns are recovered exactly through the same inner interior solves,
    and gradients (via ``custom_vjp``) match the uncondensed adjoint path.
    ``return_info=True`` reports *outer* interface iterations — strictly
    fewer than the full-system CG on the same problem.
    """
    if split is None:
        if space is None:
            raise TypeError("condensed_solve needs split= (see vertex_split)"
                            " or space=")
        split = vertex_split(space)
    spec = _COND_DEFAULT if spec is None else spec
    inner = _INNER_DEFAULT if inner_spec is None else inner_spec
    out = _condensed_solve(op, b, spec, inner, split, bool(return_info))
    if return_info:
        x, info = out
        events.record_solve("condensed_solve", info, method=spec.method,
                            backend="matfree", precond="condensed")
        return x, info
    return out


# ---------------------------------------------------------------------------
# Element-by-element (EbE) preconditioner
# ---------------------------------------------------------------------------

def ebe_preconditioner(op, *, theta: float = 0.25):
    """Element-by-element additive-Schwarz preconditioner from local
    factorizations — no global matrix.

    ``M⁻¹ = D^{-1/2} (Σ_e Pᵉ C_e⁻¹ Pᵉᵀ) D^{-1/2}`` with the regularized,
    diagonally-scaled element matrices ``C_e = θI + s K_e s``
    (``s = D^{-1/2}`` gathered per element).  ``C_e`` is symmetric positive
    definite whenever the element tensors are PSD (the raw ``K_e`` are
    singular — constant nullspace — which is why the ``θI`` regularization
    is part of the classical EbE construction), so the factorization is a
    batched Cholesky for ``spd``-declared kernels and the preconditioner is
    SPD — CG-safe.  Smaller ``θ`` strengthens the element coupling the
    preconditioner captures; ``θ = 0.25`` measured best across scalar/
    vector/anisotropic test problems.  Dirichlet DOFs pass through untouched
    (their element rows/columns are masked, the global unit diagonal is
    reinstated)."""
    base = _base_op(op)
    k_e = masked_element_matrices(base)
    d = cached_diagonal(op)
    dinv_sqrt = jnp.sqrt(jnp.where(jnp.abs(d) > 0, 1.0 / jnp.abs(d), 1.0))
    cd = _dev(base.static.cell_dofs)
    s_e = dinv_sqrt[cd]
    c_e = theta * jnp.eye(k_e.shape[-1], dtype=k_e.dtype) + (
        s_e[:, :, None] * k_e * s_e[:, None, :]
    )
    fac = factorize(c_e, spd=base.is_spd())
    st = base.static
    fm = base.free_mask

    def m(x):
        with annotate("tg.precond.ebe_apply"):
            xe = (x * dinv_sqrt)[cd]
            y = reduce_vector(fac.solve(xe), st.vec_routing, st.reduce_mode)
            y = y * dinv_sqrt
            if fm is not None:
                mask = fm.astype(x.dtype)
                y = mask * y + (1.0 - mask) * x
            return y

    return m


# ---------------------------------------------------------------------------
# Chebyshev polynomial preconditioner
# ---------------------------------------------------------------------------

def chebyshev_preconditioner(op, *, degree: int = 3, power_iters: int = 10,
                             eig_ratio: float = 30.0, safety: float = 1.05):
    """Chebyshev polynomial preconditioner on the Jacobi-scaled operator.

    ``λ_max(D⁻¹A)`` is estimated by ``power_iters`` power iterations (run
    once here, at factory time — *before* the Krylov ``while_loop``), then
    each application runs the degree-``degree`` Chebyshev recurrence for
    ``A z = r`` on the eigenvalue window ``[λ_max/eig_ratio, λ_max]``: a
    fixed polynomial ``z = p(D⁻¹A) D⁻¹ r``, hence a *linear, SPD*
    preconditioner — CG-safe, unlike restarting an inner Krylov.  Costs
    ``degree`` matvecs per application and needs only ``matvec`` +
    ``diagonal``, so it works for CSR and matrix-free operators alike."""
    d = cached_diagonal(op)
    dinv = jnp.where(jnp.abs(d) > 0, 1.0 / d, 1.0)
    matvec = op.matvec

    # deterministic start vector, not orthogonal to the dominant eigenvector
    n = d.shape[0]
    v0 = jnp.ones(n, d.dtype) + 0.5 * jnp.cos(
        jnp.arange(n, dtype=d.dtype))
    v0 = v0 / jnp.linalg.norm(v0)

    def body(_, v):
        w = dinv * matvec(v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, power_iters, body, v0)
    w = dinv * matvec(v)
    lam_max = jnp.vdot(v, w) / jnp.vdot(v, v) * safety
    lam_min = lam_max / eig_ratio
    theta = 0.5 * (lam_max + lam_min)
    delta = 0.5 * (lam_max - lam_min)
    sigma = theta / delta

    def m(r):
        # classical Chebyshev iteration for A z = r, z₀ = 0 (Jacobi-scaled)
        with annotate("tg.precond.chebyshev_apply"):
            rho = 1.0 / sigma
            dz = dinv * r / theta
            z = dz
            res = r - matvec(dz)
            for _ in range(degree - 1):
                rho_new = 1.0 / (2.0 * sigma - rho)
                dz = rho_new * rho * dz + (2.0 * rho_new / delta) * (dinv * res)
                rho = rho_new
                z = z + dz
                res = res - matvec(dz)
            return z

    return m


register_preconditioner("ebe", ebe_preconditioner)
register_preconditioner("chebyshev", chebyshev_preconditioner)
