"""Unified matvec-backend registry — ONE dispatch point for the inner loop.

Every Krylov solve, θ/Newmark rollout, residual loss and problem ``.solve``
ultimately spends its time in ``y = A @ x``.  Historically each consumer
re-derived its own dispatch (``transient.stepping.make_matvec``, the
``use_ell`` flag in ``fem.tensormesh``, ad-hoc ``csr_to_ell`` call sites);
this module is the single registry they all consume:

=============  =============================================================
backend        apply path
=============  =============================================================
``csr``        gather + sorted segment-sum on the assembled values
               (differentiable; the adjoint-solve default)
``ell``        padded ELLPACK gather, pure jnp (bounded-valence FEM layout)
``ell_pallas`` the Pallas TPU SpMV kernel over the ELL layout (broadcast
               plan: ``x`` replicated into VMEM per row block)
``ell_stream`` the streaming Pallas SpMV: ``x`` stays HBM-resident, row
               blocks double-buffered through VMEM by async DMA — VMEM use
               is independent of N, so million-DOF operators fit
``matfree``    element-local Map → per-element action → scatter-Reduce,
               no global values (:mod:`repro.core.operator`)
``matfree_sharded``  the matrix-free apply ``shard_map``-partitioned over
               the element axis of the local device mesh: per-device
               partial scatter + one psum — a single Krylov solve spans
               all devices (:class:`repro.core.operator.ShardedMatFreeOperator`)
=============  =============================================================

``make_matvec(op, backend)`` returns the apply closure;
``make_residual(op, backend)`` returns the fused ``(u, f) ↦ K·u − f``
(the ``ell_pallas`` variant runs the fused
:func:`repro.kernels.ell_residual` kernel — one pass, no extra HBM
round-trip).  Third-party backends register with
:func:`register_matvec_backend`.
"""

from __future__ import annotations

from typing import Callable

from .. import telemetry
from .sparse import CSR, csr_to_ell

__all__ = [
    "MATVEC_BACKENDS",
    "matvec_backends",
    "register_matvec_backend",
    "make_matvec",
    "make_residual",
]


def _require_csr(op, backend: str) -> CSR:
    if not isinstance(op, CSR):
        raise TypeError(
            f"backend {backend!r} needs an assembled CSR operator, got "
            f"{type(op).__name__} — assemble first, or use backend='matfree'"
        )
    return op


def _require_matfree(op):
    from .operator import LinearOperator

    if isinstance(op, CSR):
        raise TypeError(
            "backend 'matfree' needs a matrix-free operator: build one with "
            "repro.core.matfree_operator(plan, form) instead of assembling"
        )
    if not isinstance(op, LinearOperator):
        raise TypeError(
            f"backend 'matfree' needs a LinearOperator, got {type(op).__name__}"
        )
    return op


def _csr_matvec(op) -> Callable:
    return op.matvec  # CSR / BatchedCSR / LinearOperator all expose matvec


def _ell_matvec(op) -> Callable:
    return csr_to_ell(_require_csr(op, "ell")).matvec


def _ell_pallas_matvec(op) -> Callable:
    from ..kernels import ell_matvec

    ell = csr_to_ell(_require_csr(op, "ell_pallas"))
    return lambda x: ell_matvec(ell, x)


def _ell_stream_matvec(op) -> Callable:
    from ..kernels import ell_matvec_stream

    ell = csr_to_ell(_require_csr(op, "ell_stream"))
    return lambda x: ell_matvec_stream(ell, x)


def _matfree_matvec(op) -> Callable:
    return _require_matfree(op).matvec


def _as_sharded(op):
    from .operator import MatFreeOperator, ShardedMatFreeOperator

    op = _require_matfree(op)
    if isinstance(op, ShardedMatFreeOperator):
        return op
    if isinstance(op, MatFreeOperator):
        return op.sharded()
    raise TypeError(
        "backend 'matfree_sharded' needs a MatFreeOperator (or an already "
        f"sharded one), got {type(op).__name__}"
    )


def _matfree_sharded_matvec(op) -> Callable:
    return _as_sharded(op).matvec


def _csr_residual(op) -> Callable:
    return lambda u, f: op.matvec(u) - f


def _ell_residual(op) -> Callable:
    ell = csr_to_ell(_require_csr(op, "ell"))
    return lambda u, f: ell.matvec(u) - f


def _ell_pallas_residual(op) -> Callable:
    from ..kernels import ell_residual

    ell = csr_to_ell(_require_csr(op, "ell_pallas"))
    return lambda u, f: ell_residual(ell, u, f)


def _ell_stream_residual(op) -> Callable:
    from ..kernels import ell_residual_stream

    ell = csr_to_ell(_require_csr(op, "ell_stream"))
    return lambda u, f: ell_residual_stream(ell, u, f)


def _matfree_residual(op) -> Callable:
    mv = _require_matfree(op).matvec
    return lambda u, f: mv(u) - f


def _matfree_sharded_residual(op) -> Callable:
    mv = _as_sharded(op).matvec
    return lambda u, f: mv(u) - f


# name -> (matvec factory, residual factory)
_BACKENDS: dict[str, tuple[Callable, Callable]] = {
    "csr": (_csr_matvec, _csr_residual),
    "ell": (_ell_matvec, _ell_residual),
    "ell_pallas": (_ell_pallas_matvec, _ell_pallas_residual),
    "ell_stream": (_ell_stream_matvec, _ell_stream_residual),
    "matfree": (_matfree_matvec, _matfree_residual),
    "matfree_sharded": (_matfree_sharded_matvec, _matfree_sharded_residual),
}

# the BUILT-IN backends — a constant, never rebound, so every import-time
# copy (repro.core re-export, deprecated transient.stepping forward) stays
# valid.  Custom backends added via register_matvec_backend dispatch through
# make_matvec/make_residual without appearing here; use matvec_backends()
# for the live set.
MATVEC_BACKENDS = tuple(_BACKENDS)


def matvec_backends() -> tuple[str, ...]:
    """The currently registered backend names (built-ins + custom)."""
    return tuple(_BACKENDS)


def register_matvec_backend(name: str, matvec_factory: Callable,
                            residual_factory: Callable | None = None,
                            *, overwrite: bool = False) -> None:
    """Register a custom backend: ``matvec_factory(op) -> (x ↦ A x)`` and an
    optional fused-residual factory (defaults to ``matvec(u) − f``)."""
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"matvec backend {name!r} already registered")
    if residual_factory is None:
        def residual_factory(op, _mf=matvec_factory):
            mv = _mf(op)
            return lambda u, f: mv(u) - f
    _BACKENDS[name] = (matvec_factory, residual_factory)


def _lookup(backend: str):
    entry = _BACKENDS.get(backend)
    if entry is None:
        raise ValueError(
            f"unknown matvec backend {backend!r}; use one of {tuple(_BACKENDS)}"
        )
    return entry


def make_matvec(op, backend: str = "csr") -> Callable:
    """``x ↦ A @ x`` for the chosen inner-loop backend (table above)."""
    telemetry.counter_inc("matvec_backend", 1, backend=backend, role="matvec")
    return _lookup(backend)[0](op)


def make_residual(op, backend: str = "csr") -> Callable:
    """``(u, f) ↦ A·u − f`` — the Galerkin-residual inner op of the
    TensorPILS losses, fused where the backend supports it."""
    telemetry.counter_inc("matvec_backend", 1, backend=backend, role="residual")
    return _lookup(backend)[1](op)
