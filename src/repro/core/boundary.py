"""Boundary conditions.

* **Dirichlet** — hard constraints via in-pattern condensation (the paper's
  "reducing the linear system"): rows/columns of constrained DoFs are masked,
  unit diagonal inserted, RHS lifted by ``F ← F − K·u_D`` — all with *static*
  masks precomputed from the DoF set so the operation is a handful of fused
  element-wise ops inside jit (pattern and graph stay O(1)).
* **Neumann / Robin** — assembled on boundary facets through the *same*
  Map-Reduce pipeline (facet contexts + facet routing; paper SM B.1.5).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import forms
from .assembly import facet_context, reduce_matrix, reduce_vector
from .elements import get_element
from .mesh import FunctionSpace
from .routing import build_matrix_routing, build_vector_routing
from .sparse import CSR

__all__ = ["DirichletCondenser", "FacetAssembler"]


class DirichletCondenser:
    """Precomputes the static masks that impose ``u[dofs] = values``."""

    def __init__(self, space_or_routing, bc_dofs: np.ndarray):
        routing = getattr(space_or_routing, "mat_routing", space_or_routing)
        self.num_dofs = routing.num_dofs
        self.bc_dofs = np.asarray(bc_dofs, dtype=np.int64)
        is_bc = np.zeros(self.num_dofs, dtype=bool)
        is_bc[self.bc_dofs] = True
        self.is_bc = is_bc
        row_bc = is_bc[routing.row_of_nnz]
        col_bc = is_bc[routing.indices]
        self.keep_mask = jnp.asarray(~(row_bc | col_bc), dtype=float)
        # diag entries of constrained rows -> 1.0
        diag_of_bc = routing.diag_pos[self.bc_dofs]
        assert np.all(diag_of_bc >= 0), "constrained DoF missing diagonal entry"
        self.diag_of_bc = jnp.asarray(diag_of_bc)
        self.free_mask = jnp.asarray(~is_bc, dtype=float)
        # device mirrors staged once (not per traced call)
        self._bc_dofs_dev = jnp.asarray(self.bc_dofs)
        self._is_bc_dev = jnp.asarray(is_bc)

    def boundary_field(self, values, dtype=None) -> jnp.ndarray:
        """Expand Dirichlet data to a full ``(num_dofs,)`` field ``u_D``.

        ``values`` may be a scalar, a ``(n_bc,)`` array (one entry per
        constrained DoF, in ``bc_dofs`` order), or a full ``(num_dofs,)``
        field whose non-constrained entries are ignored.  Traced values are
        fine — all branching is on static shapes, so this works per-step
        inside ``lax.scan`` (time-varying boundary data).
        """
        values = jnp.asarray(values, dtype=dtype)
        u_d = jnp.zeros(self.num_dofs, dtype=values.dtype)
        if values.ndim == 0:
            return u_d.at[self._bc_dofs_dev].set(values)
        if values.shape == (self.bc_dofs.shape[0],):
            return u_d.at[self._bc_dofs_dev].set(values)
        if values.shape == (self.num_dofs,):
            # where(), not multiplication: free-DoF entries must be *ignored*,
            # even when non-finite (0 * NaN would leak into the lift matvec)
            return jnp.where(self._is_bc_dev, values, 0.0).astype(values.dtype)
        raise ValueError(f"un-interpretable Dirichlet value shape {values.shape}")

    def lift(self, k: CSR, f: jnp.ndarray, values=0.0) -> jnp.ndarray:
        """RHS-only condensation: ``F ← F − K u_D`` on free rows, ``F[bc] = g``.

        The matrix half of the condensation (:meth:`apply_matrix_only`) is
        value-independent, so for time-varying Dirichlet data the condensed
        matrix is hoisted out of the time loop and only this cheap lift runs
        per step — no condenser rebuild inside ``lax.scan``.  ``k`` must be
        the *uncondensed* matrix (the lift needs the constrained columns).
        """
        u_d = self.boundary_field(values, dtype=f.dtype)
        f_lift = (f - k.matvec(u_d)) * self.free_mask
        bc = self._bc_dofs_dev
        return f_lift.at[bc].set(u_d[bc])

    def apply(self, k: CSR, f: jnp.ndarray, values=0.0) -> tuple[CSR, jnp.ndarray]:
        """Return the condensed system (same sparsity pattern)."""
        return self.apply_matrix_only(k), self.lift(k, f, values)

    def apply_matrix_only(self, k: CSR) -> CSR:
        """Mask constrained rows/columns, unit diagonal.  The masks broadcast
        over leading axes, so this also condenses a whole ``BatchedCSR``
        family ((B, nnz) vals) in one fused elementwise op."""
        vals = k.vals * self.keep_mask.astype(k.vals.dtype)
        vals = vals.at[..., self.diag_of_bc].set(1.0)
        return dataclasses.replace(k, vals=vals)

    def project_residual(self, r: jnp.ndarray) -> jnp.ndarray:
        """Zero residual entries on constrained DoFs (for loss functions)."""
        return r * self.free_mask.astype(r.dtype)


class FacetAssembler:
    """Boundary-facet Map-Reduce: Robin matrices and Neumann loads that share
    the *volume* DoF numbering, so their reduce lands directly in the global
    system.  For matrix terms, the facet routing is built over the same
    ``num_dofs`` and merged CSR patterns are avoided by assembling into the
    volume pattern via an injection map (facet-nnz -> volume-nnz).

    A ``FacetAssembler`` is also the *integration domain* of boundary terms
    in the weak-form API — ``weakform.robin(alpha, on=fa)`` /
    ``weakform.neumann(g, on=fa)`` — where :meth:`context` supplies the
    facet geometry inside the fused assembly trace and
    :meth:`injection_into` supplies the nnz injection into the volume
    pattern of the assembling :class:`~repro.core.assembly.GalerkinAssembler`.
    """

    def __init__(self, space: FunctionSpace, facets: np.ndarray,
                 volume_routing=None, quad_order: int | None = None):
        assert space.value_size == 1, "facet terms implemented for scalar spaces"
        self.space = space
        mesh = space.mesh
        if mesh.cell_type != "tri":
            raise NotImplementedError("facet assembly: 2D triangles")
        el = get_element("P1_line")
        pts, w = el.default_rule(quad_order)
        self.w = jnp.asarray(w)
        self.phi = jnp.asarray(el.tabulate(pts))
        self.gradhat = jnp.asarray(el.tabulate_grad(pts))
        self.facets = np.asarray(facets, dtype=np.int64)       # (F, 2) vertex ids
        self.coords = jnp.asarray(mesh.points[self.facets])    # (F, 2, d)
        self._facet_dofs_dev = jnp.asarray(self.facets)
        self.vec_routing = build_vector_routing(self.facets, space.num_dofs)
        self.mat_routing = build_matrix_routing(self.facets, None, space.num_dofs)
        self._injections: dict = {}    # id(volume_routing) -> (routing, pos)
        self._vol_injection = None
        if volume_routing is not None:
            self._vol_injection = self.injection_into(volume_routing)

    def injection_into(self, volume_routing) -> np.ndarray:
        """Positions of this facet pattern's nnz inside a volume CSR pattern
        (precomputed numpy, cached per volume routing)."""
        hit = self._injections.get(id(volume_routing))
        if hit is not None:
            return hit[1]
        n = self.space.num_dofs
        vol_key = volume_routing.row_of_nnz * n + volume_routing.indices
        fac_key = self.mat_routing.row_of_nnz * n + self.mat_routing.indices
        pos = np.searchsorted(vol_key, fac_key)
        assert np.all(vol_key[pos] == fac_key), "facet entry outside volume pattern"
        # keep the routing alive so the id() key stays unique
        self._injections[id(volume_routing)] = (volume_routing, pos)
        return pos

    def context(self) -> forms.FormContext:
        return facet_context(
            self.coords, self.phi, self.gradhat, self.w,
            scalar_facet_dofs=self._facet_dofs_dev,
        )

    def neumann_load(self, g) -> jnp.ndarray:
        """∫_Γ g φ over the facet set → global (num_dofs,) vector."""
        ctx = self.context()
        f_local = forms.load(ctx, g)
        return reduce_vector(f_local, self.vec_routing)

    def robin_matrix_vals(self, alpha) -> jnp.ndarray:
        """∫_Γ α φφ — returns vals aligned with the *volume* CSR pattern."""
        ctx = self.context()
        k_local = forms.mass(ctx, alpha)
        vals = reduce_matrix(k_local, self.mat_routing)
        assert self._vol_injection is not None, "need volume_routing for Robin"
        return vals, self._vol_injection

    def add_robin(self, k: CSR, alpha) -> CSR:
        vals, inj = self.robin_matrix_vals(alpha)
        return dataclasses.replace(
            k, vals=k.vals.at[jnp.asarray(inj)].add(vals.astype(k.vals.dtype))
        )
