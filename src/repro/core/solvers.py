"""Iterative sparse solvers + the differentiable solve (paper Eq. 11).

* :func:`cg`, :func:`bicgstab` — preconditioned Krylov solvers as
  ``lax.while_loop`` (O(1) trace size; matches the paper's solver setup:
  BiCGSTAB + Jacobi, tol 1e-10, maxiter 10k — SM Table B.1).  Both return
  ``(x, SolveInfo)`` where :class:`SolveInfo` carries the iteration count,
  the final residual norm and a ``converged`` flag set from the exit
  condition — an exit at ``maxiter`` is *visible*, not silent garbage.
* :class:`SolverSpec` — one frozen, hashable value object for the solver
  knobs ``(method, tol, atol, maxiter, precond)``.  Every solve entry point
  (:func:`sparse_solve`, :func:`matfree_solve`, both ``_batched`` variants,
  problem ``.solve()``, the transient integrators) accepts ``spec=``; the
  old per-kwarg form still works but emits a :class:`DeprecationWarning`.
  Because a spec is hashable it is also the jit/custom-vjp static argument
  and the ``repro.serve`` admission-key component.
* preconditioner registry — :func:`register_preconditioner` maps a name to
  a ``factory(op) -> m(x)`` (mirroring :mod:`repro.core.matvec`'s backend
  registry).  Built-ins: ``identity``/``none``, ``jacobi``; the element
  tensor-algebra layer (:mod:`repro.core.elemalg`) registers ``ebe`` and
  ``chebyshev`` on import (resolved lazily here, so ``SolverSpec(precond=
  "chebyshev")`` works without importing elemalg first).
* :func:`sparse_solve` — ``jax.custom_vjp``: the backward pass solves the
  adjoint system ``Kᵀλ = ḡ`` with the *same* solver and emits the **sparse**
  cotangent ``∂/∂vals = −λ[rows]·U[cols]`` (only at stored nnz positions) and
  ``∂/∂F = λ``.  This is the TORCH-SLA trick: O(1) extra graph nodes per
  optimization iteration instead of O(iters × DoFs) from unrolling.
* :func:`matfree_solve` — the same adjoint structure for ANY pytree linear
  operator (notably :class:`repro.core.operator.MatFreeOperator`): the
  backward pass solves ``Aᵀλ = ḡ`` via ``rmatvec`` and obtains the operator
  cotangent as the vjp of ``θ ↦ A(θ)·x`` at ``−λ`` — so ``grad`` through a
  matrix-free solve matches the assembled adjoint path without ever
  materializing values.

Convergence diagnostics (``repro.telemetry``): every solve entry point
accepts ``return_info=True`` and then returns ``(x, SolveInfo)``.  The info
is a **non-differentiated auxiliary output** — its leaves are stop-gradient,
so the ``custom_vjp`` adjoint structure is untouched and ``jax.grad``
through the info-returning path matches the plain path to machine
precision.  Solve events are labelled with method *and* preconditioner, so
the telemetry iteration histograms split per preconditioner.

``cg`` / ``bicgstab`` accept either a matvec callable or any object with a
``.matvec`` method (CSR, MatFreeOperator); :func:`jacobi_preconditioner`
needs only ``.diagonal()`` — for matrix-free operators that is a cheap
diagonal-only assembly, memoized per operator identity through
:func:`repro.core.sparse.cached_diagonal`.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..telemetry import annotate, events, spans
from .sparse import CSR, BatchedCSR, _dev, cached_diagonal

__all__ = [
    "cg",
    "bicgstab",
    "SolverSpec",
    "resolve_solver_spec",
    "register_preconditioner",
    "make_preconditioner",
    "jacobi_preconditioner",
    "sparse_solve",
    "sparse_solve_batched",
    "matfree_solve",
    "matfree_solve_batched",
    "SolveInfo",
]


class SolveInfo(NamedTuple):
    """Per-solve diagnostics: iteration count, final residual norm, and the
    exit condition (``converged = ‖r‖ ≤ max(tol·‖b‖, atol)``).  Leaves are
    jnp arrays — a batched / per-step solve stacks them (``(B,)`` /
    ``(n_steps,)``)."""

    iters: jnp.ndarray
    residual: jnp.ndarray
    converged: jnp.ndarray


def _info_aux(info: SolveInfo) -> SolveInfo:
    """The info as a non-differentiated auxiliary output: stop-gradient on
    every leaf, so returning it cannot perturb the adjoint structure."""
    return SolveInfo(*(jax.lax.stop_gradient(leaf) for leaf in info))


# ---------------------------------------------------------------------------
# SolverSpec: the solver knobs as one frozen, hashable value object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Solver configuration ``(method, tol, atol, maxiter, precond)`` as a
    frozen, hashable value object.

    One spec flows from the public entry points through the ``custom_vjp``
    static arguments into the Krylov loop, and doubles as the solver part of
    the ``repro.serve`` admission key — requests with different specs never
    co-batch.  ``precond`` names a registered preconditioner (see
    :func:`register_preconditioner`) or is a ``factory(op) -> m`` callable.
    """

    method: str = "bicgstab"
    tol: float = 1e-10
    atol: float = 1e-10
    maxiter: int = 10000
    precond: str | Callable = "jacobi"

    def replace(self, **kw) -> "SolverSpec":
        return dataclasses.replace(self, **kw)

    @property
    def precond_name(self) -> str:
        return self.precond if isinstance(self.precond, str) else getattr(
            self.precond, "__name__", "custom")


_LEGACY_POS = ("tol", "atol", "maxiter", "precond")


def resolve_solver_spec(spec, legacy_pos=(), *, method=None, tol=None,
                        atol=None, maxiter=None, precond=None,
                        default: SolverSpec | None = None,
                        where: str = "solve") -> SolverSpec:
    """Fold a ``spec=`` argument and/or legacy per-kwarg arguments into one
    :class:`SolverSpec`.

    The pre-redesign signatures took ``method, tol, atol, maxiter, precond``
    positionally after the right-hand side; those forms still work — a bare
    string in the spec slot is the legacy ``method``, ``legacy_pos`` maps to
    ``(tol, atol, maxiter, precond)`` — but any legacy use emits a
    ``DeprecationWarning`` naming the entry point.
    """
    base_default = SolverSpec() if default is None else default
    if isinstance(spec, str):
        if method is not None:
            raise TypeError(f"{where}: got both a positional method string "
                            f"({spec!r}) and method={method!r}")
        method, spec = spec, None
    if spec is not None and not isinstance(spec, SolverSpec):
        raise TypeError(
            f"{where}: spec must be a SolverSpec (got {type(spec).__name__});"
            " build one with repro.core.SolverSpec(method=..., tol=...)"
        )
    if len(legacy_pos) > len(_LEGACY_POS):
        raise TypeError(f"{where}: too many positional arguments")
    legacy = dict(zip(_LEGACY_POS, legacy_pos))
    for name, val in (("method", method), ("tol", tol), ("atol", atol),
                      ("maxiter", maxiter), ("precond", precond)):
        if val is not None:
            if name in legacy:
                raise TypeError(f"{where}: {name} given positionally and as "
                                "a keyword")
            legacy[name] = val
    if not legacy:
        return spec if spec is not None else base_default
    warnings.warn(
        f"{where}: passing method/tol/atol/maxiter/precond individually is "
        f"deprecated — pass spec=SolverSpec({', '.join(f'{k}={v!r}' for k, v in legacy.items())})",
        DeprecationWarning, stacklevel=3,
    )
    base = spec if spec is not None else base_default
    return dataclasses.replace(base, **legacy)


# defaults per entry point: the paper's BiCGSTAB+Jacobi for assembled CSR
# systems, CG+Jacobi for the (symmetric-by-construction) matrix-free path
_SPARSE_DEFAULT = SolverSpec(method="bicgstab")
_MATFREE_DEFAULT = SolverSpec(method="cg")


# ---------------------------------------------------------------------------
# Preconditioner registry (mirrors repro.core.matvec's backend registry)
# ---------------------------------------------------------------------------

def jacobi_preconditioner(a) -> Callable:
    """Diagonal (Jacobi) preconditioner from anything with ``.diagonal()`` —
    an assembled :class:`CSR` or a matrix-free operator (diagonal-only
    assembly, no nnz vector).  The diagonal is memoized per (operator
    identity, dtype) via :func:`repro.core.sparse.cached_diagonal`, so
    repeated solves against the same operator skip the re-densification."""
    d = cached_diagonal(a)
    inv = jnp.where(jnp.abs(d) > 0, 1.0 / d, 1.0)
    return lambda x: inv * x


def _identity(x):
    return x


_PRECONDITIONERS: dict[str, Callable] = {}


def register_preconditioner(name: str, factory: Callable, *,
                            overwrite: bool = False):
    """Register ``factory(op) -> m`` under ``name`` so any
    :class:`SolverSpec` (and the legacy ``precond=`` kwarg) can select it.

    ``op`` is whatever reaches the solve (CSR, MatFreeOperator, ...);
    ``m(x)`` must be trace-compatible (it runs inside the Krylov
    ``while_loop``).  Mirrors :func:`repro.core.matvec.register_matvec_backend`.
    """
    if name in _PRECONDITIONERS and not overwrite:
        raise ValueError(
            f"preconditioner {name!r} already registered; pass overwrite=True"
        )
    _PRECONDITIONERS[name] = factory


register_preconditioner("identity", lambda op: _identity)
register_preconditioner("none", lambda op: _identity)
register_preconditioner("jacobi", jacobi_preconditioner)


def make_preconditioner(op, precond="jacobi") -> Callable:
    """Resolve a preconditioner name (or ``factory`` callable, or ``None``
    for identity) against ``op`` via the registry.  Unknown names raise a
    ``KeyError`` listing what is registered."""
    if precond is None:
        return _identity
    if callable(precond):
        return precond(op)
    factory = _PRECONDITIONERS.get(precond)
    if factory is None and precond in ("ebe", "chebyshev"):
        from . import elemalg  # noqa: F401  (registers ebe/chebyshev)
        factory = _PRECONDITIONERS.get(precond)
    if factory is None:
        raise KeyError(
            f"unknown preconditioner {precond!r}; registered: "
            f"{sorted(_PRECONDITIONERS)} — add one with "
            "repro.core.register_preconditioner(name, factory)"
        )
    return factory(op)


def _as_matvec(a) -> Callable:
    """Normalize an operator argument: a callable is used as-is, anything
    else must expose ``.matvec`` (CSR, MatFreeOperator, ELL)."""
    return a if callable(a) else a.matvec


# ---------------------------------------------------------------------------
# Conjugate gradients (SPD systems: Poisson, elasticity)
# ---------------------------------------------------------------------------

def cg(matvec, b, x0=None, *, tol=1e-10, atol=1e-10, maxiter=10000, m=_identity):
    matvec = _as_matvec(matvec)
    x0 = jnp.zeros_like(b) if x0 is None else x0
    bnorm = jnp.linalg.norm(b)
    target = jnp.maximum(tol * bnorm, atol)

    r0 = b - matvec(x0)
    z0 = m(r0)
    state = (x0, r0, z0, z0, jnp.vdot(r0, z0), jnp.array(0))

    def cond(s):
        _, r, *_, it = s
        return (jnp.linalg.norm(r) > target) & (it < maxiter)

    def body(s):
        x, r, z, p, rz, it = s
        ap = matvec(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = m(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (x, r, z, p, rz_new, it + 1)

    with annotate("tg.solve.cg"):
        x, r, *_, it = jax.lax.while_loop(cond, body, state)
    rnorm = jnp.linalg.norm(r)
    return x, SolveInfo(it, rnorm, rnorm <= target)


# ---------------------------------------------------------------------------
# BiCGSTAB (general systems; the paper's default — van der Vorst 1992)
# ---------------------------------------------------------------------------

def bicgstab(matvec, b, x0=None, *, tol=1e-10, atol=1e-10, maxiter=10000, m=_identity):
    matvec = _as_matvec(matvec)
    x0 = jnp.zeros_like(b) if x0 is None else x0
    bnorm = jnp.linalg.norm(b)
    target = jnp.maximum(tol * bnorm, atol)

    r0 = b - matvec(x0)
    rhat = r0
    state = (
        x0, r0,
        jnp.ones((), b.dtype), jnp.ones((), b.dtype), jnp.ones((), b.dtype),
        jnp.zeros_like(b), jnp.zeros_like(b),
        jnp.array(0),
    )

    def cond(s):
        _, r, *_, it = s
        return (jnp.linalg.norm(r) > target) & (it < maxiter)

    def body(s):
        x, r, rho, alpha, omega, v, p, it = s
        rho_new = jnp.vdot(rhat, r)
        beta = (rho_new / jnp.where(rho == 0, 1e-30, rho)) * (
            alpha / jnp.where(omega == 0, 1e-30, omega)
        )
        p = r + beta * (p - omega * v)
        phat = m(p)
        v = matvec(phat)
        denom = jnp.vdot(rhat, v)
        alpha = rho_new / jnp.where(denom == 0, 1e-30, denom)
        s_vec = r - alpha * v
        shat = m(s_vec)
        t = matvec(shat)
        tt = jnp.vdot(t, t)
        omega = jnp.vdot(t, s_vec) / jnp.where(tt == 0, 1e-30, tt)
        x = x + alpha * phat + omega * shat
        r = s_vec - omega * t
        return (x, r, rho_new, alpha, omega, v, p, it + 1)

    with annotate("tg.solve.bicgstab"):
        x, r, *_, it = jax.lax.while_loop(cond, body, state)
    rnorm = jnp.linalg.norm(r)
    return x, SolveInfo(it, rnorm, rnorm <= target)


_METHODS = {"cg": cg, "bicgstab": bicgstab}


def _method(name):
    try:
        return _METHODS[name]
    except KeyError:
        raise KeyError(
            f"unknown solver method {name!r}; use one of {sorted(_METHODS)}"
        ) from None


# ---------------------------------------------------------------------------
# Differentiable sparse solve (TORCH-SLA analogue)
# ---------------------------------------------------------------------------

def _solve_impl(a: CSR, b, spec: SolverSpec, transpose=False):
    matvec = a.rmatvec if transpose else a.matvec
    m = make_preconditioner(a, spec.precond)
    return _method(spec.method)(matvec, b, tol=spec.tol, atol=spec.atol,
                                maxiter=spec.maxiter, m=m)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _sparse_solve(a: CSR, b, spec: SolverSpec, return_info):
    x, info = _solve_impl(a, b, spec)
    return (x, _info_aux(info)) if return_info else x


def _solve_fwd(a, b, spec, return_info):
    x, info = _solve_impl(a, b, spec)
    out = (x, _info_aux(info)) if return_info else x
    return out, (a, x)


def _solve_bwd(spec, return_info, res, g):
    a, x = res
    gx = g[0] if return_info else g
    # adjoint: Kᵀ λ = ḡ   (Eq. 11; sign handled by the chain rule caller)
    lam, adj_info = _solve_impl(a, gx, spec, transpose=True)
    # adjoint-solve diagnostics: recorded when the backward pass runs with
    # concrete cotangents (eager grad); a no-op under further tracing
    events.record_solve("sparse_solve.adjoint", adj_info, method=spec.method,
                        precond=spec.precond_name, phase="adjoint")
    # ∂L/∂vals = −λ_r · x_c at each stored (r, c) — never densified
    dvals = -lam[_dev(a.row_of_nnz)] * x[_dev(a.indices)]
    da = CSR(dvals, a.indptr, a.indices, a.row_of_nnz, a.shape, a.diag_pos)
    return (da, lam)


_sparse_solve.defvjp(_solve_fwd, _solve_bwd)


def sparse_solve(a: CSR, b, spec: SolverSpec | None = None, *legacy,
                 method=None, tol=None, atol=None, maxiter=None, precond=None,
                 return_info=False):
    """x = A⁻¹ b, differentiable w.r.t. ``a.vals`` and ``b`` via the adjoint.

    Solver knobs come in as one :class:`SolverSpec` (``spec=``; default
    BiCGSTAB + Jacobi at 1e-10).  The legacy per-kwarg form
    (``method=, tol=, ...``) still works but emits a ``DeprecationWarning``.

    ``return_info=True`` additionally returns the :class:`SolveInfo`
    (iterations / final residual / ``converged``) as a stop-gradient
    auxiliary output — gradients are bit-identical to the plain path.
    """
    spec = resolve_solver_spec(spec, legacy, method=method, tol=tol,
                               atol=atol, maxiter=maxiter, precond=precond,
                               default=_SPARSE_DEFAULT, where="sparse_solve")
    # span-aware eager boundary: the solve (host dispatch wall) becomes a
    # span — child of any open request/driver span — and the record_solve
    # event inherits its trace identity
    with spans.span("sparse_solve", method=spec.method, backend="csr"):
        out = _sparse_solve(a, b, spec, bool(return_info))
        if return_info:
            x, info = out
            events.record_solve("sparse_solve", info, method=spec.method,
                                backend="csr", precond=spec.precond_name)
            return x, info
        return out


# ---------------------------------------------------------------------------
# Differentiable matrix-free solve: the adjoint trick for pytree operators
# ---------------------------------------------------------------------------

def _op_solve_impl(op, b, spec: SolverSpec, transpose=False):
    matvec = op.rmatvec if transpose else op.matvec
    m = make_preconditioner(op, spec.precond)
    return _method(spec.method)(matvec, b, tol=spec.tol, atol=spec.atol,
                                maxiter=spec.maxiter, m=m)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _matfree_solve(op, b, spec: SolverSpec, return_info):
    x, info = _op_solve_impl(op, b, spec)
    return (x, _info_aux(info)) if return_info else x


def _matfree_fwd(op, b, spec, return_info):
    x, info = _op_solve_impl(op, b, spec)
    out = (x, _info_aux(info)) if return_info else x
    return out, (op, x)


def _matfree_bwd(spec, return_info, res, g):
    op, x = res
    gx = g[0] if return_info else g
    lam, adj_info = _op_solve_impl(op, gx, spec, transpose=True)
    events.record_solve("matfree_solve.adjoint", adj_info, method=spec.method,
                        precond=spec.precond_name, phase="adjoint")
    # ∂L/∂θ = −λᵀ (∂A/∂θ) x — the vjp of the apply w.r.t. the operator pytree
    _, pullback = jax.vjp(lambda o: o.matvec(x), op)
    (d_op,) = pullback(-lam)
    return (d_op, lam)


_matfree_solve.defvjp(_matfree_fwd, _matfree_bwd)


def matfree_solve(op, b, spec: SolverSpec | None = None, *legacy,
                  method=None, tol=None, atol=None, maxiter=None, precond=None,
                  return_info=False):
    """``x = A⁻¹ b`` for any pytree linear operator with ``matvec`` /
    ``rmatvec`` / ``diagonal`` — differentiable w.r.t. the operator's traced
    leaves (coefficients, geometry) *and* ``b`` via the adjoint solve.

    The backward pass solves ``Aᵀλ = ḡ`` with the same Krylov method, then
    recovers the operator cotangent as ``vjp(θ ↦ A(θ)·x)(−λ)`` — for a
    :class:`~repro.core.operator.MatFreeOperator` that is one extra
    matrix-free apply-transpose, never an assembled matrix.  (A :class:`CSR`
    works too and reproduces :func:`sparse_solve`'s sparse cotangent.)

    Solver knobs come in as one :class:`SolverSpec` (default CG + Jacobi);
    legacy per-kwarg use emits a ``DeprecationWarning``.  ``return_info=True``
    additionally returns the :class:`SolveInfo` as a stop-gradient auxiliary
    output (gradients match the plain path).
    """
    spec = resolve_solver_spec(spec, legacy, method=method, tol=tol,
                               atol=atol, maxiter=maxiter, precond=precond,
                               default=_MATFREE_DEFAULT, where="matfree_solve")
    with spans.span("matfree_solve", method=spec.method, backend="matfree"):
        out = _matfree_solve(op, b, spec, bool(return_info))
        if return_info:
            x, info = out
            events.record_solve("matfree_solve", info, method=spec.method,
                                backend="matfree", precond=spec.precond_name)
            return x, info
        return out


def matfree_solve_batched(family, b, spec: SolverSpec | None = None, *legacy,
                          method=None, tol=None, atol=None, maxiter=None,
                          precond=None, return_info=False):
    """``X_b = A_b⁻¹ b_b`` over a matrix-free
    :class:`~repro.core.operator.MatFreeFamily` — one ``vmap`` of the
    differentiable :func:`matfree_solve` with the family's leaf axes, so the
    B Krylov solves (and their adjoint solves under ``grad``) share a single
    executable on one plan/signature, with zero matrix materialization.

    ``b`` is ``(B, n)`` per-instance or ``(n,)`` shared; returns ``(B, n)``
    (plus a ``SolveInfo`` with ``(B,)`` leaves under ``return_info=True``).
    Gradients w.r.t. the batched coefficient leaves match B per-instance
    adjoint :func:`matfree_solve` calls.
    """
    spec = resolve_solver_spec(spec, legacy, method=method, tol=tol,
                               atol=atol, maxiter=maxiter, precond=precond,
                               default=_MATFREE_DEFAULT,
                               where="matfree_solve_batched")
    b = jnp.asarray(b)
    in_b = None if b.ndim == 1 else 0
    with spans.span("matfree_solve_batched", method=spec.method,
                    backend="matfree"):
        out = jax.vmap(
            lambda op, bi: _matfree_solve(op, bi, spec, bool(return_info)),
            in_axes=(family.in_axes(), in_b),
        )(family.op, b)
        if return_info:
            x, info = out
            events.record_solve("matfree_solve_batched", info,
                                method=spec.method, backend="matfree",
                                precond=spec.precond_name)
            return x, info
        return out


def sparse_solve_batched(a: BatchedCSR, b, spec: SolverSpec | None = None,
                         *legacy, method=None, tol=None, atol=None,
                         maxiter=None, precond=None, return_info=False):
    """X_b = A_b⁻¹ b_b over a :class:`BatchedCSR` family — one ``vmap`` of the
    differentiable :func:`sparse_solve`, so the B Krylov solves share a
    single XLA executable (and a single adjoint executable under ``grad``).

    ``b`` is ``(B, n)`` per-instance or ``(n,)`` shared; returns ``(B, n)``
    (plus a ``SolveInfo`` with ``(B,)`` leaves under ``return_info=True``).
    """
    spec = resolve_solver_spec(spec, legacy, method=method, tol=tol,
                               atol=atol, maxiter=maxiter, precond=precond,
                               default=_SPARSE_DEFAULT,
                               where="sparse_solve_batched")
    b = jnp.asarray(b)
    in_b = None if b.ndim == 1 else 0
    with spans.span("sparse_solve_batched", method=spec.method,
                    backend="csr"):
        out = jax.vmap(
            lambda ab, bi: _sparse_solve(ab.as_csr(), bi, spec,
                                         bool(return_info)),
            in_axes=(0, in_b),
        )(a, b)
        if return_info:
            x, info = out
            events.record_solve("sparse_solve_batched", info,
                                method=spec.method, backend="csr",
                                precond=spec.precond_name)
            return x, info
        return out
