"""Iterative sparse solvers + the differentiable solve (paper Eq. 11).

* :func:`cg`, :func:`bicgstab` — preconditioned Krylov solvers as
  ``lax.while_loop`` (O(1) trace size; matches the paper's solver setup:
  BiCGSTAB + Jacobi, tol 1e-10, maxiter 10k — SM Table B.1).  Both return
  ``(x, SolveInfo)`` where :class:`SolveInfo` carries the iteration count,
  the final residual norm and a ``converged`` flag set from the exit
  condition — an exit at ``maxiter`` is *visible*, not silent garbage.
* :func:`sparse_solve` — ``jax.custom_vjp``: the backward pass solves the
  adjoint system ``Kᵀλ = ḡ`` with the *same* solver and emits the **sparse**
  cotangent ``∂/∂vals = −λ[rows]·U[cols]`` (only at stored nnz positions) and
  ``∂/∂F = λ``.  This is the TORCH-SLA trick: O(1) extra graph nodes per
  optimization iteration instead of O(iters × DoFs) from unrolling.
* :func:`matfree_solve` — the same adjoint structure for ANY pytree linear
  operator (notably :class:`repro.core.operator.MatFreeOperator`): the
  backward pass solves ``Aᵀλ = ḡ`` via ``rmatvec`` and obtains the operator
  cotangent as the vjp of ``θ ↦ A(θ)·x`` at ``−λ`` — so ``grad`` through a
  matrix-free solve matches the assembled adjoint path without ever
  materializing values.

Convergence diagnostics (``repro.telemetry``): :func:`sparse_solve`,
:func:`matfree_solve` and :func:`sparse_solve_batched` accept
``return_info=True`` and then return ``(x, SolveInfo)``.  The info is a
**non-differentiated auxiliary output** — its leaves are stop-gradient, so
the ``custom_vjp`` adjoint structure is untouched and ``jax.grad`` through
the info-returning path matches the plain path to machine precision.
Forward *and* adjoint solve statistics are recorded to the telemetry event
stream whenever values are concrete (eager boundaries); calls made under
``jit``/``vmap``/``scan`` simply skip host recording (tracer-safe).

``cg`` / ``bicgstab`` accept either a matvec callable or any object with a
``.matvec`` method (CSR, MatFreeOperator); :func:`jacobi_preconditioner`
needs only ``.diagonal()`` — for matrix-free operators that is a cheap
diagonal-only assembly.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..telemetry import annotate, events
from .sparse import CSR, BatchedCSR, _dev

__all__ = [
    "cg",
    "bicgstab",
    "jacobi_preconditioner",
    "sparse_solve",
    "sparse_solve_batched",
    "matfree_solve",
    "matfree_solve_batched",
    "SolveInfo",
]


class SolveInfo(NamedTuple):
    """Per-solve diagnostics: iteration count, final residual norm, and the
    exit condition (``converged = ‖r‖ ≤ max(tol·‖b‖, atol)``).  Leaves are
    jnp arrays — a batched / per-step solve stacks them (``(B,)`` /
    ``(n_steps,)``)."""

    iters: jnp.ndarray
    residual: jnp.ndarray
    converged: jnp.ndarray


def _info_aux(info: SolveInfo) -> SolveInfo:
    """The info as a non-differentiated auxiliary output: stop-gradient on
    every leaf, so returning it cannot perturb the adjoint structure."""
    return SolveInfo(*(jax.lax.stop_gradient(leaf) for leaf in info))


def jacobi_preconditioner(a) -> Callable:
    """Diagonal (Jacobi) preconditioner from anything with ``.diagonal()`` —
    an assembled :class:`CSR` or a matrix-free operator (diagonal-only
    assembly, no nnz vector)."""
    d = a.diagonal()
    inv = jnp.where(jnp.abs(d) > 0, 1.0 / d, 1.0)
    return lambda x: inv * x


def _identity(x):
    return x


def _as_matvec(a) -> Callable:
    """Normalize an operator argument: a callable is used as-is, anything
    else must expose ``.matvec`` (CSR, MatFreeOperator, ELL)."""
    return a if callable(a) else a.matvec


# ---------------------------------------------------------------------------
# Conjugate gradients (SPD systems: Poisson, elasticity)
# ---------------------------------------------------------------------------

def cg(matvec, b, x0=None, *, tol=1e-10, atol=1e-10, maxiter=10000, m=_identity):
    matvec = _as_matvec(matvec)
    x0 = jnp.zeros_like(b) if x0 is None else x0
    bnorm = jnp.linalg.norm(b)
    target = jnp.maximum(tol * bnorm, atol)

    r0 = b - matvec(x0)
    z0 = m(r0)
    state = (x0, r0, z0, z0, jnp.vdot(r0, z0), jnp.array(0))

    def cond(s):
        _, r, *_, it = s
        return (jnp.linalg.norm(r) > target) & (it < maxiter)

    def body(s):
        x, r, z, p, rz, it = s
        ap = matvec(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = m(r)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (x, r, z, p, rz_new, it + 1)

    with annotate("tg.solve.cg"):
        x, r, *_, it = jax.lax.while_loop(cond, body, state)
    rnorm = jnp.linalg.norm(r)
    return x, SolveInfo(it, rnorm, rnorm <= target)


# ---------------------------------------------------------------------------
# BiCGSTAB (general systems; the paper's default — van der Vorst 1992)
# ---------------------------------------------------------------------------

def bicgstab(matvec, b, x0=None, *, tol=1e-10, atol=1e-10, maxiter=10000, m=_identity):
    matvec = _as_matvec(matvec)
    x0 = jnp.zeros_like(b) if x0 is None else x0
    bnorm = jnp.linalg.norm(b)
    target = jnp.maximum(tol * bnorm, atol)

    r0 = b - matvec(x0)
    rhat = r0
    state = (
        x0, r0,
        jnp.ones((), b.dtype), jnp.ones((), b.dtype), jnp.ones((), b.dtype),
        jnp.zeros_like(b), jnp.zeros_like(b),
        jnp.array(0),
    )

    def cond(s):
        _, r, *_, it = s
        return (jnp.linalg.norm(r) > target) & (it < maxiter)

    def body(s):
        x, r, rho, alpha, omega, v, p, it = s
        rho_new = jnp.vdot(rhat, r)
        beta = (rho_new / jnp.where(rho == 0, 1e-30, rho)) * (
            alpha / jnp.where(omega == 0, 1e-30, omega)
        )
        p = r + beta * (p - omega * v)
        phat = m(p)
        v = matvec(phat)
        denom = jnp.vdot(rhat, v)
        alpha = rho_new / jnp.where(denom == 0, 1e-30, denom)
        s_vec = r - alpha * v
        shat = m(s_vec)
        t = matvec(shat)
        tt = jnp.vdot(t, t)
        omega = jnp.vdot(t, s_vec) / jnp.where(tt == 0, 1e-30, tt)
        x = x + alpha * phat + omega * shat
        r = s_vec - omega * t
        return (x, r, rho_new, alpha, omega, v, p, it + 1)

    with annotate("tg.solve.bicgstab"):
        x, r, *_, it = jax.lax.while_loop(cond, body, state)
    rnorm = jnp.linalg.norm(r)
    return x, SolveInfo(it, rnorm, rnorm <= target)


_METHODS = {"cg": cg, "bicgstab": bicgstab}


# ---------------------------------------------------------------------------
# Differentiable sparse solve (TORCH-SLA analogue)
# ---------------------------------------------------------------------------

def _solve_impl(a: CSR, b, method, tol, atol, maxiter, precond, transpose=False):
    matvec = a.rmatvec if transpose else a.matvec
    m = jacobi_preconditioner(a) if precond == "jacobi" else _identity
    return _METHODS[method](matvec, b, tol=tol, atol=atol, maxiter=maxiter, m=m)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def _sparse_solve(a: CSR, b, method, tol, atol, maxiter, precond, return_info):
    x, info = _solve_impl(a, b, method, tol, atol, maxiter, precond)
    return (x, _info_aux(info)) if return_info else x


def _solve_fwd(a, b, method, tol, atol, maxiter, precond, return_info):
    x, info = _solve_impl(a, b, method, tol, atol, maxiter, precond)
    out = (x, _info_aux(info)) if return_info else x
    return out, (a, x)


def _solve_bwd(method, tol, atol, maxiter, precond, return_info, res, g):
    a, x = res
    gx = g[0] if return_info else g
    # adjoint: Kᵀ λ = ḡ   (Eq. 11; sign handled by the chain rule caller)
    lam, adj_info = _solve_impl(a, gx, method, tol, atol, maxiter, precond,
                                transpose=True)
    # adjoint-solve diagnostics: recorded when the backward pass runs with
    # concrete cotangents (eager grad); a no-op under further tracing
    events.record_solve("sparse_solve.adjoint", adj_info, method=method,
                        phase="adjoint")
    # ∂L/∂vals = −λ_r · x_c at each stored (r, c) — never densified
    dvals = -lam[_dev(a.row_of_nnz)] * x[_dev(a.indices)]
    da = CSR(dvals, a.indptr, a.indices, a.row_of_nnz, a.shape, a.diag_pos)
    return (da, lam)


_sparse_solve.defvjp(_solve_fwd, _solve_bwd)


def sparse_solve(a: CSR, b, method="bicgstab", tol=1e-10, atol=1e-10,
                 maxiter=10000, precond="jacobi", return_info=False):
    """x = A⁻¹ b, differentiable w.r.t. ``a.vals`` and ``b`` via the adjoint.

    ``return_info=True`` additionally returns the :class:`SolveInfo`
    (iterations / final residual / ``converged``) as a stop-gradient
    auxiliary output — gradients are bit-identical to the plain path.
    """
    out = _sparse_solve(a, b, method, tol, atol, maxiter, precond,
                        bool(return_info))
    if return_info:
        x, info = out
        events.record_solve("sparse_solve", info, method=method, backend="csr")
        return x, info
    return out


# ---------------------------------------------------------------------------
# Differentiable matrix-free solve: the adjoint trick for pytree operators
# ---------------------------------------------------------------------------

def _op_solve_impl(op, b, method, tol, atol, maxiter, precond, transpose=False):
    matvec = op.rmatvec if transpose else op.matvec
    m = jacobi_preconditioner(op) if precond == "jacobi" else _identity
    return _METHODS[method](matvec, b, tol=tol, atol=atol, maxiter=maxiter, m=m)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def _matfree_solve(op, b, method, tol, atol, maxiter, precond, return_info):
    x, info = _op_solve_impl(op, b, method, tol, atol, maxiter, precond)
    return (x, _info_aux(info)) if return_info else x


def _matfree_fwd(op, b, method, tol, atol, maxiter, precond, return_info):
    x, info = _op_solve_impl(op, b, method, tol, atol, maxiter, precond)
    out = (x, _info_aux(info)) if return_info else x
    return out, (op, x)


def _matfree_bwd(method, tol, atol, maxiter, precond, return_info, res, g):
    op, x = res
    gx = g[0] if return_info else g
    lam, adj_info = _op_solve_impl(op, gx, method, tol, atol, maxiter, precond,
                                   transpose=True)
    events.record_solve("matfree_solve.adjoint", adj_info, method=method,
                        phase="adjoint")
    # ∂L/∂θ = −λᵀ (∂A/∂θ) x — the vjp of the apply w.r.t. the operator pytree
    _, pullback = jax.vjp(lambda o: o.matvec(x), op)
    (d_op,) = pullback(-lam)
    return (d_op, lam)


_matfree_solve.defvjp(_matfree_fwd, _matfree_bwd)


def matfree_solve(op, b, method="cg", tol=1e-10, atol=1e-10,
                  maxiter=10000, precond="jacobi", return_info=False):
    """``x = A⁻¹ b`` for any pytree linear operator with ``matvec`` /
    ``rmatvec`` / ``diagonal`` — differentiable w.r.t. the operator's traced
    leaves (coefficients, geometry) *and* ``b`` via the adjoint solve.

    The backward pass solves ``Aᵀλ = ḡ`` with the same Krylov method, then
    recovers the operator cotangent as ``vjp(θ ↦ A(θ)·x)(−λ)`` — for a
    :class:`~repro.core.operator.MatFreeOperator` that is one extra
    matrix-free apply-transpose, never an assembled matrix.  (A :class:`CSR`
    works too and reproduces :func:`sparse_solve`'s sparse cotangent.)

    ``return_info=True`` additionally returns the :class:`SolveInfo` as a
    stop-gradient auxiliary output (gradients match the plain path).
    """
    out = _matfree_solve(op, b, method, tol, atol, maxiter, precond,
                         bool(return_info))
    if return_info:
        x, info = out
        events.record_solve("matfree_solve", info, method=method,
                            backend="matfree")
        return x, info
    return out


def matfree_solve_batched(family, b, method="cg", tol=1e-10, atol=1e-10,
                          maxiter=10000, precond="jacobi", return_info=False):
    """``X_b = A_b⁻¹ b_b`` over a matrix-free
    :class:`~repro.core.operator.MatFreeFamily` — one ``vmap`` of the
    differentiable :func:`matfree_solve` with the family's leaf axes, so the
    B Krylov solves (and their adjoint solves under ``grad``) share a single
    executable on one plan/signature, with zero matrix materialization.

    ``b`` is ``(B, n)`` per-instance or ``(n,)`` shared; returns ``(B, n)``
    (plus a ``SolveInfo`` with ``(B,)`` leaves under ``return_info=True``).
    Gradients w.r.t. the batched coefficient leaves match B per-instance
    adjoint :func:`matfree_solve` calls.
    """
    b = jnp.asarray(b)
    in_b = None if b.ndim == 1 else 0
    out = jax.vmap(
        lambda op, bi: _matfree_solve(
            op, bi, method, tol, atol, maxiter, precond, bool(return_info)
        ),
        in_axes=(family.in_axes(), in_b),
    )(family.op, b)
    if return_info:
        x, info = out
        events.record_solve("matfree_solve_batched", info, method=method,
                            backend="matfree")
        return x, info
    return out


def sparse_solve_batched(a: BatchedCSR, b, method="bicgstab", tol=1e-10,
                         atol=1e-10, maxiter=10000, precond="jacobi",
                         return_info=False):
    """X_b = A_b⁻¹ b_b over a :class:`BatchedCSR` family — one ``vmap`` of the
    differentiable :func:`sparse_solve`, so the B Krylov solves share a
    single XLA executable (and a single adjoint executable under ``grad``).

    ``b`` is ``(B, n)`` per-instance or ``(n,)`` shared; returns ``(B, n)``
    (plus a ``SolveInfo`` with ``(B,)`` leaves under ``return_info=True``).
    """
    b = jnp.asarray(b)
    in_b = None if b.ndim == 1 else 0
    out = jax.vmap(
        lambda ab, bi: _sparse_solve(
            ab.as_csr(), bi, method, tol, atol, maxiter, precond,
            bool(return_info),
        ),
        in_axes=(0, in_b),
    )(a, b)
    if return_info:
        x, info = out
        events.record_solve("sparse_solve_batched", info, method=method,
                            backend="csr")
        return x, info
    return out
