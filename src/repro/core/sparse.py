"""Sparse containers (CSR / ELL) as jax pytrees, with SpMV/SpMM.

The CSR *pattern* (indptr/indices/row ids) is static numpy baked at setup —
only ``vals`` is traced, preserving the paper's O(1)-graph property: the
sparse operator participates in autodiff through a single dense value vector.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CSR", "ELL", "csr_to_ell"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    vals: jnp.ndarray            # (nnz,) traced
    indptr: np.ndarray           # static
    indices: np.ndarray          # static
    row_of_nnz: np.ndarray       # static, (nnz,)
    shape: tuple[int, int]       # static
    diag_pos: np.ndarray | None = None  # static

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        aux = (self.indptr, self.indices, self.row_of_nnz, self.shape, self.diag_pos)
        return (self.vals,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (vals,) = children
        return cls(vals, *aux)

    # -- ops ---------------------------------------------------------------
    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A @ x via gather + sorted segment-sum (deterministic)."""
        contrib = self.vals * x[self.indices]
        return jax.ops.segment_sum(
            contrib,
            self.row_of_nnz,
            num_segments=self.shape[0],
            indices_are_sorted=True,
        )

    def rmatvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A.T @ x (scatter over columns)."""
        contrib = self.vals * x[self.row_of_nnz]
        return jax.ops.segment_sum(
            contrib, self.indices, num_segments=self.shape[1]
        )

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A @ X for X (n, b) — batched multi-RHS SpMM."""
        contrib = self.vals[:, None] * x[self.indices]
        return jax.ops.segment_sum(
            contrib,
            self.row_of_nnz,
            num_segments=self.shape[0],
            indices_are_sorted=True,
        )

    def diagonal(self) -> jnp.ndarray:
        assert self.diag_pos is not None, "diagonal positions not precomputed"
        d = jnp.where(
            jnp.asarray(self.diag_pos) >= 0,
            self.vals[jnp.clip(jnp.asarray(self.diag_pos), 0)],
            0.0,
        )
        return d

    def to_dense(self) -> jnp.ndarray:
        out = jnp.zeros(self.shape, dtype=self.vals.dtype)
        return out.at[self.row_of_nnz, self.indices].set(self.vals)

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix(
            (np.asarray(self.vals), np.asarray(self.indices), np.asarray(self.indptr)),
            shape=self.shape,
        )

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ELL:
    """ELLPACK: fixed nnz-per-row padded format — the TPU-friendly layout
    consumed by the Pallas SpMV kernel (bounded valence of FEM meshes)."""

    vals: jnp.ndarray        # (n, L) traced, zero-padded
    cols: np.ndarray         # (n, L) static, padded with row index (self-loop)
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.vals,), (self.cols, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (vals,) = children
        return cls(vals, *aux)

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(self.vals * x[jnp.asarray(self.cols)], axis=1)


def csr_to_ell(csr: CSR) -> ELL:
    n = csr.shape[0]
    counts = np.diff(csr.indptr)
    L = int(counts.max()) if counts.size else 1
    cols = np.repeat(np.arange(n)[:, None], L, axis=1)  # pad with row idx
    slot = np.concatenate([np.arange(c) for c in counts]) if counts.size else np.array([], np.int64)
    rows_of = np.asarray(csr.row_of_nnz)
    cols[rows_of, slot] = np.asarray(csr.indices)

    # runtime scatter of vals into the padded layout (static slot map)
    flat_pos = rows_of * L + slot
    vals = jnp.zeros((n * L,), dtype=csr.vals.dtype).at[flat_pos].set(csr.vals)
    return ELL(vals.reshape(n, L), cols, csr.shape)
