"""Sparse containers (CSR / ELL / BatchedCSR) as jax pytrees, with SpMV/SpMM.

The CSR *pattern* (indptr/indices/row ids) is static numpy baked at setup —
only ``vals`` is traced, preserving the paper's O(1)-graph property: the
sparse operator participates in autodiff through a single dense value vector.
:class:`BatchedCSR` extends this to *families* of same-pattern operators:
one shared static pattern, ``(B, nnz)`` traced values — the container behind
``assemble_batched`` and the vmapped ``sparse_solve``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CSR", "ELL", "BatchedCSR", "csr_to_ell", "ell_layout"]


# device mirrors of static numpy pattern arrays, keyed by id: staged to the
# device once instead of per traced call.  The numpy key array is kept alive
# by the strong reference so ids cannot be recycled while cached; the cache
# is FIFO-bounded because some callers mint fresh pattern arrays per call
# (e.g. csr_to_ell cols in a solve loop) — eviction just costs a re-stage.
_DEVICE_MIRRORS: dict[int, tuple[np.ndarray, jnp.ndarray]] = {}
_DEVICE_MIRRORS_LIMIT = 512


def _dev(x) -> jnp.ndarray:
    if isinstance(x, jnp.ndarray):
        return x
    hit = _DEVICE_MIRRORS.get(id(x))
    if hit is not None:
        return hit[1]
    arr = jnp.asarray(x)
    if isinstance(arr, jax.core.Tracer):
        return arr  # converted inside a trace: constant-folded there, not cached
    while len(_DEVICE_MIRRORS) >= _DEVICE_MIRRORS_LIMIT:
        _DEVICE_MIRRORS.pop(next(iter(_DEVICE_MIRRORS)))
    _DEVICE_MIRRORS[id(x)] = (x, arr)
    return arr


def clear_device_mirrors():
    """Release every cached (host, device) pattern-array pair, ELL layout and
    operator diagonal — part of the ``repro.core.clear_assembly_caches``
    memory-release path."""
    _DEVICE_MIRRORS.clear()
    _ELL_LAYOUTS.clear()
    _DIAGONALS.clear()


# operator diagonals keyed by (operator identity, dtype): the Jacobi
# preconditioner asks for ``.diagonal()`` on every solve, but for a CSR the
# diagonal is a fixed gather of ``vals`` and for a matrix-free operator a
# diagonal-only assembly — both pure functions of the anchor array's values.
# Keyed on the *value anchor* (``vals`` for CSR, the operator object for
# matrix-free), with a strong reference so ids cannot be recycled while
# cached; same FIFO bound rationale as the device mirrors above.
_DIAGONALS: dict[tuple[int, str], tuple[object, jnp.ndarray]] = {}
_DIAGONALS_LIMIT = 256


def cached_diagonal(op) -> jnp.ndarray:
    """``op.diagonal()`` memoized per (operator identity, dtype).

    The cache key anchors on ``op.vals`` when present (a :class:`CSR` /
    :class:`ELL` rebuilt around the same value buffer shares the diagonal)
    and on the operator object otherwise.  Tracers are never cached: inside
    a trace the diagonal is part of the jaxpr and caching by ``id`` would
    leak abstract values across traces.
    """
    anchor = getattr(op, "vals", None)
    if anchor is None:
        anchor = op
    if any(
        isinstance(leaf, jax.core.Tracer)
        for leaf in jax.tree_util.tree_leaves(op)
    ):
        return op.diagonal()
    dtype = getattr(anchor, "dtype", None)
    if dtype is None:
        leaves = jax.tree_util.tree_leaves(op)
        dtype = getattr(leaves[0], "dtype", None) if leaves else None
    key = (id(anchor), str(dtype))
    hit = _DIAGONALS.get(key)
    if hit is not None:
        return hit[1]
    d = op.diagonal()
    if isinstance(d, jax.core.Tracer):
        return d
    while len(_DIAGONALS) >= _DIAGONALS_LIMIT:
        _DIAGONALS.pop(next(iter(_DIAGONALS)))
    _DIAGONALS[key] = (anchor, d)
    return d


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    vals: jnp.ndarray            # (nnz,) traced
    indptr: np.ndarray           # static
    indices: np.ndarray          # static
    row_of_nnz: np.ndarray       # static, (nnz,)
    shape: tuple[int, int]       # static
    diag_pos: np.ndarray | None = None  # static

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        aux = (self.indptr, self.indices, self.row_of_nnz, self.shape, self.diag_pos)
        return (self.vals,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (vals,) = children
        return cls(vals, *aux)

    # -- ops ---------------------------------------------------------------
    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A @ x via gather + sorted segment-sum (deterministic)."""
        contrib = self.vals * x[_dev(self.indices)]
        return jax.ops.segment_sum(
            contrib,
            _dev(self.row_of_nnz),
            num_segments=self.shape[0],
            indices_are_sorted=True,
        )

    def rmatvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A.T @ x (scatter over columns)."""
        contrib = self.vals * x[_dev(self.row_of_nnz)]
        return jax.ops.segment_sum(
            contrib, _dev(self.indices), num_segments=self.shape[1]
        )

    def matmat(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A @ X for X (n, b) — batched multi-RHS SpMM."""
        contrib = self.vals[:, None] * x[_dev(self.indices)]
        return jax.ops.segment_sum(
            contrib,
            _dev(self.row_of_nnz),
            num_segments=self.shape[0],
            indices_are_sorted=True,
        )

    def diagonal(self) -> jnp.ndarray:
        assert self.diag_pos is not None, "diagonal positions not precomputed"
        dp = _dev(self.diag_pos)
        return jnp.where(dp >= 0, self.vals[jnp.clip(dp, 0)], 0.0)

    def to_dense(self) -> jnp.ndarray:
        out = jnp.zeros(self.shape, dtype=self.vals.dtype)
        return out.at[self.row_of_nnz, self.indices].set(self.vals)

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix(
            (np.asarray(self.vals), np.asarray(self.indices), np.asarray(self.indptr)),
            shape=self.shape,
        )

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BatchedCSR:
    """B same-pattern sparse operators: shared static pattern, ``(B, nnz)``
    traced values — produced by ``assemble_batched`` over a family of
    coefficient sets / geometries.

    The aux layout is identical to :class:`CSR`, so condensers and other
    vals-elementwise transforms apply unchanged (masks broadcast over the
    batch axis), and ``jax.vmap(fn, in_axes=0)`` over a ``BatchedCSR`` hands
    ``fn`` a per-instance slice — :meth:`as_csr` converts that slice to a
    :class:`CSR` for single-instance code (solvers, integrators).
    """

    vals: jnp.ndarray            # (B, nnz) traced
    indptr: np.ndarray           # static (shared by all instances)
    indices: np.ndarray          # static
    row_of_nnz: np.ndarray       # static, (nnz,)
    shape: tuple[int, int]       # static, per-instance shape
    diag_pos: np.ndarray | None = None  # static

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        aux = (self.indptr, self.indices, self.row_of_nnz, self.shape, self.diag_pos)
        return (self.vals,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (vals,) = children
        return cls(vals, *aux)

    # -- constructors / views ---------------------------------------------
    @classmethod
    def stack(cls, csrs) -> "BatchedCSR":
        """Stack same-pattern :class:`CSR` instances along a new batch axis.

        Patterns must actually match (content, not just nnz count) — two
        different meshes can share an nnz by coincidence, and pairing one
        pattern with the other's values would be silently wrong.
        """
        csrs = list(csrs)
        first = csrs[0]
        for c in csrs[1:]:
            same = c.shape == first.shape and (
                c.indices is first.indices
                or (
                    np.array_equal(c.indices, first.indices)
                    and np.array_equal(c.indptr, first.indptr)
                )
            )
            if not same:
                raise ValueError(
                    "BatchedCSR.stack: CSR sparsity patterns differ — all "
                    "instances must share one (mesh topology × space) pattern"
                )
        return cls(
            vals=jnp.stack([c.vals for c in csrs]),
            indptr=first.indptr,
            indices=first.indices,
            row_of_nnz=first.row_of_nnz,
            shape=first.shape,
            diag_pos=first.diag_pos,
        )

    def as_csr(self) -> CSR:
        """Reinterpret as a single :class:`CSR` sharing this pattern — valid
        when ``vals`` is one instance's ``(nnz,)`` slice (e.g. inside a
        ``vmap`` over the batch axis)."""
        return CSR(self.vals, self.indptr, self.indices, self.row_of_nnz,
                   self.shape, self.diag_pos)

    def __getitem__(self, b):
        """Integer index → one instance as a :class:`CSR`; slice → the
        sub-family as a :class:`BatchedCSR`."""
        if isinstance(b, (int, np.integer)):
            return CSR(self.vals[b], self.indptr, self.indices,
                       self.row_of_nnz, self.shape, self.diag_pos)
        if isinstance(b, slice):
            return BatchedCSR(self.vals[b], self.indptr, self.indices,
                              self.row_of_nnz, self.shape, self.diag_pos)
        raise TypeError(
            f"BatchedCSR indices must be int or slice, got {type(b).__name__}"
        )

    @property
    def batch(self) -> int:
        return int(self.vals.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    # -- ops ---------------------------------------------------------------
    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y_b = A_b @ x_b for ``x: (B, n)`` (``(n,)`` broadcasts across the
        batch) — one vmapped gather + segment-sum."""
        in_x = None if x.ndim == 1 else 0
        return jax.vmap(lambda v, xi: self._one(v).matvec(xi),
                        in_axes=(0, in_x))(self.vals, x)

    def _one(self, vals) -> CSR:
        return CSR(vals, self.indptr, self.indices, self.row_of_nnz,
                   self.shape, self.diag_pos)

    def diagonal(self) -> jnp.ndarray:
        assert self.diag_pos is not None, "diagonal positions not precomputed"
        dp = _dev(self.diag_pos)
        return jnp.where(dp >= 0, self.vals[:, jnp.clip(dp, 0)], 0.0)

    def to_dense(self) -> jnp.ndarray:
        out = jnp.zeros((self.batch,) + self.shape, dtype=self.vals.dtype)
        return out.at[:, _dev(self.row_of_nnz), _dev(self.indices)].set(self.vals)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ELL:
    """ELLPACK: fixed nnz-per-row padded format — the TPU-friendly layout
    consumed by the Pallas SpMV kernel (bounded valence of FEM meshes)."""

    vals: jnp.ndarray        # (n, L) traced, zero-padded
    cols: np.ndarray         # (n, L) static, padded with row index (self-loop)
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.vals,), (self.cols, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (vals,) = children
        return cls(vals, *aux)

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(self.vals * x[_dev(self.cols)], axis=1)


# static ELL layouts keyed by pattern identity: the padded column table and
# nnz→slot map depend only on (indptr, indices), so deriving them per call
# (as the old per-call-site conversions did) redid an O(nnz) numpy sort-free
# pass on every solve.  Strong references to the key arrays keep ids stable;
# FIFO-bounded like the device mirrors.
_ELL_LAYOUTS: dict[int, tuple] = {}
_ELL_LAYOUTS_LIMIT = 128


def ell_layout(csr: CSR) -> tuple[np.ndarray, np.ndarray, int]:
    """Static ELL layout of a CSR pattern: ``(cols, flat_pos, L)`` — cached
    per pattern identity so repeated conversions only pay the runtime value
    scatter."""
    hit = _ELL_LAYOUTS.get(id(csr.indices))
    if hit is not None:
        return hit[1]
    n = csr.shape[0]
    counts = np.diff(csr.indptr)
    L = int(counts.max()) if counts.size else 1
    # int32 at staging time: the Pallas kernels index with int32, and casting
    # here (once per pattern) removes the per-matvec convert from solve loops
    cols = np.repeat(np.arange(n, dtype=np.int32)[:, None], L, axis=1)
    slot = np.concatenate([np.arange(c) for c in counts]) if counts.size else np.array([], np.int64)
    rows_of = np.asarray(csr.row_of_nnz)
    cols[rows_of, slot] = np.asarray(csr.indices)
    flat_pos = rows_of * L + slot
    layout = (cols, flat_pos, L)
    if isinstance(csr.indices, np.ndarray):
        while len(_ELL_LAYOUTS) >= _ELL_LAYOUTS_LIMIT:
            _ELL_LAYOUTS.pop(next(iter(_ELL_LAYOUTS)))
        _ELL_LAYOUTS[id(csr.indices)] = (csr.indices, layout)
    return layout


def csr_to_ell(csr: CSR) -> ELL:
    cols, flat_pos, L = ell_layout(csr)
    n = csr.shape[0]
    # runtime scatter of vals into the padded layout (static slot map)
    vals = jnp.zeros((n * L,), dtype=csr.vals.dtype).at[_dev(flat_pos)].set(csr.vals)
    return ELL(vals.reshape(n, L), cols, csr.shape)
