"""Weak-form library consumed by the Batch-Map stage.

Each form is a pure function ``form(ctx, **coeffs) -> K_local | F_local``
implemented as dense tensor contractions over a :class:`FormContext` — the
batched geometry tensors of Alg. 1 (Eq. 7 / Eq. A.12–A.14 of the paper).
Everything is jax-traceable; coefficients may be traced arrays (TensorPILS /
TensorOpt differentiate through them).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "FormContext",
    "eval_coefficient",
    "eval_tensor_coefficient",
    "diffusion",
    "anisotropic_diffusion",
    "advection",
    "mass",
    "elasticity",
    "load",
    "vector_load",
    "nonlinear_reaction",
]


@dataclasses.dataclass(frozen=True)
class FormContext:
    """Batched geometry at quadrature points (the paper's 𝒢, 𝒥, 𝒳̂, Ŵ).

    Frozen and registered as a jax pytree (all fields are leaves), so a
    context crosses jit/vmap boundaries cleanly — batched transient
    rollouts can close over one context instead of rebuilding it per trace.
    """

    w: jnp.ndarray          # (Q,) reference weights
    phi: jnp.ndarray        # (Q, k) basis values
    detj: jnp.ndarray       # (E, Q) |det J| (surface measure for facets)
    grad: jnp.ndarray | None  # (E, Q, k, d) physical basis gradients 𝒢
    xq: jnp.ndarray         # (E, Q, d) physical quadrature points
    scalar_cell_dofs: jnp.ndarray | None = None  # (E, k_scalar) for nodal coeffs

    @property
    def wdet(self) -> jnp.ndarray:
        """(E, Q) combined quadrature × measure weights ŵ_q |det J|."""
        return self.w[None, :] * self.detj


jax.tree_util.register_dataclass(
    FormContext,
    data_fields=["w", "phi", "detj", "grad", "xq", "scalar_cell_dofs"],
    meta_fields=[],
)


def eval_coefficient(coef, ctx: FormContext, vector_size: int | None = None):
    """Evaluate a coefficient at quadrature points → (E, Q) or (E, Q, c).

    Accepted encodings:
      * ``None``                → 1.0
      * python/0-d scalar      → constant
      * callable               → ``coef(xq)`` with ``xq: (E, Q, d)``
      * array ``(E,)``         → element-wise constant (SIMP densities)
      * array ``(E, Q)``       → per-quadrature values
      * array ``(N_scalar,)``  → nodal field, interpolated with the basis
      * array ``(c,)`` with ``vector_size == c`` → constant vector
    """
    e, q = ctx.detj.shape
    if coef is None:
        # unit coefficient in the context's dtype (a float32 geometry must
        # not upcast the whole contraction to the x64 default)
        return jnp.ones((e, q), dtype=ctx.detj.dtype)
    if callable(coef):
        out = coef(ctx.xq)
        return jnp.asarray(out)
    coef = jnp.asarray(coef)
    if coef.ndim == 0:
        return jnp.broadcast_to(coef, (e, q))
    if vector_size is not None and coef.ndim == 1 and coef.shape[0] == vector_size:
        return jnp.broadcast_to(coef[None, None, :], (e, q, vector_size))
    if coef.ndim == 1 and coef.shape[0] == e:
        return jnp.broadcast_to(coef[:, None], (e, q))
    if coef.ndim == 1:
        # nodal field: interpolate u_q = Σ_a φ_a(x̂_q) u_{g_e(a)}
        assert ctx.scalar_cell_dofs is not None, "nodal coeff needs cell dofs"
        nodal = coef[ctx.scalar_cell_dofs]                # (E, k)
        return jnp.einsum("qa,ea->eq", ctx.phi, nodal)
    if coef.shape[:2] == (e, q):
        return coef
    raise ValueError(f"un-interpretable coefficient shape {coef.shape}")


def eval_tensor_coefficient(coef, ctx: FormContext, d: int):
    """Evaluate a (d, d) tensor coefficient at quadrature points → (E, Q, d, d).

    Accepted encodings: ``None`` → identity, ``(d, d)`` constant,
    ``(E, d, d)`` per-element, ``(E, Q, d, d)`` per-quadrature, or a
    callable of x returning ``(E, Q, d, d)``.
    """
    e, q = ctx.detj.shape
    if coef is None:
        return jnp.broadcast_to(jnp.eye(d), (e, q, d, d))
    if callable(coef):
        coef = coef(ctx.xq)
    coef = jnp.asarray(coef)
    if coef.shape == (d, d):
        return jnp.broadcast_to(coef, (e, q, d, d))
    if coef.shape == (e, d, d):
        return jnp.broadcast_to(coef[:, None], (e, q, d, d))
    if coef.shape == (e, q, d, d):
        return coef
    raise ValueError(f"un-interpretable tensor coefficient shape {coef.shape}")


# ---------------------------------------------------------------------------
# Bilinear forms → (E, k, k)
# ---------------------------------------------------------------------------

def diffusion(ctx: FormContext, rho=None) -> jnp.ndarray:
    """∫ ρ ∇φ_b · ∇φ_a  — Eq. (A.12), the paper's flagship contraction."""
    rho_q = eval_coefficient(rho, ctx)
    # single fused contraction: (K_local)_{eab} = Σ_q ŵ_q|detJ| ρ G_a·G_b
    return jnp.einsum(
        "eq,eq,eqai,eqbi->eab", ctx.wdet, rho_q, ctx.grad, ctx.grad,
        optimize=True,
    )


def anisotropic_diffusion(ctx: FormContext, a=None) -> jnp.ndarray:
    """∫ (A∇u)·∇v with a (d, d) tensor coefficient A (heterogeneous /
    anisotropic media); A = I reduces to :func:`diffusion`."""
    d = ctx.grad.shape[-1]
    a_q = eval_tensor_coefficient(a, ctx, d)
    return jnp.einsum(
        "eq,eqai,eqij,eqbj->eab", ctx.wdet, ctx.grad, a_q, ctx.grad,
        optimize=True,
    )


def advection(ctx: FormContext, beta) -> jnp.ndarray:
    """∫ (β·∇u) v — the (nonsymmetric) advection bilinear form:
    K_ab = Σ_q ŵ|detJ| φ_a (β·𝒢_b)."""
    d = ctx.grad.shape[-1]
    b_q = eval_coefficient(beta, ctx, vector_size=d)      # (E, Q, d)
    return jnp.einsum(
        "eq,qa,eqi,eqbi->eab", ctx.wdet, ctx.phi, b_q, ctx.grad,
        optimize=True,
    )


def mass(ctx: FormContext, c=None) -> jnp.ndarray:
    """∫ c φ_b φ_a  (also the Robin boundary form on facet contexts)."""
    c_q = eval_coefficient(c, ctx)
    return jnp.einsum("eq,eq,qa,qb->eab", ctx.wdet, c_q, ctx.phi, ctx.phi)


def elasticity(ctx: FormContext, lam: float, mu: float, scale=None) -> jnp.ndarray:
    """Isotropic linear elasticity ∫ σ(u):ε(v) with Lamé (λ, μ).

    ``ctx.grad`` is the *scalar* basis gradient (E, Q, nv, d); the returned
    local matrix is over interleaved vector dofs (a·d + i), matching
    FunctionSpace ordering.  ``scale`` is an optional per-element factor —
    the SIMP stiffness interpolation E(ρ) enters here (TensorOpt).
    """
    g = ctx.grad
    e, q, nv, d = g.shape
    s_q = eval_coefficient(scale, ctx)
    w = ctx.wdet * s_q
    t_lam = jnp.einsum("eq,eqai,eqbj->eaibj", w, g, g, optimize=True)
    t_mu1 = jnp.einsum("eq,eqaj,eqbi->eaibj", w, g, g, optimize=True)
    gdotg = jnp.einsum("eq,eqak,eqbk->eab", w, g, g, optimize=True)
    eye = jnp.eye(d)
    t_mu2 = jnp.einsum("eab,ij->eaibj", gdotg, eye)
    k_local = lam * t_lam + mu * (t_mu1 + t_mu2)
    return k_local.reshape(e, nv * d, nv * d)


# ---------------------------------------------------------------------------
# Linear forms → (E, k)
# ---------------------------------------------------------------------------

def load(ctx: FormContext, f=None) -> jnp.ndarray:
    """∫ f φ_a — Eq. (A.11) (also the Neumann boundary load on facets)."""
    f_q = eval_coefficient(f, ctx)
    return jnp.einsum("eq,eq,qa->ea", ctx.wdet, f_q, ctx.phi)


def vector_load(ctx: FormContext, f, d: int) -> jnp.ndarray:
    """∫ f · v for vector-valued v; ``f`` is a constant (d,) vector, a
    callable returning (E, Q, d), or an (E, Q, d) array."""
    f_q = eval_coefficient(f, ctx, vector_size=d)     # (E, Q, d)
    e, q, nv = ctx.detj.shape[0], ctx.detj.shape[1], ctx.phi.shape[1]
    out = jnp.einsum("eq,eqi,qa->eai", ctx.wdet, f_q, ctx.phi)
    return out.reshape(e, nv * d)


def nonlinear_reaction(ctx: FormContext, u_nodal, fn: Callable) -> jnp.ndarray:
    """Semi-linear load ∫ fn(u) φ_a (Allen–Cahn reaction, Eq. A.1's 𝒩).

    ``u_nodal`` is the current coefficient vector; ``fn`` acts pointwise on
    quadrature values of u.
    """
    u_q = eval_coefficient(u_nodal, ctx)
    return jnp.einsum("eq,eq,qa->ea", ctx.wdet, fn(u_q), ctx.phi)
