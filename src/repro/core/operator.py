"""Matrix-free Galerkin operators: ``y = A(form) @ x`` without CSR values.

The assembled path materializes the global value vector (one float per nnz,
plus pattern arrays and optional ELL mirrors) before the Krylov loop ever
runs.  This module applies the operator **directly from the weak form**:

    gather   x_e = x[cell_dofs]                 (element-local Map input)
    apply    y_e = K_e(form) x_e                (per-element dense action)
    scatter  y   = S_vec · vec(y_e)             (the Sparse-Reduce, but onto
                                                 a vector — num_dofs segments
                                                 instead of nnz)

For the built-in kernels the per-element action is *fused*: diffusion applies
``𝒢ᵀ(w ρ (𝒢 x_e))`` through (E, Q, d) intermediates and never forms the
(E, k, k) element matrices — the same message-passing-on-the-sparsity-graph
structure that graph-Galerkin networks exploit matrix-free.  Unknown kernels
fall back to forming K_e on the fly (still no *global* values).

Storage strategies (the memory/speed dial):

=========  =====================================  ===========================
store      per-apply state beyond the plan        geometry work per apply
=========  =====================================  ===========================
"coords"   coefficient leaves only                full Stage-I recompute
"context"  the Stage-I FormContext (E·Q·k·d)      none (precomputed)
"local"    the element matrices (E·k²)            none (K_e precomputed)
=========  =====================================  ===========================

``"coords"`` shares the plan's coordinate array, so the operator adds
essentially no storage — DoF counts whose CSR values no longer fit stay
reachable.  ``"local"`` is the classical element-by-element (EbE) scheme.

Everything is a pytree: coefficient values and geometry are traced leaves,
the form signature and plan tables are identity-hashed aux data — so a
re-built operator with new coefficient *values* reuses the jitted apply
executable (zero retraces), and ``jvp``/``vjp`` flow through the apply like
any other jnp program.  :func:`repro.core.solvers.matfree_solve` adds the
O(1)-graph adjoint solve on top (grad through a matrix-free solve matches
the assembled ``sparse_solve`` path).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import telemetry
from ..telemetry import annotate
from . import forms, weakform
from .assembly import AssemblyPlan, PlanStatic, geometry_context, reduce_vector
from .sparse import _dev

__all__ = [
    "LinearOperator",
    "MatFreeOperator",
    "MatFreeFamily",
    "ShardedMatFreeOperator",
    "matfree_operator",
    "matfree_family",
    "n_matfree_traces",
]

_N_MF_TRACES = [0]


def n_matfree_traces() -> int:
    """Trace counter of the jitted matrix-free applies — re-applying with new
    coefficient/geometry *values* must not grow it (zero-retrace property)."""
    return _N_MF_TRACES[0]


class LinearOperator:
    """Minimal abstract interface the solver stack dispatches on.

    Anything exposing ``matvec`` / ``rmatvec`` / ``diagonal`` / ``shape`` can
    drive :func:`~repro.core.solvers.cg`,
    :func:`~repro.core.solvers.bicgstab`,
    :func:`~repro.core.solvers.jacobi_preconditioner` and
    :func:`~repro.core.solvers.matfree_solve`.  :class:`~repro.core.CSR`
    satisfies the protocol structurally; :class:`MatFreeOperator` is the
    matrix-free implementation.
    """

    shape: tuple[int, int]

    def matvec(self, x):  # pragma: no cover - interface
        raise NotImplementedError

    def rmatvec(self, x):  # pragma: no cover - interface
        raise NotImplementedError

    def diagonal(self):  # pragma: no cover - interface
        raise NotImplementedError

    def __matmul__(self, x):
        return self.matvec(x)


# ---------------------------------------------------------------------------
# Fused per-element actions: y_e = K_e x_e through (E, Q, ...) intermediates,
# never materializing the (E, k, k) element matrices.  One (action, transpose
# action, diagonal) triple per weak-form kernel; kernels without an entry
# fall back to forming K_e (still matrix-free at the global level).
# ---------------------------------------------------------------------------

def _diffusion_act(ctx, vs, xe, rho=None):
    rho_q = forms.eval_coefficient(rho, ctx)
    gu = jnp.einsum("eqai,ea->eqi", ctx.grad, xe)
    return jnp.einsum("eqai,eqi->ea", ctx.grad, (ctx.wdet * rho_q)[..., None] * gu)


def _diffusion_diag(ctx, vs, rho=None):
    rho_q = forms.eval_coefficient(rho, ctx)
    return jnp.einsum("eq,eq,eqai,eqai->ea", ctx.wdet, rho_q, ctx.grad, ctx.grad)


def _mass_act(ctx, vs, xe, c=None):
    c_q = forms.eval_coefficient(c, ctx)
    uq = jnp.einsum("qa,ea->eq", ctx.phi, xe)
    return jnp.einsum("eq,qa->ea", ctx.wdet * c_q * uq, ctx.phi)


def _mass_diag(ctx, vs, c=None):
    c_q = forms.eval_coefficient(c, ctx)
    return jnp.einsum("eq,qa,qa->ea", ctx.wdet * c_q, ctx.phi, ctx.phi)


def _advection_act(ctx, vs, xe, beta):
    d = ctx.grad.shape[-1]
    b_q = forms.eval_coefficient(beta, ctx, vector_size=d)
    gu = jnp.einsum("eqbi,eb->eqi", ctx.grad, xe)
    s = jnp.einsum("eqi,eqi->eq", b_q, gu)
    return jnp.einsum("eq,qa->ea", ctx.wdet * s, ctx.phi)


def _advection_act_t(ctx, vs, xe, beta):
    # Kᵀ: y_b = Σ_q ŵ|detJ| (β·𝒢_b) u_q with u_q the interpolated input
    d = ctx.grad.shape[-1]
    b_q = forms.eval_coefficient(beta, ctx, vector_size=d)
    uq = jnp.einsum("qa,ea->eq", ctx.phi, xe)
    return jnp.einsum("eq,eqi,eqbi->eb", ctx.wdet * uq, b_q, ctx.grad)


def _advection_diag(ctx, vs, beta):
    d = ctx.grad.shape[-1]
    b_q = forms.eval_coefficient(beta, ctx, vector_size=d)
    return jnp.einsum("eq,qa,eqi,eqai->ea", ctx.wdet, ctx.phi, b_q, ctx.grad)


def _aniso_act(ctx, vs, xe, a=None):
    d = ctx.grad.shape[-1]
    a_q = forms.eval_tensor_coefficient(a, ctx, d)
    gu = jnp.einsum("eqbj,eb->eqj", ctx.grad, xe)
    z = jnp.einsum("eqij,eqj->eqi", a_q, gu)
    return jnp.einsum("eq,eqai,eqi->ea", ctx.wdet, ctx.grad, z)


def _aniso_act_t(ctx, vs, xe, a=None):
    d = ctx.grad.shape[-1]
    a_q = jnp.swapaxes(forms.eval_tensor_coefficient(a, ctx, d), -1, -2)
    gu = jnp.einsum("eqbj,eb->eqj", ctx.grad, xe)
    z = jnp.einsum("eqij,eqj->eqi", a_q, gu)
    return jnp.einsum("eq,eqai,eqi->ea", ctx.wdet, ctx.grad, z)


def _aniso_diag(ctx, vs, a=None):
    d = ctx.grad.shape[-1]
    a_q = forms.eval_tensor_coefficient(a, ctx, d)
    return jnp.einsum("eq,eqai,eqij,eqaj->ea", ctx.wdet, ctx.grad, a_q, ctx.grad)


# kind -> (action, transpose action, diagonal); None → generic K_e fallback
_ACTIONS: dict[str, tuple] = {
    "diffusion": (_diffusion_act, _diffusion_act, _diffusion_diag),
    "mass": (_mass_act, _mass_act, _mass_diag),
    "advection": (_advection_act, _advection_act_t, _advection_diag),
    "anisotropic_diffusion": (_aniso_act, _aniso_act_t, _aniso_diag),
}


def _generic_act(kind, ctx, vs, xe, *coeffs, transpose=False):
    k_local = weakform.KERNELS[kind].fn(ctx, vs, *coeffs)
    sub = "eab,ea->eb" if transpose else "eab,eb->ea"
    return jnp.einsum(sub, k_local, xe)


def _generic_diag(kind, ctx, vs, *coeffs):
    k_local = weakform.KERNELS[kind].fn(ctx, vs, *coeffs)
    return jnp.einsum("eaa->ea", k_local)


# ---------------------------------------------------------------------------
# The operator
# ---------------------------------------------------------------------------

_STORES = ("coords", "context", "local")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class MatFreeOperator(LinearOperator):
    """``y = A(form) @ x`` straight from an :class:`AssemblyPlan` + lowered
    :class:`~repro.core.weakform.WeakForm` — build with
    :func:`matfree_operator`.

    Pytree layout: geometry (``coords`` | ``ctx`` | ``k_local``, per the
    storage strategy), coefficient ``leaves`` and the Dirichlet ``free_mask``
    are traced children; the plan tables, form signature and store tag are
    identity-hashed aux — so jit keys on the *signature* and re-applies with
    new values hit the compiled executable.
    """

    coords: jnp.ndarray | None      # (E, nv_geo, d)   store="coords"
    ctx: forms.FormContext | None   # Stage-I tensors  store="context"
    k_local: jnp.ndarray | None     # (E, k, k)        store="local"
    leaves: tuple                   # traced coefficient/scale leaves
    free_mask: jnp.ndarray | None   # (n,) 1=free, 0=Dirichlet (condensed)
    static: PlanStatic              # aux: plan tables
    spec: tuple                     # aux: lowered form signature
    store: str                      # aux: storage strategy tag

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return (
            (self.coords, self.ctx, self.k_local, self.leaves, self.free_mask),
            (self.static, self.spec, self.store),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- shape / dtype ----------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return (self.static.num_dofs, self.static.num_dofs)

    def condensed(self, bc) -> "MatFreeOperator":
        """Dirichlet condensation as an operator wrapper: rows/columns of
        constrained DoFs are masked and a unit diagonal inserted —
        ``y = m·A(m·x) + (1−m)·x`` — matching
        :meth:`~repro.core.boundary.DirichletCondenser.apply_matrix_only`
        on the assembled matrix exactly."""
        return dataclasses.replace(self, free_mask=bc.free_mask)

    # -- the apply --------------------------------------------------------
    def _context(self) -> forms.FormContext:
        if self.ctx is not None:
            return self.ctx
        st = self.static
        return geometry_context(
            self.coords, st.geo_phi, st.geo_grad, st.phi, st.gradhat, st.w,
            scalar_cell_dofs=st.scalar_cell_dofs,
        )

    def _term_values(self):
        leaf = iter(self.leaves)
        for kind, domain, desc in self.spec:
            vals = [next(leaf) if d == weakform.TRACED else d[1] for d in desc]
            *coeffs, scale = vals
            yield kind, coeffs, scale

    def _local_apply(self, xe, transpose: bool):
        if self.k_local is not None:
            sub = "eab,ea->eb" if transpose else "eab,eb->ea"
            return jnp.einsum(sub, self.k_local, xe)
        ctx, vs = self._context(), self.static.value_size
        out = None
        for kind, coeffs, scale in self._term_values():
            entry = _ACTIONS.get(kind)
            if entry is not None:
                act = entry[1] if transpose else entry[0]
                y = act(ctx, vs, xe, *coeffs)
            else:
                y = _generic_act(kind, ctx, vs, xe, *coeffs, transpose=transpose)
            y = y * jnp.asarray(scale)
            out = y if out is None else out + y
        return out

    def _apply_impl(self, x, transpose: bool):
        _N_MF_TRACES[0] += 1
        telemetry.count_trace("matfree", self.static, self.spec,
                              backend=self.store)
        st = self.static
        if self.free_mask is not None:
            m = self.free_mask.astype(x.dtype)
            x_in = m * x
        else:
            x_in = x
        with annotate("tg.matfree.gather"):
            xe = x_in[_dev(st.cell_dofs)]                # gather (E, k)
        with annotate("tg.matfree.action"):
            y_local = self._local_apply(xe, transpose)   # per-element apply
        with annotate("tg.matfree.scatter"):
            y = reduce_vector(y_local, st.vec_routing, st.reduce_mode)
        if self.free_mask is not None:
            y = m * y + (1.0 - m) * x
        return y

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A x — jitted, cached per (plan, form signature, store)."""
        return _apply_jit(self, x, False)

    def rmatvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = Aᵀ x.  Galerkin row and column DoF maps coincide, so the
        global transpose is the same gather → apply → scatter pipeline with
        the *per-element* apply transposed (kernels declared ``symmetric``
        in :data:`repro.core.weakform.KERNELS` reuse the forward action)."""
        if self.k_local is None and all(
            weakform.KERNELS[kind].symmetric for kind, _, _ in self.spec
        ):
            return _apply_jit(self, x, False)
        return _apply_jit(self, x, True)

    def diagonal(self) -> jnp.ndarray:
        """diag(A) by a diagonal-only assembly: per-element diagonals reduce
        through the vector routing — O(E·k) work and memory, no nnz vector —
        feeding :func:`~repro.core.solvers.jacobi_preconditioner`."""
        return _diag_jit(self)

    def _diag_local(self):
        if self.k_local is not None:
            return jnp.einsum("eaa->ea", self.k_local)
        ctx, vs = self._context(), self.static.value_size
        d_local = None
        for kind, coeffs, scale in self._term_values():
            entry = _ACTIONS.get(kind)
            d = (
                entry[2](ctx, vs, *coeffs)
                if entry is not None
                else _generic_diag(kind, ctx, vs, *coeffs)
            )
            d = d * jnp.asarray(scale)
            d_local = d if d_local is None else d_local + d
        return d_local

    def _diag_impl(self):
        st = self.static
        d_local = self._diag_local()
        diag = reduce_vector(d_local, st.vec_routing, st.reduce_mode)
        if self.free_mask is not None:
            m = self.free_mask.astype(diag.dtype)
            diag = m * diag + (1.0 - m)
        return diag

    def element_matrices(self) -> jnp.ndarray:
        """The per-element dense tensors ``K_e`` of this form, ``(E, k, k)``
        — the Map-stage output the tentpole element tensor-algebra layer
        (:mod:`repro.core.elemalg`) factorizes, condenses and inverts.
        ``store="local"`` operators return their stored tensors; the other
        stores compute them on demand (no global matrix either way).  The
        Dirichlet ``free_mask`` is *not* applied — callers mask per-element
        rows/columns themselves (see ``elemalg.masked_element_matrices``)."""
        if self.k_local is not None:
            return self.k_local
        ctx, vs = self._context(), self.static.value_size
        k_local = None
        for kind, coeffs, scale in self._term_values():
            k = weakform.KERNELS[kind].fn(ctx, vs, *coeffs)
            k = k * jnp.asarray(scale)
            k_local = k if k_local is None else k_local + k
        return k_local

    def is_spd(self) -> bool:
        """True when every kernel in the form signature is declared SPD
        (``repro.core.weakform.KERNELS[kind].spd``) — drives the
        Cholesky-vs-LU factorization choice in :mod:`repro.core.elemalg`.
        ``store="local"`` operators erase coefficient info, so they only
        keep the kind tags — the declaration still resolves."""
        return all(weakform.KERNELS[kind].spd for kind, _, _ in self.spec)

    def sharded(self, mesh=None, axis_name: str | None = None
                ) -> "ShardedMatFreeOperator":
        """This operator with its apply partitioned over the element axis of
        a device mesh (defaults to :func:`repro.sharding.fem_mesh` over all
        local devices) — see :class:`ShardedMatFreeOperator`."""
        from ..sharding.partitioning import FEM_MESH_AXIS, fem_mesh

        axis = FEM_MESH_AXIS if axis_name is None else axis_name
        if mesh is None:
            mesh = fem_mesh(axis_name=axis)
        return ShardedMatFreeOperator(self, mesh, axis)

    def in_axes(self, leaf_axes=None, coords_ax=None, free_mask_ax=None,
                k_local_ax=None, ctx_ax=None) -> "MatFreeOperator":
        """An operator-shaped ``jax.vmap`` axes object for this pytree: the
        same aux data (so tree structures match) with each traced child
        replaced by its batch axis (``0``) or ``None`` (shared).

        ``leaf_axes`` aligns with ``self.leaves`` (defaults to all-shared);
        the other slots default to shared.  This is what lets a family of
        operators with ``(B, ...)`` coefficient leaves vmap through
        ``matvec`` / ``diagonal`` / :func:`~repro.core.solvers.matfree_solve`
        without hand-building the pytree of axes.
        """
        if leaf_axes is None:
            leaf_axes = (None,) * len(self.leaves)
        if len(leaf_axes) != len(self.leaves):
            raise ValueError(
                f"leaf_axes has {len(leaf_axes)} entries but the operator "
                f"carries {len(self.leaves)} traced leaves"
            )
        return MatFreeOperator(
            coords=coords_ax, ctx=ctx_ax, k_local=k_local_ax,
            leaves=tuple(leaf_axes), free_mask=free_mask_ax,
            static=self.static, spec=self.spec, store=self.store,
        )

    # -- introspection ----------------------------------------------------
    def state_bytes(self) -> int:
        """Bytes of traced state this operator carries *beyond* the plan —
        the matrix-free side of the memory trade-off table (a ``"coords"``
        operator shares the plan's coordinates: ~coefficients only)."""
        leaves = [self.k_local, self.free_mask, *self.leaves]
        if self.store == "context":
            leaves += list(jax.tree_util.tree_leaves(self.ctx))
        return sum(
            v.nbytes for v in leaves
            if v is not None and hasattr(v, "nbytes")
        )


@partial(jax.jit, static_argnums=(2,))
def _apply_jit(op: MatFreeOperator, x, transpose: bool):
    return op._apply_impl(x, transpose)


@jax.jit
def _diag_jit(op: MatFreeOperator):
    return op._diag_impl()


def matfree_operator(plan: AssemblyPlan, form, store: str = "context",
                     coords=None) -> MatFreeOperator:
    """Build the matrix-free operator of a bilinear form on a plan.

    ``store`` picks the memory/speed point (see module docstring):
    ``"context"`` (default) precomputes the Stage-I geometry once for the
    fastest apply; ``"coords"`` recomputes it per apply and stores nothing
    beyond the plan's coordinates; ``"local"`` precomputes the (E, k, k)
    element matrices (classical EbE).  All three are differentiable w.r.t.
    coefficients and coordinates and share the assembled operator's values
    to machine precision: ``op.matvec(x) == assemble(plan, form).matvec(x)``.
    """
    if store not in _STORES:
        raise ValueError(f"unknown store {store!r}; use one of {_STORES}")
    spec, leaves = weakform.lower(form, weakform.MATRIX)
    if any(domain is not None for _, domain, _ in spec):
        raise NotImplementedError(
            "matrix-free apply supports volume terms only: assemble facet "
            "terms into a CSR and combine, or condense them into the RHS"
        )
    st = plan.static
    if st.cell_dofs is None:
        raise ValueError(
            "plan.static.cell_dofs is missing — rebuild the plan with "
            "repro.core.build_plan (older pickled plans predate the "
            "matrix-free subsystem)"
        )
    c = plan.coords if coords is None else coords
    op = MatFreeOperator(
        coords=c, ctx=None, k_local=None, leaves=leaves, free_mask=None,
        static=st, spec=spec, store=store,
    )
    if store == "context":
        op = dataclasses.replace(
            op, ctx=geometry_context(
                c, st.geo_phi, st.geo_grad, st.phi, st.gradhat, st.w,
                scalar_cell_dofs=st.scalar_cell_dofs,
            ), coords=None,
        )
    elif store == "local":
        op = dataclasses.replace(
            op, k_local=op.element_matrices(), coords=None, leaves=(),
            spec=tuple((kind, None, ()) for kind, _, _ in spec),
        )
    telemetry.gauge_set("operator_state_bytes", op.state_bytes(), store=store)
    return op


# ---------------------------------------------------------------------------
# Multi-device sharding: the same gather → action → scatter apply, with the
# element axis partitioned over a device mesh (per-device partial scatter +
# one psum) — a single Krylov solve spans every device with no materialized
# matrix and no element-sized intermediate replicated anywhere.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class ShardedMatFreeOperator(LinearOperator):
    """A :class:`MatFreeOperator` whose apply is ``shard_map``-partitioned
    over the element axis (the ``repro.sharding`` FEM mesh axis).

    Per apply, each device gathers from the replicated ``(n,)`` vector into
    its *element shard* only, runs the per-element fused action on that
    shard, reduces it to a partial touched-DoF vector, and one ``psum``
    completes the Sparse-Reduce — the element-sized intermediates (the
    gather, the (E, Q, ...) action state, the local results) exist only as
    per-device shards.  ``matvec`` / ``rmatvec`` / ``diagonal`` all ride the
    same partitioning, so :func:`~repro.core.solvers.matfree_solve` (and its
    custom-vjp adjoint solve + operator-cotangent pullback) runs sharded
    end-to-end.  Build with :meth:`MatFreeOperator.sharded`.

    Pytree: the wrapped operator is the traced child; the device mesh and
    axis name are aux — re-applies with new coefficient values reuse the
    compiled sharded executable.
    """

    op: MatFreeOperator      # traced child
    mesh: Any                # aux: jax.sharding.Mesh
    axis_name: str           # aux

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return (self.op,), (self.mesh, self.axis_name)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- structure --------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return self.op.shape

    @property
    def static(self) -> PlanStatic:
        return self.op.static

    def condensed(self, bc) -> "ShardedMatFreeOperator":
        """Dirichlet condensation — same apply wrapper as the single-device
        operator (the masking runs on the replicated vector, outside the
        sharded region)."""
        return dataclasses.replace(self, op=self.op.condensed(bc))

    def state_bytes(self) -> int:
        return self.op.state_bytes()

    # -- applies ----------------------------------------------------------
    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        return _sharded_apply_jit(self, x, False)

    def rmatvec(self, x: jnp.ndarray) -> jnp.ndarray:
        op = self.op
        if op.k_local is None and all(
            weakform.KERNELS[kind].symmetric for kind, _, _ in op.spec
        ):
            return _sharded_apply_jit(self, x, False)
        return _sharded_apply_jit(self, x, True)

    def diagonal(self) -> jnp.ndarray:
        return _sharded_diag_jit(self)


def _shard_scaffold(sop: ShardedMatFreeOperator):
    """Static partitioning tables + the traced geometry/leaf shards and their
    PartitionSpecs for one sharded apply/diagonal trace."""
    from jax.sharding import PartitionSpec as P

    op, mesh, axis_name = sop.op, sop.mesh, sop.axis_name
    st = op.static
    ndev = mesh.shape[axis_name]
    cd = np.asarray(st.cell_dofs)
    e = cd.shape[0]
    pad = (-e) % ndev
    routing = st.vec_routing
    n_seg = routing.touched.shape[0]
    slots = routing.seg_ids_unsorted.shape[0] // e

    # static numpy precompute: padded rows carry out-of-range segment ids
    # (dropped by segment_sum) and replicate the last element's DoFs
    seg = routing.seg_ids_unsorted.reshape(e, slots)
    if pad:
        seg = np.concatenate([seg, np.full((pad, slots), n_seg, seg.dtype)])
        cd = np.concatenate([cd, np.broadcast_to(cd[-1:], (pad,) + cd.shape[1:])])

    def pad_rows(a):
        if not pad:
            return a
        return jnp.concatenate(
            [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])]
        )

    shard, rep = P(axis_name), P()

    # traced geometry state, store-dependent; ``rebuild`` reassembles a
    # shard-local operator inside the shard_map body
    if op.k_local is not None:
        geo = (pad_rows(op.k_local),)
        geo_specs = (shard,)

        def rebuild(inner, geo_s, leaves_s):
            return dataclasses.replace(inner, k_local=geo_s[0],
                                       leaves=leaves_s)
    elif op.ctx is not None:
        ctx = op.ctx
        fields = [("w", ctx.w, rep), ("phi", ctx.phi, rep),
                  ("detj", pad_rows(ctx.detj), shard)]
        if ctx.grad is not None:
            fields.append(("grad", pad_rows(ctx.grad), shard))
        fields.append(("xq", pad_rows(ctx.xq), shard))
        if ctx.scalar_cell_dofs is not None:
            fields.append(
                ("scalar_cell_dofs", pad_rows(ctx.scalar_cell_dofs), shard))
        names = tuple(f[0] for f in fields)
        geo = tuple(f[1] for f in fields)
        geo_specs = tuple(f[2] for f in fields)

        def rebuild(inner, geo_s, leaves_s):
            d = dict(zip(names, geo_s))
            ctx_s = forms.FormContext(
                w=d["w"], phi=d["phi"], detj=d["detj"], grad=d.get("grad"),
                xq=d["xq"], scalar_cell_dofs=d.get("scalar_cell_dofs"),
            )
            return dataclasses.replace(inner, ctx=ctx_s, leaves=leaves_s)
    else:  # store == "coords": Stage-I geometry recomputed per shard
        scd = st.scalar_cell_dofs
        geo = (pad_rows(op.coords),) \
            + ((pad_rows(jnp.asarray(scd)),) if scd is not None else ())
        geo_specs = (shard,) + ((shard,) if scd is not None else ())

        def rebuild(inner, geo_s, leaves_s):
            ctx_s = geometry_context(
                geo_s[0], st.geo_phi, st.geo_grad, st.phi, st.gradhat, st.w,
                scalar_cell_dofs=geo_s[1] if len(geo_s) > 1 else None,
            )
            return dataclasses.replace(inner, ctx=ctx_s, coords=None,
                                       leaves=leaves_s)

    # element-aligned coefficient leaves shard; everything else replicates
    # (mirrors the leaf resolution of the sharded assembly path)
    leaf_flags = tuple(
        jnp.ndim(lv) >= 1 and jnp.shape(lv)[0] == e for lv in op.leaves
    )
    leaves_p = tuple(
        pad_rows(jnp.asarray(lv)) if flag else jnp.asarray(lv)
        for lv, flag in zip(op.leaves, leaf_flags)
    )
    leaf_specs = tuple(shard if flag else rep for flag in leaf_flags)

    inner = dataclasses.replace(op, free_mask=None)
    return (inner, rebuild, jnp.asarray(cd), jnp.asarray(seg), n_seg,
            geo, geo_specs, leaves_p, leaf_specs, routing)


def _sharded_mf_impl(sop: ShardedMatFreeOperator, x, transpose: bool):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    _N_MF_TRACES[0] += 1
    op = sop.op
    telemetry.count_trace("matfree", op.static, op.spec,
                          backend=f"sharded_{op.store}")
    (inner, rebuild, cd, seg, n_seg, geo, geo_specs, leaves_p, leaf_specs,
     routing) = _shard_scaffold(sop)
    axis_name = sop.axis_name
    n_geo = len(geo)

    if op.free_mask is not None:
        m = op.free_mask.astype(x.dtype)
        x_in = m * x
    else:
        x_in = x

    def body(x_rep, cd_s, seg_s, *rest):
        op_s = rebuild(inner, rest[:n_geo], rest[n_geo:])
        xe = x_rep[cd_s]                               # shard-local gather
        y_local = op_s._local_apply(xe, transpose)     # per-element action
        part = jax.ops.segment_sum(
            y_local.reshape(-1), seg_s.reshape(-1), num_segments=n_seg
        )
        return jax.lax.psum(part, axis_name)

    shard = P(axis_name)
    sharded = shard_map(
        body, mesh=sop.mesh,
        in_specs=(P(), shard, shard) + geo_specs + leaf_specs,
        out_specs=P(),
        check_rep=False,
    )
    with annotate("tg.matfree.sharded_apply"):
        packed = sharded(x_in, cd, seg, *geo, *leaves_p)
    out = jnp.zeros((routing.num_dofs,), dtype=packed.dtype)
    y = out.at[routing.touched_dev].set(packed)
    if op.free_mask is not None:
        y = m * y + (1.0 - m) * x
    return y


def _sharded_mf_diag_impl(sop: ShardedMatFreeOperator):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    op = sop.op
    (inner, rebuild, cd, seg, n_seg, geo, geo_specs, leaves_p, leaf_specs,
     routing) = _shard_scaffold(sop)
    axis_name = sop.axis_name
    n_geo = len(geo)

    def body(seg_s, *rest):
        op_s = rebuild(inner, rest[:n_geo], rest[n_geo:])
        d_local = op_s._diag_local()
        part = jax.ops.segment_sum(
            d_local.reshape(-1), seg_s.reshape(-1), num_segments=n_seg
        )
        return jax.lax.psum(part, axis_name)

    shard = P(axis_name)
    sharded = shard_map(
        body, mesh=sop.mesh,
        in_specs=(shard,) + geo_specs + leaf_specs,
        out_specs=P(),
        check_rep=False,
    )
    packed = sharded(seg, *geo, *leaves_p)
    out = jnp.zeros((routing.num_dofs,), dtype=packed.dtype)
    diag = out.at[routing.touched_dev].set(packed)
    if op.free_mask is not None:
        m = op.free_mask.astype(diag.dtype)
        diag = m * diag + (1.0 - m)
    return diag


@partial(jax.jit, static_argnums=(2,))
def _sharded_apply_jit(sop: ShardedMatFreeOperator, x, transpose: bool):
    return _sharded_mf_impl(sop, x, transpose)


@jax.jit
def _sharded_diag_jit(sop: ShardedMatFreeOperator):
    return _sharded_mf_diag_impl(sop)


# ---------------------------------------------------------------------------
# Batched families: B same-signature operators on ONE shared plan
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class MatFreeFamily(LinearOperator):
    """A *family* of B matrix-free operators sharing one plan and one form
    signature — the matrix-free twin of :class:`~repro.core.sparse.BatchedCSR`.

    ``op`` is a :class:`MatFreeOperator` whose batched coefficient leaves
    carry a leading ``(B, ...)`` axis (slots listed in ``leaf_axes``); the
    geometry, plan tables and Dirichlet mask are shared across the family.
    Every method vmaps the single-operator apply with the right axes, so the
    whole family runs in ONE executable:

    * ``matvec(X)`` / ``rmatvec(X)`` — ``(B, n)`` (a ``(n,)`` input
      broadcasts across the family),
    * ``diagonal()`` — ``(B, n)`` diagonals (family Jacobi preconditioning),
    * ``condensed(bc)`` — shared-mask Dirichlet condensation,
    * ``family[i]`` — instance ``i`` as a plain :class:`MatFreeOperator`.

    :func:`repro.core.solvers.matfree_solve_batched` solves the family with
    one vmapped adjoint :func:`~repro.core.solvers.matfree_solve` — gradients
    match per-instance adjoint solves.  Built by :func:`matfree_family`.
    """

    op: MatFreeOperator      # traced child: batched-leaf operator
    batch: int               # aux: family size B
    leaf_axes: tuple         # aux: per-leaf vmap axis (0 | None)
    coords_ax: Any = None    # aux: coords batch axis (0 | None)
    k_local_ax: Any = None   # aux: element-matrix batch axis (store="local")

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return (self.op,), (self.batch, self.leaf_axes, self.coords_ax,
                            self.k_local_ax)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    # -- structure --------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return self.op.shape  # per-instance shape (like BatchedCSR)

    def in_axes(self) -> MatFreeOperator:
        """The operator-shaped ``vmap`` axes pytree of this family."""
        return self.op.in_axes(leaf_axes=self.leaf_axes,
                               coords_ax=self.coords_ax,
                               k_local_ax=self.k_local_ax)

    def __getitem__(self, b: int) -> MatFreeOperator:
        if not isinstance(b, (int, np.integer)):
            raise TypeError(
                f"MatFreeFamily indices must be int, got {type(b).__name__}"
            )
        leaves = tuple(
            leaf[b] if ax == 0 else leaf
            for leaf, ax in zip(self.op.leaves, self.leaf_axes)
        )
        coords = self.op.coords
        if self.coords_ax == 0 and coords is not None:
            coords = coords[b]
        k_local = self.op.k_local
        if self.k_local_ax == 0 and k_local is not None:
            k_local = k_local[b]
        return dataclasses.replace(self.op, leaves=leaves, coords=coords,
                                   k_local=k_local)

    def condensed(self, bc) -> "MatFreeFamily":
        """Shared-mask Dirichlet condensation of the whole family (the mask
        broadcasts — one ``(n,)`` mask for all B instances)."""
        return dataclasses.replace(self, op=self.op.condensed(bc))

    # -- vmapped applies ---------------------------------------------------
    def _vmap(self, fn, x=None):
        ax = self.in_axes()
        if x is None:
            return jax.vmap(fn, in_axes=(ax,))(self.op)
        in_x = None if jnp.ndim(x) == 1 else 0
        return jax.vmap(fn, in_axes=(ax, in_x))(self.op, x)

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """``Y_b = A_b @ x_b`` for ``x: (B, n)`` (``(n,)`` broadcasts)."""
        return self._vmap(lambda o, xi: o.matvec(xi), x)

    def rmatvec(self, x: jnp.ndarray) -> jnp.ndarray:
        return self._vmap(lambda o, xi: o.rmatvec(xi), x)

    def diagonal(self) -> jnp.ndarray:
        """Per-instance diagonals ``(B, n)`` by one vmapped diagonal-only
        assembly — the family Jacobi preconditioner input."""
        return self._vmap(lambda o: o.diagonal())

    def state_bytes(self) -> int:
        return self.op.state_bytes()


def matfree_family(plan: AssemblyPlan, form, leaves_batch=None,
                   store: str = "context", coords_batch=None) -> MatFreeFamily:
    """Build a batched matrix-free operator family on one shared plan.

    ``form`` is the template form; ``leaves_batch`` batches its traced
    leaves with the same conventions as
    :func:`~repro.core.assembly.assemble_batched` — a tuple aligned with the
    form's traced leaves in slot order (per term: coefficients, then the
    scale factor), each entry ``None`` (shared) or an array with a leading
    ``(B, ...)`` batch axis; a bare array batches the first slot::

        fam = matfree_family(plan, wf.diffusion(rho_b[0]),
                             leaves_batch=(rho_b, None))     # (B, E) coeffs

    ``coords_batch: (B, E, nv, d)`` batches the geometry instead of (or in
    addition to) the coefficients; batched geometry forces ``store="coords"``
    (per-apply geometry recompute — the precomputed-context layout would
    have to materialize B full contexts).
    """
    spec, leaves0 = weakform.lower(form, weakform.MATRIX)
    if any(domain is not None for _, domain, _ in spec):
        raise NotImplementedError(
            "matrix-free families support volume terms only (same restriction "
            "as the single-instance matrix-free apply)"
        )
    if leaves_batch is None:
        leaves_batch = (None,) * len(leaves0)
    elif not isinstance(leaves_batch, (tuple, list)):
        leaves_batch = (leaves_batch,) + (None,) * (len(leaves0) - 1)
    if len(leaves_batch) != len(leaves0):
        raise ValueError(
            f"leaves_batch has {len(leaves_batch)} slots but the form lowers "
            f"to {len(leaves0)} traced leaves (per term: coefficients, then "
            "the scale factor) — pass None for slots shared across the family"
        )
    sizes = {int(jnp.shape(b)[0]) for b in leaves_batch if b is not None}
    if coords_batch is not None:
        sizes.add(int(jnp.shape(coords_batch)[0]))
        if store != "coords":
            store = "coords"
    if not sizes:
        raise ValueError(
            "nothing is batched: pass coords_batch and/or batched leaves"
        )
    if len(sizes) > 1:
        raise ValueError(f"inconsistent family batch sizes {sorted(sizes)}")
    (batch,) = sizes
    merged = tuple(
        b if b is not None else l0 for b, l0 in zip(leaves_batch, leaves0)
    )
    leaf_axes = tuple(0 if b is not None else None for b in leaves_batch)
    coords_ax = 0 if coords_batch is not None else None

    if store == "local":
        # per-instance element matrices, built by one vmapped local assembly:
        # k_local becomes the only (batched) traced leaf, like the
        # single-instance "local" store
        base = matfree_operator(plan, form, store="context")
        ctx, vs = base.ctx, plan.static.value_size

        def k_of(lv):
            k_local = None
            leaf = iter(lv)
            for kind, _, desc in spec:
                vals = [next(leaf) if d == weakform.TRACED else d[1]
                        for d in desc]
                *coeffs, scale = vals
                k = weakform.KERNELS[kind].fn(ctx, vs, *coeffs)
                k = k * jnp.asarray(scale)
                k_local = k if k_local is None else k_local + k
            return k_local

        k_b = jax.vmap(k_of, in_axes=(leaf_axes,))(merged)
        op = dataclasses.replace(
            base, k_local=k_b, ctx=None, coords=None, leaves=(),
            spec=tuple((kind, None, ()) for kind, _, _ in spec),
            store="local",
        )
        return MatFreeFamily(op=op, batch=batch, leaf_axes=(),
                             coords_ax=None, k_local_ax=0)
    coords = plan.coords if coords_batch is None else coords_batch
    op = matfree_operator(plan, form, store=store,
                          coords=coords if coords_ax is None else None)
    if coords_ax == 0:
        op = dataclasses.replace(op, coords=coords)
    op = dataclasses.replace(op, leaves=merged)
    telemetry.gauge_set("operator_state_bytes", op.state_bytes(),
                        store=f"family_{store}")
    return MatFreeFamily(op=op, batch=batch, leaf_axes=leaf_axes,
                         coords_ax=coords_ax)
