"""Mesh containers and structured generators (numpy, setup-time).

No external mesh dependency (Gmsh-free): the paper's benchmark geometries —
unit square/cube, hollow cube, L-shape, disk, non-convex "boomerang" — are
generated structurally.  A :class:`Mesh` stores vertices + cells; a
:class:`FunctionSpace` derives the DoF layout (``cell_dofs: (E, k)`` — the
local→global map ``g_e`` of the paper) for a chosen reference element.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .elements import ReferenceElement, get_element

__all__ = [
    "Mesh",
    "FunctionSpace",
    "unit_square_tri",
    "rectangle_tri",
    "rectangle_quad",
    "unit_cube_tet",
    "box_hex",
    "unit_cube_hex",
    "hollow_cube_tet",
    "l_shape_tri",
    "disk_tri",
    "annulus_sector_tri",
]


# ---------------------------------------------------------------------------
# Mesh container
# ---------------------------------------------------------------------------

_FACET_LOCAL = {
    # local vertex indices of each facet, per cell type
    "tri": np.array([[0, 1], [1, 2], [2, 0]]),
    "quad": np.array([[0, 1], [1, 2], [2, 3], [3, 0]]),
    "tet": np.array([[1, 2, 3], [0, 3, 2], [0, 1, 3], [0, 2, 1]]),
    # Q1 hex corner order matches elements._HEX_CORNERS (z=0 quad then z=1)
    "hex": np.array(
        [
            [0, 3, 2, 1],  # z = 0 (outward −z)
            [4, 5, 6, 7],  # z = 1
            [0, 1, 5, 4],  # y = 0
            [3, 7, 6, 2],  # y = 1
            [0, 4, 7, 3],  # x = 0
            [1, 2, 6, 5],  # x = 1
        ]
    ),
}


@dataclasses.dataclass
class Mesh:
    points: np.ndarray          # (n_vertices, d)
    cells: np.ndarray           # (E, verts_per_cell), int
    cell_type: str              # 'tri' | 'quad' | 'tet'

    def __post_init__(self):
        self.points = np.asarray(self.points, dtype=np.float64)
        self.cells = np.asarray(self.cells, dtype=np.int64)

    @property
    def dim(self) -> int:
        return self.points.shape[1]

    @property
    def num_vertices(self) -> int:
        return self.points.shape[0]

    @property
    def num_cells(self) -> int:
        return self.cells.shape[0]

    # -- topology -----------------------------------------------------------
    def boundary_facets(self) -> np.ndarray:
        """Facets (as sorted vertex tuples) that appear in exactly one cell.

        Returns ``(F, nv)`` vertex indices with *consistent outward
        orientation* preserved from the generating cell.
        """
        loc = _FACET_LOCAL[self.cell_type]
        facets = self.cells[:, loc]                      # (E, nf, nv)
        flat = facets.reshape(-1, loc.shape[1])          # (E*nf, nv)
        key = np.sort(flat, axis=1)
        _, inv, counts = np.unique(
            key, axis=0, return_inverse=True, return_counts=True
        )
        is_bdry = counts[inv] == 1
        return flat[is_bdry]

    def cell_volumes(self) -> np.ndarray:
        x = self.points[self.cells]
        if self.cell_type == "tri":
            a = x[:, 1] - x[:, 0]
            b = x[:, 2] - x[:, 0]
            return 0.5 * np.abs(a[:, 0] * b[:, 1] - a[:, 1] * b[:, 0])
        if self.cell_type == "tet":
            a = x[:, 1] - x[:, 0]
            b = x[:, 2] - x[:, 0]
            c = x[:, 3] - x[:, 0]
            return np.abs(np.einsum("ei,ei->e", a, np.cross(b, c))) / 6.0
        if self.cell_type == "quad":
            a = x[:, 1] - x[:, 0]
            b = x[:, 3] - x[:, 0]
            return np.abs(a[:, 0] * b[:, 1] - a[:, 1] * b[:, 0])
        if self.cell_type == "hex":
            # exact for parallelepipeds (all structured generators here)
            a = x[:, 1] - x[:, 0]
            b = x[:, 3] - x[:, 0]
            c = x[:, 4] - x[:, 0]
            return np.abs(np.einsum("ei,ei->e", a, np.cross(b, c)))
        raise ValueError(self.cell_type)


# ---------------------------------------------------------------------------
# Function spaces (DoF layouts)
# ---------------------------------------------------------------------------

def _edge_numbering(cells: np.ndarray, edge_local: np.ndarray):
    """Globally number unique edges; returns (n_edges, cell_edges (E, ne))."""
    edges = cells[:, edge_local]                      # (E, ne, 2)
    flat = np.sort(edges.reshape(-1, 2), axis=1)
    uniq, inv = np.unique(flat, axis=0, return_inverse=True)
    return uniq, inv.reshape(cells.shape[0], edge_local.shape[0])


@dataclasses.dataclass
class FunctionSpace:
    """Scalar Lagrange space on a mesh.

    Vector-valued problems (elasticity) use the same scalar space with
    ``value_size`` components; global DoF = ``node * value_size + comp``.
    """

    mesh: Mesh
    element: ReferenceElement
    value_size: int = 1

    def __post_init__(self):
        m, el = self.mesh, self.element
        if el.name in ("P1_tri", "P1_tet", "Q1_quad", "Q1_hex"):
            self.scalar_dofs = m.num_vertices
            scalar_cell_dofs = m.cells
            self.dof_points = m.points
        elif el.name == "P2_tri":
            edge_local = np.array([[0, 1], [1, 2], [2, 0]])
            uniq_edges, cell_edges = _edge_numbering(m.cells, edge_local)
            self.scalar_dofs = m.num_vertices + uniq_edges.shape[0]
            scalar_cell_dofs = np.concatenate(
                [m.cells, m.num_vertices + cell_edges], axis=1
            )
            mid = 0.5 * (m.points[uniq_edges[:, 0]] + m.points[uniq_edges[:, 1]])
            self.dof_points = np.concatenate([m.points, mid], axis=0)
        else:
            raise NotImplementedError(el.name)

        v = self.value_size
        if v == 1:
            self.cell_dofs = scalar_cell_dofs
        else:
            # interleaved components: dof = scalar_dof * v + comp
            base = scalar_cell_dofs[:, :, None] * v + np.arange(v)[None, None, :]
            self.cell_dofs = base.reshape(m.num_cells, -1)
        self.num_dofs = self.scalar_dofs * v
        self.local_dofs = self.cell_dofs.shape[1]

    # -- boundary DoFs --------------------------------------------------------
    def boundary_dofs(self, predicate=None) -> np.ndarray:
        """Scalar boundary DoFs (vertex + P2 edge DoFs) filtered by predicate
        on DoF coordinates; expanded across components for vector spaces."""
        facets = self.mesh.boundary_facets()
        verts = np.unique(facets)
        dofs = [verts]
        if self.element.name == "P2_tri":
            edge_local = np.array([[0, 1], [1, 2], [2, 0]])
            uniq_edges, _ = _edge_numbering(self.mesh.cells, edge_local)
            fkey = {tuple(sorted(f)) for f in facets}
            on_b = np.array(
                [i for i, e in enumerate(uniq_edges) if tuple(sorted(e)) in fkey],
                dtype=np.int64,
            )
            dofs.append(self.mesh.num_vertices + on_b)
        scalar = np.unique(np.concatenate(dofs))
        if predicate is not None:
            scalar = scalar[predicate(self.dof_points[scalar])]
        if self.value_size == 1:
            return scalar
        return (scalar[:, None] * self.value_size + np.arange(self.value_size)).ravel()


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def rectangle_tri(nx: int, ny: int, lx: float = 1.0, ly: float = 1.0) -> Mesh:
    """Structured crossed triangulation of [0,lx]x[0,ly]."""
    xs = np.linspace(0.0, lx, nx + 1)
    ys = np.linspace(0.0, ly, ny + 1)
    X, Y = np.meshgrid(xs, ys, indexing="ij")
    pts = np.stack([X.ravel(), Y.ravel()], axis=-1)

    def vid(i, j):
        return i * (ny + 1) + j

    cells = []
    for i in range(nx):
        for j in range(ny):
            v00, v10 = vid(i, j), vid(i + 1, j)
            v01, v11 = vid(i, j + 1), vid(i + 1, j + 1)
            if (i + j) % 2 == 0:
                cells.append([v00, v10, v11])
                cells.append([v00, v11, v01])
            else:
                cells.append([v00, v10, v01])
                cells.append([v10, v11, v01])
    return Mesh(pts, np.array(cells), "tri")


def unit_square_tri(n: int) -> Mesh:
    return rectangle_tri(n, n)


def rectangle_quad(nx: int, ny: int, lx: float, ly: float) -> Mesh:
    xs = np.linspace(0.0, lx, nx + 1)
    ys = np.linspace(0.0, ly, ny + 1)
    X, Y = np.meshgrid(xs, ys, indexing="ij")
    pts = np.stack([X.ravel(), Y.ravel()], axis=-1)

    def vid(i, j):
        return i * (ny + 1) + j

    cells = []
    for i in range(nx):
        for j in range(ny):
            cells.append([vid(i, j), vid(i + 1, j), vid(i + 1, j + 1), vid(i, j + 1)])
    return Mesh(pts, np.array(cells), "quad")


_CUBE_TETS = np.array(
    # 6-tet (Kuhn) subdivision of the unit cube, corners in lexicographic
    # order (x fastest): vertex id = 4*z + 2*y + x  -> see _cube_vid below.
    [
        [0, 1, 3, 7],
        [0, 1, 7, 5],
        [0, 5, 7, 4],
        [0, 3, 2, 7],
        [0, 2, 6, 7],
        [0, 6, 4, 7],
    ]
)


def _box_tet(ni, nj, nk, keep=None, lx=1.0, ly=1.0, lz=1.0) -> Mesh:
    xs = np.linspace(0, lx, ni + 1)
    ys = np.linspace(0, ly, nj + 1)
    zs = np.linspace(0, lz, nk + 1)
    X, Y, Z = np.meshgrid(xs, ys, zs, indexing="ij")
    pts = np.stack([X.ravel(), Y.ravel(), Z.ravel()], axis=-1)

    def vid(i, j, k):
        return (i * (nj + 1) + j) * (nk + 1) + k

    cells = []
    for i in range(ni):
        for j in range(nj):
            for k in range(nk):
                if keep is not None and not keep(i, j, k):
                    continue
                c = [
                    vid(i, j, k), vid(i + 1, j, k), vid(i, j + 1, k),
                    vid(i + 1, j + 1, k), vid(i, j, k + 1), vid(i + 1, j, k + 1),
                    vid(i, j + 1, k + 1), vid(i + 1, j + 1, k + 1),
                ]
                corners = np.array(c)
                for tet in _CUBE_TETS:
                    cells.append(corners[tet])
    cells = np.array(cells)
    # drop unused vertices (hollow meshes)
    used = np.unique(cells)
    remap = -np.ones(pts.shape[0], dtype=np.int64)
    remap[used] = np.arange(used.shape[0])
    return Mesh(pts[used], remap[cells], "tet")


def unit_cube_tet(n: int) -> Mesh:
    return _box_tet(n, n, n)


def box_hex(nx: int, ny: int, nz: int, lx: float = 1.0, ly: float = 1.0,
            lz: float = 1.0) -> Mesh:
    """Structured trilinear hexahedral box mesh (Q1_hex cells, corner order
    matching :data:`repro.core.elements._HEX_CORNERS`)."""
    xs = np.linspace(0, lx, nx + 1)
    ys = np.linspace(0, ly, ny + 1)
    zs = np.linspace(0, lz, nz + 1)
    X, Y, Z = np.meshgrid(xs, ys, zs, indexing="ij")
    pts = np.stack([X.ravel(), Y.ravel(), Z.ravel()], axis=-1)

    def vid(i, j, k):
        return (i * (ny + 1) + j) * (nz + 1) + k

    cells = []
    for i in range(nx):
        for j in range(ny):
            for k in range(nz):
                cells.append(
                    [
                        vid(i, j, k), vid(i + 1, j, k),
                        vid(i + 1, j + 1, k), vid(i, j + 1, k),
                        vid(i, j, k + 1), vid(i + 1, j, k + 1),
                        vid(i + 1, j + 1, k + 1), vid(i, j + 1, k + 1),
                    ]
                )
    return Mesh(pts, np.array(cells), "hex")


def unit_cube_hex(n: int) -> Mesh:
    return box_hex(n, n, n)


def hollow_cube_tet(n: int) -> Mesh:
    """[0,1]^3 minus the open box (0.25, 0.75)^3 (paper SM B.1.1)."""
    lo = int(round(0.25 * n))
    hi = int(round(0.75 * n))

    def keep(i, j, k):
        return not (lo <= i < hi and lo <= j < hi and lo <= k < hi)

    return _box_tet(n, n, n, keep=keep)


def l_shape_tri(n: int) -> Mesh:
    """L-shaped domain [0,1]^2 minus (0.5,1)x(0.5,1)."""
    m = rectangle_tri(n, n)
    cx = m.points[m.cells].mean(axis=1)
    keep = ~((cx[:, 0] > 0.5) & (cx[:, 1] > 0.5))
    cells = m.cells[keep]
    used = np.unique(cells)
    remap = -np.ones(m.num_vertices, dtype=np.int64)
    remap[used] = np.arange(used.shape[0])
    return Mesh(m.points[used], remap[cells], "tri")


def disk_tri(n_r: int, center=(0.5, 0.5), radius: float = 0.5) -> Mesh:
    """Structured polar triangulation of a disk (paper's circular domain)."""
    pts = [np.array(center, dtype=np.float64)]
    rings = []
    for r_i in range(1, n_r + 1):
        r = radius * r_i / n_r
        n_theta = 6 * r_i
        th = 2 * np.pi * np.arange(n_theta) / n_theta
        ring = np.stack(
            [center[0] + r * np.cos(th), center[1] + r * np.sin(th)], axis=-1
        )
        rings.append((len(pts), n_theta))
        pts.extend(ring)
    pts = np.asarray(pts)

    cells = []
    # innermost ring to center
    start, n_t = rings[0]
    for t in range(n_t):
        cells.append([0, start + t, start + (t + 1) % n_t])
    # ring-to-ring strips
    for ri in range(1, n_r):
        s0, n0 = rings[ri - 1]
        s1, n1 = rings[ri]
        # walk around matching angles
        for t in range(n1):
            a1 = s1 + t
            b1 = s1 + (t + 1) % n1
            # nearest inner vertex by angle
            t0 = int(round(t * n0 / n1)) % n0
            t0n = int(round((t + 1) * n0 / n1)) % n0
            a0 = s0 + t0
            b0 = s0 + t0n
            cells.append([a0, a1, b1])
            if t0 != t0n:
                cells.append([a0, b1, b0])
    return Mesh(pts, np.array(cells), "tri")


def annulus_sector_tri(
    n_r: int, n_t: int, r0: float = 0.4, r1: float = 1.0, angle: float = 1.5 * np.pi
) -> Mesh:
    """Non-convex 'boomerang'-style domain: a 270° annulus sector."""
    rr = np.linspace(r0, r1, n_r + 1)
    tt = np.linspace(0.0, angle, n_t + 1)
    R, T = np.meshgrid(rr, tt, indexing="ij")
    pts = np.stack([R.ravel() * np.cos(T.ravel()), R.ravel() * np.sin(T.ravel())], -1)

    def vid(i, j):
        return i * (n_t + 1) + j

    cells = []
    for i in range(n_r):
        for j in range(n_t):
            v00, v10 = vid(i, j), vid(i + 1, j)
            v01, v11 = vid(i, j + 1), vid(i + 1, j + 1)
            cells.append([v00, v10, v11])
            cells.append([v00, v11, v01])
    return Mesh(pts, np.array(cells), "tri")


def element_for_mesh(mesh: Mesh, degree: int = 1) -> ReferenceElement:
    if mesh.cell_type == "tri":
        return get_element("P1_tri" if degree == 1 else "P2_tri")
    if mesh.cell_type == "tet":
        return get_element("P1_tet")
    if mesh.cell_type == "quad":
        return get_element("Q1_quad")
    if mesh.cell_type == "hex":
        return get_element("Q1_hex")
    raise ValueError(mesh.cell_type)
