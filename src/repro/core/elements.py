"""Reference elements: basis functions and gradients on the reference cell.

A :class:`ReferenceElement` provides, for a quadrature rule ``(Q, d)``:

* ``tabulate(points) -> (Q, k)``       basis values          (``B̂`` in Alg. 1)
* ``tabulate_grad(points) -> (Q, k, d)`` reference gradients  (``∇B̂``)

All tabulation happens at setup time in numpy; the resulting dense tables are
constants of the Batch-Map einsum.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import quadrature

__all__ = ["ReferenceElement", "get_element"]


@dataclasses.dataclass(frozen=True)
class ReferenceElement:
    name: str
    dim: int           # spatial dimension d
    num_dofs: int      # local DoFs k
    cell: str          # 'simplex' | 'tensor'
    degree: int

    # ------------------------------------------------------------------
    def tabulate(self, pts: np.ndarray) -> np.ndarray:
        return _TABULATE[self.name](np.asarray(pts, dtype=np.float64))

    def tabulate_grad(self, pts: np.ndarray) -> np.ndarray:
        return _TABULATE_GRAD[self.name](np.asarray(pts, dtype=np.float64))

    def default_rule(self, order: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        """Quadrature exact for the mass-matrix degree of this element."""
        order = order if order is not None else 2 * self.degree
        if self.cell == "simplex":
            if self.dim == 1:
                return quadrature.gauss_legendre_interval(order)
            if self.dim == 2:
                return quadrature.triangle_rule(order)
            return quadrature.tetrahedron_rule(order)
        if self.dim == 2:
            return quadrature.quad_rule(order)
        return quadrature.hex_rule(order)


# --- P1 line (used for boundary facets of triangles) ------------------------

def _p1_line(p):
    x = p[:, 0]
    return np.stack([1 - x, x], axis=-1)


def _p1_line_grad(p):
    q = p.shape[0]
    g = np.zeros((q, 2, 1))
    g[:, 0, 0] = -1.0
    g[:, 1, 0] = 1.0
    return g


# --- P1 triangle -------------------------------------------------------------

def _p1_tri(p):
    x, y = p[:, 0], p[:, 1]
    return np.stack([1 - x - y, x, y], axis=-1)


def _p1_tri_grad(p):
    q = p.shape[0]
    g = np.zeros((q, 3, 2))
    g[:, 0] = [-1.0, -1.0]
    g[:, 1] = [1.0, 0.0]
    g[:, 2] = [0.0, 1.0]
    return g


# --- P2 triangle -------------------------------------------------------------
# DoF order: 3 vertices, then midpoints of edges (01), (12), (20).

def _p2_tri(p):
    x, y = p[:, 0], p[:, 1]
    lam0, lam1, lam2 = 1 - x - y, x, y
    return np.stack(
        [
            lam0 * (2 * lam0 - 1),
            lam1 * (2 * lam1 - 1),
            lam2 * (2 * lam2 - 1),
            4 * lam0 * lam1,
            4 * lam1 * lam2,
            4 * lam2 * lam0,
        ],
        axis=-1,
    )


def _p2_tri_grad(p):
    x, y = p[:, 0], p[:, 1]
    lam0 = 1 - x - y
    d0 = np.array([-1.0, -1.0])
    d1 = np.array([1.0, 0.0])
    d2 = np.array([0.0, 1.0])
    q = p.shape[0]
    g = np.zeros((q, 6, 2))
    g[:, 0] = (4 * lam0 - 1)[:, None] * d0
    g[:, 1] = (4 * x - 1)[:, None] * d1
    g[:, 2] = (4 * y - 1)[:, None] * d2
    g[:, 3] = 4 * (lam0[:, None] * d1 + x[:, None] * d0)
    g[:, 4] = 4 * (x[:, None] * d2 + y[:, None] * d1)
    g[:, 5] = 4 * (y[:, None] * d0 + lam0[:, None] * d2)
    return g


# --- P1 tetrahedron ----------------------------------------------------------

def _p1_tet(p):
    x, y, z = p[:, 0], p[:, 1], p[:, 2]
    return np.stack([1 - x - y - z, x, y, z], axis=-1)


def _p1_tet_grad(p):
    q = p.shape[0]
    g = np.zeros((q, 4, 3))
    g[:, 0] = [-1.0, -1.0, -1.0]
    g[:, 1] = [1.0, 0.0, 0.0]
    g[:, 2] = [0.0, 1.0, 0.0]
    g[:, 3] = [0.0, 0.0, 1.0]
    return g


# --- Q1 quad -----------------------------------------------------------------
# DoF order: (0,0), (1,0), (1,1), (0,1)  (counter-clockwise).

def _q1_quad(p):
    x, y = p[:, 0], p[:, 1]
    return np.stack(
        [(1 - x) * (1 - y), x * (1 - y), x * y, (1 - x) * y], axis=-1
    )


def _q1_quad_grad(p):
    x, y = p[:, 0], p[:, 1]
    q = p.shape[0]
    g = np.zeros((q, 4, 2))
    g[:, 0, 0] = -(1 - y); g[:, 0, 1] = -(1 - x)
    g[:, 1, 0] = (1 - y);  g[:, 1, 1] = -x
    g[:, 2, 0] = y;        g[:, 2, 1] = x
    g[:, 3, 0] = -y;       g[:, 3, 1] = (1 - x)
    return g


# --- Q1 hex ------------------------------------------------------------------
# DoF order: standard lexicographic corners of the unit cube.

_HEX_CORNERS = np.array(
    [
        [0, 0, 0], [1, 0, 0], [1, 1, 0], [0, 1, 0],
        [0, 0, 1], [1, 0, 1], [1, 1, 1], [0, 1, 1],
    ],
    dtype=np.float64,
)


def _q1_hex(p):
    x, y, z = p[:, 0:1], p[:, 1:2], p[:, 2:3]
    cx, cy, cz = _HEX_CORNERS[:, 0], _HEX_CORNERS[:, 1], _HEX_CORNERS[:, 2]
    fx = cx * x + (1 - cx) * (1 - x)
    fy = cy * y + (1 - cy) * (1 - y)
    fz = cz * z + (1 - cz) * (1 - z)
    return fx * fy * fz


def _q1_hex_grad(p):
    x, y, z = p[:, 0:1], p[:, 1:2], p[:, 2:3]
    cx, cy, cz = _HEX_CORNERS[:, 0], _HEX_CORNERS[:, 1], _HEX_CORNERS[:, 2]
    fx = cx * x + (1 - cx) * (1 - x)
    fy = cy * y + (1 - cy) * (1 - y)
    fz = cz * z + (1 - cz) * (1 - z)
    dfx = 2 * cx - 1.0
    dfy = 2 * cy - 1.0
    dfz = 2 * cz - 1.0
    g = np.stack([dfx * fy * fz, fx * dfy * fz, fx * fy * dfz], axis=-1)
    return g


_TABULATE = {
    "P1_line": _p1_line,
    "P1_tri": _p1_tri,
    "P2_tri": _p2_tri,
    "P1_tet": _p1_tet,
    "Q1_quad": _q1_quad,
    "Q1_hex": _q1_hex,
}
_TABULATE_GRAD = {
    "P1_line": _p1_line_grad,
    "P1_tri": _p1_tri_grad,
    "P2_tri": _p2_tri_grad,
    "P1_tet": _p1_tet_grad,
    "Q1_quad": _q1_quad_grad,
    "Q1_hex": _q1_hex_grad,
}

_ELEMENTS = {
    "P1_line": ReferenceElement("P1_line", 1, 2, "simplex", 1),
    "P1_tri": ReferenceElement("P1_tri", 2, 3, "simplex", 1),
    "P2_tri": ReferenceElement("P2_tri", 2, 6, "simplex", 2),
    "P1_tet": ReferenceElement("P1_tet", 3, 4, "simplex", 1),
    "Q1_quad": ReferenceElement("Q1_quad", 2, 4, "tensor", 1),
    "Q1_hex": ReferenceElement("Q1_hex", 3, 8, "tensor", 1),
}


def get_element(name: str) -> ReferenceElement:
    return _ELEMENTS[name]
