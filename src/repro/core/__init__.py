"""TensorGalerkin core: Batch-Map + Sparse-Reduce Galerkin assembly.

FEM numerics require double precision (the paper solves to 1e-10 residual);
importing this subpackage enables jax x64 mode.  The LM/dry-run stack is
dtype-explicit throughout and unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

from .assembly import (  # noqa: E402,F401
    AssemblyPlan,
    GalerkinAssembler,
    assemble,
    assemble_batched,
    assemble_rhs,
    assemble_rhs_batched,
    assemble_rhs_sharded,
    assemble_sharded,
    build_plan,
    clear_assembly_caches,
    facet_context,
    geometry_context,
)
from .boundary import DirichletCondenser, FacetAssembler  # noqa: E402,F401
from .elements import ReferenceElement, get_element  # noqa: E402,F401
from .matvec import (  # noqa: E402,F401
    MATVEC_BACKENDS,
    make_matvec,
    make_residual,
    matvec_backends,
    register_matvec_backend,
)
from .mesh import (  # noqa: E402,F401
    FunctionSpace,
    Mesh,
    annulus_sector_tri,
    box_hex,
    disk_tri,
    hollow_cube_tet,
    l_shape_tri,
    rectangle_quad,
    rectangle_tri,
    unit_cube_hex,
    unit_cube_tet,
    unit_square_tri,
)
from .operator import (  # noqa: E402,F401
    LinearOperator,
    MatFreeFamily,
    MatFreeOperator,
    ShardedMatFreeOperator,
    matfree_family,
    matfree_operator,
    n_matfree_traces,
)
from .solvers import (  # noqa: E402,F401
    SolveInfo,
    SolverSpec,
    bicgstab,
    cg,
    jacobi_preconditioner,
    make_preconditioner,
    matfree_solve,
    matfree_solve_batched,
    register_preconditioner,
    resolve_solver_spec,
    sparse_solve,
    sparse_solve_batched,
)
from . import elemalg  # noqa: E402,F401  (registers ebe/chebyshev preconds)
from .elemalg import (  # noqa: E402,F401
    DofSplit,
    ElementFactors,
    block_partition,
    chebyshev_preconditioner,
    condense,
    condensed_solve,
    dof_split,
    ebe_preconditioner,
    factorize,
    vertex_split,
)
from .sparse import (  # noqa: E402,F401
    CSR,
    ELL,
    BatchedCSR,
    cached_diagonal,
    csr_to_ell,
    ell_layout,
)
from . import weakform  # noqa: E402,F401
from .weakform import WeakForm  # noqa: E402,F401
