"""TensorGalerkin core: Batch-Map + Sparse-Reduce Galerkin assembly.

FEM numerics require double precision (the paper solves to 1e-10 residual);
importing this subpackage enables jax x64 mode.  The LM/dry-run stack is
dtype-explicit throughout and unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

from .assembly import GalerkinAssembler, geometry_context, facet_context  # noqa: E402,F401
from .boundary import DirichletCondenser, FacetAssembler  # noqa: E402,F401
from .elements import ReferenceElement, get_element  # noqa: E402,F401
from .mesh import (  # noqa: E402,F401
    FunctionSpace,
    Mesh,
    annulus_sector_tri,
    disk_tri,
    hollow_cube_tet,
    l_shape_tri,
    rectangle_quad,
    rectangle_tri,
    unit_cube_tet,
    unit_square_tri,
)
from .solvers import bicgstab, cg, jacobi_preconditioner, sparse_solve  # noqa: E402,F401
from .sparse import CSR, ELL, csr_to_ell  # noqa: E402,F401
from . import weakform  # noqa: E402,F401
from .weakform import WeakForm  # noqa: E402,F401
