"""Quadrature rules on reference elements.

All rules are returned as ``(points, weights)`` numpy arrays with
``points.shape == (Q, d)`` and ``weights.shape == (Q,)``.  Weights include the
reference-element measure, i.e. ``sum(w) == |ref element|`` (1/2 for the unit
triangle, 1/6 for the unit tetrahedron, 1 for the unit interval/square/cube).

These are *setup-time* objects (numpy, not jax) — they are baked into the
Batch-Map einsum as constants, matching the paper's precomputed
``(ŵ_q, x̂_q)`` (Alg. 1).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "gauss_legendre_interval",
    "triangle_rule",
    "tetrahedron_rule",
    "quad_rule",
    "hex_rule",
]


def gauss_legendre_interval(order: int) -> tuple[np.ndarray, np.ndarray]:
    """Gauss-Legendre rule on [0, 1] exact for polynomials of degree ``order``."""
    npts = order // 2 + 1
    x, w = np.polynomial.legendre.leggauss(npts)
    # map [-1, 1] -> [0, 1]
    x = 0.5 * (x + 1.0)
    w = 0.5 * w
    return x[:, None].astype(np.float64), w.astype(np.float64)


# --- Simplex rules (Dunavant / Keast style, standard references) -----------

_TRI_RULES: dict[int, tuple[list[list[float]], list[float]]] = {
    # order: (barycentric-ish points on unit triangle, weights summing to 1/2)
    1: ([[1 / 3, 1 / 3]], [0.5]),
    2: (
        [[1 / 6, 1 / 6], [2 / 3, 1 / 6], [1 / 6, 2 / 3]],
        [1 / 6, 1 / 6, 1 / 6],
    ),
    3: (
        [[1 / 3, 1 / 3], [0.6, 0.2], [0.2, 0.6], [0.2, 0.2]],
        [-27 / 96, 25 / 96, 25 / 96, 25 / 96],
    ),
    4: (
        [
            [0.445948490915965, 0.445948490915965],
            [0.445948490915965, 0.108103018168070],
            [0.108103018168070, 0.445948490915965],
            [0.091576213509771, 0.091576213509771],
            [0.091576213509771, 0.816847572980459],
            [0.816847572980459, 0.091576213509771],
        ],
        [
            0.111690794839005,
            0.111690794839005,
            0.111690794839005,
            0.054975871827661,
            0.054975871827661,
            0.054975871827661,
        ],
    ),
}

_TET_RULES: dict[int, tuple[list[list[float]], list[float]]] = {
    1: ([[0.25, 0.25, 0.25]], [1 / 6]),
    2: (
        [
            [0.138196601125011, 0.138196601125011, 0.138196601125011],
            [0.585410196624969, 0.138196601125011, 0.138196601125011],
            [0.138196601125011, 0.585410196624969, 0.138196601125011],
            [0.138196601125011, 0.138196601125011, 0.585410196624969],
        ],
        [1 / 24, 1 / 24, 1 / 24, 1 / 24],
    ),
    3: (
        [
            [0.25, 0.25, 0.25],
            [0.5, 1 / 6, 1 / 6],
            [1 / 6, 0.5, 1 / 6],
            [1 / 6, 1 / 6, 0.5],
            [1 / 6, 1 / 6, 1 / 6],
        ],
        [-4 / 30, 0.075, 0.075, 0.075, 0.075],
    ),
}


def triangle_rule(order: int) -> tuple[np.ndarray, np.ndarray]:
    """Quadrature on the unit triangle {x>=0, y>=0, x+y<=1}."""
    order = min(max(order, 1), 4)
    pts, w = _TRI_RULES[order]
    return np.asarray(pts, dtype=np.float64), np.asarray(w, dtype=np.float64)


def tetrahedron_rule(order: int) -> tuple[np.ndarray, np.ndarray]:
    """Quadrature on the unit tetrahedron."""
    order = min(max(order, 1), 3)
    pts, w = _TET_RULES[order]
    return np.asarray(pts, dtype=np.float64), np.asarray(w, dtype=np.float64)


def quad_rule(order: int) -> tuple[np.ndarray, np.ndarray]:
    """Tensor-product Gauss rule on the unit square [0,1]^2."""
    x, w = gauss_legendre_interval(order)
    x = x[:, 0]
    X, Y = np.meshgrid(x, x, indexing="ij")
    W = np.outer(w, w)
    pts = np.stack([X.ravel(), Y.ravel()], axis=-1)
    return pts, W.ravel()


def hex_rule(order: int) -> tuple[np.ndarray, np.ndarray]:
    """Tensor-product Gauss rule on the unit cube [0,1]^3."""
    x, w = gauss_legendre_interval(order)
    x = x[:, 0]
    X, Y, Z = np.meshgrid(x, x, x, indexing="ij")
    W = np.einsum("i,j,k->ijk", w, w, w)
    pts = np.stack([X.ravel(), Y.ravel(), Z.ravel()], axis=-1)
    return pts, W.ravel()
