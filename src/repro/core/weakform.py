"""Composable weak-form API: declarative terms over the Map-Reduce pipeline.

A :class:`WeakForm` is a sum of :class:`Term` objects — each a (kernel,
coefficient-spec) pair tagged with an integration domain (volume cells by
default, a :class:`~repro.core.boundary.FacetAssembler` for boundary terms).
Forms are closed under ``+`` and scalar scaling, so PDE operators compose
declaratively::

    from repro.core import weakform as wf

    form = wf.diffusion(rho) + wf.advection(beta) + wf.mass(c) \
         + wf.robin(alpha, on=facets)
    K = asm.assemble(form)                    # ONE fused Map, ONE Reduce
    F = asm.assemble_rhs(wf.source(f) + wf.neumann(g, on=facets))

:meth:`GalerkinAssembler.assemble` traces one fused Map stage evaluating
every volume term against a shared :class:`~repro.core.forms.FormContext`
(geometry built once, *inside* the jit boundary), accumulates the local
element matrices term-wise, and performs a single Sparse-Reduce; facet
terms reduce through their own facet routing and land in the volume CSR
pattern via a precomputed nnz-injection — mixed volume+boundary forms
yield one CSR from one XLA executable.

Lowering splits a form into a **static signature** (term kinds, domains,
which coefficient slots are traced vs. static) and a flat tuple of
**traced leaves** (arrays / scalars — coefficients, scale factors).  The
assembler's jit cache is keyed on the signature, so re-assembling with new
coefficient *values* (a SIMP density update, a new θ-step ``dt``) reuses
the compiled executable.  ``None`` and callable coefficients are static:
callables are evaluated at quadrature points inside the trace, so **reuse
the same function object across calls** to reuse the executable.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from . import forms

__all__ = [
    "Term",
    "WeakForm",
    "KERNELS",
    "lower",
    "diffusion",
    "anisotropic_diffusion",
    "advection",
    "mass",
    "elasticity",
    "robin",
    "source",
    "neumann",
    "reaction",
]

MATRIX = "matrix"
VECTOR = "vector"

TRACED = "traced"  # marker for a coefficient slot carried as a jit leaf


@dataclasses.dataclass(frozen=True)
class _Kernel:
    """arity + the local Map: ``fn(ctx, value_size, *coeffs) -> (E,k,k)|(E,k)``.

    ``symmetric`` declares K_e = K_eᵀ for every admissible coefficient —
    consumed by the matrix-free operator (:mod:`repro.core.operator`) to
    reuse the forward action for ``rmatvec`` (a tensor-coefficient
    anisotropic diffusion is symmetric only for symmetric A, so it is
    conservatively marked nonsymmetric).  ``spd`` additionally declares the
    element tensors symmetric positive *semi*-definite for admissible
    (positive) coefficients — the element tensor-algebra layer
    (:mod:`repro.core.elemalg`) uses it to pick Cholesky over LU for the
    batched factorizations (advection and general anisotropic tensors fall
    back to LU).
    """

    arity: str
    fn: Callable
    symmetric: bool = False
    spd: bool = False


def _source_kernel(ctx, vs, f):
    return forms.load(ctx, f) if vs == 1 else forms.vector_load(ctx, f, vs)


KERNELS: dict[str, _Kernel] = {
    "diffusion": _Kernel(
        MATRIX, lambda ctx, vs, rho: forms.diffusion(ctx, rho), symmetric=True,
        spd=True,
    ),
    "anisotropic_diffusion": _Kernel(
        MATRIX, lambda ctx, vs, a: forms.anisotropic_diffusion(ctx, a)
    ),
    "advection": _Kernel(MATRIX, lambda ctx, vs, beta: forms.advection(ctx, beta)),
    "mass": _Kernel(
        MATRIX, lambda ctx, vs, c: forms.mass(ctx, c), symmetric=True, spd=True
    ),
    "elasticity": _Kernel(
        MATRIX,
        lambda ctx, vs, lam, mu, scale: forms.elasticity(ctx, lam, mu, scale=scale),
        symmetric=True,
        spd=True,
    ),
    "source": _Kernel(VECTOR, _source_kernel),
    "reaction": _Kernel(
        VECTOR, lambda ctx, vs, u, fn: forms.nonlinear_reaction(ctx, u, fn)
    ),
}


@dataclasses.dataclass(frozen=True, eq=False)
class Term:
    """One (kernel, coefficient-spec) pair on one integration domain.

    ``domain is None`` integrates over the mesh cells; a ``FacetAssembler``
    integrates over its boundary facets (the reduce injects into the volume
    CSR pattern).  ``scale`` is a scalar factor — traced, so ``dt * form``
    re-uses the compiled executable across ``dt`` values.
    """

    kind: str
    coeffs: tuple
    domain: Any = None
    scale: Any = 1.0

    @property
    def arity(self) -> str:
        return KERNELS[self.kind].arity

    def scaled(self, s) -> "Term":
        return dataclasses.replace(self, scale=s * self.scale)


@dataclasses.dataclass(frozen=True, eq=False)
class WeakForm:
    """A sum of terms, closed under ``+``, ``-`` and scalar scaling."""

    terms: tuple[Term, ...] = ()

    def __add__(self, other):
        other = _as_form(other)
        if other is NotImplemented:
            return NotImplemented
        return WeakForm(self.terms + other.terms)

    def __radd__(self, other):
        if isinstance(other, (int, float)) and other == 0:
            return self  # sum([...]) support
        return self.__add__(other)

    def __sub__(self, other):
        other = _as_form(other)
        if other is NotImplemented:
            return NotImplemented
        return self + (-1.0) * other

    def __mul__(self, s):
        if isinstance(s, (WeakForm, Term)):
            return NotImplemented  # forms scale by scalars; use + to combine
        return WeakForm(tuple(t.scaled(s) for t in self.terms))

    __rmul__ = __mul__

    def __neg__(self):
        return (-1.0) * self


def _as_form(obj) -> WeakForm:
    if isinstance(obj, WeakForm):
        return obj
    if isinstance(obj, Term):
        return WeakForm((obj,))
    return NotImplemented


def lower(form, arity: str):
    """Split a form into its static signature and traced leaves.

    Returns ``(spec, leaves)`` where ``spec`` is a hashable tuple of
    ``(kind, domain, coeff_descriptors)`` per term — ``coeff_descriptors``
    marks each slot (coefficients + trailing scale) as either :data:`TRACED`
    or ``("static", obj)`` (``None`` / callables) — and ``leaves`` is the
    flat tuple of traced values in slot order.  ``spec`` is the jit-cache
    key; ``leaves`` cross the jit boundary as pytree leaves.
    """
    form = _as_form(form)
    if form is NotImplemented:
        raise TypeError(f"expected a WeakForm or Term, got {type(form).__name__}")
    if not form.terms:
        raise ValueError("cannot assemble an empty WeakForm")
    spec, leaves = [], []
    for t in form.terms:
        if t.arity != arity:
            raise TypeError(
                f"term '{t.kind}' is a {t.arity} form; "
                f"{'assemble' if arity == MATRIX else 'assemble_rhs'} takes "
                f"{arity} forms only"
            )
        desc = []
        for c in (*t.coeffs, t.scale):
            if c is None or callable(c):
                desc.append(("static", c))
            else:
                desc.append(TRACED)
                leaves.append(c)
        spec.append((t.kind, t.domain, tuple(desc)))
    return tuple(spec), tuple(leaves)


# ---------------------------------------------------------------------------
# term constructors (the user-facing vocabulary)
# ---------------------------------------------------------------------------

def diffusion(rho=None) -> WeakForm:
    """∫ ρ ∇u·∇v — scalar (or ``None`` → unit) coefficient."""
    return WeakForm((Term("diffusion", (rho,)),))


def anisotropic_diffusion(a) -> WeakForm:
    """∫ (A∇u)·∇v — tensor coefficient: ``(d,d)`` constant, ``(E,d,d)``
    per-element, ``(E,Q,d,d)`` per-quadrature, or a callable of x."""
    return WeakForm((Term("anisotropic_diffusion", (a,)),))


def advection(beta) -> WeakForm:
    """∫ (β·∇u) v — nonsymmetric; β is a ``(d,)`` constant, ``(E,Q,d)``
    array, or a callable of x."""
    return WeakForm((Term("advection", (beta,)),))


def mass(c=None) -> WeakForm:
    """∫ c u v (reaction / L² term)."""
    return WeakForm((Term("mass", (c,)),))


def elasticity(lam, mu, scale=None) -> WeakForm:
    """∫ σ(u):ε(v) with Lamé (λ, μ); ``scale`` is the per-element SIMP
    interpolation E(ρ) (λ, μ and scale are all traced)."""
    return WeakForm((Term("elasticity", (lam, mu, scale)),))


def robin(alpha=None, *, on) -> WeakForm:
    """∫_Γ α u v over the facets of ``on`` (a FacetAssembler built with the
    volume routing) — reduces into the volume CSR pattern."""
    if on is None:
        raise ValueError("robin(...) needs on=<FacetAssembler>")
    return WeakForm((Term("mass", (alpha,), domain=on),))


def source(f=None) -> WeakForm:
    """∫ f v — volume load (vector-valued on vector spaces)."""
    return WeakForm((Term("source", (f,)),))


def neumann(g=None, *, on) -> WeakForm:
    """∫_Γ g v over the facets of ``on`` — boundary load."""
    if on is None:
        raise ValueError("neumann(...) needs on=<FacetAssembler>")
    return WeakForm((Term("source", (g,), domain=on),))


def reaction(u_nodal, fn: Callable) -> WeakForm:
    """Semi-linear load ∫ fn(u) v with nodal coefficients ``u_nodal``
    (``fn`` is static — reuse one function object across calls)."""
    return WeakForm((Term("reaction", (u_nodal, fn)),))
