"""Fault-tolerant checkpointing.

Design (scaled-down single-host version of the multi-host layout):
  * one ``step_XXXXXXXX/`` directory per checkpoint:
      - ``manifest.json``  — flat keypath → {shape, dtype, file} + metadata
        (step, data-iterator state, mesh shape at save time)
      - ``arrays.npz``     — one entry per leaf (multi-host would write one
        file per host covering its addressable shards)
      - ``_COMMITTED``     — atomic commit marker written *last*; restore
        ignores uncommitted (crashed mid-write) checkpoints
  * **async save**: the array→host transfer happens synchronously (cheap),
    serialization runs on a background thread so the train loop continues.
  * **elastic restore**: arrays are re-placed with ``jax.device_put`` against
    the *current* mesh's shardings — a checkpoint written on N chips restores
    onto M≠N chips (elastic scaling requirement).
  * retention: keep the latest ``max_to_keep``.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def keystr(path):
        return "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )

    return {keystr(p): v for p, v in flat}


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3):
        self.dir = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save -------------------------------------------------------------------
    def save(self, step: int, state, extra: dict | None = None,
             blocking: bool = False):
        self.wait()  # one in-flight save at a time
        flat = _flatten(state)
        host_arrays = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host_arrays)
            manifest = {
                "step": step,
                "extra": extra or {},
                "leaves": {
                    k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in host_arrays.items()
                },
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._cleanup()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _cleanup(self):
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and os.path.exists(
                os.path.join(full, "_COMMITTED")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target, shardings=None):
        """Rebuild ``target``-structured state; re-shard onto the current
        mesh if ``shardings`` (same structure) is given."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_t = _flatten(target)
        flat_s = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, like in flat_t.items():
            arr = data[key]
            if shardings is not None:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # unflatten along target structure
        treedef = jax.tree.structure(target)
        keys = list(_flatten(target).keys())
        return jax.tree.unflatten(treedef, [out[k] for k in keys])

    def restore_manifest(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)
