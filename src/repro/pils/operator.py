"""Physics-informed operator learning for time-dependent PDEs (paper §B.3).

* Wave equation:   M (Uᵏ⁺² − 2Uᵏ⁺¹ + Uᵏ)/Δt² + c² K Uᵏ⁺¹ = 0      (Eq. B.16)
* Allen–Cahn:      M (Uᵏ⁺¹ − Uᵏ)/Δt + a² K Uᵏ⁺¹ − F(Uᵏ⁺¹) = 0     (Eq. B.19)

The discrete per-step residuals define the TensorPILS operator-learning loss
(Eq. B.22); reference trajectories come from the same matrices via the
:mod:`repro.transient` integrators (Newmark-β for the wave equation,
backward Euler + Newton–Krylov for Allen–Cahn).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    weakform as wf,
)
from ..core.mesh import Mesh, element_for_mesh
from ..transient import NewmarkIntegrator, NewtonKrylovIntegrator

__all__ = [
    "TimeDependentProblem",
    "random_initial_condition",
    "wave_residuals",
    "allen_cahn_residuals",
]


def random_initial_condition(key, points: np.ndarray, k_modes: int = 6,
                             r: float = 0.5, domain_scale=1.0) -> jnp.ndarray:
    """Multi-frequency sine expansion (Eq. B.15), a ~ U[-1, 1]."""
    x = jnp.asarray(points[:, 0]) / domain_scale
    y = jnp.asarray(points[:, 1]) / domain_scale
    a = jax.random.uniform(key, (k_modes, k_modes), minval=-1.0, maxval=1.0)
    ii = jnp.arange(1, k_modes + 1)[:, None]
    jj = jnp.arange(1, k_modes + 1)[None, :]
    amp = a * (ii**2 + jj**2) ** (-r)
    sx = jnp.sin(jnp.pi * ii[:, :, None] * x[None, None, :])   # (K,1,N)->(K,K,N)
    sy = jnp.sin(jnp.pi * jj[:, :, None] * y[None, None, :])
    field = jnp.einsum("kl,kln,kln->n", amp, sx, sy)
    return (jnp.pi / k_modes**2) * field


@dataclasses.dataclass
class TimeDependentProblem:
    """Owns M, K (condensed) for a mesh; provides residuals + reference
    integrators for the wave / Allen–Cahn benchmarks."""

    mesh: Mesh
    c: float = 4.0                 # wave speed
    a2: float = 1e-3               # AC diffusion a²
    eps2: float = 5.0              # AC reaction strength ε²
    dt: float = 5e-4

    def __post_init__(self):
        self.space = FunctionSpace(self.mesh, element_for_mesh(self.mesh))
        self.asm = GalerkinAssembler(self.space)
        bdofs = self.space.boundary_dofs()
        self.bc = DirichletCondenser(self.asm, bdofs)
        self.mass = self.asm.assemble(wf.mass())
        self.stiff = self.asm.assemble(wf.diffusion())
        self.interior = jnp.asarray(self.bc.free_mask, dtype=bool)
        self.n = self.space.num_dofs
        # one stable function object → one jit signature for the AC reaction
        self._react_fn = lambda u: -self.eps2 * u * (u**2 - 1.0)

    # -- discrete residuals (the TensorPILS loss terms) ------------------------
    def wave_residual(self, u0, u1, u2):
        """R = M(u2 − 2u1 + u0)/Δt² + c²K u1, masked to interior rows."""
        r = self.mass.matvec((u2 - 2 * u1 + u0) / self.dt**2) + (
            self.c**2
        ) * self.stiff.matvec(u1)
        return r * self.bc.free_mask

    def wave_residual_normalized(self, u0, u1, u2):
        """Same zero set as :meth:`wave_residual`, preconditioned for
        training: scaled by Δt² and the lumped-mass inverse so the loss is
        O(u) instead of O(u/Δt²) — the conditioning trick that makes the
        Galerkin operator-learning loss trainable at small Δt."""
        if not hasattr(self, "_m_lumped"):
            ones = jnp.ones(self.n)
            self._m_lumped = jnp.maximum(self.mass.matvec(ones), 1e-12)
        r = (u2 - 2 * u1 + u0) + self.dt**2 * self.c**2 * (
            self.stiff.matvec(u1) / self._m_lumped
        )
        return r * self.bc.free_mask

    def ac_residual(self, u0, u1):
        """R = M(u1 − u0)/Δt + a²K u1 − F_react(u1)."""
        react = self.asm.assemble_rhs(wf.reaction(u1, self._react_fn))
        r = self.mass.matvec((u1 - u0) / self.dt) + self.a2 * self.stiff.matvec(u1) - react
        return r * self.bc.free_mask

    # -- reference integrators (repro.transient drivers) -------------------------
    def newmark_integrator(self, **kw) -> NewmarkIntegrator:
        """Newmark-β (β=¼, γ=½ — average acceleration, unconditionally
        stable, energy-preserving) over M and c²K."""
        stiff_c2 = dataclasses.replace(self.stiff, vals=self.c**2 * self.stiff.vals)
        return NewmarkIntegrator(self.mass, stiff_c2, dt=self.dt, bc=self.bc, **kw)

    def newton_integrator(self, newton_iters: int = 3, **kw) -> NewtonKrylovIntegrator:
        """Backward Euler + Newton–Krylov for the Allen–Cahn semilinear term."""
        return NewtonKrylovIntegrator(
            self.asm, self.mass, self.stiff, dt=self.dt,
            reaction=self._react_fn,
            reaction_prime=lambda u: -self.eps2 * (3 * u**2 - 1.0),
            diffusion_scale=self.a2, bc=self.bc, newton_iters=newton_iters, **kw,
        )

    def wave_reference(self, u_init: jnp.ndarray, n_steps: int) -> jnp.ndarray:
        """Newmark-β reference trajectory, zero initial velocity.
        Returns (n_steps, N)."""
        return self.newmark_integrator().rollout(
            u_init * self.bc.free_mask, n_steps
        )

    def ac_reference(self, u_init: jnp.ndarray, n_steps: int,
                     newton_iters: int = 3) -> jnp.ndarray:
        """Backward Euler with Newton (paper B.3.1). Returns (n_steps, N)."""
        return self.newton_integrator(newton_iters).rollout(
            u_init * self.bc.free_mask, n_steps
        )

    # -- losses over trajectories (Eq. B.22) -------------------------------------
    def wave_trajectory_loss(self, traj: jnp.ndarray, normalized: bool = False):
        """traj: (T, N) including the first two known steps."""
        res = self.wave_residual_normalized if normalized else self.wave_residual
        r = jax.vmap(res)(traj[:-2], traj[1:-1], traj[2:])
        return jnp.mean(jnp.sum(r**2, axis=-1))

    def ac_trajectory_loss(self, traj: jnp.ndarray) -> jnp.ndarray:
        r = jax.vmap(self.ac_residual)(traj[:-1], traj[1:])
        return jnp.mean(jnp.sum(r**2, axis=-1))


def wave_residuals(problem: TimeDependentProblem, traj):
    return jax.vmap(problem.wave_residual)(traj[:-2], traj[1:-1], traj[2:])


def allen_cahn_residuals(problem: TimeDependentProblem, traj):
    return jax.vmap(problem.ac_residual)(traj[:-1], traj[1:])
