"""Physics-informed operator learning for time-dependent PDEs (paper §B.3).

* Wave equation:   M (Uᵏ⁺² − 2Uᵏ⁺¹ + Uᵏ)/Δt² + c² K Uᵏ⁺¹ = 0      (Eq. B.16)
* Allen–Cahn:      M (Uᵏ⁺¹ − Uᵏ)/Δt + a² K Uᵏ⁺¹ − F(Uᵏ⁺¹) = 0     (Eq. B.19)

The discrete per-step residuals define the TensorPILS operator-learning loss
(Eq. B.22); reference trajectories come from the same matrices via
Crank–Nicolson (wave) / backward Euler + Newton (Allen–Cahn).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    CSR,
    DirichletCondenser,
    FunctionSpace,
    GalerkinAssembler,
    cg,
    jacobi_preconditioner,
    sparse_solve,
)
from ..core.mesh import Mesh, element_for_mesh

__all__ = [
    "TimeDependentProblem",
    "random_initial_condition",
    "wave_residuals",
    "allen_cahn_residuals",
]


def random_initial_condition(key, points: np.ndarray, k_modes: int = 6,
                             r: float = 0.5, domain_scale=1.0) -> jnp.ndarray:
    """Multi-frequency sine expansion (Eq. B.15), a ~ U[-1, 1]."""
    x = jnp.asarray(points[:, 0]) / domain_scale
    y = jnp.asarray(points[:, 1]) / domain_scale
    a = jax.random.uniform(key, (k_modes, k_modes), minval=-1.0, maxval=1.0)
    ii = jnp.arange(1, k_modes + 1)[:, None]
    jj = jnp.arange(1, k_modes + 1)[None, :]
    amp = a * (ii**2 + jj**2) ** (-r)
    sx = jnp.sin(jnp.pi * ii[:, :, None] * x[None, None, :])   # (K,1,N)->(K,K,N)
    sy = jnp.sin(jnp.pi * jj[:, :, None] * y[None, None, :])
    field = jnp.einsum("kl,kln,kln->n", amp, sx, sy)
    return (jnp.pi / k_modes**2) * field


@dataclasses.dataclass
class TimeDependentProblem:
    """Owns M, K (condensed) for a mesh; provides residuals + reference
    integrators for the wave / Allen–Cahn benchmarks."""

    mesh: Mesh
    c: float = 4.0                 # wave speed
    a2: float = 1e-3               # AC diffusion a²
    eps2: float = 5.0              # AC reaction strength ε²
    dt: float = 5e-4

    def __post_init__(self):
        self.space = FunctionSpace(self.mesh, element_for_mesh(self.mesh))
        self.asm = GalerkinAssembler(self.space)
        bdofs = self.space.boundary_dofs()
        self.bc = DirichletCondenser(self.asm, bdofs)
        self.mass = self.asm.assemble_mass()
        self.stiff = self.asm.assemble_stiffness()
        self.interior = jnp.asarray(self.bc.free_mask, dtype=bool)
        self.n = self.space.num_dofs

    # -- discrete residuals (the TensorPILS loss terms) ------------------------
    def wave_residual(self, u0, u1, u2):
        """R = M(u2 − 2u1 + u0)/Δt² + c²K u1, masked to interior rows."""
        r = self.mass.matvec((u2 - 2 * u1 + u0) / self.dt**2) + (
            self.c**2
        ) * self.stiff.matvec(u1)
        return r * self.bc.free_mask

    def wave_residual_normalized(self, u0, u1, u2):
        """Same zero set as :meth:`wave_residual`, preconditioned for
        training: scaled by Δt² and the lumped-mass inverse so the loss is
        O(u) instead of O(u/Δt²) — the conditioning trick that makes the
        Galerkin operator-learning loss trainable at small Δt."""
        if not hasattr(self, "_m_lumped"):
            ones = jnp.ones(self.n)
            self._m_lumped = jnp.maximum(self.mass.matvec(ones), 1e-12)
        r = (u2 - 2 * u1 + u0) + self.dt**2 * self.c**2 * (
            self.stiff.matvec(u1) / self._m_lumped
        )
        return r * self.bc.free_mask

    def ac_residual(self, u0, u1):
        """R = M(u1 − u0)/Δt + a²K u1 − F_react(u1)."""
        react = self.asm.assemble_reaction_load(
            u1, lambda u: -self.eps2 * u * (u**2 - 1.0)
        )
        r = self.mass.matvec((u1 - u0) / self.dt) + self.a2 * self.stiff.matvec(u1) - react
        return r * self.bc.free_mask

    # -- reference integrators --------------------------------------------------
    def _condensed(self, csr_vals_shift):
        return self.bc.apply_matrix_only(csr_vals_shift)

    def wave_reference(self, u_init: jnp.ndarray, n_steps: int) -> jnp.ndarray:
        """Newmark-β (β=¼, γ=½ — average acceleration, unconditionally
        stable, energy-preserving: the paper's 'Crank–Nicolson-style'
        integrator), zero initial velocity.  Returns (n_steps, N)."""
        dt, c2 = self.dt, self.c**2
        beta, gamma = 0.25, 0.5
        lhs_vals = self.mass.vals + beta * dt**2 * c2 * self.stiff.vals
        lhs = self._condensed(dataclasses.replace(self.mass, vals=lhs_vals))
        mpre = jacobi_preconditioner(lhs)
        mass_c = self._condensed(self.mass)
        mpre_m = jacobi_preconditioner(mass_c)

        u0 = u_init * self.bc.free_mask
        v0 = jnp.zeros_like(u0)
        a0, _ = cg(
            mass_c.matvec, -c2 * self.stiff.matvec(u0) * self.bc.free_mask,
            m=mpre_m, tol=1e-10, maxiter=2000,
        )

        @jax.jit
        def step(carry, _):
            u, v, a = carry
            u_star = u + dt * v + 0.5 * dt**2 * (1 - 2 * beta) * a
            v_star = v + dt * (1 - gamma) * a
            rhs = -c2 * self.stiff.matvec(u_star) * self.bc.free_mask
            a_new, _ = cg(lhs.matvec, rhs, m=mpre, tol=1e-10, maxiter=2000)
            u_new = (u_star + beta * dt**2 * a_new) * self.bc.free_mask
            v_new = v_star + gamma * dt * a_new
            return (u_new, v_new, a_new), u_new

        _, traj = jax.lax.scan(step, (u0, v0, a0), None, length=n_steps)
        return traj

    def ac_reference(self, u_init: jnp.ndarray, n_steps: int,
                     newton_iters: int = 3) -> jnp.ndarray:
        """Backward Euler with Newton (paper B.3.1). Returns (n_steps, N)."""
        dt = self.dt

        @jax.jit
        def step(u0, _):
            u = u0

            def newton(u, _):
                # residual and Jacobian: J = M/dt + a²K + M[f'(u)] (mass-weighted)
                res = self.ac_residual(u0, u)
                # J = M/dt + a²K − M[f'(u)] with f'(u) = −ε²(3u²−1):
                # the reaction Jacobian is a mass matrix weighted by −f'(u),
                # assembled through the same Map-Reduce (nodal coefficient).
                fprime = lambda w: -self.eps2 * (3 * w**2 - 1.0)
                jac_vals = self.asm._assemble_matrix_vals(-fprime(u), "mass")
                jac = CSR(
                    self.mass.vals / dt + self.a2 * self.stiff.vals + jac_vals,
                    self.mass.indptr, self.mass.indices, self.mass.row_of_nnz,
                    self.mass.shape, self.mass.diag_pos,
                )
                jac = self.bc.apply_matrix_only(jac)
                du, _ = cg(jac.matvec, res, m=jacobi_preconditioner(jac),
                           tol=1e-10, maxiter=2000)
                return u - du, None

            u, _ = jax.lax.scan(newton, u, None, length=newton_iters)
            u = u * self.bc.free_mask
            return u, u

        u0 = u_init * self.bc.free_mask
        _, traj = jax.lax.scan(step, u0, None, length=n_steps)
        return traj

    # -- losses over trajectories (Eq. B.22) -------------------------------------
    def wave_trajectory_loss(self, traj: jnp.ndarray, normalized: bool = False):
        """traj: (T, N) including the first two known steps."""
        res = self.wave_residual_normalized if normalized else self.wave_residual
        r = jax.vmap(res)(traj[:-2], traj[1:-1], traj[2:])
        return jnp.mean(jnp.sum(r**2, axis=-1))

    def ac_trajectory_loss(self, traj: jnp.ndarray) -> jnp.ndarray:
        r = jax.vmap(self.ac_residual)(traj[:-1], traj[1:])
        return jnp.mean(jnp.sum(r**2, axis=-1))


def wave_residuals(problem: TimeDependentProblem, traj):
    return jax.vmap(problem.wave_residual)(traj[:-2], traj[1:-1], traj[2:])


def allen_cahn_residuals(problem: TimeDependentProblem, traj):
    return jax.vmap(problem.ac_residual)(traj[:-1], traj[1:])
