"""AGN — Autoregressive Graph Network backbone for operator learning
(paper SM B.3.2): encoder–processor–decoder on the element graph, GraphSAGE
processor, frequency-enhanced encoder/decoder MLPs, bundled (window-w)
autoregressive updates with boundary clamping."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["element_graph_edges", "agn_init", "agn_apply", "agn_rollout", "freq_features"]


def element_graph_edges(cells: np.ndarray) -> np.ndarray:
    """Fully-connect nodes within each element (Fig. B.13), dedup + both
    directions; returns (n_edges, 2) [src, dst]."""
    k = cells.shape[1]
    pairs = []
    for a in range(k):
        for b in range(k):
            if a != b:
                pairs.append(cells[:, [a, b]])
    edges = np.concatenate(pairs, axis=0)
    edges = np.unique(edges, axis=0)
    return edges.astype(np.int64)


def freq_features(x: jnp.ndarray, k_max: int) -> jnp.ndarray:
    """Frequency-enhanced features (Eq. B.20)."""
    feats = [x]
    for k in range(1, k_max + 1):
        feats.append(jnp.sin(k * x))
        feats.append(jnp.cos(k * x))
    return jnp.concatenate(feats, axis=-1)


def _mlp_init(key, dims, dtype):
    params = []
    keys = jax.random.split(key, len(dims) - 1)
    for kk, (i, o) in zip(keys, zip(dims[:-1], dims[1:])):
        w = jax.random.normal(kk, (i, o), dtype) * jnp.sqrt(2.0 / i)
        params.append({"w": w, "b": jnp.zeros((o,), dtype)})
    return params


def _mlp_apply(params, x, act=jax.nn.gelu):
    for layer in params[:-1]:
        x = act(x @ layer["w"] + layer["b"])
    last = params[-1]
    return x @ last["w"] + last["b"]


def agn_init(key, in_channels: int, out_channels: int, hidden: int = 64,
             n_layers: int = 3, k_freq: int = 4, coord_dim: int = 2,
             dtype=jnp.float64):
    """in_channels: state channels per node (window w); out per step bundle."""
    keys = jax.random.split(key, n_layers + 2)
    enc_in = (in_channels + coord_dim) * (2 * k_freq + 1)
    enc = _mlp_init(keys[0], [enc_in, hidden, hidden], dtype)
    sage = []
    for i in range(n_layers):
        # GraphSAGE: W_self · h + W_neigh · mean(h_nbr)
        k1, k2 = jax.random.split(keys[1 + i])
        sage.append({
            "self": jax.random.normal(k1, (hidden, hidden), dtype) * jnp.sqrt(1.0 / hidden),
            "neigh": jax.random.normal(k2, (hidden, hidden), dtype) * jnp.sqrt(1.0 / hidden),
            "b": jnp.zeros((hidden,), dtype),
        })
    dec = _mlp_init(keys[-1], [hidden, hidden, out_channels], dtype)
    return {"enc": enc, "sage": sage, "dec": dec}


def agn_apply(params, node_state: jnp.ndarray, coords: jnp.ndarray,
              edges: np.ndarray, degree: jnp.ndarray, k_freq: int = 4) -> jnp.ndarray:
    """node_state: (N, C_in), coords: (N, d) → (N, C_out) bundled update."""
    x = jnp.concatenate([node_state, coords], axis=-1)
    h = _mlp_apply(params["enc"], freq_features(x, k_freq))
    src, dst = jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1])
    for layer in params["sage"]:
        msg = jax.ops.segment_sum(h[src], dst, num_segments=h.shape[0])
        mean_nbr = msg / degree[:, None]
        h = jax.nn.gelu(h @ layer["self"] + mean_nbr @ layer["neigh"] + layer["b"])
    return _mlp_apply(params["dec"], h)


def agn_rollout(params, u_window: jnp.ndarray, coords, edges, degree,
                n_bundles: int, interior_mask: jnp.ndarray,
                bc_values: jnp.ndarray | float = 0.0):
    """Autoregressive rollout with window size w (Fig. B.14).

    u_window: (N, w) initial window; each AGN call predicts a *delta bundle*
    (N, w) that advances the window by w steps; Dirichlet nodes are clamped
    after every bundle.  Returns (N, w·n_bundles) trajectory.
    """
    def step(window, _):
        delta = agn_apply(params, window, coords, edges, degree)
        new = window + delta
        new = jnp.where(interior_mask[:, None], new, bc_values)
        return new, new

    _, traj = jax.lax.scan(step, u_window, None, length=n_bundles)
    # traj: (n_bundles, N, w) → (N, w·n_bundles)
    return jnp.transpose(traj, (1, 0, 2)).reshape(u_window.shape[0], -1)
