from .siren import siren_apply, siren_init  # noqa: F401
from .losses import (  # noqa: F401
    GalerkinResidualLoss,
    deep_ritz_loss,
    pinn_poisson_loss,
    vpinn_loss,
)
from .training import adam_init, adam_update, train_adam, lbfgs_minimize  # noqa: F401
