from .siren import siren_apply, siren_init  # noqa: F401
from .losses import (  # noqa: F401
    BatchedGalerkinResidualLoss,
    GalerkinResidualLoss,
    deep_ritz_loss,
    pinn_poisson_loss,
    vpinn_loss,
)
from .training import (  # noqa: F401
    adam_init,
    adam_update,
    fit_family,
    lbfgs_minimize,
    train_adam,
)
