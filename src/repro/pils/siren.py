"""SIREN backbone (Sitzmann et al. 2020) — shared by all neural-solver
baselines in the paper's controlled comparison (SM B.2.2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["siren_init", "siren_apply"]


def siren_init(key, in_dim: int, hidden: int, out_dim: int, depth: int = 4,
               omega0: float = 30.0, dtype=jnp.float64):
    """Paper setup: 4 hidden layers, width 64, ω0 = 30, SIREN init."""
    keys = jax.random.split(key, depth + 1)
    params = []
    dims = [in_dim] + [hidden] * depth + [out_dim]
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        k_w, _ = jax.random.split(keys[i])
        if i == 0:
            bound = 1.0 / d_in
        else:
            bound = np.sqrt(6.0 / d_in) / omega0
        w = jax.random.uniform(k_w, (d_in, d_out), minval=-bound, maxval=bound, dtype=dtype)
        b = jnp.zeros((d_out,), dtype=dtype)
        params.append({"w": w, "b": b})
    return {"layers": params, "omega0": jnp.asarray(omega0, dtype=dtype)}


def siren_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., in_dim) → (..., out_dim)."""
    omega0 = params["omega0"]
    layers = params["layers"]
    h = x
    for layer in layers[:-1]:
        h = jnp.sin(omega0 * (h @ layer["w"] + layer["b"]))
    last = layers[-1]
    return h @ last["w"] + last["b"]
