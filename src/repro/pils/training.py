"""Optimizers for PILS training: Adam and a compact L-BFGS.

Pure-jax, pytree-generic (no optax dependency).  Matches the paper's schedule
"N iterations of ADAM, followed by M iterations of L-BFGS" (Table 1).
:func:`fit_family` trains a whole *family* of problem instances against a
:class:`~repro.pils.losses.BatchedGalerkinResidualLoss` — per-sample
matrices from one batched assembly, one jitted joint update (Eq. B.22).
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["adam_init", "adam_update", "train_adam", "fit_family", "lbfgs_minimize"]


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


@partial(jax.jit, static_argnums=(4, 5, 6))
def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_adam(loss_fn, params, steps: int, lr=1e-3, log_every=0, decay=None):
    """Generic Adam loop; returns (params, history, it/s)."""
    state = adam_init(params)
    val_grad = jax.jit(jax.value_and_grad(loss_fn))
    hist = []
    t0 = time.perf_counter()
    for i in range(steps):
        cur_lr = lr if decay is None else decay(i, lr)
        loss, grads = val_grad(params)
        params, state = adam_update(params, grads, state, cur_lr)
        if log_every and i % log_every == 0:
            hist.append(float(loss))
    jax.block_until_ready(params)
    its = steps / (time.perf_counter() - t0)
    return params, hist, its


def fit_family(asm, bc, rho_batch, f=1.0, f_batch=None, steps: int = 500,
               lr: float = 1e-2, log_every: int = 0, u0_batch=None):
    """Train B per-instance coefficient vectors U_b against the batched
    Galerkin residual of a coefficient family (Eq. B.22's amortization
    pattern, directly on the DoF coefficients).

    The B system matrices K(ρ_b) are assembled in **one** batched call
    (shared static pattern), and the ``(B, num_dofs)`` prediction batch is a
    single params pytree — so the whole family trains inside one jitted
    Adam update, amortizing assembly and update dispatch B-fold.  Returns
    ``(u_batch, history, iterations/s, loss_object)``.
    """
    from .losses import BatchedGalerkinResidualLoss

    loss = BatchedGalerkinResidualLoss(asm, bc, rho_batch, f=f, f_batch=f_batch)
    if u0_batch is None:
        u0_batch = jnp.zeros((loss.batch, asm.space.num_dofs))
    u_batch, hist, its = train_adam(loss, u0_batch, steps, lr=lr,
                                    log_every=log_every)
    return u_batch, hist, its, loss


# ---------------------------------------------------------------------------
# L-BFGS (two-loop recursion + backtracking Armijo line search)
# ---------------------------------------------------------------------------

def _tree_dot(a, b):
    return sum(jnp.vdot(x, y) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _tree_axpy(alpha, x, y):
    return jax.tree.map(lambda a, b: alpha * a + b, x, y)


def lbfgs_minimize(loss_fn, params, steps: int = 200, history: int = 10,
                   c1: float = 1e-4, max_ls: int = 20):
    """Compact L-BFGS; python-level loop, jitted value_and_grad.

    Good enough to reproduce the paper's "+200 L-BFGS" refinement stage on
    CPU budgets; returns (params, losses, it/s).
    """
    val_grad = jax.jit(jax.value_and_grad(loss_fn))
    s_hist, y_hist, rho_hist = [], [], []
    f0, g = val_grad(params)
    losses = [float(f0)]
    t0 = time.perf_counter()
    n_done = 0
    for it in range(steps):
        # two-loop recursion
        q = jax.tree.map(lambda x: -x, g)
        alphas = []
        for s, y, rho in zip(reversed(s_hist), reversed(y_hist), reversed(rho_hist)):
            a = rho * _tree_dot(s, q)
            q = _tree_axpy(-a, y, q)
            alphas.append(a)
        if y_hist:
            gamma = _tree_dot(s_hist[-1], y_hist[-1]) / _tree_dot(y_hist[-1], y_hist[-1])
            q = jax.tree.map(lambda x: gamma * x, q)
        for (s, y, rho), a in zip(zip(s_hist, y_hist, rho_hist), reversed(alphas)):
            b = rho * _tree_dot(y, q)
            q = _tree_axpy(a - b, s, q)

        d = q
        gtd = _tree_dot(g, d)
        if gtd >= 0:  # not a descent direction → reset memory, steepest descent
            d = jax.tree.map(lambda x: -x, g)
            gtd = _tree_dot(g, d)
            s_hist, y_hist, rho_hist = [], [], []

        # backtracking Armijo
        step = 1.0
        f_cur = losses[-1]
        ok = False
        for _ in range(max_ls):
            trial = _tree_axpy(step, d, params)
            f_new, g_new = val_grad(trial)
            if bool(jnp.isfinite(f_new)) and float(f_new) <= f_cur + c1 * step * float(gtd):
                ok = True
                break
            step *= 0.5
        if not ok:
            break
        s = jax.tree.map(lambda a, b: a - b, trial, params)
        yv = jax.tree.map(lambda a, b: a - b, g_new, g)
        sy = float(_tree_dot(s, yv))
        if sy > 1e-12:
            s_hist.append(s); y_hist.append(yv); rho_hist.append(1.0 / sy)
            if len(s_hist) > history:
                s_hist.pop(0); y_hist.pop(0); rho_hist.pop(0)
        params, g = trial, g_new
        losses.append(float(f_new))
        n_done = it + 1
    its = max(n_done, 1) / (time.perf_counter() - t0)
    return params, losses, its
