"""The four learning paradigms of the paper's controlled comparison (Fig. B.7).

All four share a backbone ``u_fn(params, x) -> u`` and the same mesh; they
differ only in the objective:

* :func:`pinn_poisson_loss`   — strong form, two AD passes (the paper's
  "graph-within-graph" anti-pattern, kept as the baseline),
* :func:`vpinn_loss`          — variational residual against FEM test
  functions, one AD pass for ∇u,
* :func:`deep_ritz_loss`      — energy functional with deterministic Gauss
  quadrature, one AD pass,
* :class:`GalerkinResidualLoss` — **TensorPILS**: the network predicts the
  *coefficient vector* U; spatial derivatives are analytic shape-function
  gradients inside the assembled K — **zero** AD passes through space
  (Eq. 4), Dirichlet BCs imposed by condensation (hard constraints).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (
    DirichletCondenser,
    GalerkinAssembler,
    assemble_batched,
    assemble_rhs,
    assemble_rhs_batched,
    make_residual,
    matfree_family,
    matfree_operator,
    SolverSpec,
    matfree_solve_batched,
    sparse_solve_batched,
    weakform as wf,
)
from ..core.assembly import reduce_vector

__all__ = [
    "pinn_poisson_loss",
    "vpinn_loss",
    "deep_ritz_loss",
    "GalerkinResidualLoss",
    "BatchedGalerkinResidualLoss",
]


# ---------------------------------------------------------------------------
# strong-form PINN (−Δu = f): 2 AD passes per point
# ---------------------------------------------------------------------------

def _laplacian(u_scalar, x):
    """Δu at a single point via forward-over-reverse."""
    def grad_fn(y):
        return jax.grad(u_scalar)(y)

    d = x.shape[-1]
    eye = jnp.eye(d, dtype=x.dtype)
    diag = [jax.jvp(grad_fn, (x,), (eye[i],))[1][i] for i in range(d)]
    return sum(diag)


def pinn_poisson_loss(u_fn, params, interior_pts, f_vals, boundary_pts,
                      boundary_vals=0.0, lambda_bc: float = 100.0):
    u_scalar = lambda x: u_fn(params, x[None, :])[0, 0]

    res = jax.vmap(lambda x, f: _laplacian(u_scalar, x) + f)(interior_pts, f_vals)
    loss_pde = jnp.mean(res**2)
    ub = u_fn(params, boundary_pts)[:, 0]
    loss_bc = jnp.mean((ub - boundary_vals) ** 2)
    return loss_pde + lambda_bc * loss_bc


# ---------------------------------------------------------------------------
# Deep Ritz: E(u) = ∫ ½|∇u|² − f u with Gauss quadrature on elements
# ---------------------------------------------------------------------------

def deep_ritz_loss(u_fn, params, xq, wdet, f_q, boundary_pts,
                   boundary_vals=0.0, lambda_bc: float = 100.0):
    """xq: (E, Q, d) physical quadrature points; wdet: (E, Q) weights."""
    pts = xq.reshape(-1, xq.shape[-1])

    def u_scalar(x):
        return u_fn(params, x[None, :])[0, 0]

    grads = jax.vmap(jax.grad(u_scalar))(pts)
    u_vals = u_fn(params, pts)[:, 0]
    integrand = 0.5 * jnp.sum(grads**2, axis=-1) - f_q.reshape(-1) * u_vals
    energy = jnp.sum(wdet.reshape(-1) * integrand)
    ub = u_fn(params, boundary_pts)[:, 0]
    return energy + lambda_bc * jnp.mean((ub - boundary_vals) ** 2)


# ---------------------------------------------------------------------------
# VPINN: variational residual r_i = ∫ ∇u·∇φ_i − ∫ f φ_i (FEM test functions)
# ---------------------------------------------------------------------------

def vpinn_loss(u_fn, params, asm: GalerkinAssembler, f_load, free_mask,
               boundary_pts, boundary_vals=0.0, lambda_bc: float = 100.0):
    ctx = asm.context()
    pts = ctx.xq.reshape(-1, ctx.xq.shape[-1])

    def u_scalar(x):
        return u_fn(params, x[None, :])[0, 0]

    grads = jax.vmap(jax.grad(u_scalar))(pts).reshape(ctx.xq.shape)  # (E,Q,d)
    # ∫ ∇u·∇φ_a over each element → local vector, then Sparse-Reduce
    local = jnp.einsum("eq,eqi,eqai->ea", ctx.wdet, grads, ctx.grad)
    r = reduce_vector(local, asm.vec_routing) - f_load
    r = r * free_mask
    loss_var = jnp.sum(r**2)
    ub = u_fn(params, boundary_pts)[:, 0]
    return loss_var + lambda_bc * jnp.mean((ub - boundary_vals) ** 2)


# ---------------------------------------------------------------------------
# TensorPILS: discrete Galerkin residual ‖K U − F‖², hard BCs, no spatial AD
# ---------------------------------------------------------------------------

class GalerkinResidualLoss:
    """Precompiles K, F, and the condenser once; the per-step loss is a
    single SpMV + norm — the O(1)-graph training objective of Eq. (4).

    The network may predict U directly (``coeffs_from(params)``) or via a
    pointwise backbone evaluated at DoF coordinates.

    ``backend`` picks the residual inner op from the unified registry
    (:mod:`repro.core.matvec`): ``"csr"`` (default), ``"ell"``,
    ``"ell_pallas"`` (the fused ``r = K·u − f`` Pallas kernel — one pass, no
    extra HBM round-trip), or ``"matfree"`` (K is never assembled; the
    residual applies the weak form element-locally).
    """

    def __init__(self, asm: GalerkinAssembler, bc: DirichletCondenser,
                 rho=None, f=1.0, backend: str = "csr"):
        load = asm.assemble_rhs(wf.source(f))
        if backend == "matfree":
            self.k = matfree_operator(asm.plan, wf.diffusion(rho)).condensed(bc)
            # homogeneous lift: K·u_D ≡ 0, so condensation reduces to masking
            self.f = bc.project_residual(load)
        else:
            k = asm.assemble(wf.diffusion(rho))
            self.k, self.f = bc.apply(k, load)
        self._residual = make_residual(self.k, backend)
        self.backend = backend
        self.bc = bc
        self.dof_points = jnp.asarray(asm.space.dof_points)

    def residual(self, u: jnp.ndarray) -> jnp.ndarray:
        return self._residual(u, self.f)

    def __call__(self, u: jnp.ndarray) -> jnp.ndarray:
        r = self.residual(u)
        return jnp.sum(r**2)

    def loss_from_net(self, u_fn, params) -> jnp.ndarray:
        """Hard-constrained: predicted values are *overwritten* on Dirichlet
        DoFs (system reduction), so no boundary penalty exists."""
        u = u_fn(params, self.dof_points)[:, 0]
        u = u * self.bc.free_mask + self.f * (1.0 - self.bc.free_mask)
        return self(u)


class BatchedGalerkinResidualLoss:
    """Family-of-instances TensorPILS objective (Eq. B.22): B per-sample
    systems K(ρ_b) U_b = F_b with the per-sample matrices assembled in
    **one batched call** (shared static pattern, ``(B, nnz)`` values) and
    condensed with the shared static Dirichlet masks.

    The loss of a ``(B, num_dofs)`` prediction batch is the mean squared
    Galerkin residual over the family — one vmapped SpMV, one executable,
    zero AD passes through space.  Homogeneous Dirichlet BCs (hard
    constraints via condensation, matching :class:`GalerkinResidualLoss`).

    ``backend="matfree"`` keeps the whole family matrix-free: the per-sample
    operators are one :class:`~repro.core.operator.MatFreeFamily` on the
    shared plan — residuals are vmapped fused element actions and
    :meth:`solve` goes through
    :func:`~repro.core.solvers.matfree_solve_batched`, with zero CSR values
    for the B instances.
    """

    def __init__(self, asm: GalerkinAssembler, bc: DirichletCondenser,
                 rho_batch, f=1.0, f_batch=None, backend="csr"):
        plan = asm.plan
        rho_batch = jnp.asarray(rho_batch)
        self.backend = backend
        if backend == "matfree":
            fam = matfree_family(
                plan, wf.diffusion(rho_batch[0]), leaves_batch=(rho_batch, None)
            )
            self.k = fam.condensed(bc)
        elif backend == "csr":
            kb = assemble_batched(
                plan, wf.diffusion(rho_batch[0]), leaves_batch=(rho_batch, None)
            )
            self.k = bc.apply_matrix_only(kb)   # masks broadcast over (B, nnz)
        else:
            raise ValueError(
                f"unknown backend {backend!r}: expected 'csr' or 'matfree'"
            )
        if f_batch is not None:
            f_batch = jnp.asarray(f_batch)
            load = assemble_rhs_batched(
                plan, wf.source(f_batch[0]), leaves_batch=(f_batch, None)
            )
        else:
            load = assemble_rhs(plan, wf.source(f))
        # homogeneous lift: F ← F·free_mask (u_D = 0, so the K·u_D matvec is
        # identically zero and the bc rows of F become the bc values)
        self.f = bc.project_residual(load)
        self.bc = bc
        self.batch = int(rho_batch.shape[0])
        self.dof_points = jnp.asarray(asm.space.dof_points)

    def residual(self, u_batch: jnp.ndarray) -> jnp.ndarray:
        return self.k.matvec(u_batch) - self.f

    def __call__(self, u_batch: jnp.ndarray) -> jnp.ndarray:
        r = self.residual(u_batch)
        return jnp.mean(jnp.sum(r**2, axis=-1))

    def solve(self, spec: SolverSpec | None = None, *, tol=1e-10,
              maxiter=10000) -> jnp.ndarray:
        """Direct FEM solutions of the whole family — one vmapped adjoint
        solve (reference targets / sanity checks for the learned U_b).
        ``spec=`` overrides the default CG+Jacobi configuration."""
        if spec is None:
            spec = SolverSpec(method="cg", tol=tol, atol=tol, maxiter=maxiter)
        if self.backend == "matfree":
            return matfree_solve_batched(self.k, self.f, spec)
        return sparse_solve_batched(self.k, self.f, spec)

    def loss_from_net(self, u_fn, params_batch) -> jnp.ndarray:
        """Hard-constrained family loss for B per-instance backbones: each
        parameter set predicts its instance's coefficients at the DoF
        coordinates, Dirichlet rows are overwritten by condensation (no
        boundary penalty) — the batched twin of
        :meth:`GalerkinResidualLoss.loss_from_net`."""
        u = jax.vmap(lambda p: u_fn(p, self.dof_points)[:, 0])(params_batch)
        u = u * self.bc.free_mask + self.f * (1.0 - self.bc.free_mask)
        return self(u)
