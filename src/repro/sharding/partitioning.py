"""Logical-axis partitioning (MaxText-style rules → GSPMD).

Every parameter spec carries *logical* axis names; a :class:`ShardingRules`
maps logical names → mesh axes.  Activations are annotated inside model code
via :func:`annotate` (no-op when no rules are active, so models run un-meshed
on CPU tests).

Default layout (single pod, mesh ('data', 'model') = (16, 16)):
  * FSDP: the residual dimension 'embed' shards over 'data' — ZeRO-3-style;
    XLA inserts the all-gathers at use sites.
  * Tensor parallel: 'heads' / 'kv' / 'mlp' / 'vocab' / 'expert' over 'model'
    (Megatron layout: qkv+up are column-parallel, o+down row-parallel).
  * Activations: batch over 'data' (and 'pod'); 'seq_act' optionally over
    'model' (sequence parallelism — a perf-iteration lever, see §Perf).
  * KV cache: 'kv_cache' heads over 'model' (replicated to TP degree when
    kv_heads < TP), batch over 'data'.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.layers import P as ParamP, is_spec

__all__ = [
    "ShardingRules",
    "RULES_SINGLE_POD",
    "RULES_MULTI_POD",
    "FEM_MESH_AXIS",
    "fem_mesh",
    "use_rules",
    "annotate",
    "logical_to_spec",
    "make_shardings",
]

# ---------------------------------------------------------------------------
# FEM mesh axis: element-parallel Galerkin assembly
# ---------------------------------------------------------------------------

#: the named mesh axis over which ``repro.core.assemble_sharded`` partitions
#: the element axis of the Batch-Map stage (one 1-D axis — FEM assembly is
#: embarrassingly element-parallel; the Reduce is a single all-reduce of
#: partial nnz contributions)
FEM_MESH_AXIS = "elem"


def fem_mesh(n_devices: int | None = None, axis_name: str = FEM_MESH_AXIS) -> Mesh:
    """1-D device mesh for element-parallel sharded assembly.

    Uses all local devices by default; emulate a multi-device CPU host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI sharded
    job does exactly this).
    """
    devices = jax.devices()
    if n_devices is not None:
        if n_devices > len(devices):
            raise ValueError(
                f"fem_mesh: requested {n_devices} devices but only "
                f"{len(devices)} are available"
            )
        devices = devices[:n_devices]
    return Mesh(np.array(devices), (axis_name,))


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mapping: dict  # logical axis name -> mesh axis | tuple | None

    def spec_for(self, axes: tuple) -> PartitionSpec:
        used: set = set()
        out = []
        for ax in axes:
            mesh_ax = self.mapping.get(ax) if ax is not None else None
            # a mesh axis may appear at most once in a PartitionSpec
            if mesh_ax is None:
                out.append(None)
                continue
            key = tuple(mesh_ax) if isinstance(mesh_ax, (tuple, list)) else (mesh_ax,)
            if used & set(key):
                out.append(None)
                continue
            used |= set(key)
            out.append(mesh_ax if not isinstance(mesh_ax, list) else tuple(mesh_ax))
        return PartitionSpec(*out)


_BASE = {
    "embed": "data",          # FSDP
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "layers": None,
    "batch": "data",
    "seq_act": None,          # flip to 'model' for sequence parallelism
    "seq_cache": None,
    "kv_cache": "model",
    "ssm_heads": "model",
}

RULES_SINGLE_POD = ShardingRules(dict(_BASE))
RULES_MULTI_POD = ShardingRules(
    {**_BASE, "embed": ("pod", "data"), "batch": ("pod", "data")}
)


class _State(threading.local):
    rules: ShardingRules | None = None
    active: bool = False


_state = _State()


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev_r, prev_a = _state.rules, _state.active
    _state.rules, _state.active = rules, rules is not None
    try:
        yield
    finally:
        _state.rules, _state.active = prev_r, prev_a


def logical_to_spec(axes: tuple, rules: ShardingRules | None = None) -> PartitionSpec:
    rules = rules or _state.rules
    assert rules is not None
    return rules.spec_for(axes)


def annotate(x, *axes):
    """with_sharding_constraint via logical axes; no-op without active rules.

    Must be called under a ``jax.sharding.use_mesh`` (or jit-with-mesh)
    context so bare PartitionSpecs resolve.
    """
    if not _state.active:
        return x
    spec = _state.rules.spec_for(axes)
    return jax.lax.with_sharding_constraint(x, spec)


def make_shardings(specs, mesh: Mesh, rules: ShardingRules):
    """Pytree of P-specs (or axes tuples) → pytree of NamedShardings."""

    def one(s):
        axes = s.axes if is_spec(s) else s
        return NamedSharding(mesh, rules.spec_for(axes))

    return jax.tree.map(one, specs, is_leaf=lambda s: is_spec(s) or (
        isinstance(s, tuple) and all(isinstance(a, (str, type(None))) for a in s)
    ))
