from .partitioning import (  # noqa: F401
    ShardingRules,
    annotate,
    make_shardings,
    logical_to_spec,
    use_rules,
    RULES_SINGLE_POD,
    RULES_MULTI_POD,
)
