from .tensormesh import (  # noqa: F401
    ElasticityProblem,
    MixedBCPoisson,
    PoissonProblem,
)
