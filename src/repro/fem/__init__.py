from .tensormesh import (  # noqa: F401
    AdvectionDiffusionProblem,
    ElasticityProblem,
    MixedBCPoisson,
    PoissonProblem,
)
