"""TensorMesh — the numerical PDE solver built on TensorGalerkin (paper §3 i).

Problem classes own (mesh → space → assembler → condenser) and expose:
* ``solve()``                 — assembly + preconditioned Krylov solve,
* ``solve_batch(fs)``         — many-query batched-RHS solves (SM B.1.4):
  one assembly, one jitted vmapped solve over the RHS batch,
* ``residual(u)``             — relative linear-system residual (Eq. B.8).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import telemetry
from ..telemetry import events
from ..core import (
    DirichletCondenser,
    FacetAssembler,
    FunctionSpace,
    GalerkinAssembler,
    SolverSpec,
    make_matvec,
    make_preconditioner,
    matfree_operator,
    resolve_solver_spec,
    weakform as wf,
)
from ..core.solvers import _method
from ..core import forms
from ..core.mesh import Mesh, element_for_mesh

__all__ = [
    "PoissonProblem",
    "AdvectionDiffusionProblem",
    "ElasticityProblem",
    "MixedBCPoisson",
]


@dataclasses.dataclass
class _SolveResult:
    u: jnp.ndarray
    iters: int
    residual: float
    converged: bool = True


class _ProblemBase:
    method = "cg"
    use_ell = True  # ELL matvec in the Krylov loop: 2.1× end-to-end (§Perf-FEM)
    backend = None  # default matvec backend (None → "ell" per use_ell flag)

    def _spec(self, spec, tol, maxiter, where) -> SolverSpec:
        """One :class:`~repro.core.SolverSpec` per solve: ``spec=`` wins,
        legacy ``tol=``/``maxiter=`` kwargs shim into it (deprecated)."""
        return resolve_solver_spec(
            spec, tol=tol, maxiter=maxiter,
            default=SolverSpec(method=self.method),
            where=f"{type(self).__name__}.{where}")

    @property
    def plan(self):
        """The problem's :class:`~repro.core.AssemblyPlan` — the functional
        assembly signature consumed by the pure ``assemble`` /
        ``assemble_batched`` / ``assemble_sharded`` entry points."""
        return self.asm.plan

    def _default_backend(self) -> str:
        if self.backend is not None:
            return self.backend
        return "ell" if self.use_ell else "csr"

    def _solve_system(self, k, f, spec: SolverSpec, backend=None,
                      return_info=False):
        """Krylov solve on an assembled operator with the inner matvec from
        the unified registry (:mod:`repro.core.matvec`) and the
        preconditioner resolved from ``spec.precond`` via the registry.  A
        ``maxiter`` exit is reported through
        :func:`repro.telemetry.check_convergence` (warn/raise per policy)
        and the ``converged`` flag on the result; ``return_info=True``
        appends the raw :class:`~repro.core.solvers.SolveInfo`."""
        be = backend or self._default_backend()
        matvec = make_matvec(k, be)
        t0 = time.perf_counter()
        u, info = _method(spec.method)(
            matvec, f, m=make_preconditioner(k, spec.precond),
            tol=spec.tol, atol=spec.atol, maxiter=spec.maxiter)
        where = f"{type(self).__name__}.solve"
        events.check_convergence(info, where=where)
        if telemetry.is_enabled():
            events.record_solve(where, info, method=spec.method, backend=be,
                                precond=spec.precond_name,
                                wall_us=(time.perf_counter() - t0) * 1e6)
        rel = float(jnp.linalg.norm(k.matvec(u) - f) / jnp.linalg.norm(f))
        res = _SolveResult(u, int(info.iters), rel, bool(info.converged))
        return (res, info) if return_info else res

    def _solve_matfree(self, form, load, spec: SolverSpec,
                       dirichlet_values=0.0, return_info=False,
                       sharded=False, condensed=False):
        """Matrix-free Krylov solve: the operator applies ``form`` straight
        from the plan (element-local Map → per-element action →
        scatter-Reduce), Jacobi from a diagonal-only assembly, Dirichlet
        condensation as an apply wrapper (the RHS lift runs one matrix-free
        apply of the uncondensed operator) — global CSR values are never
        materialized.  ``sharded=True`` partitions every apply (including
        the Jacobi diagonal assembly and the RHS lift) over the local device
        mesh, so one Krylov solve spans all devices.  (For a
        *differentiable* matrix-free solve use
        :func:`repro.core.matfree_solve` on the same operator.)

        ``spec.precond`` selects any registered preconditioner — ``"ebe"``
        and ``"chebyshev"`` stay matrix-free.  ``condensed=True`` statically
        condenses the higher-order DOFs (degree ≥ 2 spaces) and runs the
        Krylov iteration on the interface Schur complement only
        (:func:`repro.core.elemalg.condensed_solve` machinery)."""
        from ..core import elemalg

        op_full = matfree_operator(self.plan, form)
        if sharded:
            op_full = op_full.sharded()
        op = op_full.condensed(self.bc)
        if isinstance(dirichlet_values, (int, float)) and dirichlet_values == 0.0:
            # homogeneous: the lift reduces to masking — skip the dead
            # matrix-free apply of the all-zero boundary field
            f = self.bc.project_residual(load)
        else:
            f = self.bc.lift(op_full, load, dirichlet_values)
        t0 = time.perf_counter()
        if condensed:
            sys = elemalg.condense(op, elemalg.vertex_split(self.space))
            u, info = sys.solve(f, spec)
        else:
            u, info = _method(spec.method)(
                op.matvec, f, m=make_preconditioner(op, spec.precond),
                tol=spec.tol, atol=spec.atol, maxiter=spec.maxiter)
        where = f"{type(self).__name__}.solve"
        events.check_convergence(info, where=where)
        if telemetry.is_enabled():
            events.record_solve(
                where, info, method=spec.method,
                backend="matfree_sharded" if sharded else "matfree",
                precond="condensed" if condensed else spec.precond_name,
                wall_us=(time.perf_counter() - t0) * 1e6)
        rel = float(jnp.linalg.norm(op.matvec(u) - f) / jnp.linalg.norm(f))
        res = _SolveResult(u, int(info.iters), rel, bool(info.converged))
        return (res, info) if return_info else res


class PoissonProblem(_ProblemBase):
    """−∇·(ρ∇u) = f with homogeneous Dirichlet BCs (paper Benchmark I)."""

    def __init__(self, mesh: Mesh, degree: int = 1, quad_order: int | None = None):
        self.mesh = mesh
        self.space = FunctionSpace(mesh, element_for_mesh(mesh, degree))
        self.asm = GalerkinAssembler(self.space, quad_order)
        self.bc = DirichletCondenser(self.asm, self.space.boundary_dofs())

    def assemble(self, rho=None, f=1.0):
        k = self.asm.assemble(wf.diffusion(rho))
        load = self.asm.assemble_rhs(wf.source(f))
        return self.bc.apply(k, load)

    def solve(self, rho=None, f=1.0, spec: SolverSpec | None = None,
              tol=None, maxiter=None, backend=None, return_info=False,
              condensed=False):
        """Solve with a registry-selected matvec backend; ``"matfree"``
        skips matrix assembly entirely (only the RHS vector is assembled)
        and ``"matfree_sharded"`` additionally spans the solve over all
        local devices.  Solver knobs come in as one
        :class:`~repro.core.SolverSpec` (``spec=``; legacy ``tol=`` /
        ``maxiter=`` kwargs still work but are deprecated).
        ``condensed=True`` (matfree backends, degree ≥ 2) runs the Krylov
        iteration on the statically condensed interface system.
        ``return_info=True`` appends the raw
        :class:`~repro.core.solvers.SolveInfo`."""
        spec = self._spec(spec, tol, maxiter, "solve")
        if backend in ("matfree", "matfree_sharded"):
            load = self.asm.assemble_rhs(wf.source(f))
            return self._solve_matfree(wf.diffusion(rho), load, spec,
                                       return_info=return_info,
                                       sharded=backend == "matfree_sharded",
                                       condensed=condensed)
        if condensed:
            raise ValueError("condensed=True needs a matfree backend")
        k, load = self.assemble(rho, f)
        return self._solve_system(k, load, spec, backend=backend,
                                  return_info=return_info)

    # -- many-query batched data generation (SM B.1.4) ------------------------
    def solve_batch(self, f_batch: jnp.ndarray, rho=None, tol=1e-10, maxiter=2000):
        """Solve K u_b = F(f_b) for a batch of nodal source fields
        ``f_batch: (B, num_dofs)`` — assembly amortized, solve vmapped."""
        k = self.bc.apply_matrix_only(self.asm.assemble(wf.diffusion(rho)))
        m = make_preconditioner(k, "jacobi")

        @jax.jit
        def run(fb):
            def solve_one(f_nodal):
                load = self.asm.assemble_rhs(wf.source(f_nodal))
                load = self.bc.project_residual(load)
                u, info = _method("cg")(k.matvec, load, m=m, tol=tol,
                                        maxiter=maxiter)
                return u, info.iters

            return jax.vmap(solve_one)(fb)

        return run(f_batch)

    def solve_coeff_batch(self, rho_batch: jnp.ndarray, f=1.0, tol=1e-10,
                          maxiter=10000):
        """Solve the *family* −∇·(ρ_b ∇u_b) = f for a batch of per-element
        coefficient fields ``rho_batch: (B, E)``: ONE batched assembly
        (``assemble_batched`` → shared-pattern ``BatchedCSR``), shared-mask
        condensation, and one vmapped adjoint ``sparse_solve`` — a single
        XLA executable for all B operators.  Returns ``(B, num_dofs)``.
        """
        from ..core import assemble_batched, assemble_rhs, sparse_solve_batched

        rho_batch = jnp.asarray(rho_batch)
        kb = assemble_batched(
            self.plan, wf.diffusion(rho_batch[0]), leaves_batch=(rho_batch, None)
        )
        kc = self.bc.apply_matrix_only(kb)
        load = self.bc.project_residual(assemble_rhs(self.plan, wf.source(f)))
        return sparse_solve_batched(
            kc, load, SolverSpec(method="cg", tol=tol, atol=tol,
                                 maxiter=maxiter))


class AdvectionDiffusionProblem(_ProblemBase):
    """−∇·(ε∇u) + β·∇u = f with Dirichlet BCs — the steady nonsymmetric
    problem the composable weak-form API unlocks: no assembler edits, just
    ``diffusion(eps) + advection(beta)`` (BiCGStab since K is nonsymmetric).
    """

    method = "bicgstab"

    def __init__(self, mesh: Mesh, degree: int = 1, quad_order: int | None = None):
        self.mesh = mesh
        self.space = FunctionSpace(mesh, element_for_mesh(mesh, degree))
        self.asm = GalerkinAssembler(self.space, quad_order)
        self.bc = DirichletCondenser(self.asm, self.space.boundary_dofs())

    def assemble(self, eps=1.0, beta=(1.0, 0.0), f=1.0, dirichlet_values=0.0):
        form = wf.diffusion(eps) + wf.advection(jnp.asarray(beta))
        k = self.asm.assemble(form)
        load = self.asm.assemble_rhs(wf.source(f))
        return self.bc.apply(k, load, dirichlet_values)

    def solve(self, eps=1.0, beta=(1.0, 0.0), f=1.0, dirichlet_values=0.0,
              spec: SolverSpec | None = None, tol=None, maxiter=None,
              backend=None, return_info=False):
        spec = self._spec(spec, tol, maxiter, "solve")
        if backend in ("matfree", "matfree_sharded"):
            form = wf.diffusion(eps) + wf.advection(jnp.asarray(beta))
            load = self.asm.assemble_rhs(wf.source(f))
            return self._solve_matfree(form, load, spec,
                                       dirichlet_values=dirichlet_values,
                                       return_info=return_info,
                                       sharded=backend == "matfree_sharded")
        k, load = self.assemble(eps, beta, f, dirichlet_values)
        return self._solve_system(k, load, spec, backend=backend,
                                  return_info=return_info)


class ElasticityProblem(_ProblemBase):
    """Isotropic linear elasticity, constant body force (paper Benchmark II)."""

    method = "bicgstab"

    def __init__(self, mesh: Mesh, e_mod=1.0, nu=0.3):
        d = mesh.dim
        self.mesh = mesh
        self.space = FunctionSpace(mesh, element_for_mesh(mesh), value_size=d)
        self.asm = GalerkinAssembler(self.space)
        self.bc = DirichletCondenser(self.asm, self.space.boundary_dofs())
        self.lam = e_mod * nu / ((1 + nu) * (1 - 2 * nu))
        self.mu = e_mod / (2 * (1 + nu))

    def assemble(self, body_force=None, scale=None):
        d = self.mesh.dim
        bf = jnp.ones(d) if body_force is None else jnp.asarray(body_force)
        k = self.asm.assemble(wf.elasticity(self.lam, self.mu, scale=scale))
        f = self.asm.assemble_rhs(wf.source(bf))
        return self.bc.apply(k, f)

    def solve(self, body_force=None, spec: SolverSpec | None = None,
              tol=None, maxiter=None, backend=None, return_info=False):
        spec = self._spec(spec, tol, maxiter, "solve")
        if backend in ("matfree", "matfree_sharded"):
            d = self.mesh.dim
            bf = jnp.ones(d) if body_force is None else jnp.asarray(body_force)
            load = self.asm.assemble_rhs(wf.source(bf))
            return self._solve_matfree(
                wf.elasticity(self.lam, self.mu), load, spec,
                return_info=return_info,
                sharded=backend == "matfree_sharded",
            )
        k, f = self.assemble(body_force)
        return self._solve_system(k, f, spec, backend=backend,
                                  return_info=return_info)


class MixedBCPoisson(_ProblemBase):
    """Poisson with simultaneous Dirichlet + Neumann + Robin boundary parts
    (paper SM B.1.5).  Boundary parts are selected by coordinate predicates;
    Neumann/Robin route through the same Map-Reduce (FacetAssembler)."""

    method = "bicgstab"

    def __init__(self, mesh: Mesh, dirichlet_pred, neumann_pred=None, robin_pred=None):
        self.mesh = mesh
        self.space = FunctionSpace(mesh, element_for_mesh(mesh))
        self.asm = GalerkinAssembler(self.space)

        facets = mesh.boundary_facets()
        centers = mesh.points[facets].mean(axis=1)
        d_mask = dirichlet_pred(centers)
        n_mask = neumann_pred(centers) if neumann_pred else np.zeros(len(facets), bool)
        r_mask = robin_pred(centers) if robin_pred else np.zeros(len(facets), bool)
        # Dirichlet wins on overlaps; remaining facets default to Dirichlet
        n_mask &= ~d_mask
        r_mask &= ~(d_mask | n_mask)
        self.d_facets = facets[d_mask | ~(n_mask | r_mask)]
        self.n_facets = facets[n_mask]
        self.r_facets = facets[r_mask]

        d_dofs = np.unique(self.d_facets)
        self.bc = DirichletCondenser(self.asm, d_dofs)
        self._fa_n = (
            FacetAssembler(self.space, self.n_facets, volume_routing=self.asm.mat_routing)
            if len(self.n_facets)
            else None
        )
        self._fa_r = (
            FacetAssembler(self.space, self.r_facets, volume_routing=self.asm.mat_routing)
            if len(self.r_facets)
            else None
        )
        # quadrature contexts, built once: per-solve callables are evaluated
        # on them *eagerly* so they enter the fused assembly as traced array
        # leaves — fresh lambdas per solve() reuse one compiled executable
        self._vol_ctx = self.asm.context()
        self._ctx_n = self._fa_n.context() if self._fa_n is not None else None
        self._ctx_r = self._fa_r.context() if self._fa_r is not None else None

    def solve(self, f, g_neumann=None, robin_alpha=1.0, g_robin=None,
              dirichlet_values=None, rho=None,
              spec: SolverSpec | None = None, tol=None, maxiter=None,
              backend=None, return_info=False):
        spec = self._spec(spec, tol, maxiter, "solve")
        if backend in ("matfree", "matfree_sharded"):
            raise NotImplementedError(
                "MixedBCPoisson has Robin facet terms, which the matrix-free "
                "apply does not support (volume terms only) — use an "
                "assembled backend ('csr'/'ell'/'ell_pallas'/'ell_stream')"
            )
        # mixed volume + boundary form → ONE CSR from one fused assembly
        # (Robin facet terms inject into the volume pattern), and one fused
        # RHS over volume source + Neumann/Robin boundary loads.  Callables
        # are pre-evaluated to quadrature arrays (traced leaves) so per-call
        # lambdas don't recompile the fused executable.
        if callable(rho):
            rho = forms.eval_coefficient(rho, self._vol_ctx)
        if callable(f):
            f = forms.eval_coefficient(f, self._vol_ctx)
        form = wf.diffusion(rho)
        rhs = wf.source(f)
        if self._fa_r is not None:
            form = form + wf.robin(robin_alpha, on=self._fa_r)
            if g_robin is not None:
                if callable(g_robin):
                    g_robin = forms.eval_coefficient(g_robin, self._ctx_r)
                rhs = rhs + wf.neumann(g_robin, on=self._fa_r)
        if self._fa_n is not None and g_neumann is not None:
            if callable(g_neumann):
                g_neumann = forms.eval_coefficient(g_neumann, self._ctx_n)
            rhs = rhs + wf.neumann(g_neumann, on=self._fa_n)
        k = self.asm.assemble(form)
        load = self.asm.assemble_rhs(rhs)
        bvals = 0.0
        if dirichlet_values is not None:
            d_dofs = self.bc.bc_dofs
            bvals = jnp.asarray(dirichlet_values(self.space.dof_points[d_dofs]))
        kc, fc = self.bc.apply(k, load, bvals)
        return self._solve_system(kc, fc, spec, backend=backend,
                                  return_info=return_info)
