"""Unified model API: every assigned architecture behind one interface.

``build_model(cfg)`` returns a :class:`ModelAPI` with:
  * ``param_specs()``                  — P-spec pytree (one source of truth)
  * ``loss(params, batch)``            — training objective
  * ``prefill(params, batch)``         — prompt → (last logits, cache)
  * ``decode(params, batch, cache)``   — one token vs cache/state
  * ``cache_specs(batch, max_len)``    — P-spec pytree for the cache
  * ``input_specs(shape)``             — ShapeDtypeStructs for the dry-run
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from . import encdec, hybrid, transformer
from .layers import abstract_params

__all__ = ["ModelAPI", "build_model"]


@dataclasses.dataclass
class ModelAPI:
    cfg: ArchConfig
    tp_degree: int = 16

    # -- parameters -----------------------------------------------------------
    def param_specs(self):
        fam = self.cfg.family
        if fam == "hybrid":
            return hybrid.hybrid_specs(self.cfg)
        if fam == "audio":
            return encdec.encdec_specs(self.cfg)
        return transformer.decoder_specs(self.cfg)

    def abstract_params(self):
        return abstract_params(self.param_specs())

    # -- training --------------------------------------------------------------
    def loss(self, params, batch):
        fam = self.cfg.family
        if fam == "hybrid":
            return hybrid.hybrid_loss(self.cfg, params, batch)
        if fam == "audio":
            return encdec.encdec_loss(self.cfg, params, batch)
        return transformer.lm_loss(self.cfg, params, batch)

    # -- serving ----------------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int):
        fam = self.cfg.family
        if fam == "hybrid":
            return hybrid.hybrid_cache_specs(self.cfg, batch, max_len, self.tp_degree)
        if fam == "audio":
            return encdec.encdec_cache_specs(self.cfg, batch, max_len, self.tp_degree)
        return transformer.decoder_cache_specs(self.cfg, batch, max_len, self.tp_degree)

    def prefill(self, params, batch, max_len: int):
        fam = self.cfg.family
        if fam == "hybrid":
            return hybrid.hybrid_prefill(self.cfg, params, batch, max_len, self.tp_degree)
        if fam == "audio":
            return encdec.encdec_prefill(self.cfg, params, batch, max_len, self.tp_degree)
        return transformer.decoder_prefill(self.cfg, params, batch, max_len, self.tp_degree)

    def decode(self, params, batch, cache):
        fam = self.cfg.family
        if fam == "hybrid":
            return hybrid.hybrid_decode(self.cfg, params, batch, cache, self.tp_degree)
        if fam == "audio":
            return encdec.encdec_decode(self.cfg, params, batch, cache, self.tp_degree)
        return transformer.decoder_decode(self.cfg, params, batch, cache, self.tp_degree)

    # -- dry-run inputs -----------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, i32)
        f32 = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)

        if shape.kind == "train":
            specs = {"tokens": tok(b, s), "labels": tok(b, s)}
            if cfg.frontend == "patch_embed":
                n = cfg.num_frontend_tokens
                specs = {
                    "tokens": tok(b, s - n),
                    "labels": tok(b, s - n),
                    "vision_embeds": f32(b, n, cfg.d_model),
                }
            elif cfg.frontend == "audio_frames":
                specs["audio_embeds"] = f32(b, encdec.ENC_FRAMES, cfg.d_model)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": tok(b, s)}
            if cfg.frontend == "patch_embed":
                n = cfg.num_frontend_tokens
                specs = {"tokens": tok(b, s - n), "vision_embeds": f32(b, n, cfg.d_model)}
            elif cfg.frontend == "audio_frames":
                specs["audio_embeds"] = f32(b, encdec.ENC_FRAMES, cfg.d_model)
            return specs
        # decode: one new token against a seq_len cache
        return {"tokens": tok(b, 1), "cache_len": jax.ShapeDtypeStruct((), i32)}

    def batch_axes(self, shape: ShapeSpec) -> dict:
        """Logical axes for each input (for in_shardings)."""
        cfg = self.cfg
        if shape.kind in ("train", "prefill"):
            axes = {"tokens": ("batch", None)}
            if shape.kind == "train":
                axes["labels"] = ("batch", None)
            if cfg.frontend == "patch_embed":
                axes["vision_embeds"] = ("batch", None, None)
            elif cfg.frontend == "audio_frames":
                axes["audio_embeds"] = ("batch", None, None)
            return axes
        return {"tokens": ("batch", None), "cache_len": ()}


def build_model(cfg: ArchConfig, tp_degree: int = 16) -> ModelAPI:
    return ModelAPI(cfg, tp_degree)
