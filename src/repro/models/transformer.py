"""Decoder-only transformer assembly (dense / MoE / VLM / RWKV6 families).

Blocks are *stacked* on a leading 'layers' axis and executed with
``lax.scan`` (+ optional ``jax.checkpoint``): compile time and HLO size are
O(1) in depth — the LM-side analogue of the paper's O(1)-graph property.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..sharding.partitioning import annotate
from . import attention as attn
from . import moe as moe_mod
from . import rwkv6 as rwkv
from .layers import P, mlp_apply, mlp_specs, rms_norm, stack_specs

__all__ = [
    "decoder_specs",
    "decoder_forward",
    "decoder_prefill",
    "decoder_decode",
    "lm_loss",
]


def _block_specs(cfg):
    d = cfg.d_model
    if cfg.family == "ssm":                       # rwkv6
        return rwkv.rwkv6_block_specs(cfg)
    block = {
        "ln1": P((d,), (None,), "ones"),
        "attn": attn.attention_specs(cfg),
        "ln2": P((d,), (None,), "ones"),
    }
    if cfg.num_experts:
        block["moe"] = moe_mod.moe_specs(cfg)
    else:
        block["mlp"] = mlp_specs(d, cfg.d_ff, cfg.mlp)
    return block


def vocab_mask(cfg):
    """(padded_vocab,) additive mask: 0 on real tokens, −inf on padding."""
    import numpy as np
    pv = cfg.padded_vocab
    if pv == cfg.vocab_size:
        return None
    return jnp.asarray(
        np.where(np.arange(pv) < cfg.vocab_size, 0.0, -1e30), jnp.float32
    )


def decoder_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    specs = {
        "embed": P((v, d), ("vocab", "embed"), scale=1.0),
        "blocks": stack_specs(_block_specs(cfg), cfg.num_layers),
        "final_ln": P((d,), (None,), "ones"),
        "unembed": P((d, v), ("embed", "vocab")),
    }
    if cfg.frontend == "patch_embed":
        # stubbed modality frontend: a single projection of precomputed
        # patch embeddings into the residual stream
        specs["patch_proj"] = P((d, d), ("embed", "heads"))
    return specs


def _embed_inputs(cfg, params, batch, compute_dtype):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.frontend == "patch_embed" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(compute_dtype)
        ve = jnp.einsum("bnd,dk->bnk", ve, params["patch_proj"].astype(compute_dtype))
        x = jnp.concatenate([ve, x], axis=1)
    return x


def _dense_block(cfg, blk, x, positions):
    x = annotate(x, "batch", "seq_act", None)
    h = rms_norm(x, blk["ln1"])
    a, _ = attn.attention_train(cfg, blk["attn"], h, positions)
    x = x + a
    x = annotate(x, "batch", "seq_act", None)
    h = rms_norm(x, blk["ln2"])
    if cfg.num_experts:
        m, aux = moe_mod.moe_apply(cfg, blk["moe"], h)
    else:
        m, aux = mlp_apply(blk["mlp"], h, cfg.mlp), 0.0
    return x + m, aux


def decoder_forward(cfg, params, batch):
    """Full causal forward → logits (B, S, vocab) in f32 (+ moe aux loss)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = _embed_inputs(cfg, params, batch, cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "ssm":
        def body(carry, blk):
            x = carry
            state = _zero_rwkv_state(cfg, b, cdt)
            x, _ = rwkv.rwkv6_block(cfg, blk, x, state)
            return x, jnp.zeros((), jnp.float32)
    else:
        def body(carry, blk):
            x = carry
            x, aux = _dense_block(cfg, blk, x, positions)
            return x, jnp.asarray(aux, jnp.float32)

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    x, auxs = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, jnp.sum(auxs)


def _zero_rwkv_state(cfg, b, dtype):
    h = cfg.d_model // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    return {
        "wkv": jnp.zeros((b, h, hd, hd), jnp.float32),
        "shift": jnp.zeros((b, cfg.d_model), dtype),
        "shift_c": jnp.zeros((b, cfg.d_model), dtype),
    }


def lm_loss(cfg, params, batch):
    logits, aux = decoder_forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.frontend == "patch_embed" and "vision_embeds" in batch:
        # loss only over text positions (vision prefix predicts nothing)
        n_img = batch["vision_embeds"].shape[1]
        logits = logits[:, n_img:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - true)
    if cfg.num_experts:
        nll = nll + 0.01 * aux
    return nll


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def kv_repeat_for(cfg, tp_degree: int = 16) -> int:
    """Replicate kv heads toward the TP degree, bounded by the GQA group
    size (kv·rep must still divide q heads)."""
    kvh, h = cfg.num_kv_heads, cfg.num_heads
    if not kvh or kvh >= tp_degree:
        return 1
    rep = min(tp_degree // kvh, h // kvh)
    while rep > 1 and (h % (kvh * rep) or tp_degree % (kvh * rep)):
        rep -= 1
    return max(rep, 1)


def decoder_cache_specs(cfg, batch: int, max_len: int, tp_degree: int = 16):
    if cfg.family == "ssm":
        per_layer = rwkv.rwkv6_state_specs(cfg, batch)
        # stack along layers
        return stack_specs(per_layer, cfg.num_layers)
    rep = kv_repeat_for(cfg, tp_degree)
    per_layer = attn.init_kv_cache_specs(cfg, batch, max_len, rep, tp_degree=tp_degree)
    return stack_specs(per_layer, cfg.num_layers)


def decoder_prefill(cfg, params, batch, max_len: int, tp_degree: int = 16):
    """Run the full prompt, return (last-token logits, populated cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = _embed_inputs(cfg, params, batch, cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "ssm":
        def body(x, blk):
            state = _zero_rwkv_state(cfg, b, cdt)
            x, new_state = rwkv.rwkv6_block(cfg, blk, x, state)
            return x, new_state
    else:
        rep = kv_repeat_for(cfg, tp_degree)

        def body(x, blk):
            x = annotate(x, "batch", "seq_act", None)
            h = rms_norm(x, blk["ln1"])
            a, (k, v) = attn.attention_train(cfg, blk["attn"], h, positions)
            x = x + a
            h = rms_norm(x, blk["ln2"])
            if cfg.num_experts:
                m, _ = moe_mod.moe_apply(cfg, blk["moe"], h)
            else:
                m = mlp_apply(blk["mlp"], h, cfg.mlp)
            x = x + m
            if rep > 1:
                k = jnp.repeat(k, rep, axis=2)
                v = jnp.repeat(v, rep, axis=2)
            pad = max_len - k.shape[1]
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
            k = annotate(k, "batch", "seq_cache", "kv_cache", None)
            v = annotate(v, "batch", "seq_cache", "kv_cache", None)
            return x, {"k": k, "v": v}

    x, cache = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x[:, -1:], params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, cache


def decoder_decode(cfg, params, batch, cache, tp_degree: int = 16):
    """One decode step: batch = {tokens (B,1), cache_len ()} → (logits, cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    cache_len = batch["cache_len"]

    if cfg.family == "ssm":
        def body(x, inp):
            blk, state = inp
            x, new_state = rwkv.rwkv6_decode_step(cfg, blk, x, state)
            return x, new_state
    else:
        rep = kv_repeat_for(cfg, tp_degree)

        def body(x, inp):
            blk, layer_cache = inp
            h = rms_norm(x, blk["ln1"])
            a, k_all, v_all = attn.attention_decode(
                cfg, blk["attn"], h, layer_cache["k"], layer_cache["v"],
                cache_len, rep,
            )
            x = x + a
            h = rms_norm(x, blk["ln2"])
            if cfg.num_experts:
                m, _ = moe_mod.moe_apply(cfg, blk["moe"], h)
            else:
                m = mlp_apply(blk["mlp"], h, cfg.mlp)
            return x + m, {"k": k_all, "v": v_all}

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, new_cache
