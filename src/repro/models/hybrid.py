"""Zamba2-style hybrid: Mamba2 backbone + a single weight-*shared* attention
block invoked every ``shared_attn_every`` layers, with per-invocation LoRA
adapters on the attention projections (arXiv:2411.15242).

Layer layout for L layers, period p: G = L // p groups of p Mamba2 blocks,
each followed by one shared-attention invocation; the remaining L − G·p
Mamba2 blocks form a tail.  Grouping keeps the scan homogeneous and — unlike
a cond-in-scan formulation — allocates KV cache only for the G invocations
(6× cache saving for the long_500k cell).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.partitioning import annotate
from . import attention as attn
from . import mamba2 as m2
from .layers import P, mlp_apply, mlp_specs, rms_norm, stack_specs

__all__ = [
    "hybrid_specs",
    "hybrid_forward",
    "hybrid_loss",
    "hybrid_prefill",
    "hybrid_decode",
    "hybrid_cache_specs",
]


def _layout(cfg):
    p = cfg.shared_attn_every
    groups = cfg.num_layers // p
    tail = cfg.num_layers - groups * p
    return groups, p, tail


def _lora_specs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    r = cfg.shared_attn_lora_rank
    return {
        "qa": P((d, r), ("embed", None), scale=1.0),
        "qb": P((r, h * hd), (None, "heads"), "zeros"),
        "ka": P((d, r), ("embed", None), scale=1.0),
        "kb": P((r, kv * hd), (None, "kv"), "zeros"),
        "va": P((d, r), ("embed", None), scale=1.0),
        "vb": P((r, kv * hd), (None, "kv"), "zeros"),
    }


def hybrid_specs(cfg) -> dict:
    groups, p, tail = _layout(cfg)
    mamba = m2.mamba2_block_specs(cfg)
    d = cfg.d_model
    shared = {
        "ln1": P((d,), (None,), "ones"),
        "attn": attn.attention_specs(cfg),
        "ln2": P((d,), (None,), "ones"),
        "mlp": mlp_specs(d, cfg.d_ff, "swiglu"),
    }
    specs = {
        "embed": P((cfg.padded_vocab, d), ("vocab", "embed"), scale=1.0),
        "groups": stack_specs(stack_specs(mamba, p), groups),
        "shared": shared,
        "lora": stack_specs(_lora_specs(cfg), groups),
        "final_ln": P((d,), (None,), "ones"),
        "unembed": P((d, cfg.padded_vocab), ("embed", "vocab")),
    }
    if tail:
        specs["tail"] = stack_specs(mamba, tail)
    return specs


def _shared_attn_train(cfg, shared, lora, x, positions):
    """Shared block with LoRA deltas folded into the projections."""
    h = rms_norm(x, shared["ln1"])
    ap = dict(shared["attn"])
    cdt = x.dtype
    ap = {
        **shared["attn"],
        "wq": shared["attn"]["wq"] + (lora["qa"] @ lora["qb"]).astype(
            shared["attn"]["wq"].dtype
        ),
        "wk": shared["attn"]["wk"] + (lora["ka"] @ lora["kb"]).astype(
            shared["attn"]["wk"].dtype
        ),
        "wv": shared["attn"]["wv"] + (lora["va"] @ lora["vb"]).astype(
            shared["attn"]["wv"].dtype
        ),
    }
    a, kv = attn.attention_train(cfg, ap, h, positions)
    x = x + a
    h = rms_norm(x, shared["ln2"])
    x = x + mlp_apply(shared["mlp"], h, "swiglu")
    return x, ap, kv


def _zero_m2_state(cfg, b):
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((b, cfg.ssm_conv - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros((b, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


def hybrid_forward(cfg, params, batch):
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    groups, p, tail = _layout(cfg)

    def mamba_body(x, blk):
        x = annotate(x, "batch", "seq_act", None)
        x, _ = m2.mamba2_block(cfg, blk, x, _zero_m2_state(cfg, b))
        return x, None

    if cfg.remat:
        _policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                   if cfg.remat_policy == "dots"
                   else jax.checkpoint_policies.nothing_saveable)
        mamba_body = jax.checkpoint(mamba_body, policy=_policy)

    def group_body(x, inp):
        grp, lora = inp
        x, _ = jax.lax.scan(mamba_body, x, grp)
        x, _, _ = _shared_attn_train(cfg, params["shared"], lora, x, positions)
        return x, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body, policy=_policy)
    x, _ = jax.lax.scan(group_body, x, (params["groups"], params["lora"]))
    if tail:
        x, _ = jax.lax.scan(mamba_body, x, params["tail"])
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    from .transformer import vocab_mask
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits


def hybrid_loss(cfg, params, batch):
    logits = hybrid_forward(cfg, params, batch)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def hybrid_cache_specs(cfg, batch: int, max_len: int, tp_degree: int = 16):
    groups, p, tail = _layout(cfg)
    m_state = m2.mamba2_state_specs(cfg, batch)
    from .transformer import kv_repeat_for
    rep = kv_repeat_for(cfg, tp_degree)
    kv = attn.init_kv_cache_specs(cfg, batch, max_len, rep, tp_degree=tp_degree)
    specs = {
        "mamba": stack_specs(stack_specs(m_state, p), groups),
        "kv": stack_specs(kv, groups),
    }
    if tail:
        specs["mamba_tail"] = stack_specs(m_state, tail)
    return specs


def hybrid_prefill(cfg, params, batch, max_len: int, tp_degree: int = 16):
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    groups, p, tail = _layout(cfg)
    from .transformer import kv_repeat_for
    rep = kv_repeat_for(cfg, tp_degree)

    def mamba_body(x, blk):
        x, st = m2.mamba2_block(cfg, blk, x, _zero_m2_state(cfg, b))
        return x, st

    def group_body(x, inp):
        grp, lora = inp
        x, states = jax.lax.scan(mamba_body, x, grp)
        x, ap, (k, v) = _shared_attn_train(cfg, params["shared"], lora, x, positions)
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        pad = max_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        return x, {"mamba": states, "kv": {"k": k, "v": v}}

    x, caches = jax.lax.scan(group_body, x, (params["groups"], params["lora"]))
    cache = {"mamba": caches["mamba"], "kv": caches["kv"]}
    if tail:
        x, tail_states = jax.lax.scan(mamba_body, x, params["tail"])
        cache["mamba_tail"] = tail_states
    x = rms_norm(x[:, -1:], params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    from .transformer import vocab_mask
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, cache


def hybrid_decode(cfg, params, batch, cache, tp_degree: int = 16):
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    cache_len = batch["cache_len"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    groups, p, tail = _layout(cfg)
    from .transformer import kv_repeat_for
    rep = kv_repeat_for(cfg, tp_degree)

    def mamba_body(x, inp):
        blk, st = inp
        x, st = m2.mamba2_decode_step(cfg, blk, x, st)
        return x, st

    def group_body(x, inp):
        grp, lora, mstates, kvcache = inp
        x, new_m = jax.lax.scan(mamba_body, x, (grp, mstates))
        h = rms_norm(x, params["shared"]["ln1"])
        ap = {
            **params["shared"]["attn"],
            "wq": params["shared"]["attn"]["wq"]
            + (lora["qa"] @ lora["qb"]).astype(cdt),
            "wk": params["shared"]["attn"]["wk"]
            + (lora["ka"] @ lora["kb"]).astype(cdt),
            "wv": params["shared"]["attn"]["wv"]
            + (lora["va"] @ lora["vb"]).astype(cdt),
        }
        a, k_all, v_all = attn.attention_decode(
            cfg, ap, h, kvcache["k"], kvcache["v"], cache_len, rep
        )
        x = x + a
        h = rms_norm(x, params["shared"]["ln2"])
        x = x + mlp_apply(params["shared"]["mlp"], h, "swiglu")
        return x, {"mamba": new_m, "kv": {"k": k_all, "v": v_all}}

    x, new_caches = jax.lax.scan(
        group_body, x,
        (params["groups"], params["lora"], cache["mamba"], cache["kv"]),
    )
    new_cache = {"mamba": new_caches["mamba"], "kv": new_caches["kv"]}
    if tail:
        x, new_tail = jax.lax.scan(
            mamba_body, x, (params["tail"], cache["mamba_tail"])
        )
        new_cache["mamba_tail"] = new_tail
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    from .transformer import vocab_mask
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, new_cache
