"""LM architecture pool.  Lazy re-export to avoid an import cycle:
``sharding.partitioning`` needs ``models.layers`` (the P-spec type) while
model modules need ``sharding.partitioning`` (activation annotation)."""


def build_model(*args, **kwargs):
    from .model_zoo import build_model as _build

    return _build(*args, **kwargs)
