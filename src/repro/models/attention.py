"""Attention: GQA + RoPE + optional qk-norm, with three execution paths:

* :func:`flash_attention`   — blockwise online-softmax over KV chunks
  (``lax.scan``): O(S·C) live memory instead of O(S²); used for train and
  prefill (32k prefill would otherwise materialize S² logits).
* :func:`decode_attention`  — one new token against a (possibly huge) KV
  cache with a length mask; logits in f32.
* KV-head replication: when TP degree exceeds ``num_kv_heads`` the cache is
  stored with kv heads repeated to the TP degree so attention stays local to
  each model shard (the classic serving layout; see DESIGN §4).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .layers import P, rms_norm, rope

__all__ = ["attention_specs", "attention_train", "attention_decode", "init_kv_cache_specs"]

NEG_INF = -1e30


def attention_specs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": P((d, h * hd), ("embed", "heads")),
        "wk": P((d, kv * hd), ("embed", "kv")),
        "wv": P((d, kv * hd), ("embed", "kv")),
        "wo": P((h * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = P((hd,), (None,), init="ones")
        specs["k_norm"] = P((hd,), (None,), init="ones")
    return specs


def _project_qkv(cfg, params, x, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dn->bsn", x, params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dn->bsn", x, params["wk"].astype(x.dtype)).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dn->bsn", x, params["wv"].astype(x.dtype)).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, chunk: int, q_offset: int = 0):
    """Online-softmax attention.  q (B,Sq,H,D); k/v (B,Skv,KV,D) with
    H % KV == 0 (GQA).  Scans KV in chunks of ``chunk``; f32 accumulators."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_chunks = k.reshape(b, n_chunks, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    v_chunks = v.reshape(b, n_chunks, chunk, kvh, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        acc, m_i, l_i = carry
        idx, k_c, v_c = inputs
        kv_pos = idx * chunk + jnp.arange(chunk)
        logits = jnp.einsum(
            "bskgd,bckd->bskgc", qg, k_c, preferred_element_type=jnp.float32
        ) * scale                                              # (B,Sq,KV,G,C)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else (
            kv_pos[None, :] >= -1
        )
        valid = kv_pos < skv
        mask = mask & valid[None, :]
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m_i, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bskgc,bckd->bskgd", p.astype(v_c.dtype), v_c,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    (acc, m_f, l_f), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(n_chunks), k_chunks, v_chunks)
    )
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention_train(cfg, params, x, positions):
    """Full training/prefill attention; returns (out, (k, v)) so prefill can
    populate the cache."""
    q, k, v = _project_qkv(cfg, params, x, positions)
    out = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    b, s, _, _ = out.shape
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bsn,nd->bsd", out, params["wo"].astype(x.dtype)), (k, v)


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache_specs(cfg, batch: int, max_len: int, kv_repeat: int = 1,
                        dtype=jnp.bfloat16, tp_degree: int = 16):
    """Cache layout (B, S_max, KV·repeat, D), logical axes
    (batch, seq_cache, kv_cache, None).  When the (repeated) head count does
    not divide the TP degree the head axis is left replicated (tiny models
    like whisper-tiny) — pjit arguments require even shardings."""
    kvh = cfg.num_kv_heads * kv_repeat
    head_ax = "kv_cache" if kvh % tp_degree == 0 else None
    shape = (batch, max_len, kvh, cfg.head_dim)
    return {
        "k": P(shape, ("batch", "seq_cache", head_ax, None), "zeros", dtype=dtype),
        "v": P(shape, ("batch", "seq_cache", head_ax, None), "zeros", dtype=dtype),
    }


def attention_decode(cfg, params, x, cache_k, cache_v, cache_len, kv_repeat: int = 1):
    """x: (B, 1, d); cache: (B, S, KV·rep, D) already containing ``cache_len``
    valid positions.  Returns (out, new_k_entry, new_v_entry)."""
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, params, x, positions)
    if kv_repeat > 1:
        k_new = jnp.repeat(k_new, kv_repeat, axis=2)
        v_new = jnp.repeat(v_new, kv_repeat, axis=2)
    k_all = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), cache_len, axis=1
    )
    v_all = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), cache_len, axis=1
    )
    kvh_eff = kvh * kv_repeat
    g = h // kvh_eff
    qg = q.reshape(b, 1, kvh_eff, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum(
        "bskgd,bckd->bskgc", qg, k_all.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) * scale                                               # (B,1,KV,G,S)
    pos = jnp.arange(k_all.shape[1])
    mask = pos[None, :] <= cache_len
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgc,bckd->bskgd", p.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    out = jnp.einsum("bsn,nd->bsd", out, params["wo"].astype(x.dtype))
    return out, k_all, v_all
