"""RWKV6 ("Finch") — attention-free time-mix with *data-dependent decay*.

Training/prefill use a chunk-parallel linear-attention formulation
(intra-chunk matmuls + an inter-chunk ``lax.scan`` over the matrix state);
decode is the O(1) recurrence  S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ,
o_t = r_t·(S_{t-1} + diag(u)·k_t v_tᵀ).

Simplifications vs the full release (recorded in DESIGN §5): token-shift
mixing coefficients are learned per-channel (RWKV5-style) while the *decay*
keeps the RWKV6 data-dependent low-rank form w_t = exp(−exp(w0 + tanh(x A) B));
head layer-norm is RMS.  The chunked intra term uses the standard
q·exp(Λ_excl) / k·exp(−Λ_incl) split in f32 (bounded for moderate chunk
lengths; chunk size is a config knob).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P, rms_norm

__all__ = ["rwkv6_block_specs", "rwkv6_block", "rwkv6_decode_step", "rwkv6_state_specs"]

DECAY_LORA = 64


def rwkv6_block_specs(cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "ln1": P((d,), (None,), "ones"),
        "ln2": P((d,), (None,), "ones"),
        "time": {
            "mu": P((5, d), (None, "embed"), "zeros"),       # r,k,v,w,g shift mixes
            "wr": P((d, d), ("embed", "heads")),
            "wk": P((d, d), ("embed", "heads")),
            "wv": P((d, d), ("embed", "heads")),
            "wg": P((d, d), ("embed", "heads")),
            "wo": P((d, d), ("heads", "embed")),
            "w0": P((d,), (None,), "zeros"),                 # base decay
            "wa": P((d, DECAY_LORA), ("embed", None)),       # decay lora in
            "wb": P((DECAY_LORA, d), (None, "embed")),       # decay lora out
            "u": P((d,), (None,), "zeros"),                  # per-channel bonus
            "head_ln": P((d,), (None,), "ones"),
        },
        "channel": {
            "mu": P((2, d), (None, "embed"), "zeros"),
            "wk": P((d, ff), ("embed", "mlp")),
            "wv": P((ff, d), ("mlp", "embed")),
            "wr": P((d, d), ("embed", "heads")),
        },
    }


def rwkv6_state_specs(cfg, batch: int, dtype=jnp.float32) -> dict:
    h = cfg.d_model // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    return {
        "wkv": P((batch, h, hd, hd), ("batch", None, None, None), "zeros", dtype=dtype),
        "shift": P((batch, cfg.d_model), ("batch", "embed"), "zeros", dtype=dtype),
        "shift_c": P((batch, cfg.d_model), ("batch", "embed"), "zeros", dtype=dtype),
    }


def _decay(params, xw):
    inner = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, params["wa"].astype(xw.dtype)))
    lora = jnp.einsum("bsr,rd->bsd", inner, params["wb"].astype(xw.dtype))
    logw = -jnp.exp(params["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    return logw                                                  # ≤ 0


def _shift(x, prev):
    """Token shift: x_{t-1} with ``prev`` filling t=0; returns shifted, last."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted, x[:, -1, :]


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """r/k/v/logw: (B, S, H, D); u: (H, D); state: (B, H, D, D) f32.
    Returns (out (B,S,H,D), new_state)."""
    b, s, h, dd = r.shape
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # pad decay 0 → w=1

    def split(a):
        return a.reshape(b, n, chunk, h, dd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = split(r), split(k), split(v), split(logw)
    f32 = jnp.float32

    def body(s_in, inp):
        rc, kc, vc, lw = [a.astype(f32) for a in inp]
        lam_incl = jnp.cumsum(lw, axis=1)                     # (B,C,H,D)
        lam_excl = lam_incl - lw
        lam_last = lam_incl[:, -1:]                           # (B,1,H,D)

        q_d = rc * jnp.exp(lam_excl)
        k_in = kc * jnp.exp(-lam_incl)
        k_out = kc * jnp.exp(lam_last - lam_incl)

        inter = jnp.einsum("bchd,bhde->bche", q_d, s_in)
        scores = jnp.einsum("bchd,bshd->bhcs", q_d, k_in)
        idx = jnp.arange(rc.shape[1])
        mask = idx[:, None] > idx[None, :]
        scores = scores * mask[None, None]
        intra = jnp.einsum("bhcs,bshe->bche", scores, vc)
        bonus = jnp.einsum("bchd,bchd,bche->bche",
                           rc * u[None, None].astype(f32), kc, vc)
        # ^ elementwise r·u·k summed over d applied to v — expand properly:
        out = inter + intra + bonus
        s_out = jnp.exp(lam_last[:, 0])[..., None] * s_in + jnp.einsum(
            "bshd,bshe->bhde", k_out, vc
        )
        return s_out, out

    state, outs = jax.lax.scan(body, state.astype(f32), (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n * chunk, h, dd)[:, :s]
    return out, state


def rwkv6_time_mix(cfg, tp, x, shift_prev, state, chunk):
    b, s, d = x.shape
    h = d // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    xs, last = _shift(x, shift_prev)
    mu = tp["mu"].astype(x.dtype)
    mix = lambda i: x + (xs - x) * mu[i][None, None, :]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = jnp.einsum("bsd,dn->bsn", xr, tp["wr"].astype(x.dtype)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dn->bsn", xk, tp["wk"].astype(x.dtype)).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,dn->bsn", xv, tp["wv"].astype(x.dtype)).reshape(b, s, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,dn->bsn", xg, tp["wg"].astype(x.dtype)))
    logw = _decay(tp, xw).reshape(b, s, h, hd)
    u = tp["u"].astype(jnp.float32).reshape(h, hd)
    out, state = _wkv_chunked(r, k, v, logw, u, state, chunk)
    out = rms_norm(out.reshape(b, s, d).astype(x.dtype), tp["head_ln"])
    out = out * g
    return jnp.einsum("bsn,nd->bsd", out, tp["wo"].astype(x.dtype)), last, state


def rwkv6_channel_mix(cfg, cp, x, shift_prev):
    xs, last = _shift(x, shift_prev)
    mu = cp["mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0][None, None, :]
    xr = x + (xs - x) * mu[1][None, None, :]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, cp["wk"].astype(x.dtype))))
    r = jax.nn.sigmoid(jnp.einsum("bsd,dn->bsn", xr, cp["wr"].astype(x.dtype)))
    return r * jnp.einsum("bsf,fd->bsd", k, cp["wv"].astype(x.dtype)), last


def rwkv6_block(cfg, params, x, state, chunk=None):
    """One RWKV6 layer. state: dict(wkv, shift, shift_c). Returns (x, state)."""
    chunk = chunk or cfg.ssm_chunk
    h1 = rms_norm(x, params["ln1"])
    tm, shift_last, wkv = rwkv6_time_mix(
        cfg, params["time"], h1, state["shift"].astype(x.dtype), state["wkv"], chunk
    )
    x = x + tm
    h2 = rms_norm(x, params["ln2"])
    cm, shift_c_last = rwkv6_channel_mix(
        cfg, params["channel"], h2, state["shift_c"].astype(x.dtype)
    )
    x = x + cm
    new_state = {
        "wkv": wkv,
        "shift": shift_last.astype(state["shift"].dtype),
        "shift_c": shift_c_last.astype(state["shift_c"].dtype),
    }
    return x, new_state


def rwkv6_decode_step(cfg, params, x, state):
    """x: (B, 1, d) — exact single-token recurrence (no chunking)."""
    b, _, d = x.shape
    h = d // cfg.ssm_head_dim
    hd = cfg.ssm_head_dim
    tp = params["time"]
    h1 = rms_norm(x, params["ln1"])[:, 0]                     # (B, d)
    prev = state["shift"].astype(x.dtype)
    mu = tp["mu"].astype(x.dtype)
    mix = lambda i: h1 + (prev - h1) * mu[i][None, :]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ tp["wr"].astype(x.dtype)).reshape(b, h, hd).astype(jnp.float32)
    k = (xk @ tp["wk"].astype(x.dtype)).reshape(b, h, hd).astype(jnp.float32)
    v = (xv @ tp["wv"].astype(x.dtype)).reshape(b, h, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ tp["wg"].astype(x.dtype))
    lora = jnp.tanh(xw @ tp["wa"].astype(x.dtype)) @ tp["wb"].astype(x.dtype)
    logw = -jnp.exp(tp["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    w = jnp.exp(logw).reshape(b, h, hd)
    u = tp["u"].astype(jnp.float32).reshape(h, hd)
    s_prev = state["wkv"]
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    o = jnp.einsum("bhd,bhde->bhe", r, s_prev + u[None, :, :, None] * kv)
    s_new = w[..., None] * s_prev + kv
    o = rms_norm(o.reshape(b, 1, d).astype(x.dtype), tp["head_ln"]) * g[:, None, :]
    out = jnp.einsum("bsn,nd->bsd", o, tp["wo"].astype(x.dtype))
    x = x + out

    h2 = rms_norm(x, params["ln2"])[:, 0]
    cp = params["channel"]
    prev_c = state["shift_c"].astype(x.dtype)
    mu_c = cp["mu"].astype(x.dtype)
    xk2 = h2 + (prev_c - h2) * mu_c[0][None, :]
    xr2 = h2 + (prev_c - h2) * mu_c[1][None, :]
    kk = jnp.square(jax.nn.relu(xk2 @ cp["wk"].astype(x.dtype)))
    rr = jax.nn.sigmoid(xr2 @ cp["wr"].astype(x.dtype))
    x = x + (rr * (kk @ cp["wv"].astype(x.dtype)))[:, None, :]
    new_state = {
        "wkv": s_new,
        "shift": h1.astype(state["shift"].dtype),
        "shift_c": h2.astype(state["shift_c"].dtype),
    }
    return x, new_state
