"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed encoder frame embeddings (B, T_enc, d).  The encoder is
bidirectional self-attention with fixed sinusoidal positions; the decoder is
causal self-attention (RoPE — a documented deviation from Whisper's learned
positions, keeping parameter shapes length-agnostic) + cross-attention to
the encoder output.  Decode caches both the self-attn KV and the
once-computed cross KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from .layers import P, mlp_apply, mlp_specs, rms_norm, stack_specs

__all__ = [
    "encdec_specs",
    "encdec_loss",
    "encdec_prefill",
    "encdec_decode",
    "encdec_cache_specs",
    "ENC_FRAMES",
]

ENC_FRAMES = 1500  # whisper 30 s @ 50 Hz


def _cross_specs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": P((d, h * hd), ("embed", "heads")),
        "wk": P((d, kv * hd), ("embed", "kv")),
        "wv": P((d, kv * hd), ("embed", "kv")),
        "wo": P((h * hd, d), ("heads", "embed")),
    }


def encdec_specs(cfg) -> dict:
    d = cfg.d_model
    enc_block = {
        "ln1": P((d,), (None,), "ones"),
        "attn": attn.attention_specs(cfg),
        "ln2": P((d,), (None,), "ones"),
        "mlp": mlp_specs(d, cfg.d_ff, "gelu"),
    }
    dec_block = {
        "ln1": P((d,), (None,), "ones"),
        "attn": attn.attention_specs(cfg),
        "lnx": P((d,), (None,), "ones"),
        "cross": _cross_specs(cfg),
        "ln2": P((d,), (None,), "ones"),
        "mlp": mlp_specs(d, cfg.d_ff, "gelu"),
    }
    return {
        "embed": P((cfg.padded_vocab, d), ("vocab", "embed"), scale=1.0),
        "enc_blocks": stack_specs(enc_block, cfg.encoder_layers),
        "enc_ln": P((d,), (None,), "ones"),
        "dec_blocks": stack_specs(dec_block, cfg.num_layers),
        "final_ln": P((d,), (None,), "ones"),
        "unembed": P((d, cfg.padded_vocab), ("embed", "vocab")),
    }


def _sinusoidal(s, d, dtype):
    pos = np.arange(s)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype)


def encode(cfg, params, frames):
    """frames: (B, T_enc, d) precomputed embeddings (frontend stub)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) + _sinusoidal(frames.shape[1], cfg.d_model, cdt)[None]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, blk):
        h = rms_norm(x, blk["ln1"])
        q, k, v = attn._project_qkv(cfg, blk["attn"], h, positions)
        o = attn.flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        o = o.reshape(b, s, cfg.num_heads * cfg.head_dim)
        x = x + jnp.einsum("bsn,nd->bsd", o, blk["attn"]["wo"].astype(cdt))
        h = rms_norm(x, blk["ln2"])
        return x + mlp_apply(blk["mlp"], h, "gelu"), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_ln"])


def _cross_attend(cfg, cp, x, enc_k, enc_v):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dn->bsn", x, cp["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    o = attn.flash_attention(q, enc_k, enc_v, causal=False, chunk=cfg.attn_chunk)
    o = o.reshape(b, s, h * hd)
    return jnp.einsum("bsn,nd->bsd", o, cp["wo"].astype(x.dtype))


def _cross_kv(cfg, cp, enc_out):
    b, t, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("btd,dn->btn", enc_out, cp["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dn->btn", enc_out, cp["wv"].astype(enc_out.dtype))
    return k.reshape(b, t, kv, hd), v.reshape(b, t, kv, hd)


def decode_stack_train(cfg, params, tokens, enc_out):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, blk):
        h = rms_norm(x, blk["ln1"])
        a, _ = attn.attention_train(cfg, blk["attn"], h, positions)
        x = x + a
        h = rms_norm(x, blk["lnx"])
        enc_k, enc_v = _cross_kv(cfg, blk["cross"], enc_out)
        x = x + _cross_attend(cfg, blk["cross"], h, enc_k, enc_v)
        h = rms_norm(x, blk["ln2"])
        return x + mlp_apply(blk["mlp"], h, "gelu"), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    from .transformer import vocab_mask
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits


def encdec_loss(cfg, params, batch):
    enc_out = encode(cfg, params, batch["audio_embeds"])
    logits = decode_stack_train(cfg, params, batch["tokens"], enc_out)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_cache_specs(cfg, batch: int, max_len: int, tp_degree: int = 16):
    from .transformer import kv_repeat_for
    rep = kv_repeat_for(cfg, tp_degree)
    self_kv = attn.init_kv_cache_specs(cfg, batch, max_len, rep, tp_degree=tp_degree)
    kvh = cfg.num_kv_heads * rep
    head_ax = "kv_cache" if kvh % tp_degree == 0 else None
    cross = {
        "k": P((batch, ENC_FRAMES, kvh, cfg.head_dim),
               ("batch", None, head_ax, None), "zeros", dtype=jnp.bfloat16),
        "v": P((batch, ENC_FRAMES, kvh, cfg.head_dim),
               ("batch", None, head_ax, None), "zeros", dtype=jnp.bfloat16),
    }
    return stack_specs({"self": self_kv, "cross": cross}, cfg.num_layers)


def encdec_prefill(cfg, params, batch, max_len: int, tp_degree: int = 16):
    """Encode audio + run the decoder prompt, build both caches."""
    cdt = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(cfg, params, batch["audio_embeds"])
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    from .transformer import kv_repeat_for
    rep = kv_repeat_for(cfg, tp_degree)

    def body(x, blk):
        h = rms_norm(x, blk["ln1"])
        a, (k, v) = attn.attention_train(cfg, blk["attn"], h, positions)
        x = x + a
        h = rms_norm(x, blk["lnx"])
        ck, cv = _cross_kv(cfg, blk["cross"], enc_out)
        x = x + _cross_attend(cfg, blk["cross"], h, ck, cv)
        h = rms_norm(x, blk["ln2"])
        x = x + mlp_apply(blk["mlp"], h, "gelu")
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
            ck = jnp.repeat(ck, rep, axis=2)
            cv = jnp.repeat(cv, rep, axis=2)
        pad = max_len - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        cache = {
            "self": {"k": k, "v": v},
            "cross": {"k": ck.astype(jnp.bfloat16), "v": cv.astype(jnp.bfloat16)},
        }
        return x, cache

    x, cache = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x[:, -1:], params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    from .transformer import vocab_mask
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, cache


def encdec_decode(cfg, params, batch, cache, tp_degree: int = 16):
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    cache_len = batch["cache_len"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    b = x.shape[0]
    from .transformer import kv_repeat_for
    rep = kv_repeat_for(cfg, tp_degree)
    h_heads, hd = cfg.num_heads, cfg.head_dim

    def body(x, inp):
        blk, layer_cache = inp
        h = rms_norm(x, blk["ln1"])
        a, k_all, v_all = attn.attention_decode(
            cfg, blk["attn"], h, layer_cache["self"]["k"],
            layer_cache["self"]["v"], cache_len, rep,
        )
        x = x + a
        h = rms_norm(x, blk["lnx"])
        # cross attention against the fixed encoder KV (already repeated)
        q = jnp.einsum("bsd,dn->bsn", h, blk["cross"]["wq"].astype(cdt)).reshape(
            b, 1, h_heads, hd
        )
        o = attn.flash_attention(
            q, layer_cache["cross"]["k"].astype(cdt),
            layer_cache["cross"]["v"].astype(cdt),
            causal=False, chunk=cfg.attn_chunk,
        ).reshape(b, 1, h_heads * hd)
        x = x + jnp.einsum("bsn,nd->bsd", o, blk["cross"]["wo"].astype(cdt))
        h = rms_norm(x, blk["ln2"])
        x = x + mlp_apply(blk["mlp"], h, "gelu")
        return x, {"self": {"k": k_all, "v": v_all}, "cross": layer_cache["cross"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    x = rms_norm(x, params["final_ln"])
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["unembed"].astype(cdt),
        preferred_element_type=jnp.float32,
    )
    from .transformer import vocab_mask
    mask = vocab_mask(cfg)
    if mask is not None:
        logits = logits + mask
    return logits, new_cache
