"""Shared layers + the parameter-spec system.

A model is described by a pytree of :class:`P` (shape, logical axes, init);
from that single source of truth we derive real parameters (``init_params``),
ShapeDtypeStructs (dry-run), and NamedShardings (``repro.sharding``).

Logical axes used across the stack:
  embed   — the model (residual) dimension            → fsdp axis
  heads   — attention heads × head_dim (fused)        → tensor axis
  kv      — kv heads × head_dim                       → tensor axis
  mlp     — feed-forward hidden                       → tensor axis
  vocab   — vocabulary                                → tensor axis
  expert  — MoE expert                                → tensor axis (EP)
  layers  — stacked-block leading axis                → unsharded (scanned)
  (None)  — replicated
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["P", "init_params", "abstract_params", "RMSNorm helpers"]


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape + logical axes (+ init style)."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 1.0            # stddev multiplier (normal → scale/√fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def _leaf_init(key, spec: P):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
    if len(spec.shape) >= 2:
        fan_in = spec.shape[-2]
    std = spec.scale / np.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)


def init_params(specs, key):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStructs for lowering without allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_specs(specs, n: int):
    """Prepend a scanned 'layers' axis to every spec in a block."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.dtype),
        specs,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D) with D even; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def mlp_specs(d_model: int, d_ff: int, kind: str) -> dict:
    if kind == "swiglu":
        return {
            "wi": P((d_model, d_ff), ("embed", "mlp")),
            "wg": P((d_model, d_ff), ("embed", "mlp")),
            "wo": P((d_ff, d_model), ("mlp", "embed")),
        }
    return {  # squared_relu / gelu: 2-matrix MLP
        "wi": P((d_model, d_ff), ("embed", "mlp")),
        "wo": P((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_apply(params: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(x.dtype))
        if kind == "squared_relu":                      # nemotron-4
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))
