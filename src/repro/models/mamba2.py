"""Mamba2 (SSD — state-space duality) block, chunked.

Per-head scalar decay a_t = exp(Δt·A) makes the chunked form simpler than
RWKV6: the intra-chunk kernel exp(Λ_t − Λ_s) is materialized directly
(s ≤ t ⇒ exponent ≤ 0, numerically safe at any chunk length).

Recurrence (head h, state S ∈ R^{P×N}):
    S_t = a_t S_{t−1} + (Δt_t x_t) ⊗ B_t ,   y_t = S_t · C_t + D x_t
Decode carries (conv_state, ssm_state) exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P, rms_norm

__all__ = ["mamba2_block_specs", "mamba2_block", "mamba2_decode_step", "mamba2_state_specs"]


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_block_specs(cfg) -> dict:
    d = cfg.d_model
    d_in, h, p, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "ln": P((d,), (None,), "ones"),
        "in_proj": P((d, 2 * d_in + 2 * n + h), ("embed", "mlp")),
        "conv_w": P((cfg.ssm_conv, conv_ch), (None, "mlp"), scale=1.0),
        "conv_b": P((conv_ch,), ("mlp",), "zeros"),
        "a_log": P((h,), (None,), "ones"),
        "dt_bias": P((h,), (None,), "zeros"),
        "d_skip": P((h,), (None,), "ones"),
        "out_norm": P((d_in,), ("mlp",), "ones"),
        "out_proj": P((d_in, d), ("mlp", "embed")),
    }


def mamba2_state_specs(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_in, h, p, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": P((batch, cfg.ssm_conv - 1, conv_ch), ("batch", None, "mlp"),
                  "zeros", dtype=dtype),
        "ssm": P((batch, h, p, n), ("batch", None, None, None), "zeros", dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv; x (B,S,C), w (K,C).  state (B,K-1,C) holds the
    previous tail for decode/prefill continuity."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(k)
    )
    new_state = xp[:, -(k - 1):, :]
    return jax.nn.silu(out + b.astype(x.dtype)), new_state


def _ssd_chunked(x, dt, a_log, b_in, c_in, state, chunk: int):
    """x (B,S,H,P); dt (B,S,H) (post-softplus); b_in/c_in (B,S,N);
    state (B,H,P,N) f32.  Returns (y, new_state)."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))

    a = -jnp.exp(a_log.astype(jnp.float32))                    # (H,) negative
    la = dt.astype(jnp.float32) * a[None, None, :]             # log decay (B,S,H)

    def split(t, extra):
        return t.reshape((bsz, nc, chunk) + extra).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(extra)))
        )

    xc = split(x.astype(jnp.float32), (h, p))
    dtc = split(dt.astype(jnp.float32), (h,))
    lac = split(la, (h,))
    bc = split(b_in.astype(jnp.float32), (n,))
    cc = split(c_in.astype(jnp.float32), (n,))

    def body(s_in, inp):
        xk, dtk, lak, bk, ck = inp
        lam = jnp.cumsum(lak, axis=1)                          # (B,C,H) inclusive
        lam_last = lam[:, -1]                                  # (B,H)
        # inter-chunk: y_t += exp(Λ_t) C_t · S_in
        inter = jnp.einsum("bch,bcn,bhpn->bchp", jnp.exp(lam), ck, s_in)
        # intra-chunk: kernel L_{t,s} = exp(Λ_t − Λ_s) for s ≤ t
        diff = lam[:, :, None, :] - lam[:, None, :, :]         # (B,C,C,H)
        idx = jnp.arange(xk.shape[1])
        mask = idx[:, None] >= idx[None, :]
        kern = jnp.exp(diff) * mask[None, :, :, None]
        cb = jnp.einsum("bcn,bsn->bcs", ck, bk)                # (B,C,C)
        w_s = dtk[:, :, :, None] * xk                          # Δt·x (B,C,H,P)
        intra = jnp.einsum("bcs,bcsh,bshp->bchp",
                           cb, kern.transpose(0, 1, 2, 3), w_s)
        y = inter + intra
        # state update: S_out = exp(Λ_last) S_in + Σ_s exp(Λ_last − Λ_s) w_s ⊗ B_s
        decay_out = jnp.exp(lam_last[:, None, :] - lam)        # (B,C,H)
        s_out = jnp.exp(lam_last)[..., None, None] * s_in + jnp.einsum(
            "bch,bchp,bcn->bhpn", decay_out, w_s, bk
        )
        return s_out, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32), (xc, dtc, lac, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)[:, :s]
    return y, state


def mamba2_block(cfg, params, x, state, chunk=None):
    """x (B,S,d); state {conv, ssm}.  Returns (x, new_state)."""
    chunk = chunk or cfg.ssm_chunk
    d_in, h, p, n = _dims(cfg)
    bsz, s, _ = x.shape
    res = x
    xh = rms_norm(x, params["ln"])
    proj = jnp.einsum("bsd,dk->bsk", xh, params["in_proj"].astype(x.dtype))
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_in + 2 * n], axis=-1)
    xbc, conv_state = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], state["conv"]
    )
    xs, b_in, c_in = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    y, ssm_state = _ssd_chunked(
        xs.reshape(bsz, s, h, p), dt, params["a_log"], b_in, c_in,
        state["ssm"], chunk,
    )
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xs.reshape(
        bsz, s, h, p
    ).astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"])
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"].astype(x.dtype))
    return res + out, {"conv": conv_state.astype(state["conv"].dtype), "ssm": ssm_state}


def mamba2_decode_step(cfg, params, x, state):
    """Single-token exact recurrence; x (B,1,d)."""
    out, new_state = mamba2_block(cfg, params, x, state, chunk=1)
    return out, new_state
