"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch
(GShard-style one-hot einsums — compiles to dense contractions that GSPMD
partitions over the expert axis; see DESIGN §4).

Tokens are grouped per-sample (G = batch, T = seq): routing and capacity are
per group, so the dispatch tensor (G, T, E, C) shards as
(batch→data, ·, expert→model, ·) and stays small per chip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs = {
        "router": P((d, e), ("embed", None)),
        # experts shard over 'model' (EP); their ff dim stays local
        "wi": P((e, d, ff), ("expert", "embed", "expert_mlp")),
        "wg": P((e, d, ff), ("expert", "embed", "expert_mlp")),
        "wo": P((e, ff, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.moe_shared_expert:
        specs["shared"] = {
            "wi": P((d, ff), ("embed", "mlp")),
            "wg": P((d, ff), ("embed", "mlp")),
            "wo": P((ff, d), ("mlp", "embed")),
        }
    return specs


def _capacity(cfg, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.experts_per_token / cfg.num_experts
            * cfg.moe_capacity_factor)
    return max(c, cfg.experts_per_token)


def moe_apply(cfg, params, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (out, aux_loss).  B is the group axis."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = _capacity(cfg, s)

    router_logits = jnp.einsum(
        "gtd,de->gte", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    gates = jax.nn.softmax(router_logits, axis=-1)             # (G,T,E)

    top_vals, top_idx = jax.lax.top_k(gates, k)                # (G,T,K)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # --- position-in-expert via k-major cumulative count --------------------
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)     # (G,T,K,E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(b, k * s, e)   # k-major (G,KT,E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (G,KT,E)
    pos_scalar = jnp.sum(pos * flat, axis=-1)                  # (G,KT)
    keep = (pos_scalar < c).astype(jnp.float32)
    slot_oh = jax.nn.one_hot(pos_scalar.astype(jnp.int32), c, dtype=jnp.float32)
    # dispatch (G,KT,E,C), then fold k slots back onto tokens
    dispatch_kt = flat[..., :, None] * slot_oh[..., None, :] * keep[..., None, None]
    dispatch = dispatch_kt.reshape(b, k, s, e, c).sum(axis=1)  # (G,T,E,C)

    weights_kt = top_vals.transpose(0, 2, 1).reshape(b, k * s) # k-major weights
    combine_kt = dispatch_kt * weights_kt[..., None, None]
    combine = combine_kt.reshape(b, k, s, e, c).sum(axis=1)    # (G,T,E,C)

    # --- expert computation --------------------------------------------------
    cd = x.dtype
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(cd), x)   # (G,E,C,d)
    h = jnp.einsum("gecd,edf->gecf", expert_in, params["wi"].astype(cd))
    g = jnp.einsum("gecd,edf->gecf", expert_in, params["wg"].astype(cd))
    h = jax.nn.silu(g) * h
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(cd))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(cd), expert_out)

    if cfg.moe_shared_expert:
        sh = params["shared"]
        hh = jnp.einsum("gtd,df->gtf", x, sh["wi"].astype(cd))
        gg = jnp.einsum("gtd,df->gtf", x, sh["wg"].astype(cd))
        out = out + jnp.einsum(
            "gtf,fd->gtd", jax.nn.silu(gg) * hh, sh["wo"].astype(cd)
        )

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(onehot.sum(2), axis=1)                  # (G,E) token frac
    prob_mean = jnp.mean(gates, axis=1)                        # (G,E)
    aux = e * jnp.mean(jnp.sum(density * prob_mean, axis=-1))
    return out, aux
