"""Config module for --arch qwen3-4b (exact assigned dimensions)."""

from .registry import QWEN3_4B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
