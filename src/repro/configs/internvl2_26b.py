"""Config module for --arch internvl2-26b (exact assigned dimensions)."""

from .registry import INTERNVL2_26B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
