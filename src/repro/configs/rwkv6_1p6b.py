"""Config module for --arch rwkv6-1.6b (exact assigned dimensions)."""

from .registry import RWKV6_1P6B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
