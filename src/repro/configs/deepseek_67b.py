"""Config module for --arch deepseek-67b (exact assigned dimensions)."""

from .registry import DEEPSEEK_67B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
