from .base import SHAPES, ArchConfig, ShapeSpec, smoke_variant  # noqa: F401
from .registry import ARCHS, get_config  # noqa: F401
