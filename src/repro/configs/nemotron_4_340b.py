"""Config module for --arch nemotron-4-340b (exact assigned dimensions)."""

from .registry import NEMOTRON_340B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
