"""Config module for --arch qwen3-32b (exact assigned dimensions)."""

from .registry import QWEN3_32B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
