"""Architecture + shape configuration system (``--arch <id>``).

Every assigned architecture is a frozen :class:`ArchConfig`; every input
shape is a :class:`ShapeSpec`.  The dry-run iterates the cross product.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "smoke_variant"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e6
    attn_logit_dtype: str = "float32"

    # MLP
    mlp: str = "swiglu"            # swiglu | squared_relu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0     # zamba2: shared attn block period (0 = off)
    shared_attn_lora_rank: int = 0

    # frontends (stubbed modalities)
    frontend: str | None = None    # patch_embed | audio_frames | None
    num_frontend_tokens: int = 0

    # encoder-decoder
    encoder_layers: int = 0

    # training policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_dtype: str = "float32"    # "bfloat16" = compressed grad all-reduce
    optimizer: str = "adamw"       # adamw | adafactor
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (save matmul outputs)
    microbatches: Mapping[str, int] = dataclasses.field(default_factory=dict)
    # attention kv-block for the flash-style scan
    attn_chunk: int = 1024
    ssm_chunk: int = 256

    # which shapes apply (e.g. full-attention archs skip long_500k)
    skip_shapes: tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding: embedding/unembedding tables are
        padded to a multiple of 256 so they shard evenly over the tensor
        axis; logits at padded positions are masked to −inf."""
        return -(-self.vocab_size // 256) * 256

    def grad_accum(self, shape_name: str) -> int:
        return self.microbatches.get(shape_name, 1)

    def param_count(self) -> int:
        """Approximate total parameters (reported in the roofline table)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.family in ("ssm",):
            d_in = self.ssm_expand * d
            mix = d * d_in * 2 + d_in * d + d * (2 * self.ssm_state)
            per_layer = mix + 2 * d * ff  # channel-mix style
        elif self.family == "moe":
            dense_mlp = 3 * d * ff * self.num_experts
            if self.moe_shared_expert:
                dense_mlp += 3 * d * ff
            per_layer = attn + dense_mlp + d * self.num_experts
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            per_layer = (d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim)
                         + d_in * d)
        else:
            mlp = (3 if self.mlp == "swiglu" else 2) * d * ff
            per_layer = attn + mlp
        layers = self.num_layers + self.encoder_layers
        total = layers * per_layer + 2 * v * d
        if self.family == "hybrid" and self.shared_attn_every:
            mlp = 3 * d * ff
            total += attn + mlp  # one shared block
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE uses top-k of the experts."""
        if self.family != "moe" or self.num_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        k = self.experts_per_token + (1 if self.moe_shared_expert else 0)
        per_layer = attn + 3 * d * ff * k + d * self.num_experts
        return int(self.num_layers * per_layer + 2 * self.vocab_size * d)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        shared_attn_lora_rank=4 if cfg.shared_attn_lora_rank else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_frontend_tokens=8 if cfg.frontend else 0,
        attn_chunk=32,
        ssm_chunk=16,
        microbatches={},
    )
