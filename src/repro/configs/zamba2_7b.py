"""Config module for --arch zamba2-7b (exact assigned dimensions)."""

from .registry import ZAMBA2_7B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
