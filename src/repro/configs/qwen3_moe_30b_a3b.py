"""Config module for --arch qwen3-moe-30b-a3b (exact assigned dimensions)."""

from .registry import QWEN3_MOE_30B as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
