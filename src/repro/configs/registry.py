"""Registry of the 10 assigned architectures (``--arch <id>``).

Sources are recorded per entry; verified-tier tags from the assignment.
Microbatch (grad-accum) counts are sized so per-chip activations fit HBM on
the (16, 16) v5e pod — see EXPERIMENTS.md §Dry-run for measured bytes.
"""

from __future__ import annotations

from .base import ArchConfig

__all__ = ["ARCHS", "get_config"]


ARCHS: dict[str, ArchConfig] = {}


def _register(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- [ssm] RWKV6 "Finch" 1.6B — data-dependent decay [arXiv:2404.05892] -----
RWKV6_1P6B = _register(ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    ssm_state=64, ssm_head_dim=64,
    microbatches={"train_4k": 2},
))

# --- [dense] Qwen3-32B — qk_norm + GQA [hf:Qwen/Qwen3-8B family] -------------
QWEN3_32B = _register(ArchConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936, qk_norm=True, mlp="swiglu",
    microbatches={"train_4k": 4, "prefill_32k": 1},
))

# --- [dense] Qwen3-4B ---------------------------------------------------------
QWEN3_4B = _register(ArchConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936, qk_norm=True, mlp="swiglu",
    microbatches={"train_4k": 2},
))

# --- [dense] Nemotron-4 340B — squared-ReLU MLP [arXiv:2402.16819] ------------
NEMOTRON_340B = _register(ArchConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000, mlp="squared_relu",
    optimizer="adafactor", grad_dtype="bfloat16",
    microbatches={"train_4k": 16, "prefill_32k": 2},
))

# --- [dense] DeepSeek 67B — llama-arch [arXiv:2401.02954] ---------------------
DEEPSEEK_67B = _register(ArchConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400, mlp="swiglu",
    microbatches={"train_4k": 8, "prefill_32k": 1},
))

# --- [vlm] InternVL2 26B — InternViT (stub) + InternLM2 [arXiv:2404.16821] ----
INTERNVL2_26B = _register(ArchConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, mlp="swiglu",
    frontend="patch_embed", num_frontend_tokens=256,
    microbatches={"train_4k": 4, "prefill_32k": 1},
))

# --- [hybrid] Zamba2 7B — Mamba2 + shared attn [arXiv:2411.15242] -------------
ZAMBA2_7B = _register(ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6, shared_attn_lora_rank=64,
    microbatches={"train_4k": 4},
))

# --- [moe] Qwen3-MoE 30B-A3B — 128e top-8 [hf:Qwen/Qwen3-30B-A3B] -------------
QWEN3_MOE_30B = _register(ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True,
    num_experts=128, experts_per_token=8,
    microbatches={"train_4k": 2},
))

# --- [moe] Llama4 Maverick 400B-A17B — 128e top-1 + shared expert -------------
LLAMA4_MAVERICK = _register(ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_shared_expert=True,
    optimizer="adafactor", grad_dtype="bfloat16",
    microbatches={"train_4k": 8, "prefill_32k": 1},
))

# --- [audio] Whisper-tiny — enc-dec, conv frontend stub [arXiv:2212.04356] ----
WHISPER_TINY = _register(ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, encoder_layers=4,
    d_model=384, num_heads=6, num_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865, mlp="gelu",
    frontend="audio_frames",
    microbatches={"train_4k": 8},
))


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]
