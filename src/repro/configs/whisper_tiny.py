"""Config module for --arch whisper-tiny (exact assigned dimensions)."""

from .registry import WHISPER_TINY as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
