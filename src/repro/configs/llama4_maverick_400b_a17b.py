"""Config module for --arch llama4-maverick-400b-a17b (exact assigned dimensions)."""

from .registry import LLAMA4_MAVERICK as CONFIG  # noqa: F401
from .base import smoke_variant

SMOKE = smoke_variant(CONFIG)
