"""Quickstart: solve a 3D Poisson problem with TensorMesh in ~10 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core import unit_cube_tet
from repro.fem import PoissonProblem

# -Δu = 1 on the unit cube, u = 0 on the boundary
problem = PoissonProblem(unit_cube_tet(8))
result = problem.solve(f=1.0, tol=1e-10)

print(f"DoFs:               {problem.space.num_dofs}")
print(f"CG iterations:      {result.iters}")
print(f"relative residual:  {result.residual:.2e}   (paper tolerance: 1e-10)")
print(f"max u:              {float(result.u.max()):.6f}  (≈0.056 as h→0)")

# spatially varying coefficient + batched right-hand sides (many-query mode)
result2 = problem.solve(rho=lambda x: 1.0 + x[..., 0], f=1.0)
print(f"variable-ρ solve:   residual {result2.residual:.2e}")

import numpy as np

f_batch = jnp.asarray(np.random.default_rng(0).normal(size=(8, problem.space.num_dofs)))
us, iters = problem.solve_batch(f_batch)
print(f"batched solve:      {us.shape[0]} RHS in one vmapped call, iters={list(map(int, iters))}")

# composable weak forms: steady advection–diffusion is one fused assembly —
# diffusion(eps) + advection(beta) — no per-PDE assembler code needed
from repro.core import unit_square_tri
from repro.fem import AdvectionDiffusionProblem

ad = AdvectionDiffusionProblem(unit_square_tri(24))
res3 = ad.solve(eps=0.05, beta=(1.0, 0.5), f=1.0)
print(f"advection-diffusion: residual {res3.residual:.2e}  max u {float(res3.u.max()):.4f}")
