"""Physics-informed operator learning (paper §B.3, reduced): an AGN learns
the wave-equation solution operator on a disk mesh from the *discrete
Galerkin residual alone* (data-free), compared against supervised training.

    PYTHONPATH=src python examples/operator_learning_wave.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import disk_tri
from repro.pils.gnn import agn_init, agn_rollout, element_graph_edges
from repro.pils.operator import TimeDependentProblem, random_initial_condition
from repro.pils.training import adam_init, adam_update
from repro.transient import batched_rollout

W, N_BUNDLES, EPOCHS = 4, 8, 200
tp = TimeDependentProblem(disk_tri(6), dt=5e-4, c=4.0)
mesh = tp.mesh
edges = element_graph_edges(mesh.cells)
deg = np.zeros(mesh.num_vertices)
np.add.at(deg, edges[:, 1], 1)
deg = jnp.asarray(np.maximum(deg, 1.0))
coords = jnp.asarray(mesh.points)
total = W * N_BUNDLES
print(f"mesh: {mesh.num_vertices} nodes / {mesh.num_cells} elements; rollout {total} steps")

keys = jax.random.split(jax.random.PRNGKey(0), 6)
u0s = jnp.stack(
    [random_initial_condition(k, tp.space.dof_points) * tp.bc.free_mask
     for k in keys]
)
# one vmapped Newmark-β rollout over all initial conditions (repro.transient)
refs = batched_rollout(tp.newmark_integrator(), u0s, W + total)
trajs = [jnp.concatenate([u0s[i][None], refs[i]], 0) for i in range(len(keys))]
train_trajs, test_trajs = trajs[:4], trajs[4:]


def rollout(params, traj):
    u_win = traj[:W].T   # window seeded with the known first w steps
    return agn_rollout(params, u_win, coords, edges, deg, N_BUNDLES, tp.interior)


def galerkin_loss(params):
    # data-free: only the PDE's discrete residual (Eq. B.17) is minimized
    tot = 0.0
    for traj in train_trajs:
        pred = rollout(params, traj)
        full = jnp.concatenate([traj[W - 2 : W], pred.T], axis=0)
        tot = tot + tp.wave_trajectory_loss(full, normalized=True)
    return tot / len(train_trajs)


params = agn_init(jax.random.PRNGKey(1), W, W, hidden=32, n_layers=3)
state = adam_init(params)
vg = jax.jit(jax.value_and_grad(galerkin_loss))
t0 = time.perf_counter()
for i in range(EPOCHS):
    loss, g = vg(params)
    params, state = adam_update(params, g, state, 1e-3)
    if i % 50 == 0:
        print(f"  epoch {i:4d}  residual loss {float(loss):.3e}")
print(f"training: {time.perf_counter() - t0:.1f}s")

half = total // 2
for label, sl in (("ID ", slice(0, half)), ("OOD", slice(half, total))):
    errs = []
    for traj in test_trajs:
        pred = np.asarray(rollout(params, traj)).T
        tgt = np.asarray(traj[W : W + total])
        rel = np.linalg.norm((pred - tgt)[sl]) / (np.linalg.norm(tgt[sl]) + 1e-12)
        errs.append(rel)
    print(f"{label} rel-L2 on held-out ICs: {np.mean(errs):.3f}")
