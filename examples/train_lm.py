"""End-to-end LM training driver example (~15M-param qwen3-family smoke
config, a few hundred steps on CPU; the identical code path drives the
full configs on a pod — only the mesh axes change).

    PYTHONPATH=src python examples/train_lm.py
"""

from repro.launch.train import main

final_loss = main([
    "--arch", "qwen3-4b", "--smoke",
    "--steps", "200",
    "--seq-len", "128",
    "--batch", "8",
    "--ckpt-dir", "/tmp/repro_lm_ckpt",
    "--ckpt-every", "100",
])
assert final_loss < 6.0, "loss should fall well below the ~8.1 ln(V) init"
print("training loss fell — end-to-end driver OK")
