"""TensorPILS as a neural PDE solver (paper Table 1, reduced budget).

Trains the same SIREN backbone with the strong-form PINN loss and the
TensorPILS discrete Galerkin residual on the K=4 checkerboard Poisson
problem, then compares accuracy vs the FEM reference.

    PYTHONPATH=src python examples/poisson_pils.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    DirichletCondenser, FunctionSpace, GalerkinAssembler, cg,
    jacobi_preconditioner, unit_square_tri,
)
from repro.core.mesh import element_for_mesh
from repro.pils import (
    GalerkinResidualLoss, lbfgs_minimize, pinn_poisson_loss, siren_apply,
    siren_init, train_adam,
)

K = 4
ADAM_STEPS, LBFGS_STEPS = 400, 40

mesh = unit_square_tri(16)
space = FunctionSpace(mesh, element_for_mesh(mesh))
asm = GalerkinAssembler(space)
bc = DirichletCondenser(asm, space.boundary_dofs())
f = lambda x: jnp.sign(
    jnp.sin(K * np.pi * x[..., 0] + 1e-9) * jnp.sin(K * np.pi * x[..., 1] + 1e-9)
)

gl = GalerkinResidualLoss(asm, bc, f=f)
u_fem, _ = cg(gl.k.matvec, gl.f, m=jacobi_preconditioner(gl.k), tol=1e-12)
norm = float(jnp.linalg.norm(u_fem))

pts = jnp.asarray(space.dof_points)
free = np.asarray(bc.free_mask, bool)


def rel_err(params):
    u = np.asarray(siren_apply(params, pts)[:, 0]) * free
    return np.linalg.norm(u - np.asarray(u_fem)) / norm


key = jax.random.PRNGKey(0)
for name, loss in (
    ("TensorPILS", lambda p: gl.loss_from_net(siren_apply, p)),
    ("PINN", lambda p: pinn_poisson_loss(
        siren_apply, p, pts[free], f(pts[free][None])[0], pts[~free]
    )),
):
    params = siren_init(key, 2, 64, 1, depth=4)
    params, hist, its_adam = train_adam(loss, params, ADAM_STEPS, lr=1e-3, log_every=100)
    params, losses, its_lbfgs = lbfgs_minimize(loss, params, steps=LBFGS_STEPS)
    print(
        f"{name:12s} rel-L2 vs FEM: {rel_err(params):.4f}   "
        f"adam {its_adam:6.1f} it/s   lbfgs {its_lbfgs:6.1f} it/s"
    )
