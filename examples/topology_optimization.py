"""TensorOpt: cantilever compliance minimization (paper §B.4, Table 3).

Sensitivities come from autodiff through the differentiable assembly +
sparse solve (the adjoint custom-vjp); MMA drives the densities.

    PYTHONPATH=src python examples/topology_optimization.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.opt import CantileverProblem, MMAState, mma_update

t0 = time.perf_counter()
prob = CantileverProblem(nx=40, ny=20, lx=40.0, ly=20.0)
rho = jnp.full((prob.n_elem,), 0.5)
c0, _ = prob.compliance_and_sensitivity(rho)
print(f"setup+compile: {time.perf_counter() - t0:.2f}s, elements={prob.n_elem}")
print(f"initial compliance: {float(c0):.2f}")

state = MMAState(low=rho - 0.5, upp=rho + 0.5)
dg = jnp.full((prob.n_elem,), 1.0 / prob.n_elem)
t0 = time.perf_counter()
for it in range(25):
    c, g = prob.compliance_and_sensitivity(rho)
    g_f = prob.filter(g * rho) / jnp.maximum(rho, 1e-3)
    vol_violation = jnp.asarray(float(rho.mean()) - prob.volfrac)
    rho, state = mma_update(rho, g_f, vol_violation, dg, state)
    if it % 5 == 0:
        print(f"  iter {it:3d}  compliance {float(c):9.2f}  vol {float(rho.mean()):.3f}")
c_end, _ = prob.compliance_and_sensitivity(rho)
print(f"optimization loop: {time.perf_counter() - t0:.2f}s")
print(f"final compliance: {float(c_end):.2f}  ({float(c_end)/float(c0):.0%} of initial)")

# ASCII rendering of the design (ρ > 0.5 = material)
grid = np.asarray(rho).reshape(40, 20).T[::-1]
print("\nfinal topology (viewed y-up):")
for row in grid[::2]:
    print("".join("#" if v > 0.5 else "." for v in row))
